"""Shared benchmark plumbing: WALL-E iteration harness + CSV emission.

Measurement methodology on a 1-core container (DESIGN.md §2): each
sampler's work is executed and timed separately; the *critical path* of an
N-parallel deployment is the max over samplers (reported), the N=1 cost is
the sum. Queue/orchestration overhead is measured from the async runtime.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax

from repro import envs
from repro.algos.ppo import PPOConfig, make_mlp_learner
from repro.core import sampler as sampler_mod
from repro.core.backends import make_backend
from repro.core.fused import FusedRunner
from repro.core.orchestrator import SyncRunner
from repro.models import mlp_policy
from repro.optim import adam

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row)


def build_walle(env_name: str, num_samplers: int, total_samples: int,
                env_batch: int = 8, seed: int = 0,
                backend: str = "inline", chunk=None):
    """The paper's setup: PPO + MLP policy + N samplers splitting a fixed
    per-iteration sample budget (20000 in the paper), scheduled by the
    selected SamplerBackend — or the fused single-dispatch engine."""
    env = envs.make(env_name)
    key = jax.random.PRNGKey(seed)
    params = mlp_policy.init_policy(key, env.obs_dim, env.act_dim, 64)
    opt = adam(3e-4)
    learn = make_mlp_learner(opt, PPOConfig(epochs=4, minibatches=4))
    per_sampler = total_samples // num_samplers
    horizon = max(1, per_sampler // env_batch)
    if backend == "fused":
        carry = sampler_mod.init_env_carry(
            env, jax.random.PRNGKey(seed + 1), env_batch * num_samplers)
        return FusedRunner(env, learn, params, opt.init(params), carry,
                           horizon=horizon, chunk=chunk)
    rollout = sampler_mod.make_env_rollout(env, horizon)
    carries = [
        sampler_mod.init_env_carry(env, jax.random.PRNGKey(seed + 1 + i),
                                   env_batch)
        for i in range(num_samplers)
    ]
    bk = make_backend(backend, rollout, carries, env=env, horizon=horizon)
    return SyncRunner(None, learn, params, opt.init(params), backend=bk)


def timed(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters
