"""Shared benchmark plumbing: WALL-E iteration harness + CSV emission.

Measurement methodology on a 1-core container (DESIGN.md §2): each
sampler's work is executed and timed separately; the *critical path* of an
N-parallel deployment is the max over samplers (reported), the N=1 cost is
the sum. Queue/orchestration overhead is measured from the async runtime.

``build_walle`` resolves everything through the unified experiment API
(``repro.experiment``), so any registered algo (ppo/trpo/ddpg) can be
benchmarked on any backend — ``fig_parallel.py --algo trpo`` etc.
"""
from __future__ import annotations

import time
from typing import Callable, List

import jax

from repro import experiment
from repro.experiment import ExperimentSpec, Schedule

ROWS: List[str] = []
# structured mirror of ROWS — what benchmarks/run.py serializes into
# BENCH_<rev>.json so the perf trajectory is recorded across PRs
RECORDS: List[dict] = []


def _parse_metrics(derived: str) -> dict:
    """Pull ``key=value`` numeric pairs out of a derived string
    (``adds_per_sec=123`` -> {"adds_per_sec": 123.0}); non-numeric or
    free-form text is kept only in the raw ``derived`` field."""
    metrics = {}
    for part in derived.split():
        if "=" not in part:
            continue
        k, _, v = part.partition("=")
        try:
            metrics[k] = float(v.rstrip(","))
        except ValueError:
            pass
    return metrics


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    RECORDS.append({"name": name, "us_per_call": round(us_per_call, 1),
                    "derived": derived,
                    "metrics": _parse_metrics(derived)})
    print(row)


def build_walle(env_name: str, num_samplers: int, total_samples: int,
                env_batch: int = 8, seed: int = 0,
                backend: str = "inline", chunk=None, algo: str = "ppo"):
    """The paper's setup: an MLP-policy learner + N samplers splitting a
    fixed per-iteration sample budget (20000 in the paper), scheduled by
    the selected SamplerBackend — or the fused single-dispatch engine."""
    per_sampler = total_samples // num_samplers
    horizon = max(1, per_sampler // env_batch)
    runtime = "fused" if backend == "fused" else "sync"
    spec = ExperimentSpec(
        env=env_name, algo=algo,
        backend="inline" if backend == "fused" else backend,
        runtime=runtime,
        model={"hidden": 64},
        schedule=Schedule(num_samplers=num_samplers,
                          global_batch=env_batch * num_samplers,
                          horizon=horizon, seed=seed, chunk=chunk),
    )
    return experiment.build(spec)


def timed(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters
