"""The env-plane row: device-resident env stepping throughput.

Two measurements per revision (DESIGN.md §7):

* ``env_step_{ref,pallas}_<env>_B<B>`` — the fused step+auto-reset
  kernel against its batched reference at B ∈ {1k, 10k, 100k}
  (``steps_per_sec``). Off-accelerator the pallas rows run the
  *interpreter* — they time the correctness harness, not the kernel;
  the compiled rows on TPU/GPU are the real measurement. The ref rows
  double as the XLA fusion baseline the kernels have to beat there.
* ``env_step_vector_B<B>`` vs ``env_step_inline_N1`` — collection
  throughput (``samples_per_sec``) of one jitted rollout over a
  VectorEnv batch of B instances against the legacy inline N=1
  sampler at its paper configuration (global_batch=4). This is the
  claim the env plane rests on: one batched state pytree stepped in
  place beats host-orchestrated small-batch collection by orders of
  magnitude once B reaches ~10k.
"""
from __future__ import annotations

from functools import partial
from typing import Sequence, Tuple

import jax

from benchmarks.common import emit, timed

BS: Tuple[int, ...] = (1_000, 10_000, 100_000)
ENV_PARAMS = {
    "pendulum": dict(max_torque=2.0),
    "cartpole": dict(force_max=10.0),
    "cheetah": dict(ctrl_cost=0.1),
}


def _kernel_inputs(name: str, B: int):
    from repro import envs
    env = envs.make(name, max_episode_steps=3)
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    states, _ = jax.vmap(env.reset)(jax.random.split(ks[0], B))
    actions = jax.random.uniform(ks[1], (B, env.act_dim),
                                 minval=-1.0, maxval=1.0)
    rs, ro = jax.vmap(env.reset)(jax.random.split(ks[2], B))
    params = dict(max_episode_steps=3, reward_scale=1.0, **ENV_PARAMS[name])
    return states, actions, rs, ro, params


def bench_kernels(bs: Sequence[int] = BS,
                  env_names: Sequence[str] = tuple(ENV_PARAMS)) -> None:
    from repro.kernels.env_step import ops as env_ops
    for name in env_names:
        for B in bs:
            states, actions, rs, ro, params = _kernel_inputs(name, B)
            for impl in ("ref", "pallas"):
                step = jax.jit(partial(env_ops.env_step, name, impl=impl,
                                       **params))
                dt = timed(step, states, actions, rs, ro)
                emit(f"env_step_{impl}_{name}_B{B}", dt * 1e6,
                     f"steps_per_sec={B / dt:.0f} B={B}")


def _rollout_throughput(env, batch: int, horizon: int, seed: int = 5) -> float:
    """samples/sec of one jitted ``make_env_rollout`` dispatch."""
    from repro.core import sampler as sampler_mod
    from repro.models import mlp_policy
    params = mlp_policy.init_policy(jax.random.PRNGKey(seed), env.obs_dim,
                                    env.act_dim, hidden=64)
    carry = sampler_mod.init_env_carry(env, jax.random.PRNGKey(seed + 1),
                                       batch)
    rollout = jax.jit(sampler_mod.make_env_rollout(env, horizon))
    dt = timed(rollout, params, carry)
    return batch * horizon / dt


def bench_vector_rollout(bs: Sequence[int] = BS, horizon: int = 4,
                         env_name: str = "pendulum") -> None:
    from repro import envs
    from repro.envs.vector import VectorEnv
    env = envs.make(env_name)
    # the legacy serial baseline: N=1 inline sampler, global_batch=4
    # (the actor-plane configuration the paper measures against)
    base = _rollout_throughput(env, 4, 512)
    emit("env_step_inline_N1", 4 * 512 / base * 1e6,
         f"samples_per_sec={base:.0f} batch=4")
    for B in bs:
        sps = _rollout_throughput(VectorEnv(env, B), B, horizon)
        emit(f"env_step_vector_B{B}", B * horizon / sps * 1e6,
             f"samples_per_sec={sps:.0f} B={B} speedup_vs_inline="
             f"{sps / base:.1f}")


def run_all(bs: Sequence[int] = BS) -> None:
    bench_kernels(bs)
    bench_vector_rollout(bs)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--bs", default=",".join(map(str, BS)))
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run_all(tuple(int(b) for b in args.bs.split(",")))
