"""The robustness row: throughput under injected faults + recovery latency.

Quantifies what the supervised actor fleet (DESIGN.md §10) costs and
buys. Two measurements land in ``BENCH_<rev>.json`` via
``benchmarks/run.py --sections fault``:

* ``fault_ppo_kill<rate>`` — lock-step process-backend PPO with workers
  SIGKILLed on a seeded schedule at per-step probability
  rate ∈ ``KILL_RATES`` (0 = the supervised-but-quiet baseline). The
  metric is end-to-end ``samples_per_sec`` — supervision overhead at
  rate 0, degradation-under-churn at the others — plus the observed
  ``respawns``.
* ``fault_recovery`` — median supervisor recovery latency
  (``recovery_ms``: detect a SIGKILLed worker, reclaim its ring slots,
  respawn, worker ready) over the respawns the killed runs performed.

Both are driven through the public spec (``faults="kill:<rate>"``), so
the numbers measure the shipped path: heartbeat sweep + result-timeout
detection, slot reclamation, spec-respawn with backoff.

``recovery_ms`` is judged lower-is-better by ``run.py --compare`` (the
``_ms`` suffix rule); ``samples_per_sec`` rows gate like every other
throughput row.
"""
from __future__ import annotations

import statistics
from typing import Dict, Sequence

from benchmarks.common import emit

KILL_RATES: Sequence[float] = (0.0, 0.1, 0.3)


def _chaos_run(rate: float, iterations: int, seed: int = 3):
    """One supervised lock-step process run; returns (logs, supervisor)."""
    from repro import experiment
    from repro.experiment import ExperimentSpec, Schedule

    spec = ExperimentSpec(
        env="pendulum", algo="ppo", backend="process", runtime="sync",
        model={"hidden": 64},
        faults=f"kill:{rate}" if rate else None,
        schedule=Schedule(num_samplers=2, global_batch=8, horizon=32,
                          iterations=iterations, seed=seed,
                          max_respawns=max(8, iterations * 2)))
    runner = experiment.build(spec)
    try:
        logs = runner.run(iterations)
    finally:
        runner.close()
    return logs, runner.backend.supervisor


def sweep_kill(rates: Sequence[float] = KILL_RATES, iterations: int = 6,
               warmup: int = 1) -> Dict[float, float]:
    """samples/sec at each kill rate, plus pooled recovery latency."""
    out: Dict[float, float] = {}
    recoveries = []
    for rate in rates:
        logs, sup = _chaos_run(rate, iterations)
        steady = logs[warmup:]
        secs = sum(log.collect_time for log in steady)
        samples = sum(log.samples for log in steady)
        sps = samples / secs if secs else 0.0
        respawns = sup.respawns if sup is not None else 0
        if sup is not None:
            recoveries.extend(sup.recovery_s)
        out[rate] = sps
        emit(f"fault_ppo_kill{rate:g}", secs / max(samples, 1) * 1e6,
             f"samples_per_sec={sps:.0f} respawns={respawns} "
             f"kill_rate={rate:g}")
    if recoveries:
        med = statistics.median(recoveries)
        emit("fault_recovery", med * 1e6,
             f"recovery_ms={med * 1e3:.0f} n_respawns={len(recoveries)}")
    return out


def async_chaos(rate: float = 0.1, iterations: int = 6, seed: int = 3):
    """The free-run analogue: async DDPG draining the ring while workers
    are killed and respawned mid-stream — experiences/sec under churn."""
    import time

    from repro import experiment
    from repro.experiment import ExperimentSpec, Schedule

    spec = ExperimentSpec(
        env="pendulum", algo="ddpg", backend="process", runtime="async",
        model={"hidden": 64},
        faults=f"kill:{rate}" if rate else None,
        buffer_kwargs={"capacity": 4096, "batch_size": 64},
        schedule=Schedule(num_samplers=2, global_batch=8, horizon=32,
                          iterations=iterations, seed=seed,
                          max_respawns=max(8, iterations * 2)))
    runner = experiment.build(spec)
    t0 = time.perf_counter()
    try:
        logs = runner.run(iterations)
    finally:
        runner.close()
    wall = time.perf_counter() - t0
    samples = sum(log.samples for log in logs)
    sps = samples / wall if wall else 0.0
    respawns = logs[-1].respawns if logs else 0
    emit(f"fault_ddpg_async_kill{rate:g}", wall / max(samples, 1) * 1e6,
         f"samples_per_sec={sps:.0f} respawns={respawns} kill_rate={rate:g}")
    return sps


def run_all() -> Dict[float, float]:
    out = sweep_kill()
    async_chaos()
    return out


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run_all()
