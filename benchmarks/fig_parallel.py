"""Paper figures 3-7: the parallel-sampler measurements.

* Fig 3 — average return, N=10 vs N=1 (same per-iteration sample budget;
  the N=10 run additionally reports its wall-clock advantage).
* Fig 4 — rollout (collection) time vs N at a fixed total sample budget.
* Fig 5 — speedup T(1)/T(N) (derived from Fig 4).
* Fig 6 — % of iteration time in learning vs collection, vs N.
* Fig 7 — absolute policy-learning time per iteration vs N (~flat).

Scaled for a small CPU container: budget defaults to 4096 samples /
iteration instead of the paper's 20000 (same shape of the curves; the
measurement is the per-sampler critical path, see benchmarks/common.py).

Every figure runs for any registered algorithm through the unified
experiment API — ``python -m benchmarks.fig_parallel --algo {ppo,trpo,ddpg}``
produces the cross-algo grid the paper's PPO-only plots could not — and
on any sampler backend: ``--backend process`` reruns the whole sweep with
*real worker processes* over shared-memory transport (the paper's actual
N-process deployment; DESIGN.md §6), where the critical path is genuine
wall-clock concurrency rather than inline's max-over-serial-runs.
``--quick`` shrinks the sweep (N ∈ {1,2,4}, smaller budget, no Fig 3)
for CI artifact runs.
"""
from __future__ import annotations

import json
from typing import Dict, List, Sequence

from benchmarks.common import build_walle, emit

NS = (1, 2, 4, 8, 10)

# Default SamplerBackend the figure harness schedules collection with
# ("inline" reproduces the paper's single-host measurement; "threaded" /
# "process" measure real concurrency on multi-core hosts).
BACKEND = "inline"


def _sfx(backend: str) -> str:
    """Benchmark-name suffix: inline rows keep their historical names so
    the recorded trajectory stays comparable across revisions."""
    return "" if backend == BACKEND else f"_{backend}"


def _run_closed(runner, iterations: int):
    try:
        return runner.run(iterations)
    finally:
        runner.close()


def fig3_return_curves(env_name: str = "pendulum", iterations: int = 10,
                       per_sampler: int = 2048, algo: str = "ppo",
                       backend: str = BACKEND) -> Dict:
    """The paper's comparison: N=10 vs N=1 at equal *wall-clock*.

    Each sampler does the same work per iteration (same env batch, same
    horizon -> equal collection critical path); N=10 therefore learns from
    10x the experience per iteration and should reach higher return — the
    paper's Fig 3 claim. Iteration 0 (jit compile) is excluded from the
    wall-clock accounting.
    """
    out = {}
    for n in (1, 10):
        runner = build_walle(env_name, n, per_sampler * n, env_batch=8,
                             seed=42, backend=backend, algo=algo)
        logs = _run_closed(runner, iterations)
        rets = [l.mean_return for l in logs if l.mean_return != 0.0]
        out[f"N={n}"] = {
            "returns": [l.mean_return for l in logs],
            "collect_time": [l.collect_time for l in logs[1:]],
            "final_return": rets[-1] if rets else float("nan"),
        }
        emit(f"fig3_{algo}_return_N{n}_final{_sfx(backend)}",
             sum(out[f"N={n}"]["collect_time"]) * 1e6 / (iterations - 1),
             f"return={out[f'N={n}']['final_return']:.1f} "
             f"(samples/iter={per_sampler * n})")
    t1 = sum(out["N=1"]["collect_time"])
    t10 = sum(out["N=10"]["collect_time"])
    gain = out["N=10"]["final_return"] - out["N=1"]["final_return"]
    emit(f"fig3_{algo}_N10_vs_N1{_sfx(backend)}", 0.0,
         f"return_gain={gain:+.1f} at collect-time ratio "
         f"x{t10 / max(t1, 1e-9):.2f} (1.0 = equal wall-clock)")
    return out


def fig4_rollout_time(env_name: str = "cheetah", budget: int = 4096,
                      iterations: int = 3, algo: str = "ppo",
                      backend: str = BACKEND, ns: Sequence[int] = NS
                      ) -> Dict[int, float]:
    times = {}
    for n in ns:
        runner = build_walle(env_name, n, budget, env_batch=8, seed=7,
                             backend=backend, algo=algo)
        logs = _run_closed(runner, iterations)
        # skip iteration 0 (jit compile)
        ts = [l.collect_time for l in logs[1:]]
        times[n] = sum(ts) / len(ts)
        emit(f"fig4_{algo}_rollout_time_N{n}{_sfx(backend)}",
             times[n] * 1e6, f"samples={budget}")
    return times


def fig5_speedup(times: Dict[int, float], algo: str = "ppo",
                 backend: str = BACKEND) -> Dict[int, float]:
    t1 = times[1]
    speedups = {n: t1 / t for n, t in times.items()}
    for n, s in speedups.items():
        linear = "near-linear" if s > 0.6 * n else "sub-linear"
        emit(f"fig5_{algo}_speedup_N{n}{_sfx(backend)}", times[n] * 1e6,
             f"x{s:.2f} ({linear})")
    return speedups


def fig6_fig7_time_split(env_name: str = "cheetah", budget: int = 4096,
                         iterations: int = 3, algo: str = "ppo",
                         backend: str = BACKEND,
                         ns: Sequence[int] = NS) -> Dict:
    out = {}
    for n in ns:
        runner = build_walle(env_name, n, budget, env_batch=8, seed=13,
                             backend=backend, algo=algo)
        logs = _run_closed(runner, iterations)
        collect = sum(l.collect_time for l in logs[1:])
        learn = sum(l.learn_time for l in logs[1:])
        frac_learn = learn / (learn + collect)
        mean_learn = learn / (len(logs) - 1)
        out[n] = {"frac_learn": frac_learn, "learn_time": mean_learn}
        emit(f"fig6_{algo}_learn_fraction_N{n}{_sfx(backend)}", 0.0,
             f"{100 * frac_learn:.1f}%")
        emit(f"fig7_{algo}_learn_time_N{n}{_sfx(backend)}",
             mean_learn * 1e6, "per-iteration")
    return out


def run_all(out_path: str = "results/paper_figs.json",
            algo: str = "ppo", backend: str = BACKEND,
            quick: bool = False) -> None:
    import os
    if os.path.dirname(out_path):
        os.makedirs(os.path.dirname(out_path), exist_ok=True)
    ns: Sequence[int] = (1, 2, 4) if quick else NS
    budget = 1024 if quick else 4096
    iterations = 2 if quick else 3
    results: Dict = {"algo": algo, "backend": backend, "quick": quick}
    if not quick:        # fig3 is the expensive return-quality comparison
        results["fig3"] = fig3_return_curves(algo=algo, backend=backend)
    times = fig4_rollout_time(algo=algo, backend=backend, ns=ns,
                              budget=budget, iterations=iterations)
    results["fig4"] = times
    results["fig5"] = fig5_speedup(times, algo=algo, backend=backend)
    results["fig6_fig7"] = fig6_fig7_time_split(
        algo=algo, backend=backend, ns=ns, budget=budget,
        iterations=iterations)
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2, default=float)


if __name__ == "__main__":
    import argparse

    from repro import registry
    ap = argparse.ArgumentParser()
    ap.add_argument("--algo", default="ppo",
                    choices=registry.choices("algo"),
                    help="which registered algorithm to measure")
    ap.add_argument("--backend", default=BACKEND,
                    choices=("inline", "threaded", "process"),
                    help="sampler backend the sweep schedules collection "
                         "with ('process' = real worker processes)")
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized sweep: N in {1,2,4}, smaller budget, "
                         "no Fig 3")
    ap.add_argument("--out", default=None,
                    help="results JSON path (default: "
                         "results/paper_figs_<algo>[_<backend>].json)")
    args = ap.parse_args()
    out = args.out or (f"results/paper_figs_{args.algo}"
                       f"{_sfx(args.backend)}.json")
    print("name,us_per_call,derived")
    run_all(out_path=out, algo=args.algo, backend=args.backend,
            quick=args.quick)
