"""Dispatch-overhead microbench: fused engine vs stepped runner.

At small batch the device work per iteration is tiny, so the stepped
runner's per-iteration cost is dominated by host overhead: one dispatch +
block per sampler rollout, a host-side merge, one dispatch + block for the
learner update. The fused engine pays one dispatch per *chunk* of
iterations, so its per-iteration host overhead is that cost divided by the
chunk length (DESIGN.md §2).

Rows:
  fused_vs_stepped_inline_us      per-iteration wall time, stepped inline
  fused_vs_stepped_fused_us       per-iteration wall time, fused chunk
  fused_vs_stepped_overhead       host-overhead ratio (>= 2x is the
                                  acceptance bar; typically far higher)

  PYTHONPATH=src python benchmarks/fused_vs_stepped.py
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import build_walle, emit

ENV = "pendulum"
BATCH = 4          # small batch: dispatch dominates device work
HORIZON = 32
ITERS = 32


def _timed_run(runner, iterations: int) -> float:
    """Wall time per iteration, excluding the compile-bearing first run.

    The warmup run uses the same iteration count so the fused runner's
    chunk-length-``iterations`` scan is compiled before the timed run.
    """
    runner.run(iterations)                     # warmup / compile
    t0 = time.perf_counter()
    runner.run(iterations)
    return (time.perf_counter() - t0) / iterations


def run_all() -> dict:
    total = BATCH * HORIZON
    stepped = build_walle(ENV, 1, total, env_batch=BATCH, seed=0,
                          backend="inline")
    t_stepped = _timed_run(stepped, ITERS)

    fused = build_walle(ENV, 1, total, env_batch=BATCH, seed=0,
                        backend="fused", chunk=ITERS)
    t_fused = _timed_run(fused, ITERS)

    # The fused chunk is ~pure device time (one dispatch amortized over
    # ITERS iterations), so it bounds the per-iteration device compute;
    # everything the stepped path pays on top of it is host overhead.
    overhead_stepped = max(t_stepped - t_fused, 1e-12)
    overhead_fused = max(t_fused / ITERS, 1e-12)   # one dispatch / chunk
    ratio = t_stepped / t_fused

    emit("fused_vs_stepped_inline_us", t_stepped * 1e6,
         f"batch={BATCH} horizon={HORIZON}")
    emit("fused_vs_stepped_fused_us", t_fused * 1e6,
         f"chunk={ITERS} (1 dispatch)")
    emit("fused_vs_stepped_overhead", overhead_stepped * 1e6,
         f"x{ratio:.1f} lower per-iteration time fused vs stepped "
         f"(>=2x bar: {'PASS' if ratio >= 2.0 else 'FAIL'})")
    return {"stepped_s": t_stepped, "fused_s": t_fused, "ratio": ratio,
            "overhead_stepped_s": overhead_stepped,
            "overhead_fused_s": overhead_fused}


if __name__ == "__main__":
    print("name,us_per_call,derived")
    out = run_all()
    assert out["ratio"] >= 2.0, (
        f"fused engine only x{out['ratio']:.2f} faster per iteration")
