"""Microbenchmarks of the compute hot-spots.

Two sections:
* LM sampler hot-spots (attention / selective-scan / decode) — CPU
  reference implementations; the Pallas kernels are TPU-target and
  interpret mode is a correctness harness, not a timing one.
* RL hot-loop kernel plane (gae / sum_tree / replay_ring) — every family
  timed ref *and* pallas so the kernel plane's speedup is measured, not
  asserted. Off-TPU the pallas rows time the interpreter (expect them to
  lose badly on CPU — the comparison is only meaningful on TPU); the
  ref rows are the production CPU numbers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.kernels import gae as gae_k
from repro.kernels import replay_ring as ring_k
from repro.kernels import sum_tree as tree_k
from repro.models import attention as A
from repro.models.ssm import selective_scan as model_scan


def attention_bench():
    key = jax.random.PRNGKey(0)
    for S in (512, 1024, 2048):
        B, K, G, hd = 1, 4, 2, 64
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, S, K, G, hd), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, K, hd), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, K, hd), jnp.float32)
        f = jax.jit(lambda q, k, v: A.full_causal(q, k, v, leaf=512,
                                                  kv_block=512))
        dt = timed(f, q, k, v)
        flops = 2 * 2 * B * K * G * S * S / 2 * hd
        emit(f"attn_causal_S{S}", dt * 1e6,
             f"{flops / dt / 1e9:.1f}GFLOP/s")
        fw = jax.jit(lambda q, k, v: A.swa(q, k, v, 256, q_block=256))
        dtw = timed(fw, q, k, v)
        emit(f"attn_swa256_S{S}", dtw * 1e6, "")


def scan_bench():
    key = jax.random.PRNGKey(1)
    for S, Di in ((512, 256), (1024, 512)):
        B, N = 1, 16
        ks = jax.random.split(key, 5)
        dt_in = jax.nn.softplus(jax.random.normal(ks[0], (B, S, Di))) * 0.1
        Am = -jnp.exp(jax.random.normal(ks[1], (Di, N)) * 0.2)
        b = jax.random.normal(ks[2], (B, S, N))
        c = jax.random.normal(ks[3], (B, S, N))
        x = jax.random.normal(ks[4], (B, S, Di))
        h0 = jnp.zeros((B, Di, N))
        f = jax.jit(lambda *a: model_scan(*a, chunk=128))
        t = timed(f, dt_in, Am, b, c, x, h0)
        emit(f"selective_scan_S{S}_D{Di}", t * 1e6,
             f"{B * S * Di * N / t / 1e6:.0f}Melem/s")


def decode_bench():
    from repro.configs import get_config
    from repro.models import transformer as T
    key = jax.random.PRNGKey(2)
    for arch in ("hymba-1.5b", "mixtral-8x7b"):
        cfg = get_config(arch).reduced()
        params = T.init_params(cfg, key)
        state = T.init_decode_state(cfg, 4, 128)
        tok = jnp.zeros((4, 1), jnp.int32)
        f = jax.jit(lambda p, s, t: T.decode_step(cfg, p, s, t))
        t = timed(f, params, state, tok)
        emit(f"decode_step_{arch}-reduced", t * 1e6, "B=4")


# ------------------------------------------------ RL hot-loop kernel plane
IMPLS = ("ref", "pallas")


def gae_rl_bench():
    key = jax.random.PRNGKey(3)
    T, B = 128, 32
    ks = jax.random.split(key, 4)
    r = jax.random.normal(ks[0], (T, B))
    v = jax.random.normal(ks[1], (T, B))
    d = jax.random.bernoulli(ks[2], 0.05, (T, B))
    lv = jax.random.normal(ks[3], (B,))
    for impl in IMPLS:
        f = jax.jit(lambda r, v, d, lv, impl=impl:
                    gae_k.gae(r, v, d, lv, impl=impl))
        dt = timed(f, r, v, d, lv)
        emit(f"gae_{impl}_T{T}_B{B}", dt * 1e6,
             f"steps_per_sec={T * B / dt:.0f}")
        fr = jax.jit(lambda r, d, lv, impl=impl:
                     gae_k.discounted_returns(r, d, lv, impl=impl))
        dtr = timed(fr, r, d, lv)
        emit(f"gae_returns_{impl}_T{T}_B{B}", dtr * 1e6,
             f"steps_per_sec={T * B / dtr:.0f}")


def sum_tree_bench():
    cap, B = 4096, 256
    leaves = jnp.abs(jax.random.normal(jax.random.PRNGKey(4), (cap,)))
    tree = tree_k.sumtree_build(leaves)
    masses = (jnp.arange(B, dtype=jnp.float32) + 0.5) / B * tree.total
    idx = jax.random.randint(jax.random.PRNGKey(5), (B,), 0, cap)
    vals = jnp.abs(jax.random.normal(jax.random.PRNGKey(6), (B,)))
    for impl in IMPLS:
        f = jax.jit(lambda t, m, impl=impl:
                    tree_k.sumtree_find_batch(t, m, impl=impl))
        dt = timed(f, tree, masses)
        emit(f"sum_tree_find_{impl}_cap{cap}_B{B}", dt * 1e6,
             f"samples_per_sec={B / dt:.0f}")
        fu = jax.jit(lambda t, i, v, impl=impl:
                     tree_k.sumtree_update(t, i, v, impl=impl))
        dtu = timed(fu, tree, idx, vals)
        emit(f"sum_tree_update_{impl}_cap{cap}_B{B}", dtu * 1e6,
             f"writes_per_sec={B / dtu:.0f}")


def replay_ring_bench():
    cap, n, B, D = 4096, 256, 256, 16
    storage = {"obs": jnp.zeros((cap, D)), "rewards": jnp.zeros((cap,))}
    batch = {"obs": jnp.ones((n, D)), "rewards": jnp.ones((n,))}
    idx = jax.random.randint(jax.random.PRNGKey(7), (B,), 0, cap)
    for impl in IMPLS:
        f = jax.jit(lambda s, b, i, impl=impl:
                    ring_k.ring_insert(s, b, i, impl=impl))
        dt = timed(f, storage, batch, jnp.int32(100))
        emit(f"replay_ring_insert_{impl}_cap{cap}_n{n}", dt * 1e6,
             f"adds_per_sec={n / dt:.0f}")
        g = jax.jit(lambda s, i, impl=impl:
                    ring_k.ring_gather(s, i, impl=impl))
        dtg = timed(g, storage, idx)
        emit(f"replay_ring_gather_{impl}_cap{cap}_B{B}", dtg * 1e6,
             f"samples_per_sec={B / dtg:.0f}")


def run_lm():
    attention_bench()
    scan_bench()
    decode_bench()


def run_rl():
    gae_rl_bench()
    sum_tree_bench()
    replay_ring_bench()


def run_all():
    run_lm()
    run_rl()
