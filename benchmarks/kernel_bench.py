"""Microbenchmarks of the sampler's compute hot-spots (CPU reference
implementations — the Pallas kernels are TPU-target and interpret mode is a
correctness harness, not a timing one)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.models import attention as A
from repro.models.ssm import selective_scan as model_scan


def attention_bench():
    key = jax.random.PRNGKey(0)
    for S in (512, 1024, 2048):
        B, K, G, hd = 1, 4, 2, 64
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, S, K, G, hd), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, K, hd), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, K, hd), jnp.float32)
        f = jax.jit(lambda q, k, v: A.full_causal(q, k, v, leaf=512,
                                                  kv_block=512))
        dt = timed(f, q, k, v)
        flops = 2 * 2 * B * K * G * S * S / 2 * hd
        emit(f"attn_causal_S{S}", dt * 1e6,
             f"{flops / dt / 1e9:.1f}GFLOP/s")
        fw = jax.jit(lambda q, k, v: A.swa(q, k, v, 256, q_block=256))
        dtw = timed(fw, q, k, v)
        emit(f"attn_swa256_S{S}", dtw * 1e6, "")


def scan_bench():
    key = jax.random.PRNGKey(1)
    for S, Di in ((512, 256), (1024, 512)):
        B, N = 1, 16
        ks = jax.random.split(key, 5)
        dt_in = jax.nn.softplus(jax.random.normal(ks[0], (B, S, Di))) * 0.1
        Am = -jnp.exp(jax.random.normal(ks[1], (Di, N)) * 0.2)
        b = jax.random.normal(ks[2], (B, S, N))
        c = jax.random.normal(ks[3], (B, S, N))
        x = jax.random.normal(ks[4], (B, S, Di))
        h0 = jnp.zeros((B, Di, N))
        f = jax.jit(lambda *a: model_scan(*a, chunk=128))
        t = timed(f, dt_in, Am, b, c, x, h0)
        emit(f"selective_scan_S{S}_D{Di}", t * 1e6,
             f"{B * S * Di * N / t / 1e6:.0f}Melem/s")


def decode_bench():
    from repro.configs import get_config
    from repro.models import transformer as T
    key = jax.random.PRNGKey(2)
    for arch in ("hymba-1.5b", "mixtral-8x7b"):
        cfg = get_config(arch).reduced()
        params = T.init_params(cfg, key)
        state = T.init_decode_state(cfg, 4, 128)
        tok = jnp.zeros((4, 1), jnp.int32)
        f = jax.jit(lambda p, s, t: T.decode_step(cfg, p, s, t))
        t = timed(f, params, state, tok)
        emit(f"decode_step_{arch}-reduced", t * 1e6, "B=4")


def run_all():
    attention_bench()
    scan_bench()
    decode_bench()
