"""Learner-plane scaling rows: train-step time + samples/sec vs D devices.

The multi-device learner (``distributed/learner.py``, DESIGN.md §9) shards
the learner batch over D mesh devices and all-reduces gradients with one
psum. This section records that trajectory: for each D in ``DS`` the same
ppo experiment is trained with ``Schedule.learner_devices=D`` and the
steady-state train-step time (min over post-compile iterations) lands in
``BENCH_<rev>.json`` as ``learner_ppo_D{d}`` with ``samples_per_sec`` and
``train_step_ms`` metrics.

Two further row families cover the pipelined FSDP learner (DESIGN.md §11):

* ``learner_ppo_fsdp_D{d}`` — params + Adam moments sharded per the
  ``_param_spec`` layout (``Schedule.fsdp``); the extra
  ``state_bytes_per_device`` metric is the peak live params+opt-state
  footprint of one device (sharded leaves count their shard only), so
  the ZeRO-3 memory win is recorded alongside the gather/reduce-scatter
  time cost.
* ``learner_ppo_overlap_{on,off}`` — the same D=4 FSDP experiment with
  and without the double-buffered collect/learn pipeline
  (``Schedule.overlap``); ``iter_ms`` is the measured steady-state
  wall-clock per iteration (the A/B ground truth) and the on-row's
  ``overlap_saved_s`` is the runner-accounted learn time hidden under
  collection per iteration.

Each config runs in its own subprocess because device fan-out must be
fixed *before* jax initialises: the child sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` at the top, ahead
of the jax import. On a real multi-core/multi-accelerator host the forced
host devices map to genuinely parallel compute and the rows measure
speedup; on a 1-core container they time-slice one core, so the rows
instead measure the sharding + collective *overhead* floor — either way
the trajectory is recorded per revision and ``run.py --compare`` can
flag regressions.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Dict, Sequence, Tuple

from benchmarks.common import emit

DS: Tuple[int, ...] = (1, 2, 4, 8)
FSDP_DS: Tuple[int, ...] = (2, 4, 8)
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# child: force 8 host devices before jax import, train ppo with the
# sharded learner, report steady-state timings on one JSON line
_CHILD = r"""
import json, math, os, sys, time
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8"
                           ).strip()
import jax
from repro import experiment
from repro.experiment import ExperimentSpec, Schedule

d, iters, budget, env_batch, fsdp, overlap = map(int, sys.argv[1:7])
spec = ExperimentSpec(
    env="pendulum", algo="ppo", backend="inline", runtime="sync",
    model={"hidden": 64},
    schedule=Schedule(num_samplers=1, global_batch=env_batch,
                      horizon=budget // env_batch, seed=3,
                      learner_devices=(d if d > 1 else None),
                      fsdp=bool(fsdp), overlap=bool(overlap)))
runner = experiment.build(spec)


def bytes_per_device(tree):
    total = 0
    for leaf in jax.tree.leaves(tree):
        sh = getattr(leaf, "sharding", None)
        shape = (sh.shard_shape(leaf.shape) if sh is not None
                 else leaf.shape)
        total += math.prod(shape) * leaf.dtype.itemsize
    return total


try:
    runner.run(2)                    # jit compile (+ overlap learn_ref)
    t0 = time.perf_counter()
    logs = runner.run(iters)[-iters:]    # run() returns cumulative logs
    wall = time.perf_counter() - t0
finally:
    runner.close()
# under overlap the first 2 iterations of each run() call are the serial
# warmup; measure the pipelined (or, serial mode, post-compile) tail
steady = logs[2:] if overlap else logs[1:]
state_bytes = (bytes_per_device(runner.params)
               + bytes_per_device(runner.opt_state))
print("LEARNER_RESULT " + json.dumps(
    {"d": d, "learn_s": min(l.learn_time for l in steady),
     "samples": logs[0].samples,
     "iter_s": wall / iters,
     "saved_s": (sum(l.overlap_saved_s for l in steady) / len(steady)),
     "state_bytes": state_bytes}))
"""


def _child(d: int, iterations: int, budget: int, env_batch: int,
           fsdp: bool = False, overlap: bool = False) -> dict:
    env = dict(os.environ,
               PYTHONPATH=os.pathsep.join(
                   p for p in (os.path.join(REPO, "src"),
                               os.environ.get("PYTHONPATH", "")) if p))
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD, str(d), str(iterations),
         str(budget), str(env_batch), str(int(fsdp)), str(int(overlap))],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=900)
    if proc.returncode:
        raise RuntimeError(
            f"learner scaling child D={d} fsdp={fsdp} overlap={overlap} "
            f"failed:\n{proc.stderr[-2000:]}")
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("LEARNER_RESULT ")][-1]
    return json.loads(line.split(" ", 1)[1])


def sweep(ds: Sequence[int] = DS, iterations: int = 4, budget: int = 2048,
          env_batch: int = 16) -> Dict[int, float]:
    """samples/sec through the learner plane for each device count D."""
    out = {}
    for d in ds:
        rec = _child(d, iterations, budget, env_batch)
        sps = rec["samples"] / rec["learn_s"]
        emit(f"learner_ppo_D{d}", rec["learn_s"] * 1e6,
             f"samples_per_sec={sps:.0f} "
             f"train_step_ms={rec['learn_s'] * 1e3:.2f} "
             f"d={d} budget={budget}")
        out[d] = sps
    return out


def sweep_fsdp(ds: Sequence[int] = FSDP_DS, iterations: int = 4,
               budget: int = 2048, env_batch: int = 16) -> Dict[int, float]:
    """The FSDP layout's time + per-device memory trajectory vs D."""
    out = {}
    for d in ds:
        rec = _child(d, iterations, budget, env_batch, fsdp=True)
        sps = rec["samples"] / rec["learn_s"]
        emit(f"learner_ppo_fsdp_D{d}", rec["learn_s"] * 1e6,
             f"samples_per_sec={sps:.0f} "
             f"train_step_ms={rec['learn_s'] * 1e3:.2f} "
             f"state_bytes_per_device={rec['state_bytes']} "
             f"d={d} budget={budget}")
        out[d] = sps
    return out


def sweep_overlap(d: int = 4, iterations: int = 8, budget: int = 2048,
                  env_batch: int = 16) -> Dict[str, float]:
    """A/B the double-buffered pipeline against the serial schedule at
    fixed D (both FSDP, so the only variable is the overlap)."""
    out = {}
    for name, overlap in (("off", False), ("on", True)):
        rec = _child(d, iterations, budget, env_batch, fsdp=True,
                     overlap=overlap)
        sps = rec["samples"] / rec["iter_s"]
        derived = (f"iter_ms={rec['iter_s'] * 1e3:.2f} "
                   f"samples_per_sec={sps:.0f} d={d} budget={budget}")
        if overlap:
            derived += f" overlap_saved_s={rec['saved_s']:.6f}"
        emit(f"learner_ppo_overlap_{name}", rec["iter_s"] * 1e6, derived)
        out[name] = rec["iter_s"]
    return out


def run_all(ds: Sequence[int] = DS) -> Dict[int, float]:
    out = sweep(ds=ds)
    sweep_fsdp()
    sweep_overlap()
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--ds", default=",".join(map(str, DS)))
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run_all(ds=tuple(int(d) for d in args.ds.split(",")))
