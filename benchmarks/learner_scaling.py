"""Learner-plane scaling row: train-step time + samples/sec vs D devices.

The multi-device learner (``distributed/learner.py``, DESIGN.md §9) shards
the learner batch over D mesh devices and all-reduces gradients with one
psum. This section records that trajectory: for each D in ``DS`` the same
ppo experiment is trained with ``Schedule.learner_devices=D`` and the
steady-state train-step time (min over post-compile iterations) lands in
``BENCH_<rev>.json`` as ``learner_ppo_D{d}`` with ``samples_per_sec`` and
``train_step_ms`` metrics.

Each D runs in its own subprocess because device fan-out must be fixed
*before* jax initialises: the child sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` at the top, ahead
of the jax import. On a real multi-core/multi-accelerator host the forced
host devices map to genuinely parallel compute and the row measures
speedup; on a 1-core container they time-slice one core, so the row
instead measures the sharding + collective *overhead* floor — either way
the D-trajectory is recorded per revision and ``run.py --compare`` can
flag regressions.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Dict, Sequence, Tuple

from benchmarks.common import emit

DS: Tuple[int, ...] = (1, 2, 4, 8)
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# child: force 8 host devices before jax import, train ppo with the
# sharded learner, report steady-state train-step time on one JSON line
_CHILD = r"""
import json, os, sys
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8"
                           ).strip()
from repro import experiment
from repro.experiment import ExperimentSpec, Schedule

d, iters, budget, env_batch = map(int, sys.argv[1:5])
spec = ExperimentSpec(
    env="pendulum", algo="ppo", backend="inline", runtime="sync",
    model={"hidden": 64},
    schedule=Schedule(num_samplers=1, global_batch=env_batch,
                      horizon=budget // env_batch, seed=3,
                      learner_devices=(d if d > 1 else None)))
runner = experiment.build(spec)
try:
    logs = runner.run(iters)
finally:
    runner.close()
steady = logs[1:]  # iteration 0 is jit compile
print("LEARNER_RESULT " + json.dumps(
    {"d": d, "learn_s": min(l.learn_time for l in steady),
     "samples": steady[0].samples}))
"""


def sweep(ds: Sequence[int] = DS, iterations: int = 4, budget: int = 2048,
          env_batch: int = 16) -> Dict[int, float]:
    """samples/sec through the learner plane for each device count D."""
    env = dict(os.environ,
               PYTHONPATH=os.pathsep.join(
                   p for p in (os.path.join(REPO, "src"),
                               os.environ.get("PYTHONPATH", "")) if p))
    out = {}
    for d in ds:
        proc = subprocess.run(
            [sys.executable, "-c", _CHILD, str(d), str(iterations),
             str(budget), str(env_batch)],
            capture_output=True, text=True, env=env, cwd=REPO, timeout=900)
        if proc.returncode:
            raise RuntimeError(
                f"learner scaling child D={d} failed:\n"
                f"{proc.stderr[-2000:]}")
        line = [ln for ln in proc.stdout.splitlines()
                if ln.startswith("LEARNER_RESULT ")][-1]
        rec = json.loads(line.split(" ", 1)[1])
        sps = rec["samples"] / rec["learn_s"]
        emit(f"learner_ppo_D{d}", rec["learn_s"] * 1e6,
             f"samples_per_sec={sps:.0f} "
             f"train_step_ms={rec['learn_s'] * 1e3:.2f} "
             f"d={d} budget={budget}")
        out[d] = sps
    return out


def run_all(ds: Sequence[int] = DS) -> Dict[int, float]:
    return sweep(ds=ds)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--ds", default=",".join(map(str, DS)))
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run_all(ds=tuple(int(d) for d in args.ds.split(",")))
