"""Experience-plane throughput microbenchmark.

Per buffer kind (fifo / uniform / prioritized): adds/sec (transitions
absorbed from a collected trajectory batch, including the n-step
transform and — for prioritized — the sum-tree path updates) and samples/sec
(transitions drawn per learner minibatch, including importance weights
for prioritized). All ops run jitted on device, state-in/state-out, i.e.
exactly what the composed train step pays per iteration.

  PYTHONPATH=src python -m benchmarks.replay_bench
  (or as the ``replay_*`` section of ``python -m benchmarks.run``)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro import registry
from repro.kernels import select as kernel_select

T, B = 64, 16                     # one collected trajectory batch
CAPACITY = 16_384
BATCH_SIZE = 256
OBS_DIM, ACT_DIM = 8, 2


def _traj():
    t = jnp.linspace(0.0, 1.0, T * B * OBS_DIM).reshape(T, B, OBS_DIM)
    return {
        "obs": t,
        "actions": jnp.zeros((T, B, ACT_DIM)),
        "rewards": jnp.ones((T, B)),
        "dones": jnp.zeros((T, B), bool),
        "next_obs": t + 1.0,
    }


def _example():
    return {
        "obs": jnp.zeros((1, OBS_DIM)),
        "actions": jnp.zeros((1, ACT_DIM)),
        "rewards": jnp.zeros((1,)),
        "next_obs": jnp.zeros((1, OBS_DIM)),
        "dones": jnp.zeros((1,), bool),
    }


def bench_buffer(kind: str, n_step: int = 1, kernels: str = "auto",
                 iters: int = 20) -> None:
    kwargs = ({} if kind == "fifo"
              else {"capacity": CAPACITY, "batch_size": BATCH_SIZE,
                    "n_step": n_step})
    prev = kernel_select.set_kernel_mode(kernels)
    try:
        buf = registry.make("buffer", kind, **kwargs)
        traj = _traj()
        example = traj if kind == "fifo" else _example()
        state = buf.init(example)
        add = jax.jit(buf.add)
        sample = jax.jit(buf.sample)
        key = jax.random.PRNGKey(0)

        state = add(state, traj)      # fill once so sampling is valid
        tag = (f"replay_{kind}" + (f"_n{n_step}" if n_step != 1 else "")
               + (f"_{kernels}" if kernels != "auto" else ""))
        dt_add = timed(add, state, traj, warmup=2, iters=iters)
        adds_per_sec = (T - n_step + 1) * B / dt_add
        emit(f"{tag}_add", dt_add * 1e6, f"adds_per_sec={adds_per_sec:.0f}")

        dt_sample = timed(sample, state, key, warmup=2, iters=iters)
        drawn = T * B if kind == "fifo" else BATCH_SIZE
        emit(f"{tag}_sample", dt_sample * 1e6,
             f"samples_per_sec={drawn / dt_sample:.0f}")
    finally:
        kernel_select.set_kernel_mode(prev)


def run_all() -> None:
    for kind in ("fifo", "uniform", "prioritized"):
        bench_buffer(kind)
    bench_buffer("uniform", n_step=3)
    # the same jitted buffer ops with each kernel-plane implementation
    # pinned (off-TPU the pallas rows time the interpreter — a
    # correctness harness, not production numbers; see kernel_bench's RL
    # section for the per-kernel breakdown)
    for kernels in ("ref", "pallas"):
        bench_buffer("prioritized", kernels=kernels, iters=5)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run_all()
