"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

Per (arch x shape x mesh), from results/dryrun/*.json:
  compute term    = dot_flops_per_device / PEAK_FLOPS        [s]
  memory term     = hbm_bytes_per_device / HBM_BW            [s]
                    (hbm_bytes ~ args + outputs + 2*temps: weights/inputs
                    read, temps written+read; cost_analysis 'bytes accessed'
                    is reported too but does not weight loop trip counts)
  collective term = collective_bytes_per_device / ICI_BW     [s]
                    (trip-count-weighted, parsed from partitioned HLO)

MODEL_FLOPS (useful work) = 6*N_active*tokens (train) / 2*N_active*tokens
(prefill) / 2*N_active*batch (decode, one token), per device.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import INPUT_SHAPES

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_SUGGEST = {
    "compute": ("raise arithmetic efficiency: larger per-device batch, "
                "drop attention-head padding waste, or reduce remat "
                "recompute"),
    "memory": ("cut HBM traffic: fuse elementwise chains, keep weights "
               "resident (less remat), or quantize weights/cache"),
    "collective": ("cut traffic on the slowest axis: resident-weight "
                   "layout instead of FSDP gathers, overlap collectives "
                   "with compute, or quantize gathered operands"),
}


def analyze_one(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    shape = INPUT_SHAPES[rec["shape"]]
    chips = 512 if rec["mesh"] == "2x16x16" else 256
    mem = rec["memory"]
    hbm_bytes = (mem["argument_bytes"] + mem["output_bytes"]
                 + 2 * mem["temp_bytes"])
    compute_t = rec["dot_flops_per_device"] / PEAK_FLOPS
    memory_t = hbm_bytes / HBM_BW
    coll = rec["collectives"]
    # bf16-equivalent corrects XLA-CPU's f32 dot-operand upcast (2x gather
    # inflation vs a TPU lowering); absent in older artifacts
    coll_t = coll.get("total_bytes_bf16eq", coll["total_bytes"]) / ICI_BW
    n_active = rec["active_params"]
    if shape.kind == "train":
        model_flops = 6 * n_active * shape.tokens
    elif shape.kind == "prefill":
        model_flops = 2 * n_active * shape.tokens
    else:
        model_flops = 2 * n_active * shape.global_batch
    model_flops_dev = model_flops / chips
    terms = {"compute": compute_t, "memory": memory_t,
             "collective": coll_t}
    dominant = max(terms, key=terms.get)
    ratio = (model_flops_dev / rec["dot_flops_per_device"]
             if rec["dot_flops_per_device"] else 0.0)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": compute_t, "memory_s": memory_t,
        "collective_s": coll_t, "dominant": dominant,
        "model_flops_per_device": model_flops_dev,
        "hlo_dot_flops_per_device": rec["dot_flops_per_device"],
        "useful_flops_ratio": ratio,
        "hbm_gib_per_device": (mem["argument_bytes"] + mem["temp_bytes"])
        / 2 ** 30,
        "fits_16gib": (mem["argument_bytes"] + mem["temp_bytes"])
        < 16 * 2 ** 30,
        "suggestion": _SUGGEST[dominant],
    }


def load_all(dryrun_dir: str = "results/dryrun") -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        row = analyze_one(rec)
        if row is None:
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec.get("mesh", "?"),
                         "dominant": rec.get("status"),
                         "skip_reason": rec.get("reason",
                                                rec.get("error", ""))})
        else:
            rows.append(row)
    return rows


def markdown_table(rows: List[Dict], mesh: str = "16x16") -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant "
        "| useful/HLO flops | HBM GiB/dev | fits |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("mesh") != mesh:
            continue
        if "compute_s" not in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"{r['dominant']} | — | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"**{r['dominant']}** | {r['useful_flops_ratio']:.2f} | "
            f"{r['hbm_gib_per_device']:.1f} | "
            f"{'y' if r.get('fits_16gib') else 'n'} |")
    return "\n".join(lines)


def main(dryrun_dir: str = "results/dryrun",
         out_json: str = "results/roofline.json") -> List[Dict]:
    rows = load_all(dryrun_dir)
    if not rows:
        print("roofline: no dry-run artifacts found; run "
              "`python -m repro.launch.dryrun --all --both-meshes` first")
        return []
    os.makedirs(os.path.dirname(out_json), exist_ok=True)
    with open(out_json, "w") as f:
        json.dump(rows, f, indent=2)
    from benchmarks.common import emit
    for r in rows:
        if "compute_s" in r:
            emit(f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
                 max(r["compute_s"], r["memory_s"], r["collective_s"])
                 * 1e6,
                 f"dominant={r['dominant']} ratio="
                 f"{r['useful_flops_ratio']:.2f}")
        else:
            emit(f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}", 0.0,
                 f"{r['dominant']}")
    return rows


if __name__ == "__main__":
    main()
