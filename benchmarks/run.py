"""Benchmark driver — one section per paper table/figure, with a
machine-readable record of every run.

Prints ``name,us_per_call,derived`` CSV rows and, at the end, writes
``BENCH_<rev>.json`` (per-benchmark throughput + config + timestamp)
into ``--out-dir`` so the perf trajectory is recorded across PRs instead
of evaporating into stdout. Sections:

  fig         fig3..fig7 return/rollout/speedup curves   (paper Figs 3-7)
  fused       fused-engine dispatch-overhead savings
  replay      experience-plane adds/sec + samples/sec per buffer kind
              (including kernel-plane ref/pallas rows for prioritized)
  sampler     actor-plane scaling: samples/sec vs N per backend
              (inline vs threaded vs true worker processes), plus the
              vector-collection row at env_batch=B     [DESIGN.md §6]
  learner     learner-plane scaling: train-step time + samples/sec vs
              D devices (sharded learner, forced host devices)
                                                       [DESIGN.md §9]
  env_step    env-plane: fused step+auto-reset kernels ref-vs-pallas at
              B in {1k,10k,100k} + VectorEnv rollout throughput vs the
              inline N=1 baseline                      [DESIGN.md §7]
  serving     serving plane: PolicyServer p50/p99 latency + requests/sec
              vs batch-window deadline, and the hot-swap pickup latency
                                                       [DESIGN.md §8]
  kernels_lm  attn_* / selective_scan_* / decode_step_* sampler benches
  kernels_rl  gae / sum_tree / replay_ring ref-vs-pallas  [DESIGN.md §5]
  roofline    three-term roofline per (arch x shape x mesh)

The roofline section reads results/dryrun/*.json produced by
``python -m repro.launch.dryrun --all --both-meshes`` (run it first; rows
are skipped gracefully if absent).

  python -m benchmarks.run                          # everything
  python -m benchmarks.run --sections kernels_rl    # one section, fast
  python -m benchmarks.run --compare OLD.json NEW.json
                                                    # diff two reports;
                                                    # exit 1 on regression

``--compare`` diffs the rows two BENCH files share and prints per-metric
deltas; throughput metrics (``*_per_sec``) that drop — or ``us_per_call``
that rises — by more than ``--threshold`` percent count as regressions
and make the exit status nonzero, so CI can consume the BENCH trajectory
directly.
"""
from __future__ import annotations

import argparse
import datetime
import json
import os
import platform
import subprocess
import sys
import time


def _sections():
    from benchmarks import env_step_bench, fault_bench, fig_parallel, \
        fused_vs_stepped, kernel_bench, learner_scaling, replay_bench, \
        roofline, sampler_scaling, serving_bench
    return {
        "fig": fig_parallel.run_all,
        "fused": fused_vs_stepped.run_all,
        "replay": replay_bench.run_all,
        "sampler": sampler_scaling.run_all,
        "learner": learner_scaling.run_all,
        "env_step": env_step_bench.run_all,
        "serving": serving_bench.run_all,
        "fault": fault_bench.run_all,
        "kernels_lm": kernel_bench.run_lm,
        "kernels_rl": kernel_bench.run_rl,
        "roofline": roofline.main,
    }


def _git_rev() -> str:
    """Short HEAD rev, ``-dirty``-suffixed when the tree has uncommitted
    changes — numbers from unfinished work must not be attributed to the
    last commit in the recorded trajectory."""
    cwd = os.path.dirname(os.path.abspath(__file__))
    try:
        rev = subprocess.check_output(
            ["git", "rev-parse", "--short", "HEAD"], cwd=cwd,
            stderr=subprocess.DEVNULL).decode().strip()
        dirty = subprocess.call(
            ["git", "diff-index", "--quiet", "HEAD"], cwd=cwd,
            stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL) != 0
        untracked = subprocess.check_output(
            ["git", "ls-files", "--others", "--exclude-standard"],
            cwd=cwd, stderr=subprocess.DEVNULL).strip()
        return rev + ("-dirty" if dirty or untracked else "")
    except (OSError, subprocess.CalledProcessError):
        return "unknown"


def check_dirty_overwrite(out_dir: str, rev: str, force: bool) -> None:
    """Refuse to land a ``-dirty`` report next to its clean-rev sibling.

    A dirty tree's numbers describe unfinished work; writing
    ``BENCH_<rev>-dirty.json`` beside the committed ``BENCH_<rev>.json``
    invites comparing (or worse, shipping) them as if they were the
    recorded trajectory. ``--force`` overrides for local iteration.
    """
    if force or not rev.endswith("-dirty"):
        return
    clean = os.path.join(out_dir, f"BENCH_{rev[:-len('-dirty')]}.json")
    if os.path.exists(clean):
        sys.exit(
            f"error: the tree is dirty but {clean} already records this "
            f"rev from a clean tree; commit your changes (so the report "
            f"lands under the new rev) or pass --force to write "
            f"BENCH_{rev}.json anyway")


def write_report(out_dir: str, sections, force: bool = False) -> str:
    """Serialize every emitted row (benchmarks.common.RECORDS) plus the
    run's config into ``<out_dir>/BENCH_<rev>.json``; returns the path."""
    import jax

    from benchmarks import common
    check_dirty_overwrite(out_dir, _git_rev(), force)
    payload = {
        "rev": _git_rev(),
        "timestamp": datetime.datetime.now(
            datetime.timezone.utc).isoformat(),
        "unix_time": time.time(),
        "config": {
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "python": platform.python_version(),
            "platform": platform.platform(),
            "sections": list(sections),
        },
        "benchmarks": common.RECORDS,
    }
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{payload['rev']}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    return path


def _load_records(path: str) -> dict:
    with open(path) as f:
        payload = json.load(f)
    # index by row name; a re-emitted name keeps its latest measurement
    return {r["name"]: r for r in payload.get("benchmarks", [])}, \
        payload.get("rev", "?")


def compare(old_path: str, new_path: str, threshold: float) -> int:
    """Diff the benchmark rows two BENCH reports share.

    Prints one line per (row, metric) with old/new values and the percent
    delta. ``us_per_call`` and latency metrics (``*_ms`` — e.g. the fault
    section's ``recovery_ms``) are lower-is-better; ``*_per_sec`` metrics
    are higher-is-better; everything else is informational. Returns the
    number of metrics that regressed by more than ``threshold`` percent.
    """
    old, old_rev = _load_records(old_path)
    new, new_rev = _load_records(new_path)
    for side, rev in (("old", old_rev), ("new", new_rev)):
        if rev.endswith("-dirty"):
            print(f"# WARNING: {side} report {rev} was produced from a "
                  f"dirty tree — its numbers may not match any commit",
                  file=sys.stderr)
    shared = [n for n in new if n in old]
    only_old = sorted(set(old) - set(new))
    only_new = sorted(set(new) - set(old))
    print(f"# compare {old_rev} -> {new_rev}: {len(shared)} shared rows, "
          f"{len(only_old)} dropped, {len(only_new)} added")
    print("name,metric,old,new,delta_pct,verdict")
    regressions = 0
    for name in shared:
        pairs = [("us_per_call", old[name]["us_per_call"],
                  new[name]["us_per_call"], False)]
        om, nm = old[name].get("metrics", {}), new[name].get("metrics", {})
        for k in sorted(set(om) & set(nm)):
            pairs.append((k, om[k], nm[k], k.endswith("per_sec")))
        for metric, o, n, higher_better in pairs:
            if not o:
                continue
            delta = (n - o) / abs(o) * 100.0
            lower_better = (metric == "us_per_call"
                            or metric.endswith("_ms"))
            judged = higher_better or lower_better
            regressed = judged and (
                -delta > threshold if higher_better else delta > threshold)
            verdict = ("REGRESSED" if regressed
                       else "ok" if judged else "info")
            regressions += regressed
            print(f"{name},{metric},{o:.6g},{n:.6g},{delta:+.1f},{verdict}")
    if regressions:
        print(f"# {regressions} metric(s) regressed more than "
              f"{threshold:.0f}%")
    return regressions


def main(argv=None) -> None:
    table = _sections()
    ap = argparse.ArgumentParser()
    ap.add_argument("--sections", default=",".join(table),
                    help="comma-separated subset of: " + ", ".join(table))
    ap.add_argument("--out-dir", default="results",
                    help="where BENCH_<rev>.json lands (default: results)")
    ap.add_argument("--compare", nargs=2, metavar=("OLD.json", "NEW.json"),
                    default=None,
                    help="diff two BENCH reports instead of running "
                         "benchmarks; nonzero exit on regression")
    ap.add_argument("--threshold", type=float, default=10.0,
                    help="--compare: percent drop in *_per_sec (or rise "
                         "in us_per_call) that counts as a regression "
                         "(default 10)")
    ap.add_argument("--force", action="store_true",
                    help="write BENCH_<rev>-dirty.json even when the "
                         "clean-tree BENCH_<rev>.json already exists "
                         "(local iteration only — dirty reports are not "
                         "part of the recorded trajectory)")
    args = ap.parse_args(argv)
    if args.compare is not None:
        sys.exit(1 if compare(args.compare[0], args.compare[1],
                              args.threshold) else 0)
    names = [s.strip() for s in args.sections.split(",") if s.strip()]
    unknown = [s for s in names if s not in table]
    if unknown:
        ap.error(f"unknown sections {unknown}; choose from {list(table)}")
    # fail before benchmarks run, not after minutes of measurement
    check_dirty_overwrite(args.out_dir, _git_rev(), args.force)

    print("name,us_per_call,derived")
    for name in names:
        table[name]()
    path = write_report(args.out_dir, names, force=args.force)
    print(f"# wrote {path}")


if __name__ == "__main__":
    main()
