"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  fig3_*  return curves N=10 vs N=1            (paper Fig 3)
  fig4_*  rollout time vs N                    (paper Fig 4)
  fig5_*  collection speedup vs N              (paper Fig 5)
  fig6_*  learning-time fraction vs N          (paper Fig 6)
  fig7_*  learning time per iteration vs N     (paper Fig 7)
  fused_vs_stepped_*  fused-engine dispatch-overhead savings
  replay_*  experience-plane adds/sec + samples/sec per buffer kind
  attn_* / selective_scan_* / decode_step_*    sampler hot-spot microbenches
  roofline_*  three-term roofline per (arch x shape x mesh)  [§Roofline]

The roofline section reads results/dryrun/*.json produced by
``python -m repro.launch.dryrun --all --both-meshes`` (run it first; rows
are skipped gracefully if absent).
"""
from __future__ import annotations


def main() -> None:
    print("name,us_per_call,derived")
    from benchmarks import fig_parallel, fused_vs_stepped, kernel_bench, \
        replay_bench, roofline
    fig_parallel.run_all()
    fused_vs_stepped.run_all()
    replay_bench.run_all()
    kernel_bench.run_all()
    roofline.main()


if __name__ == "__main__":
    main()
