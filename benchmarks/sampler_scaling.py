"""The actor-plane scaling row: samples/sec vs N, per sampler backend.

The paper's central claim — N parallel sampler processes dominate
single-process collection — tracked release-over-release. For each
backend (inline = the serial single-host measurement, threaded = in-
process fan-out, process = true worker processes over shared-memory
transport) the same fixed per-iteration sample budget is split across
N ∈ ``NS`` samplers and the steady-state collection critical path is
measured (iteration 0 excluded: jit compile; the *minimum* over the
remaining iterations is reported to keep the row stable on noisy CI
hosts). Rows land in ``BENCH_<rev>.json`` via ``benchmarks/run.py
--sections sampler`` with a parsed ``samples_per_sec`` metric, so the
scaling trajectory is recorded per revision.

On any multi-core host the expectation is monotonically non-decreasing
samples/sec in N for the ``process`` backend: each worker owns its own
interpreter and XLA client, so adding workers shrinks the per-worker
budget without adding GIL or dispatch-queue contention.

Measurement methodology (the BENCH_ee46a01 N4 regression, diagnosed):
the critical path is ``max`` over per-sampler self-timed rollouts
(DESIGN.md §2 — each sampler's work is timed separately). Broadcasting
the lock-step collect wakes every worker at once, so on a host with
fewer cores than workers each worker's self-timed rollout *includes
being preempted by its peers* — N4 measured slower than N1 purely from
scheduler time-slicing, not sampler work. The sweep therefore runs the
process backend **staggered** (workers commanded one at a time, each
timed uncontended — the exact analogue of the inline backend's serial
sweep), and skips ``warmup`` iterations rather than one so per-worker
caches reach steady state before any timing counts.
"""
from __future__ import annotations

from typing import Dict, Sequence, Tuple

from benchmarks.common import build_walle, emit

NS: Tuple[int, ...] = (1, 2, 4)
BACKENDS: Tuple[str, ...] = ("inline", "threaded", "process")


def sweep(backend: str, ns: Sequence[int] = NS, budget: int = 2048,
          env_batch: int = 4, iterations: int = 12, repeats: int = 2,
          warmup: int = 3, env_name: str = "pendulum") -> Dict[int, float]:
    """samples/sec for each N on one backend (fixed total budget).

    Each N is measured ``repeats`` times end-to-end and the best run is
    reported (external interference on a shared host only ever *slows* a
    run, so max-over-runs of min-over-iterations estimates the true
    achievable throughput). The first ``warmup`` iterations are excluded
    (jit compile + cache warm, not steady state).
    """
    out = {}
    for n in ns:
        best = 0.0
        for _ in range(repeats):
            runner = build_walle(env_name, n, budget, env_batch=env_batch,
                                 seed=3, backend=backend)
            if backend == "process":
                runner.backend.staggered = True
            try:
                logs = runner.run(iterations)
            finally:
                runner.close()
            critical = min(log.collect_time for log in logs[warmup:])
            best = max(best, logs[warmup].samples / critical)
        out[n] = best
        emit(f"sampler_{backend}_N{n}", logs[warmup].samples / best * 1e6,
             f"samples_per_sec={best:.0f} n={n} budget={budget}")
    return out


def sweep_vector(bs: Sequence[int] = (1024, 4096), iterations: int = 6,
                 repeats: int = 2,
                 env_name: str = "pendulum") -> Dict[int, float]:
    """The env-plane row alongside the backend sweep: one device-resident
    VectorEnv batch of B instances (``schedule.env_batch``, no sampler
    split) measured on the same collect critical path. The full B sweep
    up to 100k lives in ``benchmarks/env_step_bench.py``."""
    from repro.experiment import ExperimentSpec, Schedule

    from repro import experiment
    out = {}
    for b in bs:
        best = 0.0
        for _ in range(repeats):
            spec = ExperimentSpec(
                env=env_name, algo="ppo", backend="inline",
                model={"hidden": 64},
                schedule=Schedule(horizon=2, seed=3, env_batch=b))
            runner = experiment.build(spec)
            try:
                logs = runner.run(iterations)
            finally:
                runner.close()
            critical = min(log.collect_time for log in logs[1:])
            best = max(best, logs[1].samples / critical)
        out[b] = best
        emit(f"sampler_vector_B{b}", logs[1].samples / best * 1e6,
             f"samples_per_sec={best:.0f} env_batch={b}")
    return out


def run_all(ns: Sequence[int] = NS,
            backends: Sequence[str] = BACKENDS) -> Dict[str, Dict[int, float]]:
    out = {backend: sweep(backend, ns=ns) for backend in backends}
    out["vector"] = sweep_vector()
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--backends", default=",".join(BACKENDS))
    ap.add_argument("--ns", default=",".join(map(str, NS)))
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run_all(ns=tuple(int(n) for n in args.ns.split(",")),
            backends=tuple(b for b in args.backends.split(",") if b))
