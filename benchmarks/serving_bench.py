"""Serving-plane benchmark: p50/p99 latency and requests/sec vs the
dynamic-batching window (DESIGN.md §8).

One ``PolicyServer`` (ppo x pendulum MLP policy, built in-process — the
bench measures the serving plane, not checkpoint IO) is swept over
batch-window deadlines with a fixed concurrent client load. Short
deadlines dispatch small partial batches (low latency, low occupancy);
long deadlines fill the slots (high throughput per dispatch, queue-wait
bounded by the window). Each row records the shared serving-stats
schema into ``BENCH_<rev>.json`` via ``benchmarks.run`` section
``serving``:

    serving_ppo_pendulum_d<window>ms,<mean latency us>,
        p50_ms=... p99_ms=... req_per_sec=... occupancy=... dispatches=...

plus one ``serving_hot_swap`` row measuring the params-version pickup
latency mid-traffic (publish -> first completion served by the new
version).
"""
from __future__ import annotations

import concurrent.futures
import time

import numpy as np

from benchmarks.common import emit

REQUESTS = 512
CLIENTS = 16
SLOTS = 16
WINDOWS_MS = (1.0, 5.0, 20.0)


def _build_policy():
    import jax

    from repro import registry
    env = registry.make("env", "pendulum")
    algo = registry.make("algo", "ppo")
    params, _ = algo.init(jax.random.PRNGKey(0), env)
    return env, algo, params


def _fire(server, observations, clients: int, timeout: float = 120.0):
    with concurrent.futures.ThreadPoolExecutor(clients) as pool:
        futures = [pool.submit(server.act, obs, timeout=timeout)
                   for obs in observations]
        for fut in concurrent.futures.as_completed(futures):
            fut.result()


def sweep_window(env, algo, params) -> None:
    from repro.serve import PolicyServer
    rng = np.random.RandomState(0)
    observations = rng.randn(REQUESTS, env.obs_dim).astype(np.float32)
    for window_ms in WINDOWS_MS:
        with PolicyServer(env, algo, params, slots=SLOTS,
                          deadline_ms=window_ms,
                          queue_cap=REQUESTS) as server:
            _fire(server, observations, CLIENTS)
            snap = server.snapshot()
        lat = snap["latency_ms"]
        emit(f"serving_ppo_pendulum_d{window_ms:g}ms",
             lat["mean"] * 1e3,
             f"p50_ms={lat['p50']:.3f} p99_ms={lat['p99']:.3f} "
             f"req_per_sec={snap['requests_per_sec']:.0f} "
             f"occupancy={snap['batch_occupancy']:.3f} "
             f"dispatches={snap['dispatches']}")


def hot_swap_latency(env, algo, params) -> None:
    """Publish a new params version mid-traffic; report how long until a
    completion is served by it (the replica-refresh latency)."""
    import os
    import uuid

    import jax

    from repro.core.ipc import ParamsChannel
    from repro.serve import PolicyServer
    leaves = [np.asarray(x) for x in jax.tree_util.tree_leaves(params)]
    channel = ParamsChannel.create(
        leaves, f"walle-bench-{os.getpid()}-{uuid.uuid4().hex[:6]}")
    channel.publish(leaves)
    try:
        with PolicyServer(env, algo, params, slots=SLOTS, deadline_ms=2.0,
                          queue_cap=REQUESTS,
                          params_channel=channel) as server:
            rng = np.random.RandomState(1)
            with concurrent.futures.ThreadPoolExecutor(CLIENTS) as pool:
                futures = [
                    pool.submit(server.act,
                                rng.randn(env.obs_dim).astype(np.float32),
                                timeout=120.0)
                    for _ in range(REQUESTS)]
                time.sleep(0.01)               # traffic in flight
                t_publish = time.perf_counter()
                channel.publish([x * 1.01 for x in leaves])
                swap_seen = None
                for fut in concurrent.futures.as_completed(futures):
                    fut.result()
                    if swap_seen is None and server.params_version >= 2:
                        swap_seen = time.perf_counter() - t_publish
            pickup_ms = (swap_seen if swap_seen is not None else -1) * 1e3
            emit("serving_hot_swap", pickup_ms * 1e3,
                 f"pickup_ms={pickup_ms:.3f} "
                 f"final_version={server.params_version} "
                 f"requests={server.stats.requests}")
    finally:
        channel.close(unlink=True)


def run_all() -> None:
    env, algo, params = _build_policy()
    sweep_window(env, algo, params)
    hot_swap_latency(env, algo, params)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run_all()
