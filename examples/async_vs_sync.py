"""The paper's architecture (Fig 2) end-to-end: asynchronous sampler threads
+ policy/experience queues vs the synchronous baseline, with staleness and
queue accounting printed.

Both sides are the *same* ``ExperimentSpec`` with only ``runtime`` flipped
(``sync`` over the threaded backend vs ``async``) — the unified experiment
API makes the runtime a one-word choice.

  PYTHONPATH=src python examples/async_vs_sync.py
"""
import time

from repro import experiment
from repro.experiment import ExperimentSpec, Schedule

N = 3
UPDATES = 6


def spec_for(runtime: str) -> ExperimentSpec:
    return ExperimentSpec(
        env="cartpole", algo="ppo",
        # sync baseline collects with the threaded backend, so its
        # fan-out matches the async runtime's sampler threads 1:1
        backend="threaded", runtime=runtime,
        model={"hidden": 32},
        algo_kwargs={"lr": 1e-3, "epochs": 2, "minibatches": 2},
        schedule=Schedule(num_samplers=N, global_batch=8 * N, horizon=128,
                          iterations=UPDATES, seed=0,
                          min_batches_per_update=2),
    )


if __name__ == "__main__":
    sync = experiment.build(spec_for("sync"))
    t0 = time.perf_counter()
    sync_logs = sync.run(UPDATES)
    t_sync = time.perf_counter() - t0

    orch = experiment.build(spec_for("async"))
    t0 = time.perf_counter()
    async_logs = orch.run(UPDATES, timeout=300)
    t_async = time.perf_counter() - t0

    print(f"\nsync:  {UPDATES} updates in {t_sync:.1f}s, final return "
          f"{sync_logs[-1].mean_return:.1f}")
    print(f"async: {UPDATES} updates in {t_async:.1f}s, final return "
          f"{async_logs[-1].mean_return:.1f}")
    print(f"async policy staleness (mean versions behind): "
          f"{orch.expq.mean_staleness():.2f}")
    print(f"async queue waits: mean "
          f"{sum(orch.expq.queue_wait) / max(len(orch.expq.queue_wait), 1):.3f}s "
          f"over {orch.expq.put_count} experiences from {N} samplers")
    print("\nthe async agent never blocks on a single slow sampler — the "
          "paper's Fig 2 architecture; staleness is the price, bounded by "
          "queue depth")
