"""The paper's architecture (Fig 2) end-to-end: asynchronous sampler threads
+ policy/experience queues vs the synchronous baseline, with staleness and
queue accounting printed.

  PYTHONPATH=src python examples/async_vs_sync.py
"""
import time

import jax

from repro import envs
from repro.algos.ppo import PPOConfig, make_mlp_learner
from repro.core import AsyncOrchestrator, SyncRunner, make_backend
from repro.core import sampler as S
from repro.models import mlp_policy
from repro.optim import adam

N = 3
UPDATES = 6


def build(cls, backend=None, **kw):
    env = envs.make("cartpole")
    key = jax.random.PRNGKey(0)
    params = mlp_policy.init_policy(key, env.obs_dim, env.act_dim, 32)
    opt = adam(1e-3)
    learn = make_mlp_learner(opt, PPOConfig(epochs=2, minibatches=2))
    rollout = S.make_env_rollout(env, horizon=128)
    carries = [S.init_env_carry(env, jax.random.PRNGKey(1 + i), 8)
               for i in range(N)]
    if backend is not None:
        return cls(None, learn, params, opt.init(params),
                   backend=make_backend(backend, rollout, carries), **kw)
    return cls(rollout, learn, params, opt.init(params), carries, N, **kw)


if __name__ == "__main__":
    # the sync baseline timed with the threaded backend, so its collection
    # fan-out matches the async runtime's sampler threads 1:1
    sync = build(SyncRunner, backend="threaded")
    t0 = time.perf_counter()
    sync_logs = sync.run(UPDATES)
    t_sync = time.perf_counter() - t0

    orch = build(AsyncOrchestrator, min_batches_per_update=2)
    t0 = time.perf_counter()
    async_logs = orch.run(UPDATES, timeout=300)
    t_async = time.perf_counter() - t0

    print(f"\nsync:  {UPDATES} updates in {t_sync:.1f}s, final return "
          f"{sync_logs[-1].mean_return:.1f}")
    print(f"async: {UPDATES} updates in {t_async:.1f}s, final return "
          f"{async_logs[-1].mean_return:.1f}")
    print(f"async policy staleness (mean versions behind): "
          f"{orch.expq.mean_staleness():.2f}")
    print(f"async queue waits: mean "
          f"{sum(orch.expq.queue_wait) / max(len(orch.expq.queue_wait), 1):.3f}s "
          f"over {orch.expq.put_count} experiences from {N} samplers")
    print("\nthe async agent never blocks on a single slow sampler — the "
          "paper's Fig 2 architecture; staleness is the price, bounded by "
          "queue depth")
