"""The 2026 instantiation of WALL-E: RLHF-style token rollouts.

A reduced assigned architecture (default mixtral-8x7b-reduced) acts as the
policy; experience collection = autoregressive decode against a synthetic
reward model; the learner is token-level PPO (the exact computation the
``train_4k`` dry-run lowers at full scale). Return improves within a few
updates on CPU.

  PYTHONPATH=src python examples/llm_rollout.py [--arch hymba-1.5b-reduced]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.algos.gae import gae, normalize
from repro.algos.ppo import PPOConfig, make_lm_train_step
from repro.configs import get_config
from repro.core.sampler import make_lm_rollout
from repro.envs import lm_env
from repro.models import transformer as T
from repro.optim import adam

GEN = 24
PROMPT = 8
BATCH = 8
N_SAMPLERS = 2


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b-reduced")
    ap.add_argument("--updates", type=int, default=6)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    env = lm_env.make(cfg.vocab_size, episode_len=GEN)
    rollout = jax.jit(make_lm_rollout(cfg, env, GEN))
    opt = adam(3e-4)
    opt_state = opt.init(params)
    ppo = PPOConfig(entropy_coef=0.003)
    train = jax.jit(make_lm_train_step(cfg, opt, ppo))

    for it in range(args.updates):
        key, *kr = jax.random.split(key, N_SAMPLERS + 2)
        t0 = time.perf_counter()
        trajs = [rollout(params,
                         jax.random.randint(kr[i], (BATCH, PROMPT), 0,
                                            cfg.vocab_size),
                         kr[i])
                 for i in range(N_SAMPLERS)]   # N parallel decode samplers
        traj = {k: jnp.concatenate([t[k] for t in trajs])
                for k in trajs[0]}
        collect = time.perf_counter() - t0

        # GAE over token rewards (values ~ 0 baseline for the demo)
        rew_tm = traj["rewards"].T                      # (T, B)
        adv, ret = gae(rew_tm, jnp.zeros_like(rew_tm),
                       jnp.zeros_like(rew_tm),
                       jnp.zeros(rew_tm.shape[1]), 0.99, 0.95)
        context = jnp.concatenate(
            [traj["prompt"][:, -1:], traj["tokens"][:, :-1]], axis=1)
        batch = {
            "tokens": context,
            "targets": traj["tokens"],
            "behavior_logp": traj["logp"],
            "advantages": normalize(adv.T),
            "returns": ret.T,
            "mask": jnp.ones_like(traj["logp"]),
        }
        if cfg.frontend_embeds:
            batch["extra_embeds"] = jnp.zeros(
                (batch["tokens"].shape[0], cfg.frontend_embeds,
                 cfg.d_model), jnp.dtype(cfg.dtype))
        t0 = time.perf_counter()
        params, opt_state, metrics = train(params, opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        learn = time.perf_counter() - t0
        print(f"update {it}: mean token reward "
              f"{float(traj['rewards'].mean()):+.3f}  "
              f"loss={float(metrics['loss']):.3f}  "
              f"collect={collect:.1f}s learn={learn:.1f}s  "
              f"({N_SAMPLERS} samplers x {BATCH} seqs x {GEN} tokens)")
    print("\ncollection (decode) dominates the iteration — the paper's "
          "bottleneck argument, reproduced at token scale; the full-size "
          "version of this computation is what prefill_32k/decode_32k "
          "lower in the dry-run")


if __name__ == "__main__":
    main()
