"""Paper §6 further-work #1: DDPG + replay buffer fed by parallel samplers.

Off-policy learning is even hungrier for samples, so parallel collection
helps more: samplers record full transitions (``next_obs``), the learner
pushes them through a shared replay ring and draws uniform minibatches.

Through the unified experiment API this is just ``algo="ddpg"`` on the
threaded backend. The replay ring is part of the **experience plane** —
a runner-owned buffer selected by ``buffer=``/``buffer_kwargs`` (swap in
``buffer="prioritized"`` for sum-tree prioritized replay, set
``n_step=3`` for n-step returns, or ``algo="sac"`` for soft actor-critic)
— so the same runners/backends that drive PPO drive any off-policy algo
(swap ``backend`` for ``"inline"``/``"sharded"``, or set
``runtime="fused"`` with ``backend="inline"``, and it still runs).

  PYTHONPATH=src python examples/offpolicy_ddpg.py
"""
from repro import experiment
from repro.experiment import ExperimentSpec, Schedule

N_SAMPLERS = 4
ENV_BATCH = 4
HORIZON = 64
UPDATES = 40

if __name__ == "__main__":
    spec = ExperimentSpec(
        env="pendulum", algo="ddpg", backend="threaded",
        model={"hidden": 64},
        algo_kwargs={"noise_std": 0.2, "updates_per_collect": 1},
        buffer="uniform",
        buffer_kwargs={"capacity": 50_000, "batch_size": 256},
        schedule=Schedule(num_samplers=N_SAMPLERS,
                          global_batch=ENV_BATCH * N_SAMPLERS,
                          horizon=HORIZON, iterations=UPDATES, seed=0),
    )
    result = experiment.run(spec)
    for log in result.logs[:: 5] + result.logs[-1:]:
        print(f"update {log.iteration}: collect={log.collect_time:.3f}s "
              f"(critical path over {N_SAMPLERS} samplers) "
              f"learn={log.learn_time:.3f}s samples={log.samples}")
    ring = result.runner.buffer_state
    print(f"\nreplay filled by {N_SAMPLERS} parallel samplers; "
          f"{int(ring.size)} transitions "
          f"({UPDATES} learner updates drew uniform minibatches at their "
          f"own pace)")
