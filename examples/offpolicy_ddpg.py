"""Paper §6 further-work #1: DDPG + replay buffer fed by parallel samplers.

Off-policy learning is even hungrier for samples, so parallel collection
helps more: samplers record full transitions (``next_obs``), the learner
pushes them through a shared replay ring and draws uniform minibatches.

Through the unified experiment API this is just ``algo="ddpg"`` on the
threaded backend — the replay buffer lives inside the algorithm's
``opt_state``, so the same runners/backends that drive PPO drive DDPG
(swap ``backend`` for ``"inline"``/``"sharded"``, or set
``runtime="fused"`` with ``backend="inline"``, and it still runs).

  PYTHONPATH=src python examples/offpolicy_ddpg.py
"""
from repro import experiment
from repro.experiment import ExperimentSpec, Schedule

N_SAMPLERS = 4
ENV_BATCH = 4
HORIZON = 64
UPDATES = 40

if __name__ == "__main__":
    spec = ExperimentSpec(
        env="pendulum", algo="ddpg", backend="threaded",
        model={"hidden": 64},
        algo_kwargs={"noise_std": 0.2, "replay_capacity": 50_000,
                     "batch_size": 256, "updates_per_collect": 1},
        schedule=Schedule(num_samplers=N_SAMPLERS,
                          global_batch=ENV_BATCH * N_SAMPLERS,
                          horizon=HORIZON, iterations=UPDATES, seed=0),
    )
    result = experiment.run(spec)
    for log in result.logs[:: 5] + result.logs[-1:]:
        print(f"update {log.iteration}: collect={log.collect_time:.3f}s "
              f"(critical path over {N_SAMPLERS} samplers) "
              f"learn={log.learn_time:.3f}s samples={log.samples}")
    replay = result.runner.opt_state[2]
    print(f"\nreplay filled by {N_SAMPLERS} parallel samplers; "
          f"{int(replay.size)} transitions "
          f"({UPDATES} learner updates drew uniform minibatches at their "
          f"own pace)")
