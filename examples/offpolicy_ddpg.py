"""Paper §6 further-work #1: DDPG + replay buffer fed by parallel samplers.

Off-policy learning is even hungrier for samples, so parallel collection
helps more: samplers write transitions into a shared replay ring and the
learner draws uniform minibatches at its own pace.

  PYTHONPATH=src python examples/offpolicy_ddpg.py
"""
import jax
import jax.numpy as jnp

from repro import envs
from repro.algos import ddpg
from repro.data.replay import add_batch, init_replay, sample
from repro.envs.base import auto_reset
from repro.optim import adam

N_SAMPLERS = 4
ENV_BATCH = 4
HORIZON = 64
UPDATES = 40


def make_collector(env):
    step_fn = auto_reset(env)

    def collect(params, carry, key, noise):
        def body(c, k):
            state, obs = c
            ka, ke = jax.random.split(k)
            a = ddpg.actor_apply(params["actor"], obs)
            a = jnp.clip(a + noise * jax.random.normal(ka, a.shape), -1, 1)
            state2, obs2, rew, done = jax.vmap(step_fn)(
                state, a, jax.random.split(ke, obs.shape[0]))
            out = {"obs": obs, "actions": a, "rewards": rew,
                   "next_obs": obs2, "dones": done}
            return (state2, obs2), out

        keys = jax.random.split(key, HORIZON)
        carry, traj = jax.lax.scan(body, carry, keys)
        flat = jax.tree.map(lambda x: x.reshape((-1,) + x.shape[2:]), traj)
        return carry, flat

    return jax.jit(collect)


if __name__ == "__main__":
    env = envs.make("pendulum")
    key = jax.random.PRNGKey(0)
    params = ddpg.init_ddpg(key, env.obs_dim, env.act_dim, hidden=64)
    cfg = ddpg.DDPGConfig(noise_std=0.2)
    a_opt, c_opt = adam(cfg.actor_lr), adam(cfg.critic_lr)
    opt_states = (a_opt.init(params["actor"]), c_opt.init(params["critic"]))

    example = {"obs": jnp.zeros((1, env.obs_dim)),
               "actions": jnp.zeros((1, env.act_dim)),
               "rewards": jnp.zeros((1,)),
               "next_obs": jnp.zeros((1, env.obs_dim)),
               "dones": jnp.zeros((1,), bool)}
    replay = init_replay(50_000, example)

    collect = make_collector(env)
    carries = []
    for i in range(N_SAMPLERS):
        k = jax.random.PRNGKey(10 + i)
        states, obs = jax.vmap(env.reset)(jax.random.split(k, ENV_BATCH))
        carries.append((states, obs))

    update = jax.jit(lambda p, s, b: ddpg.ddpg_update(p, s, b, cfg,
                                                      a_opt, c_opt))
    for it in range(UPDATES):
        key, *ks = jax.random.split(key, N_SAMPLERS + 2)
        for i in range(N_SAMPLERS):        # parallel samplers fill replay
            carries[i], flat = collect(params, carries[i], ks[i],
                                       cfg.noise_std)
            replay = add_batch(replay, flat)
        batch = sample(replay, ks[-1], 256)
        params, opt_states, metrics = update(params, opt_states, batch)
        if it % 5 == 0 or it == UPDATES - 1:
            print(f"update {it}: replay={int(replay.size)} "
                  f"critic_loss={float(metrics['critic_loss']):.3f} "
                  f"q_mean={float(metrics['q_mean']):.2f} "
                  f"reward_mean={float(batch['rewards'].mean()):.2f}")
    print("\nreplay filled by", N_SAMPLERS, "parallel samplers;",
          int(replay.size), "transitions")
