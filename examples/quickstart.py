"""Quickstart: WALL-E's experiment in miniature.

PPO on a pure-JAX pendulum with N=4 parallel samplers vs N=1, printing the
per-iteration collection/learning split — the paper's Figs 3/6 story in
~2 minutes on CPU — then the fused engine: the same iterations under a
single jit dispatch (no host round-trips at all).

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro import envs
from repro.algos.ppo import PPOConfig, make_mlp_learner
from repro.core import FusedRunner, SyncRunner, make_backend
from repro.core import sampler as S
from repro.models import mlp_policy
from repro.optim import adam


def setup(num_samplers: int, batch: int = 8, horizon: int = 200):
    env = envs.make("pendulum")
    key = jax.random.PRNGKey(0)
    params = mlp_policy.init_policy(key, env.obs_dim, env.act_dim, 64)
    opt = adam(1e-3)
    learn = make_mlp_learner(opt, PPOConfig(epochs=4, minibatches=4))
    rollout = S.make_env_rollout(env, horizon)
    carries = [S.init_env_carry(env, jax.random.PRNGKey(1 + i), batch)
               for i in range(num_samplers)]
    return env, rollout, learn, params, opt.init(params), carries


def run(num_samplers: int, iterations: int = 8, backend: str = "inline"):
    env, rollout, learn, params, opt_state, carries = setup(num_samplers)
    runner = SyncRunner(None, learn, params, opt_state,
                        backend=make_backend(backend, rollout, carries,
                                             env=env, horizon=200))
    logs = runner.run(iterations)
    print(f"\n=== N={num_samplers} parallel samplers ({backend}) ===")
    for log in logs:
        print(f"iter {log.iteration}: return={log.mean_return:8.1f}  "
              f"collect={log.collect_time:.3f}s "
              f"(serial-equivalent {log.collect_time_serial:.3f}s)  "
              f"learn={log.learn_time:.3f}s  samples={log.samples}")
    return logs


def run_fused(iterations: int = 8):
    env, _, learn, params, opt_state, carries = setup(1)
    runner = FusedRunner(env, learn, params, opt_state, carries[0],
                         horizon=200, chunk=iterations)
    runner.run(iterations)                 # compile the chunk once
    logs = runner.run(iterations)[iterations:]
    print(f"\n=== fused engine (1 dispatch for {iterations} iterations) ===")
    for log in logs:
        print(f"iter {log.iteration}: return={log.mean_return:8.1f}  "
              f"iter_time={log.learn_time:.3f}s  samples={log.samples}")
    return logs


if __name__ == "__main__":
    one = run(1)
    four = run(4)
    t1 = sum(l.collect_time for l in one[1:])
    t4 = sum(l.collect_time for l in four[1:])
    print(f"\ncollection critical path per iteration: N=1 {t1:.3f}s vs "
          f"N=4 {t4:.3f}s (equal per-sampler work -> ~equal wall-clock)")
    print("N=4 collected", sum(l.samples for l in four),
          "samples vs", sum(l.samples for l in one), "for N=1 in that "
          "time — more experience per wall-clock iteration is the paper's "
          "Fig 3 claim")
    fused = run_fused()
    t_f = sum(l.learn_time for l in fused) / len(fused)
    t_s = sum(l.collect_time + l.learn_time for l in one[1:]) / (len(one) - 1)
    print(f"\nfused whole-iteration time {t_f:.3f}s/iter vs stepped "
          f"{t_s:.3f}s/iter at this batch; the fused engine's single "
          f"dispatch per chunk pays off as per-iteration device work "
          f"shrinks (see benchmarks/fused_vs_stepped.py for the "
          f"dispatch-bound regime)")
