"""Quickstart: WALL-E's experiment in miniature.

PPO on a pure-JAX pendulum with N=4 parallel samplers vs N=1, printing the
per-iteration collection/learning split — the paper's Figs 3/6 story in
~2 minutes on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro import envs
from repro.algos.ppo import PPOConfig, make_mlp_learner
from repro.core import SyncRunner
from repro.core import sampler as S
from repro.models import mlp_policy
from repro.optim import adam


def run(num_samplers: int, iterations: int = 8):
    env = envs.make("pendulum")
    key = jax.random.PRNGKey(0)
    params = mlp_policy.init_policy(key, env.obs_dim, env.act_dim, 64)
    opt = adam(1e-3)
    learn = make_mlp_learner(opt, PPOConfig(epochs=4, minibatches=4))
    rollout = S.make_env_rollout(env, horizon=200)
    carries = [S.init_env_carry(env, jax.random.PRNGKey(1 + i), 8)
               for i in range(num_samplers)]
    runner = SyncRunner(rollout, learn, params, opt.init(params), carries,
                        num_samplers)
    logs = runner.run(iterations)
    print(f"\n=== N={num_samplers} parallel samplers ===")
    for log in logs:
        print(f"iter {log.iteration}: return={log.mean_return:8.1f}  "
              f"collect={log.collect_time:.3f}s "
              f"(serial-equivalent {log.collect_time_serial:.3f}s)  "
              f"learn={log.learn_time:.3f}s  samples={log.samples}")
    return logs


if __name__ == "__main__":
    one = run(1)
    four = run(4)
    t1 = sum(l.collect_time for l in one[1:])
    t4 = sum(l.collect_time for l in four[1:])
    print(f"\ncollection critical path per iteration: N=1 {t1:.3f}s vs "
          f"N=4 {t4:.3f}s (equal per-sampler work -> ~equal wall-clock)")
    print("N=4 collected", sum(l.samples for l in four),
          "samples vs", sum(l.samples for l in one), "for N=1 in that "
          "time — more experience per wall-clock iteration is the paper's "
          "Fig 3 claim")
