"""Quickstart: WALL-E's experiment in miniature, via the unified API.

One declarative ``ExperimentSpec`` names the whole experiment — env, algo,
backend, runtime, model, schedule — and ``repro.experiment.run`` is the
single entry point. Swap ``algo="ppo"`` for ``"trpo"`` / ``"ddpg"`` or
``backend="inline"`` for ``"threaded"`` / ``"sharded"`` and nothing else
changes: every algorithm rides every backend through the ``Algorithm``
protocol (DESIGN.md §3).

Here: PPO on a pure-JAX pendulum with N=4 parallel samplers vs N=1,
printing the per-iteration collection/learning split — the paper's
Figs 3/6 story in ~2 minutes on CPU — then the fused runtime: the same
iterations under a single jit dispatch (no host round-trips at all).

  PYTHONPATH=src python examples/quickstart.py
"""
from repro import experiment
from repro.experiment import ExperimentSpec, Schedule


def spec_for(num_samplers: int, iterations: int = 8, backend: str = "inline",
             runtime: str = "sync", batch: int = 8,
             horizon: int = 200) -> ExperimentSpec:
    return ExperimentSpec(
        env="pendulum", algo="ppo", backend=backend, runtime=runtime,
        model={"hidden": 64},
        algo_kwargs={"lr": 1e-3, "epochs": 4, "minibatches": 4},
        schedule=Schedule(num_samplers=num_samplers,
                          global_batch=batch * num_samplers,
                          horizon=horizon, iterations=iterations, seed=0),
    )


def run(num_samplers: int, iterations: int = 8, backend: str = "inline"):
    result = experiment.run(spec_for(num_samplers, iterations, backend))
    print(f"\n=== N={num_samplers} parallel samplers ({backend}) ===")
    for log in result.logs:
        print(f"iter {log.iteration}: return={log.mean_return:8.1f}  "
              f"collect={log.collect_time:.3f}s "
              f"(serial-equivalent {log.collect_time_serial:.3f}s)  "
              f"learn={log.learn_time:.3f}s  samples={log.samples}")
    return result.logs


def run_fused(iterations: int = 8):
    spec = spec_for(1, iterations, runtime="fused")
    runner = experiment.build(spec)
    runner.run(iterations)                 # compile the chunk once
    logs = runner.run(iterations)[iterations:]
    print(f"\n=== fused engine (1 dispatch for {iterations} iterations) ===")
    for log in logs:
        print(f"iter {log.iteration}: return={log.mean_return:8.1f}  "
              f"iter_time={log.learn_time:.3f}s  samples={log.samples}")
    return logs


if __name__ == "__main__":
    one = run(1)
    four = run(4)
    t1 = sum(l.collect_time for l in one[1:])
    t4 = sum(l.collect_time for l in four[1:])
    print(f"\ncollection critical path per iteration: N=1 {t1:.3f}s vs "
          f"N=4 {t4:.3f}s (equal per-sampler work -> ~equal wall-clock)")
    print("N=4 collected", sum(l.samples for l in four),
          "samples vs", sum(l.samples for l in one), "for N=1 in that "
          "time — more experience per wall-clock iteration is the paper's "
          "Fig 3 claim")
    fused = run_fused()
    t_f = sum(l.learn_time for l in fused) / len(fused)
    t_s = sum(l.collect_time + l.learn_time for l in one[1:]) / (len(one) - 1)
    print(f"\nfused whole-iteration time {t_f:.3f}s/iter vs stepped "
          f"{t_s:.3f}s/iter at this batch; the fused engine's single "
          f"dispatch per chunk pays off as per-iteration device work "
          f"shrinks (see benchmarks/fused_vs_stepped.py for the "
          f"dispatch-bound regime)")
