from repro.algos import ddpg, gae, ppo  # noqa: F401
