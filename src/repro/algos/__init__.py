from repro.algos import ddpg, gae, ppo, trpo  # noqa: F401

# The Algorithm protocol + registered adapters live in repro.algos.api;
# imported lazily (via registry autoload or an explicit import) to keep
# `import repro.algos` light.
