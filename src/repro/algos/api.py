"""The ``Algorithm`` protocol — one seam between learners and the runtime.

Every algorithm the framework can train is an object with three methods:

    init(key, env)                  -> (params, opt_state)
    learn(params, opt_state, batch) -> (params, opt_state, metrics) [jittable]
    act(params, obs, key)           -> (action, extras)             [per-obs]

``batch`` is whatever the experiment's **experience buffer** sampled: the
whole merged trajectory for on-policy algorithms (``fifo`` pass-through),
a flat replay minibatch (with ``discounts``/``weights``/``indices``) for
off-policy ones. The plane hooks connect the two:

* ``observe(buffer, state, traj)`` / ``sample(buffer, state, key)`` —
  how the algorithm pushes collected experience into its buffer and draws
  learner batches back out; defaults delegate straight to the buffer.
* ``default_buffer`` — the buffer kind a spec gets when it names none
  (``fifo`` on-policy, ``uniform`` off-policy).
* ``updates_per_collect`` — gradient steps per collected trajectory.
* ``transition_example(env)`` — the per-transition storage schema
  off-policy buffers allocate from.

``make_train_step`` composes an algorithm with a buffer into the single
jittable ``(params, opt_state, plane, traj) -> (params, opt_state, plane,
metrics)`` function every runner drives, where ``plane = (buffer_state,
sample_key)`` is runner-owned — buffer storage no longer hides inside
``opt_state`` (DDPG's old ring did; it now rides the plane like SAC's).

Plus declarative attributes the runtime uses to schedule the collection:
``make_rollout(env, horizon)``, ``step_keys`` / ``tail_keys`` (trajectory
layout -> PartitionSpecs for the sharded backend), ``needs_next_obs``
(off-policy algorithms record full transitions).

``SyncRunner``, ``AsyncOrchestrator`` and ``FusedRunner`` consume any
conforming object through this seam — that is what lets every algo run on
every backend (``repro.experiment``). Adapters for PPO, TRPO, DDPG and
SAC are registered under the ``"algo"`` registry kind.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp

from repro import registry
from repro.algos.ddpg import DDPGConfig, ddpg_update, explore_action, init_ddpg
from repro.algos.ppo import PPOConfig, make_mlp_learner
from repro.algos.staleness import STALENESS_OFF, StalenessConfig
from repro.algos.staleness import decay_weights as _decay_weights
from repro.algos.trpo import TRPOConfig, make_trpo_learner
from repro.core import sampler as sampler_mod
from repro.models import mlp_policy
from repro.optim import adam


@runtime_checkable
class Algorithm(Protocol):
    """What a learner must provide to ride the unified runtime."""

    name: str

    def init(self, key, env) -> Tuple[Any, Any]:
        """Build (params, opt_state) for ``env``."""
        ...

    def learn(self, params, opt_state, batch) -> Tuple[Any, Any, Dict]:
        """One update from a sampled batch. Must be jittable."""
        ...

    def act(self, params, obs, key) -> Tuple[jnp.ndarray, Dict]:
        """Action (+ per-step extras) for a single observation."""
        ...


class AlgorithmBase:
    """Default runtime + experience-plane hooks shared by the adapters."""

    name = "base"
    on_policy = True
    needs_next_obs = False
    step_keys: Tuple[str, ...] = ("obs", "actions", "rewards", "dones")
    tail_keys: Tuple[str, ...] = ()
    default_buffer = "fifo"
    updates_per_collect = 1
    # safe to wrap in the shard_map data-parallel learner: the algorithm's
    # ``learn`` routes every gradient through ``grad_sync.value_and_grad``
    # (TRPO's conjugate-gradient line search does not, so it opts out)
    shardable = True
    # importance-weighted staleness correction (algos/staleness.py): the
    # algorithm can consume the async runtime's per-trajectory params-
    # version gap and down-weight stale experience. Off (an inert config)
    # unless the experiment enables it through ``enable_staleness``.
    supports_staleness = False
    staleness: StalenessConfig = STALENESS_OFF

    def enable_staleness(self, cfg) -> None:
        """Install a staleness-correction config (mode string / dict /
        ``StalenessConfig``). A disabled config is always accepted (and
        is a no-op); an enabled one requires ``supports_staleness``."""
        cfg = StalenessConfig.parse(cfg)
        if cfg.enabled and not self.supports_staleness:
            raise ValueError(
                f"algorithm {self.name!r} does not support staleness "
                f"correction (supports_staleness=False) — its update has "
                f"no importance-weighting seam; use staleness mode 'off' "
                f"or a supporting algorithm (ppo, ddpg, sac)")
        self.staleness = cfg

    def make_rollout(self, env, horizon: int):
        return sampler_mod.make_algo_rollout(self, env, horizon)

    def rollout_tail(self, params, final_obs) -> Dict[str, jnp.ndarray]:
        return {}

    # ------------------------------------------- experience-plane hooks
    def observe(self, buffer, state, traj):
        """Push one collected trajectory into the buffer. Jittable."""
        return buffer.add(state, traj)

    def sample(self, buffer, state, key):
        """Draw one learner batch from the buffer. Jittable."""
        return buffer.sample(state, key)


class OffPolicyAlgorithm(AlgorithmBase):
    """Shared plane wiring for replay-based learners (DDPG, SAC):
    full transitions recorded at collect time, a transition-schema hook
    for buffer allocation, and per-update learner RNG threaded through
    the sampled batch as ``batch["rng"]``.

    Staleness correction (when enabled): the per-trajectory
    params-version gap is converted to a per-transition weight at
    *ingest* time (``observe`` — the gap is fixed once the transition
    enters replay), stored alongside the transition, and multiplied
    into the buffer's importance weights at ``sample`` time; DDPG/SAC
    critic losses already honor ``batch["weights"]``. Disabled, none of
    these keys exist and the plane is byte-identical to before."""

    on_policy = False
    needs_next_obs = True
    default_buffer = "uniform"
    updates_per_collect = 4
    step_keys = ("obs", "actions", "rewards", "dones", "next_obs")
    tail_keys: Tuple[str, ...] = ()
    supports_staleness = True

    def transition_example(self, env) -> Dict[str, jnp.ndarray]:
        """One zeroed transition — the storage schema buffers allocate."""
        ex = {
            "obs": jnp.zeros((1, env.obs_dim)),
            "actions": jnp.zeros((1, env.act_dim)),
            "rewards": jnp.zeros((1,)),
            "next_obs": jnp.zeros((1, env.obs_dim)),
            "dones": jnp.zeros((1,), bool),
        }
        if self.staleness.enabled:
            ex["staleness_w"] = jnp.zeros((1,))
        return ex

    def observe(self, buffer, state, traj):
        if self.staleness.enabled:
            traj = dict(traj)
            gap = traj.pop("staleness_gap", None)
            traj["staleness_w"] = (
                jnp.ones_like(traj["rewards"], dtype=jnp.float32)
                if gap is None           # lock-step paths record no gap
                else _decay_weights(self.staleness, gap))
        return buffer.add(state, traj)

    def sample(self, buffer, state, key):
        k_buf, k_learn = jax.random.split(key)
        batch = buffer.sample(state, k_buf)
        if "staleness_w" in batch:
            sw = batch.pop("staleness_w")
            batch["weights"] = batch.get("weights", 1.0) * sw
        batch["rng"] = k_learn          # stochastic learners (SAC) draw here
        return batch


# ==================================================== the composed step
def make_train_step(algo, buffer) -> Callable:
    """Fuse ``algo`` and ``buffer`` into the one jittable step runners
    drive:

        step(params, opt_state, plane, traj)
            -> (params, opt_state, plane, metrics)

    with ``plane = (buffer_state, key)`` owned by the runner (carried
    across iterations device-side — inside the fused engine's donated
    scan, across the sync/async learners' jit calls). Per call: observe
    the trajectory, then ``algo.updates_per_collect`` sample->learn steps
    under ``lax.scan``; learners that report per-sample ``priorities``
    get them routed into ``buffer.update_priorities``.

    For pass-through buffers (``fifo``) with one update per collect the
    step collapses to exactly the historical ``learn(params, opt_state,
    traj)`` call — no scan, no PRNG consumption — which keeps ``ppo`` ×
    ``inline`` bitwise-identical to the pre-plane path.
    """
    updates = int(getattr(algo, "updates_per_collect", 1))

    if getattr(buffer, "passthrough", False) and updates == 1:
        def step(params, opt_state, plane, traj):
            buf_state, key = plane
            buf_state = algo.observe(buffer, buf_state, traj)
            batch = algo.sample(buffer, buf_state, key)
            params, opt_state, metrics = algo.learn(params, opt_state,
                                                    batch)
            return params, opt_state, (buf_state, key), metrics
        return step

    def step(params, opt_state, plane, traj):
        buf_state, key = plane
        buf_state = algo.observe(buffer, buf_state, traj)
        keys = jax.random.split(key, updates + 1)

        def one(carry, k):
            params, opt_state, buf_state = carry
            batch = algo.sample(buffer, buf_state, k)
            params, opt_state, metrics = algo.learn(params, opt_state,
                                                    batch)
            metrics = dict(metrics)
            priorities = metrics.pop("priorities", None)
            if priorities is not None:
                buf_state = buffer.update_priorities(
                    buf_state, batch["indices"], priorities)
            return (params, opt_state, buf_state), metrics

        (params, opt_state, buf_state), metrics = jax.lax.scan(
            one, (params, opt_state, buf_state), keys[1:])
        return (params, opt_state, (buf_state, keys[0]),
                jax.tree.map(jnp.mean, metrics))

    return step


# ======================================================== PPO-family base
class GaussianMLPAlgorithm(AlgorithmBase):
    """Shared hooks for algorithms on the paper's Gaussian-MLP policy +
    value model (PPO, TRPO): same params structure, same trajectory
    layout (behaviour logp + values + GAE bootstrap), same rollout."""

    step_keys = ("obs", "actions", "rewards", "dones", "logp", "values")
    tail_keys = ("last_value",)

    hidden: int = 64

    def _init_policy(self, key, env):
        return mlp_policy.init_policy(key, env.obs_dim, env.act_dim,
                                      hidden=self.hidden)

    def act(self, params, obs, key):
        action, logp = mlp_policy.sample_action(params, obs, key)
        return action, {"logp": logp,
                        "values": mlp_policy.value_apply(params, obs)}

    def make_rollout(self, env, horizon: int):
        # the historical rollout, verbatim: keeps ppo x inline bitwise-
        # identical to the pre-refactor SyncRunner path
        return sampler_mod.make_env_rollout(env, horizon)

    def rollout_tail(self, params, final_obs):
        return {"last_value": mlp_policy.value_apply(params, final_obs)}


# ===================================================================== PPO
class PPOAlgorithm(GaussianMLPAlgorithm):
    """Clipped-surrogate PPO with the paper's Gaussian-MLP policy."""

    name = "ppo"
    supports_staleness = True

    def __init__(self, lr: float = 3e-4, hidden: int = 64, **cfg_kwargs):
        self.cfg = PPOConfig(lr=lr, **cfg_kwargs)
        self.hidden = hidden
        self._opt = adam(self.cfg.lr)
        self._learn = make_mlp_learner(self._opt, self.cfg)

    def enable_staleness(self, cfg) -> None:
        super().enable_staleness(cfg)
        if self.staleness.enabled:      # weighted advantage path
            self._learn = make_mlp_learner(self._opt, self.cfg,
                                           staleness=self.staleness)

    def init(self, key, env):
        params = self._init_policy(key, env)
        return params, self._opt.init(params)

    def learn(self, params, opt_state, traj):
        return self._learn(params, opt_state, traj)


# ==================================================================== TRPO
class TRPOAlgorithm(GaussianMLPAlgorithm):
    """Natural-gradient TRPO; same policy/value model and trajectory
    layout as PPO, so it shares the PPO rollout."""

    name = "trpo"
    shardable = False               # CG/line-search grads bypass grad_sync

    def __init__(self, lr: float = None, hidden: int = 64, **cfg_kwargs):
        if lr is not None:
            cfg_kwargs.setdefault("vf_lr", lr)
        self.cfg = TRPOConfig(**cfg_kwargs)
        self.hidden = hidden
        self._learn = make_trpo_learner(self.cfg)

    def init(self, key, env):
        return self._init_policy(key, env), None   # no optimizer state

    def learn(self, params, opt_state, traj):
        return self._learn(params, opt_state, traj)


# ==================================================================== DDPG
class DDPGAlgorithm(OffPolicyAlgorithm):
    """Off-policy DDPG on the experience plane: the collect path records
    full transitions (``next_obs``) and each ``learn`` call consumes one
    replay minibatch the plane sampled (uniform or prioritized, any
    ``n_step``).

    ``opt_state`` is now *only* the two Adam states — the replay ring it
    used to smuggle lives in the runner-owned plane state, so capacity /
    batch size / n-step are experiment-level choices
    (``ExperimentSpec.buffer_kwargs``), not algorithm constructor args.
    """

    name = "ddpg"

    def __init__(self, lr: float = None, hidden: int = 64,
                 updates_per_collect: int = 4, **cfg_kwargs):
        if lr is not None:
            cfg_kwargs.setdefault("actor_lr", lr)
            cfg_kwargs.setdefault("critic_lr", lr)
        self.cfg = DDPGConfig(**cfg_kwargs)
        self.hidden = hidden
        self.updates_per_collect = updates_per_collect
        self._a_opt = adam(self.cfg.actor_lr)
        self._c_opt = adam(self.cfg.critic_lr)

    def init(self, key, env):
        params = init_ddpg(key, env.obs_dim, env.act_dim,
                           hidden=self.hidden)
        return params, (self._a_opt.init(params["actor"]),
                        self._c_opt.init(params["critic"]))

    def learn(self, params, opt_state, batch):
        params, opt_state, metrics = ddpg_update(
            params, opt_state, batch, self.cfg, self._a_opt, self._c_opt)
        return params, opt_state, metrics

    def act(self, params, obs, key):
        return explore_action(params, obs, key, self.cfg), {}


def _make_sac(**kwargs):
    # lazy so api <-> sac imports never cycle (sac subclasses
    # OffPolicyAlgorithm from this module)
    from repro.algos.sac import SACAlgorithm
    return SACAlgorithm(**kwargs)


registry.register("algo", "ppo", PPOAlgorithm)
registry.register("algo", "trpo", TRPOAlgorithm)
registry.register("algo", "ddpg", DDPGAlgorithm)
registry.register("algo", "sac", _make_sac)
