"""The ``Algorithm`` protocol — one seam between learners and the runtime.

Every algorithm the framework can train is an object with three methods:

    init(key, env)                 -> (params, opt_state)
    learn(params, opt_state, traj) -> (params, opt_state, metrics)   [jittable]
    act(params, obs, key)          -> (action, extras)               [per-obs]

plus declarative attributes the runtime uses to schedule it:

* ``make_rollout(env, horizon)`` — the experience-collection function the
  backends run. The default builds ``sampler.make_algo_rollout`` around
  ``act``; the PPO family overrides it with the historical
  ``make_env_rollout`` so refactoring changed no numerics.
* ``step_keys`` / ``tail_keys`` — the trajectory layout (per-step arrays
  vs end-of-rollout arrays), which the sharded backend turns into
  PartitionSpecs.
* ``needs_next_obs`` — off-policy algorithms record ``next_obs`` so their
  replay buffer can store full transitions.

``SyncRunner``, ``AsyncOrchestrator`` and ``FusedRunner`` consume any
conforming object through this seam — that is what lets every algo run on
every backend (``repro.experiment``). Adapters for PPO, TRPO and DDPG are
registered under the ``"algo"`` registry kind.
"""
from __future__ import annotations

from typing import Any, Dict, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp

from repro import registry
from repro.algos.ddpg import DDPGConfig, ddpg_update, explore_action, init_ddpg
from repro.algos.ppo import PPOConfig, make_mlp_learner
from repro.algos.trpo import TRPOConfig, make_trpo_learner
from repro.core import sampler as sampler_mod
from repro.data.replay import add_batch, init_replay, sample
from repro.models import mlp_policy
from repro.optim import adam


@runtime_checkable
class Algorithm(Protocol):
    """What a learner must provide to ride the unified runtime."""

    name: str

    def init(self, key, env) -> Tuple[Any, Any]:
        """Build (params, opt_state) for ``env``."""
        ...

    def learn(self, params, opt_state, traj) -> Tuple[Any, Any, Dict]:
        """One update from a trajectory batch. Must be jittable."""
        ...

    def act(self, params, obs, key) -> Tuple[jnp.ndarray, Dict]:
        """Action (+ per-step extras) for a single observation."""
        ...


class AlgorithmBase:
    """Default runtime hooks shared by the shipped adapters."""

    name = "base"
    on_policy = True
    needs_next_obs = False
    step_keys: Tuple[str, ...] = ("obs", "actions", "rewards", "dones")
    tail_keys: Tuple[str, ...] = ()

    def make_rollout(self, env, horizon: int):
        return sampler_mod.make_algo_rollout(self, env, horizon)

    def rollout_tail(self, params, final_obs) -> Dict[str, jnp.ndarray]:
        return {}


# ======================================================== PPO-family base
class GaussianMLPAlgorithm(AlgorithmBase):
    """Shared hooks for algorithms on the paper's Gaussian-MLP policy +
    value model (PPO, TRPO): same params structure, same trajectory
    layout (behaviour logp + values + GAE bootstrap), same rollout."""

    step_keys = ("obs", "actions", "rewards", "dones", "logp", "values")
    tail_keys = ("last_value",)

    hidden: int = 64

    def _init_policy(self, key, env):
        return mlp_policy.init_policy(key, env.obs_dim, env.act_dim,
                                      hidden=self.hidden)

    def act(self, params, obs, key):
        action, logp = mlp_policy.sample_action(params, obs, key)
        return action, {"logp": logp,
                        "values": mlp_policy.value_apply(params, obs)}

    def make_rollout(self, env, horizon: int):
        # the historical rollout, verbatim: keeps ppo x inline bitwise-
        # identical to the pre-refactor SyncRunner path
        return sampler_mod.make_env_rollout(env, horizon)

    def rollout_tail(self, params, final_obs):
        return {"last_value": mlp_policy.value_apply(params, final_obs)}


# ===================================================================== PPO
class PPOAlgorithm(GaussianMLPAlgorithm):
    """Clipped-surrogate PPO with the paper's Gaussian-MLP policy."""

    name = "ppo"

    def __init__(self, lr: float = 3e-4, hidden: int = 64, **cfg_kwargs):
        self.cfg = PPOConfig(lr=lr, **cfg_kwargs)
        self.hidden = hidden
        self._opt = adam(self.cfg.lr)
        self._learn = make_mlp_learner(self._opt, self.cfg)

    def init(self, key, env):
        params = self._init_policy(key, env)
        return params, self._opt.init(params)

    def learn(self, params, opt_state, traj):
        return self._learn(params, opt_state, traj)


# ==================================================================== TRPO
class TRPOAlgorithm(GaussianMLPAlgorithm):
    """Natural-gradient TRPO; same policy/value model and trajectory
    layout as PPO, so it shares the PPO rollout."""

    name = "trpo"

    def __init__(self, lr: float = None, hidden: int = 64, **cfg_kwargs):
        if lr is not None:
            cfg_kwargs.setdefault("vf_lr", lr)
        self.cfg = TRPOConfig(**cfg_kwargs)
        self.hidden = hidden
        self._learn = make_trpo_learner(self.cfg)

    def init(self, key, env):
        return self._init_policy(key, env), None   # no optimizer state

    def learn(self, params, opt_state, traj):
        return self._learn(params, opt_state, traj)


# ==================================================================== DDPG
class DDPGAlgorithm(AlgorithmBase):
    """Off-policy DDPG: the collect path records full transitions
    (``next_obs``) and ``learn`` pushes them through a replay ring before
    drawing uniform minibatches — the paper's §6 further-work item, now a
    first-class citizen of every backend.

    The replay state and the sampling PRNG live inside ``opt_state`` so
    the runners (which treat opt_state opaquely) carry them across
    iterations — including on-device across fused chunks.
    """

    name = "ddpg"
    on_policy = False
    needs_next_obs = True

    step_keys = ("obs", "actions", "rewards", "dones", "next_obs")
    tail_keys = ()

    def __init__(self, lr: float = None, hidden: int = 64,
                 replay_capacity: int = 50_000, batch_size: int = 128,
                 updates_per_collect: int = 4, **cfg_kwargs):
        if lr is not None:
            cfg_kwargs.setdefault("actor_lr", lr)
            cfg_kwargs.setdefault("critic_lr", lr)
        self.cfg = DDPGConfig(**cfg_kwargs)
        self.hidden = hidden
        self.replay_capacity = replay_capacity
        self.batch_size = batch_size
        self.updates_per_collect = updates_per_collect
        self._a_opt = adam(self.cfg.actor_lr)
        self._c_opt = adam(self.cfg.critic_lr)

    def init(self, key, env):
        k_net, k_sample = jax.random.split(key)
        params = init_ddpg(k_net, env.obs_dim, env.act_dim,
                           hidden=self.hidden)
        example = {
            "obs": jnp.zeros((1, env.obs_dim)),
            "actions": jnp.zeros((1, env.act_dim)),
            "rewards": jnp.zeros((1,)),
            "next_obs": jnp.zeros((1, env.obs_dim)),
            "dones": jnp.zeros((1,), bool),
        }
        opt_state = (self._a_opt.init(params["actor"]),
                     self._c_opt.init(params["critic"]),
                     init_replay(self.replay_capacity, example),
                     k_sample)
        return params, opt_state

    def learn(self, params, opt_state, traj):
        a_state, c_state, replay, key = opt_state
        flat = {k: traj[k].reshape((-1,) + traj[k].shape[2:])
                for k in self.step_keys}
        replay = add_batch(replay, flat)
        keys = jax.random.split(key, self.updates_per_collect + 1)

        def update(carry, k):
            params, a_state, c_state = carry
            batch = sample(replay, k, self.batch_size)
            params, (a_state, c_state), metrics = ddpg_update(
                params, (a_state, c_state), batch, self.cfg,
                self._a_opt, self._c_opt)
            return (params, a_state, c_state), metrics

        (params, a_state, c_state), metrics = jax.lax.scan(
            update, (params, a_state, c_state), keys[1:])
        return (params, (a_state, c_state, replay, keys[0]),
                jax.tree.map(jnp.mean, metrics))

    def act(self, params, obs, key):
        return explore_action(params, obs, key, self.cfg), {}


registry.register("algo", "ppo", PPOAlgorithm)
registry.register("algo", "trpo", TRPOAlgorithm)
registry.register("algo", "ddpg", DDPGAlgorithm)
