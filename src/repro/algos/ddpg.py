"""DDPG with replay buffer — the paper's §6 "further work" item 1.

Off-policy learning benefits even more from parallel experience collection
(the paper's own argument); samplers fill a shared replay buffer and the
learner draws uniform minibatches asynchronously.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import grad_sync
from repro.models.mlp_policy import init_mlp_net, mlp_apply
from repro.optim import apply_updates


@dataclasses.dataclass(frozen=True)
class DDPGConfig:
    actor_lr: float = 1e-3
    critic_lr: float = 1e-3
    gamma: float = 0.99
    tau: float = 0.005              # polyak target update
    noise_std: float = 0.1


def init_ddpg(key, obs_dim: int, act_dim: int, hidden: int = 64) -> Dict:
    ka, kc = jax.random.split(key)
    actor = init_mlp_net(ka, [obs_dim, hidden, hidden, act_dim])
    critic = init_mlp_net(kc, [obs_dim + act_dim, hidden, hidden, 1])
    return {
        "actor": actor,
        "critic": critic,
        "target_actor": jax.tree.map(jnp.copy, actor),
        "target_critic": jax.tree.map(jnp.copy, critic),
    }


def actor_apply(net, obs) -> jnp.ndarray:
    return jnp.tanh(mlp_apply(net, obs))


def critic_apply(net, obs, act) -> jnp.ndarray:
    return mlp_apply(net, jnp.concatenate([obs, act], axis=-1))[..., 0]


def explore_action(params, obs, key, cfg: DDPGConfig) -> jnp.ndarray:
    a = actor_apply(params["actor"], obs)
    return jnp.clip(a + cfg.noise_std * jax.random.normal(key, a.shape),
                    -1.0, 1.0)


def ddpg_update(params, opt_states, batch, cfg: DDPGConfig,
                actor_opt, critic_opt) -> Tuple[Dict, Tuple, Dict]:
    """One gradient step on a replay minibatch.

    batch: obs, actions, rewards, next_obs — all (N, ...) — plus either
    per-transition ``discounts`` (the experience plane's n-step bootstrap
    factor, gamma^n or 0 past a terminal) or plain ``dones`` (legacy
    1-step form: the discount is then ``gamma * (1 - dones)``). Optional
    ``weights`` (N,) importance-weight the critic regression (prioritized
    replay); metrics always carry per-sample ``priorities`` (|TD error|)
    for the buffer to absorb.
    """
    def critic_loss(cnet, b):
        # targets are recomputed per (micro)batch slice — elementwise
        # identical to the historical whole-batch form, and what lets the
        # sharded learner (grad_sync) slice/shard this loss freely
        if "discounts" in b:
            discounts = b["discounts"]
        else:
            discounts = cfg.gamma * (1.0 - b["dones"].astype(jnp.float32))
        weights = b.get("weights", jnp.ones_like(b["rewards"]))
        a_next = actor_apply(params["target_actor"], b["next_obs"])
        q_next = critic_apply(params["target_critic"], b["next_obs"], a_next)
        target = b["rewards"] + discounts * q_next
        q = critic_apply(cnet, b["obs"], b["actions"])
        loss = jnp.mean(weights * (q - jax.lax.stop_gradient(target)) ** 2)
        return loss, (q, jax.lax.stop_gradient(target))

    (c_loss, (q_pre, target)), c_grads = grad_sync.value_and_grad(
        critic_loss, params["critic"], batch, has_aux=True)
    c_upd, c_state = critic_opt.update(c_grads, opt_states[1],
                                       params["critic"])
    critic = apply_updates(params["critic"], c_upd)

    def actor_loss(anet, b):
        a = actor_apply(anet, b["obs"])
        return -jnp.mean(critic_apply(critic, b["obs"], a))

    a_loss, a_grads = grad_sync.value_and_grad(
        actor_loss, params["actor"], batch)
    a_upd, a_state = actor_opt.update(a_grads, opt_states[0],
                                      params["actor"])
    actor = apply_updates(params["actor"], a_upd)

    polyak = lambda t, s: jax.tree.map(
        lambda a, b: (1 - cfg.tau) * a + cfg.tau * b, t, s)
    new_params = {
        "actor": actor,
        "critic": critic,
        "target_actor": polyak(params["target_actor"], actor),
        "target_critic": polyak(params["target_critic"], critic),
    }
    metrics = {"critic_loss": c_loss, "actor_loss": a_loss,
               "q_mean": jnp.mean(target),
               "priorities": jax.lax.stop_gradient(jnp.abs(q_pre - target))}
    return new_params, (a_state, c_state), metrics
