"""Generalised Advantage Estimation — the learner-facing entry point.

The recurrence itself lives in the kernel plane
(``repro.kernels.gae``): a pure-JAX reverse-scan reference plus a
chunked Pallas kernel, selected per experiment through
``kernels.select`` (``ExperimentSpec.kernels`` / ``--kernels``). With
the ref selection — the CPU default — this module is bitwise-identical
to the historical sequential ``lax.scan`` implementation.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.kernels.gae import discounted_returns, gae as _gae_op  # noqa: F401


def gae(rewards: jnp.ndarray, values: jnp.ndarray, dones: jnp.ndarray,
        last_value: jnp.ndarray, gamma: float = 0.99, lam: float = 0.95,
        *, impl: Optional[str] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Compute advantages + returns.

    rewards/values/dones: (T, ...) time-major; last_value: (...) bootstrap.
    ``dones[t]`` marks that the episode ended *at* step t (no bootstrap
    across the boundary). Returns (advantages, returns), both (T, ...).
    """
    return _gae_op(rewards, values, dones, last_value, gamma, lam,
                   impl=impl)


def normalize(adv: jnp.ndarray, eps: float = 1e-8) -> jnp.ndarray:
    """Standardise advantages over the *global* batch.

    Inside a sharded learner trace (``grad_sync.activate``) each shard
    only holds its batch slice, so the mean/variance are pmean'd across
    the data axes — every shard normalises by the same global statistics,
    matching what a single device would compute over the full batch (up
    to reduction order). Outside that context this is bitwise the
    historical ``(adv - mean) / (std + eps)``.
    """
    from repro.distributed import grad_sync
    axes = grad_sync.reduce_axes()
    if axes is None:
        return (adv - jnp.mean(adv)) / (jnp.std(adv) + eps)
    import jax
    m = jax.lax.pmean(jnp.mean(adv), axes)
    var = jax.lax.pmean(jnp.mean((adv - m) ** 2), axes)
    return (adv - m) / (jnp.sqrt(var) + eps)
