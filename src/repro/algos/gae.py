"""Generalised Advantage Estimation (reverse-scan, jittable)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def gae(rewards: jnp.ndarray, values: jnp.ndarray, dones: jnp.ndarray,
        last_value: jnp.ndarray, gamma: float = 0.99, lam: float = 0.95
        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Compute advantages + returns.

    rewards/values/dones: (T, ...) time-major; last_value: (...) bootstrap.
    ``dones[t]`` marks that the episode ended *at* step t (no bootstrap
    across the boundary). Returns (advantages, returns), both (T, ...).
    """
    nonterm = 1.0 - dones.astype(jnp.float32)

    def step(carry, xs):
        adv_next, v_next = carry
        r, v, nt = xs
        delta = r + gamma * v_next * nt - v
        adv = delta + gamma * lam * nt * adv_next
        return (adv, v), adv

    init = (jnp.zeros_like(last_value), last_value)
    _, advs = jax.lax.scan(step, init, (rewards, values, nonterm),
                           reverse=True)
    return advs, advs + values


def normalize(adv: jnp.ndarray, eps: float = 1e-8) -> jnp.ndarray:
    return (adv - jnp.mean(adv)) / (jnp.std(adv) + eps)
