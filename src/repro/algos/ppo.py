"""Proximal Policy Optimization — the learner in WALL-E's agent processor.

Two instantiations share the same clipped-surrogate math:
* ``mlp_ppo_*`` — Gaussian-MLP policy on continuous-control envs (the
  paper's experimental setup);
* ``lm_ppo_loss`` — token-level PPO on a sequence-model policy (the
  RLHF-style workload the assigned architectures serve; this is what
  ``train_4k`` lowers in the dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.algos import gae as gae_mod
from repro.distributed import grad_sync
from repro.models import mlp_policy, transformer
from repro.optim import adam, apply_updates, clip_by_global_norm


@dataclasses.dataclass(frozen=True)
class PPOConfig:
    lr: float = 3e-4
    clip_eps: float = 0.2
    value_coef: float = 0.5
    entropy_coef: float = 0.01
    gamma: float = 0.99
    lam: float = 0.95
    epochs: int = 4
    minibatches: int = 4
    max_grad_norm: float = 0.5
    aux_coef: float = 0.01          # MoE router load-balance weight


def clipped_surrogate(logp, behavior_logp, adv, clip_eps) -> jnp.ndarray:
    ratio = jnp.exp(logp - behavior_logp)
    return -jnp.minimum(ratio * adv,
                        jnp.clip(ratio, 1 - clip_eps, 1 + clip_eps) * adv)


# ============================================================ MLP policy PPO
def mlp_ppo_loss(params, batch: Dict[str, jnp.ndarray], cfg: PPOConfig):
    logp = mlp_policy.action_logp(params, batch["obs"], batch["actions"])
    pg = jnp.mean(clipped_surrogate(logp, batch["behavior_logp"],
                                    batch["advantages"], cfg.clip_eps))
    v = mlp_policy.value_apply(params, batch["obs"])
    v_loss = 0.5 * jnp.mean((v - batch["returns"]) ** 2)
    ent = mlp_policy.entropy(params)
    loss = pg + cfg.value_coef * v_loss - cfg.entropy_coef * ent
    metrics = {"loss": loss, "pg_loss": pg, "v_loss": v_loss, "entropy": ent,
               "approx_kl": jnp.mean(batch["behavior_logp"] - logp)}
    return loss, metrics


def mlp_ppo_update(params, opt_state, batch, cfg: PPOConfig, optimizer):
    """One epoch of minibatched PPO on a flat (N, ...) batch."""
    n = batch["obs"].shape[0]
    mb = n // cfg.minibatches
    perm_batch = jax.tree.map(lambda x: x[:mb * cfg.minibatches], batch)

    def mb_step(carry, idx):
        params, opt_state = carry
        sl = jax.tree.map(
            lambda x: jax.lax.dynamic_slice_in_dim(x, idx * mb, mb), perm_batch)
        (loss, metrics), grads = grad_sync.value_and_grad(
            lambda p, b: mlp_ppo_loss(p, b, cfg), params, sl, has_aux=True)
        grads, gnorm = clip_by_global_norm(grads, cfg.max_grad_norm)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics["grad_norm"] = gnorm
        return (params, opt_state), metrics

    (params, opt_state), metrics = jax.lax.scan(
        mb_step, (params, opt_state), jnp.arange(cfg.minibatches))
    return params, opt_state, jax.tree.map(jnp.mean, metrics)


def make_mlp_learner(optimizer, cfg: PPOConfig):
    """jit-ready multi-epoch PPO update from a trajectory batch."""

    def learn(params, opt_state, traj: Dict[str, jnp.ndarray]):
        # traj arrays: (T, B, ...) time-major from the sampler
        adv, ret = gae_mod.gae(traj["rewards"], traj["values"],
                               traj["dones"], traj["last_value"],
                               cfg.gamma, cfg.lam)
        batch = {
            "obs": traj["obs"],
            "actions": traj["actions"],
            "behavior_logp": traj["logp"],
            "advantages": gae_mod.normalize(adv),
            "returns": ret,
        }
        flat = jax.tree.map(
            lambda x: x.reshape((-1,) + x.shape[2:]), batch)

        def epoch(carry, _):
            params, opt_state = carry
            params, opt_state, metrics = mlp_ppo_update(
                params, opt_state, flat, cfg, optimizer)
            return (params, opt_state), metrics

        (params, opt_state), metrics = jax.lax.scan(
            epoch, (params, opt_state), None, length=cfg.epochs)
        return params, opt_state, jax.tree.map(jnp.mean, metrics)

    return learn


# ======================================================== LM (token) PPO
def lm_ppo_loss(model_cfg, params, batch: Dict[str, jnp.ndarray],
                cfg: PPOConfig, *, impl: str = "reference",
                remat: str = "full"):
    """Token-level PPO loss for a sequence-model policy.

    batch: tokens (B,S) int32 — input context; targets (B,S) — actions
    (next tokens); behavior_logp, advantages, returns, mask (B,S) f32.
    This is the exact computation ``train_4k`` lowers in the dry-run.
    """
    h, aux = transformer.forward(
        model_cfg, params, batch["tokens"],
        extra_embeds=batch.get("extra_embeds"),
        positions=batch.get("positions"), impl=impl, remat=remat)
    S = batch["targets"].shape[1]
    h = h[:, -S:]                                   # drop prefix positions
    logp, ent = transformer.token_logp_entropy(model_cfg, params, h,
                                               batch["targets"])
    v = transformer.value(model_cfg, params, h)
    mask = batch["mask"]
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    pg = jnp.sum(clipped_surrogate(logp, batch["behavior_logp"],
                                   batch["advantages"], cfg.clip_eps)
                 * mask) / denom
    v_loss = 0.5 * jnp.sum((v - batch["returns"]) ** 2 * mask) / denom
    ent_mean = jnp.sum(ent * mask) / denom
    loss = (pg + cfg.value_coef * v_loss - cfg.entropy_coef * ent_mean
            + cfg.aux_coef * aux)
    metrics = {"loss": loss, "pg_loss": pg, "v_loss": v_loss,
               "entropy": ent_mean, "aux": aux}
    return loss, metrics


def make_lm_train_step(model_cfg, optimizer, cfg: PPOConfig,
                       impl: str = "reference", remat: str = "full"):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: lm_ppo_loss(model_cfg, p, batch, cfg, impl=impl,
                                  remat=remat), has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, cfg.max_grad_norm)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics["grad_norm"] = gnorm
        return params, opt_state, metrics

    return train_step
