"""Proximal Policy Optimization — the learner in WALL-E's agent processor.

Two instantiations share the same clipped-surrogate math:
* ``mlp_ppo_*`` — Gaussian-MLP policy on continuous-control envs (the
  paper's experimental setup);
* ``lm_ppo_loss`` — token-level PPO on a sequence-model policy (the
  RLHF-style workload the assigned architectures serve; this is what
  ``train_4k`` lowers in the dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.algos import gae as gae_mod
from repro.distributed import grad_sync
from repro.models import mlp_policy, transformer
from repro.optim import adam, apply_updates, clip_by_global_norm


@dataclasses.dataclass(frozen=True)
class PPOConfig:
    lr: float = 3e-4
    clip_eps: float = 0.2
    value_coef: float = 0.5
    entropy_coef: float = 0.01
    gamma: float = 0.99
    lam: float = 0.95
    epochs: int = 4
    minibatches: int = 4
    max_grad_norm: float = 0.5
    aux_coef: float = 0.01          # MoE router load-balance weight


def clipped_surrogate(logp, behavior_logp, adv, clip_eps) -> jnp.ndarray:
    ratio = jnp.exp(logp - behavior_logp)
    return -jnp.minimum(ratio * adv,
                        jnp.clip(ratio, 1 - clip_eps, 1 + clip_eps) * adv)


# ============================================================ MLP policy PPO
def mlp_ppo_loss(params, batch: Dict[str, jnp.ndarray], cfg: PPOConfig):
    """Clipped-surrogate loss; an optional per-sample ``weights`` key
    (staleness correction, DESIGN.md §10) scales both the surrogate and
    the value error. Without the key the math is the historical,
    bitwise-stable computation — the key's mere absence IS the exact-off
    guarantee, so nothing here may touch the no-weights path."""
    logp = mlp_policy.action_logp(params, batch["obs"], batch["actions"])
    surrogate = clipped_surrogate(logp, batch["behavior_logp"],
                                  batch["advantages"], cfg.clip_eps)
    v = mlp_policy.value_apply(params, batch["obs"])
    w = batch.get("weights")
    if w is None:
        pg = jnp.mean(surrogate)
        v_loss = 0.5 * jnp.mean((v - batch["returns"]) ** 2)
    else:
        pg = jnp.mean(w * surrogate)
        v_loss = 0.5 * jnp.mean(w * (v - batch["returns"]) ** 2)
    ent = mlp_policy.entropy(params)
    loss = pg + cfg.value_coef * v_loss - cfg.entropy_coef * ent
    metrics = {"loss": loss, "pg_loss": pg, "v_loss": v_loss, "entropy": ent,
               "approx_kl": jnp.mean(batch["behavior_logp"] - logp)}
    return loss, metrics


def mlp_ppo_update(params, opt_state, batch, cfg: PPOConfig, optimizer):
    """One epoch of minibatched PPO on a flat (N, ...) batch."""
    n = batch["obs"].shape[0]
    mb = n // cfg.minibatches
    perm_batch = jax.tree.map(lambda x: x[:mb * cfg.minibatches], batch)

    def mb_step(carry, idx):
        params, opt_state = carry
        sl = jax.tree.map(
            lambda x: jax.lax.dynamic_slice_in_dim(x, idx * mb, mb), perm_batch)
        (loss, metrics), grads = grad_sync.value_and_grad(
            lambda p, b: mlp_ppo_loss(p, b, cfg), params, sl, has_aux=True)
        grads, gnorm = clip_by_global_norm(grads, cfg.max_grad_norm)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics["grad_norm"] = gnorm
        return (params, opt_state), metrics

    (params, opt_state), metrics = jax.lax.scan(
        mb_step, (params, opt_state), jnp.arange(cfg.minibatches))
    return params, opt_state, jax.tree.map(jnp.mean, metrics)


def make_mlp_learner(optimizer, cfg: PPOConfig, staleness=None):
    """jit-ready multi-epoch PPO update from a trajectory batch.

    ``staleness`` (an enabled ``algos.staleness.StalenessConfig``) turns
    on importance-weighted staleness correction for the advantage path:
    each sample is weighted by ``decay ** staleness_gap`` (the
    params-version gap the async runtime stamps onto the trajectory) —
    and, in ``vtrace`` mode, additionally by the truncated importance
    ratio ``min(rho_clip, pi_now / pi_behavior)`` under stop-gradient.
    With ``staleness`` disabled or no gap recorded (every lock-step
    path), no ``weights`` key is built and the computation is the
    historical one, bitwise."""

    def learn(params, opt_state, traj: Dict[str, jnp.ndarray]):
        # traj arrays: (T, B, ...) time-major from the sampler
        adv, ret = gae_mod.gae(traj["rewards"], traj["values"],
                               traj["dones"], traj["last_value"],
                               cfg.gamma, cfg.lam)
        batch = {
            "obs": traj["obs"],
            "actions": traj["actions"],
            "behavior_logp": traj["logp"],
            "advantages": gae_mod.normalize(adv),
            "returns": ret,
        }
        if (staleness is not None and staleness.enabled
                and "staleness_gap" in traj):
            from repro.algos import staleness as staleness_mod
            w = staleness_mod.decay_weights(staleness,
                                            traj["staleness_gap"])
            if staleness.mode == "vtrace":
                logp_now = mlp_policy.action_logp(
                    params, traj["obs"], traj["actions"])
                w = w * staleness_mod.vtrace_rho(staleness, logp_now,
                                                 traj["logp"])
            batch["weights"] = jax.lax.stop_gradient(w)
        flat = jax.tree.map(
            lambda x: x.reshape((-1,) + x.shape[2:]), batch)

        def epoch(carry, _):
            params, opt_state = carry
            params, opt_state, metrics = mlp_ppo_update(
                params, opt_state, flat, cfg, optimizer)
            return (params, opt_state), metrics

        (params, opt_state), metrics = jax.lax.scan(
            epoch, (params, opt_state), None, length=cfg.epochs)
        return params, opt_state, jax.tree.map(jnp.mean, metrics)

    return learn


# ======================================================== LM (token) PPO
def lm_ppo_loss(model_cfg, params, batch: Dict[str, jnp.ndarray],
                cfg: PPOConfig, *, impl: str = "reference",
                remat: str = "full"):
    """Token-level PPO loss for a sequence-model policy.

    batch: tokens (B,S) int32 — input context; targets (B,S) — actions
    (next tokens); behavior_logp, advantages, returns, mask (B,S) f32.
    This is the exact computation ``train_4k`` lowers in the dry-run.
    """
    h, aux = transformer.forward(
        model_cfg, params, batch["tokens"],
        extra_embeds=batch.get("extra_embeds"),
        positions=batch.get("positions"), impl=impl, remat=remat)
    S = batch["targets"].shape[1]
    h = h[:, -S:]                                   # drop prefix positions
    logp, ent = transformer.token_logp_entropy(model_cfg, params, h,
                                               batch["targets"])
    v = transformer.value(model_cfg, params, h)
    mask = batch["mask"]
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    pg = jnp.sum(clipped_surrogate(logp, batch["behavior_logp"],
                                   batch["advantages"], cfg.clip_eps)
                 * mask) / denom
    v_loss = 0.5 * jnp.sum((v - batch["returns"]) ** 2 * mask) / denom
    ent_mean = jnp.sum(ent * mask) / denom
    loss = (pg + cfg.value_coef * v_loss - cfg.entropy_coef * ent_mean
            + cfg.aux_coef * aux)
    metrics = {"loss": loss, "pg_loss": pg, "v_loss": v_loss,
               "entropy": ent_mean, "aux": aux}
    return loss, metrics


def make_lm_train_step(model_cfg, optimizer, cfg: PPOConfig,
                       impl: str = "reference", remat: str = "full"):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: lm_ppo_loss(model_cfg, p, batch, cfg, impl=impl,
                                  remat=remat), has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, cfg.max_grad_norm)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics["grad_norm"] = gnorm
        return params, opt_state, metrics

    return train_step
