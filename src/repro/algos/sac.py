"""Soft Actor-Critic — the second off-policy algorithm on the experience
plane (twin Q critics, squashed-Gaussian actor, learned entropy
temperature).

SAC exists here to prove the plane's seam is real: it shares no model
code with DDPG, yet rides the same runner-owned buffers (uniform or
prioritized, any ``n_step``) on every backend and runtime because all it
implements is the ``Algorithm`` protocol — ``learn`` consumes whatever
batch ``buffer.sample`` produced (including ``discounts``/``weights``)
and reports per-sample ``priorities`` back for prioritized replay.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import grad_sync
from repro.models.mlp_policy import gaussian_logp, init_mlp_net, mlp_apply
from repro.optim import adam, apply_updates

LOG_STD_MIN, LOG_STD_MAX = -5.0, 2.0


@dataclasses.dataclass(frozen=True)
class SACConfig:
    actor_lr: float = 3e-4
    critic_lr: float = 3e-4
    alpha_lr: float = 3e-4
    gamma: float = 0.99
    tau: float = 0.005              # polyak target update
    init_alpha: float = 0.1         # initial entropy temperature


def init_sac(key, obs_dim: int, act_dim: int, hidden: int = 64,
             init_alpha: float = 0.1) -> Dict:
    ka, k1, k2 = jax.random.split(key, 3)
    critic = {
        "q1": init_mlp_net(k1, [obs_dim + act_dim, hidden, hidden, 1]),
        "q2": init_mlp_net(k2, [obs_dim + act_dim, hidden, hidden, 1]),
    }
    return {
        # one head, two halves: [mean, log_std] (state-dependent std)
        "actor": init_mlp_net(ka, [obs_dim, hidden, hidden, 2 * act_dim]),
        "critic": critic,
        "target_critic": jax.tree.map(jnp.copy, critic),
        "log_alpha": jnp.asarray(math.log(init_alpha), jnp.float32),
    }


def actor_dist(net, obs) -> Tuple[jnp.ndarray, jnp.ndarray]:
    out = mlp_apply(net, obs)
    mean, log_std = jnp.split(out, 2, axis=-1)
    return mean, jnp.exp(jnp.clip(log_std, LOG_STD_MIN, LOG_STD_MAX))


def sample_action(net, obs, key) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tanh-squashed reparameterized Gaussian sample + its log-prob.

    log pi(a) = log N(u) - sum log(1 - tanh(u)^2), with the squash
    correction in the numerically stable softplus form.
    """
    mean, std = actor_dist(net, obs)
    u = mean + std * jax.random.normal(key, mean.shape)
    action = jnp.tanh(u)
    squash = 2.0 * (math.log(2.0) - u - jax.nn.softplus(-2.0 * u))
    logp = gaussian_logp(mean, std, u) - jnp.sum(squash, axis=-1)
    return action, logp


def q_apply(qnet, obs, act) -> jnp.ndarray:
    return mlp_apply(qnet, jnp.concatenate([obs, act], axis=-1))[..., 0]


def sac_update(params, opt_states, batch, key, cfg: SACConfig,
               actor_opt, critic_opt, alpha_opt
               ) -> Tuple[Dict, Tuple, Dict]:
    """One SAC step on a replay minibatch.

    batch: obs, actions, rewards, next_obs, discounts (gamma^n bootstrap
    factor from the buffer's n-step transform) and optional ``weights``
    (prioritized-replay importance weights, applied to the critic
    regression). Returns per-sample ``priorities`` in metrics.
    """
    a_state, c_state, al_state = opt_states
    k_next, k_new = jax.random.split(key)
    act_dim = batch["actions"].shape[-1]
    target_entropy = -float(act_dim)
    alpha = jnp.exp(params["log_alpha"])

    # ---- twin-critic regression against the entropy-regularized target
    # (target built inside the loss so the sharded learner can slice the
    # batch; elementwise-identical to the historical whole-batch form —
    # the caveat being that under microbatching each slice reuses the
    # same k_next/k_new, see DESIGN.md §9)
    def critic_loss(cnet, b):
        w = b.get("weights", jnp.ones_like(b["rewards"]))
        a_next, logp_next = sample_action(params["actor"], b["next_obs"],
                                          k_next)
        q_next = jnp.minimum(
            q_apply(params["target_critic"]["q1"], b["next_obs"], a_next),
            q_apply(params["target_critic"]["q2"], b["next_obs"], a_next))
        target = jax.lax.stop_gradient(
            b["rewards"] + b["discounts"] * (q_next - alpha * logp_next))
        q1 = q_apply(cnet["q1"], b["obs"], b["actions"])
        q2 = q_apply(cnet["q2"], b["obs"], b["actions"])
        loss = 0.5 * jnp.mean(
            w * ((q1 - target) ** 2 + (q2 - target) ** 2))
        return loss, (q1, q2, target)

    (c_loss, (q1, q2, target)), c_grads = grad_sync.value_and_grad(
        critic_loss, params["critic"], batch, has_aux=True)
    c_upd, c_state = critic_opt.update(c_grads, c_state, params["critic"])
    critic = apply_updates(params["critic"], c_upd)

    # ---- reparameterized actor step against the fresh critic
    def actor_loss(anet, b):
        a_new, logp = sample_action(anet, b["obs"], k_new)
        q_min = jnp.minimum(q_apply(critic["q1"], b["obs"], a_new),
                            q_apply(critic["q2"], b["obs"], a_new))
        return jnp.mean(alpha * logp - q_min), logp

    (a_loss, logp_new), a_grads = grad_sync.value_and_grad(
        actor_loss, params["actor"], batch, has_aux=True)
    a_upd, a_state = actor_opt.update(a_grads, a_state, params["actor"])
    actor = apply_updates(params["actor"], a_upd)

    # ---- temperature: pull entropy toward -act_dim
    def alpha_loss(log_alpha):
        return -jnp.mean(log_alpha * jax.lax.stop_gradient(
            logp_new + target_entropy))

    al_loss, al_grad = jax.value_and_grad(alpha_loss)(params["log_alpha"])
    al_grad = grad_sync.sync(al_grad)
    al_upd, al_state = alpha_opt.update(al_grad, al_state,
                                        params["log_alpha"])
    log_alpha = apply_updates(params["log_alpha"], al_upd)

    new_params = {
        "actor": actor,
        "critic": critic,
        "target_critic": jax.tree.map(
            lambda t, s: (1 - cfg.tau) * t + cfg.tau * s,
            params["target_critic"], critic),
        "log_alpha": log_alpha,
    }
    td = 0.5 * (jnp.abs(q1 - target) + jnp.abs(q2 - target))
    metrics = {"critic_loss": c_loss, "actor_loss": a_loss,
               "alpha": alpha, "alpha_loss": al_loss,
               "entropy": -jnp.mean(logp_new),
               "q_mean": jnp.mean(target),
               "priorities": jax.lax.stop_gradient(td)}
    return new_params, (a_state, c_state, al_state), metrics


# ===================================================== protocol adapter
from repro.algos.api import OffPolicyAlgorithm  # noqa: E402


class SACAlgorithm(OffPolicyAlgorithm):
    """SAC through the Algorithm protocol + experience-plane hooks.

    Defined in its own module (not ``algos.api``) on purpose: a new
    off-policy algorithm rides every backend/runtime by subclassing
    ``OffPolicyAlgorithm`` — the buffer hooks (``observe``/``sample``),
    trajectory layout and transition schema all come from the base;
    ``api.py`` registers it under a lazy factory so import order never
    matters.
    """

    name = "sac"

    def __init__(self, lr: float = None, hidden: int = 64,
                 updates_per_collect: int = 4, **cfg_kwargs):
        if lr is not None:
            cfg_kwargs.setdefault("actor_lr", lr)
            cfg_kwargs.setdefault("critic_lr", lr)
        self.cfg = SACConfig(**cfg_kwargs)
        self.hidden = hidden
        self.updates_per_collect = updates_per_collect
        self._a_opt = adam(self.cfg.actor_lr)
        self._c_opt = adam(self.cfg.critic_lr)
        self._al_opt = adam(self.cfg.alpha_lr)

    def init(self, key, env):
        params = init_sac(key, env.obs_dim, env.act_dim, hidden=self.hidden,
                          init_alpha=self.cfg.init_alpha)
        opt_state = (self._a_opt.init(params["actor"]),
                     self._c_opt.init(params["critic"]),
                     self._al_opt.init(params["log_alpha"]))
        return params, opt_state

    def learn(self, params, opt_state, batch):
        return sac_update(params, opt_state, batch, batch["rng"], self.cfg,
                          self._a_opt, self._c_opt, self._al_opt)

    def act(self, params, obs, key):
        action, _ = sample_action(params["actor"], obs, key)
        return action, {}
