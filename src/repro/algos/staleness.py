"""Importance-weighted staleness correction (DESIGN.md §10).

Free-running workers act with whatever params version the channel last
published, so by the time the learner consumes a trajectory it may be
``gap = learner_version - acted_with_version`` updates stale. Parallel
Q-Learning (PAPERS.md) shows mixing data of varying staleness works when
it is *corrected for*; this module is that correction as a composable
hook, keyed off the params-version gap the shared-memory ring already
records per trajectory.

Two modes on top of ``off`` (the default — a no-op that preserves every
bitwise guarantee):

* ``decay``  — geometric down-weighting: ``w = decay ** gap``. Applies
  to any learner; for off-policy replay the weight is computed at
  *ingest* time (the gap is known when the transition enters the
  buffer) and multiplies the buffer's importance weights at sample
  time.
* ``vtrace`` — for PPO's advantage path: the decay weight times the
  V-trace-style truncated importance ratio
  ``rho = min(rho_clip, pi_now(a|s) / pi_behavior(a|s))`` evaluated
  under stop-gradient, so stale actions the current policy would no
  longer take stop steering the update (Espeholt et al., 2018). The
  replay path has no behavior logp, so ``vtrace`` degrades to ``decay``
  there.

The correction is **exact-off by default**: with ``mode="off"`` (or in
lock-step mode, where the gap is identically zero and no gap key is ever
attached) no trajectory key is added, no loss term changes, and the
ppo×inline / process==inline / fused==stepped parity guarantees hold
bitwise.

Plumbing: ``AsyncOrchestrator`` attaches the per-trajectory gap as a
``(T, B)`` float32 ``"staleness_gap"`` leaf before merging;
``algos.api`` routes it into the PPO loss (``make_mlp_learner``) or into
replay ingest (``OffPolicyAlgorithm.observe`` -> ``staleness_w`` ->
``batch["weights"]``). Algorithms opt in via ``supports_staleness`` /
``enable_staleness`` (PPO, DDPG, SAC; TRPO's line search does not).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Union

MODES = ("off", "decay", "vtrace")


@dataclasses.dataclass(frozen=True)
class StalenessConfig:
    """How stale experience is down-weighted (plain data, spec-friendly)."""

    mode: str = "off"
    decay: float = 0.9          # geometric weight per version of staleness
    rho_clip: float = 1.0       # vtrace: truncation of the importance ratio

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(
                f"unknown staleness mode {self.mode!r}; choose from {MODES}")
        if not 0.0 < self.decay <= 1.0:
            raise ValueError(f"staleness decay={self.decay} must be in "
                             f"(0, 1]")
        if self.rho_clip <= 0.0:
            raise ValueError(f"rho_clip={self.rho_clip} must be > 0")

    @property
    def enabled(self) -> bool:
        return self.mode != "off"

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def parse(cls, value: Union[None, str, Dict[str, Any],
                                "StalenessConfig"]) -> "StalenessConfig":
        """Normalize the spec-level field: None / a mode string / a kwargs
        dict / an existing config all resolve to one ``StalenessConfig``."""
        if value is None:
            return cls()
        if isinstance(value, cls):
            return value
        if isinstance(value, str):
            return cls(mode=value)
        return cls(**dict(value))


STALENESS_OFF = StalenessConfig()

GAP_KEY = "staleness_gap"       # (T, B) f32 versions-behind, runner-attached
WEIGHT_KEY = "staleness_w"      # per-transition weight stored in replay


def decay_weights(cfg: StalenessConfig, gap):
    """``decay ** gap`` as float32 — the geometric down-weighting shared
    by both modes (jittable; ``gap`` is a float array of versions
    behind)."""
    import jax.numpy as jnp
    return jnp.asarray(cfg.decay, jnp.float32) ** gap.astype(jnp.float32)


def vtrace_rho(cfg: StalenessConfig, logp_now, behavior_logp):
    """Truncated importance ratio ``min(rho_clip, exp(logp_now - mu))``
    under stop-gradient — the V-trace correction factor (jittable)."""
    import jax
    import jax.numpy as jnp
    ratio = jnp.exp(jax.lax.stop_gradient(logp_now) - behavior_logp)
    return jnp.minimum(jnp.asarray(cfg.rho_clip, jnp.float32), ratio)
