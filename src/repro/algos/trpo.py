"""Trust-Region Policy Optimization (natural gradient + line search).

The paper's Related Work contrasts WALL-E with Frans & Hafner's parallel
TRPO; implementing TRPO alongside PPO lets the framework reproduce that
comparison under the same parallel-sampler runtime (both learners consume
identical trajectory batches).

Natural gradient via conjugate-gradient on Fisher-vector products
(Hessian-of-KL vp, computed with jvp-of-grad), then a backtracking line
search enforcing the KL trust region. The whole update — CG, line search
and value-function regression — is device-side (``lax.scan``), so
``trpo_update`` jits and the learner rides every runner/backend through
the ``Algorithm`` seam exactly like PPO.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.algos import gae as gae_mod
from repro.models import mlp_policy


@dataclasses.dataclass(frozen=True)
class TRPOConfig:
    max_kl: float = 0.01
    cg_iters: int = 10
    cg_damping: float = 0.1
    backtrack_coef: float = 0.8
    backtrack_iters: int = 10
    gamma: float = 0.99
    lam: float = 0.95
    vf_lr: float = 1e-3
    vf_steps: int = 25


# ----------------------------------------------------------- flat helpers
def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    sizes = [l.size for l in leaves]
    flat = jnp.concatenate([l.reshape(-1) for l in leaves])
    return flat, (treedef, [l.shape for l in leaves], sizes)


def _unflatten(flat, meta):
    treedef, shapes, sizes = meta
    out, i = [], 0
    for shape, size in zip(shapes, sizes):
        out.append(flat[i:i + size].reshape(shape))
        i += size
    return jax.tree_util.tree_unflatten(treedef, out)


# ------------------------------------------------------------- objective
def surrogate(pi_params, batch) -> jnp.ndarray:
    logp = mlp_policy.gaussian_logp(
        *_dist(pi_params, batch["obs"]), batch["actions"])
    ratio = jnp.exp(logp - batch["behavior_logp"])
    return jnp.mean(ratio * batch["advantages"])


def _dist(pi_params, obs):
    mean = mlp_policy.mlp_apply(pi_params["pi"], obs)
    std = jnp.exp(pi_params["log_std"])
    return mean, jnp.broadcast_to(std, mean.shape)


def mean_kl(pi_params, old_mean, old_std, obs) -> jnp.ndarray:
    """KL(old || new) for diagonal Gaussians, averaged over the batch."""
    mean, std = _dist(pi_params, obs)
    kl = (jnp.log(std / old_std)
          + (old_std ** 2 + (old_mean - mean) ** 2) / (2 * std ** 2) - 0.5)
    return jnp.mean(jnp.sum(kl, axis=-1))


def fisher_vp(pi_params, obs, old_mean, old_std, vec, meta, damping):
    """(H_KL + damping I) @ vec via jvp of grad (Pearlmutter trick)."""

    def kl_flat(flat):
        return mean_kl(_unflatten(flat, meta), old_mean, old_std, obs)

    flat0, _ = _flatten(pi_params)
    g = jax.grad(kl_flat)
    _, hvp = jax.jvp(g, (flat0,), (vec,))
    return hvp + damping * vec


def conjugate_gradient(avp, b, iters: int) -> jnp.ndarray:
    x = jnp.zeros_like(b)
    r = b
    p = b
    rs = jnp.dot(r, r)

    def body(carry, _):
        x, r, p, rs = carry
        ap = avp(p)
        alpha = rs / (jnp.dot(p, ap) + 1e-10)
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = jnp.dot(r, r)
        p = r + (rs_new / (rs + 1e-10)) * p
        return (x, r, p, rs_new), None

    (x, _, _, _), _ = jax.lax.scan(body, (x, r, p, rs), None, length=iters)
    return x


# ----------------------------------------------------------------- update
def trpo_update(params: Dict, batch: Dict, cfg: TRPOConfig
                ) -> Tuple[Dict, Dict]:
    """One TRPO policy step (+ vf regression). batch: flat (N, ...) arrays
    with obs/actions/behavior_logp/advantages/returns."""
    pi_params = {"pi": params["pi"], "log_std": params["log_std"]}
    old_mean, old_std = _dist(pi_params, batch["obs"])
    old_mean = jax.lax.stop_gradient(old_mean)
    old_std = jax.lax.stop_gradient(old_std)

    flat0, meta = _flatten(pi_params)
    g_tree = jax.grad(surrogate)(pi_params, batch)
    g, _ = _flatten(g_tree)

    avp = lambda v: fisher_vp(pi_params, batch["obs"], old_mean, old_std,
                              v, meta, cfg.cg_damping)
    step_dir = conjugate_gradient(avp, g, cfg.cg_iters)
    shs = jnp.dot(step_dir, avp(step_dir))
    step_scale = jnp.sqrt(2 * cfg.max_kl / jnp.maximum(shs, 1e-10))
    full_step = step_scale * step_dir
    base_surr = surrogate(pi_params, batch)

    def try_step(coef):
        cand = _unflatten(flat0 + coef * full_step, meta)
        return (surrogate(cand, batch),
                mean_kl(cand, old_mean, old_std, batch["obs"]))

    # backtracking line search, device-side: evaluate the backtracked
    # coefficients in order and keep the first that improves the surrogate
    # within the trust region (jittable equivalent of break-on-success)
    def ls_body(carry, _):
        coef, accepted, found = carry
        surr, kl = try_step(coef)
        ok = (surr > base_surr) & (kl <= 1.5 * cfg.max_kl)
        accepted = jnp.where(ok & ~found, coef, accepted)
        return (coef * cfg.backtrack_coef, accepted, found | ok), None

    (_, accepted, _), _ = jax.lax.scan(
        ls_body, (jnp.ones(()), jnp.zeros(()), jnp.zeros((), bool)),
        None, length=cfg.backtrack_iters)
    new_pi = _unflatten(flat0 + accepted * full_step, meta)

    # value-function regression (simple Adam-free GD for self-containment)
    def vf_body(vf, _):
        vg = jax.grad(
            lambda v: jnp.mean((mlp_policy.mlp_apply(v, batch["obs"])[..., 0]
                                - batch["returns"]) ** 2))(vf)
        return jax.tree.map(lambda p, g: p - cfg.vf_lr * g, vf, vg), None

    vf, _ = jax.lax.scan(vf_body, params["vf"], None, length=cfg.vf_steps)

    new_params = {"pi": new_pi["pi"], "log_std": new_pi["log_std"],
                  "vf": vf}
    surr, kl = try_step(accepted)
    metrics = {"surrogate_gain": surr - base_surr, "kl": kl,
               "step_coef": accepted}
    return new_params, metrics


def make_trpo_learner(cfg: TRPOConfig):
    """Same interface as ppo.make_mlp_learner: consumes (T,B,...) trajs."""

    def learn(params, opt_state, traj):
        adv, ret = gae_mod.gae(traj["rewards"], traj["values"],
                               traj["dones"], traj["last_value"],
                               cfg.gamma, cfg.lam)
        batch = {
            "obs": traj["obs"].reshape((-1,) + traj["obs"].shape[2:]),
            "actions": traj["actions"].reshape(
                (-1,) + traj["actions"].shape[2:]),
            "behavior_logp": traj["logp"].reshape(-1),
            "advantages": gae_mod.normalize(adv).reshape(-1),
            "returns": ret.reshape(-1),
        }
        params, metrics = trpo_update(params, batch, cfg)
        return params, opt_state, metrics

    return learn
