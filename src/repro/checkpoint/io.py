"""Pytree checkpointing: flattened-path .npz + json metadata, keep-last-k.

No orbax dependency; restore takes a template pytree (from ``init_params``)
so structure and dtypes are authoritative.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Dict, Optional

import jax
import numpy as np

_SEP = "/"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_part(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _part(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(directory: str, step: int, tree: Any,
         metadata: Optional[Dict] = None, keep: int = 3) -> str:
    """Write ``<dir>/ckpt_<step>/arrays.npz`` (+meta.json); prune old."""
    path = os.path.join(directory, f"ckpt_{step:010d}")
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"), **_flatten(tree))
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, **(metadata or {})}, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    _prune(directory, keep)
    return path


def _ckpt_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"ckpt_(\d+)", name)
        if m:
            steps.append(int(m.group(1)))
    return sorted(steps)


def _prune(directory: str, keep: int) -> None:
    steps = _ckpt_steps(directory)
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(directory, f"ckpt_{s:010d}"),
                      ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    steps = _ckpt_steps(directory)
    return steps[-1] if steps else None


def _resolve_step(directory: str, step: Optional[int]) -> int:
    """Resolve (and validate) the step to load, with an error that names
    the directory and what ``latest_step`` found — an absent or empty
    checkpoint directory must fail here, loudly, not as an opaque
    ``np.load``/``open`` failure deep in the restore."""
    latest = latest_step(directory)
    if step is None:
        if latest is None:
            raise FileNotFoundError(
                f"no checkpoints under {directory!r} (latest_step() -> "
                f"None: the directory "
                f"{'exists but holds' if os.path.isdir(directory) else 'does not exist, so it holds'}"
                f" no ckpt_<step> subdirectories) — check the path, or "
                f"train with --ckpt-dir first")
        return latest
    if not os.path.isdir(os.path.join(directory, f"ckpt_{step:010d}")):
        raise FileNotFoundError(
            f"checkpoint step {step} not found under {directory!r} "
            f"(latest_step() -> {latest})")
    return step


def restore(directory: str, template: Any, step: Optional[int] = None
            ) -> Any:
    """Load arrays into the structure of ``template`` (dtypes preserved)."""
    step = _resolve_step(directory, step)
    path = os.path.join(directory, f"ckpt_{step:010d}", "arrays.npz")
    data = np.load(path)
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in paths:
        key = _SEP.join(_part(x) for x in p)
        arr = data[key]
        leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_metadata(directory: str, step: Optional[int] = None) -> Dict:
    step = _resolve_step(directory, step)
    with open(os.path.join(directory, f"ckpt_{step:010d}", "meta.json")) as f:
        return json.load(f)
