"""Architecture registry: ``get_config(arch_id)`` / ``list_archs()``.

Arch names live in the unified registry (``repro.registry``, kind
``"arch"``) alongside envs, algos and backends; each entry is a lazy
loader so importing ``repro.configs`` never pulls in every config module.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro import registry
from repro.configs.base import (  # noqa: F401
    INPUT_SHAPES,
    InputShape,
    ModelConfig,
    supports_shape,
)

_ARCH_MODULES = {
    "falcon-mamba-7b": "falcon_mamba_7b",
    "llama3-405b": "llama3_405b",
    "mixtral-8x7b": "mixtral_8x7b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "mixtral-8x22b": "mixtral_8x22b",
    "starcoder2-15b": "starcoder2_15b",
    "qwen1.5-32b": "qwen1_5_32b",
    "musicgen-medium": "musicgen_medium",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "hymba-1.5b": "hymba_1_5b",
    "walle-mlp": "walle_mlp",
}

ASSIGNED_ARCHS = [a for a in _ARCH_MODULES if a != "walle-mlp"]


def _loader(module_name: str):
    def load() -> ModelConfig:
        return importlib.import_module(
            f"repro.configs.{module_name}").CONFIG
    return load


for _arch_id, _mod in _ARCH_MODULES.items():
    registry.register("arch", _arch_id, _loader(_mod))


def get_config(arch_id: str) -> ModelConfig:
    if arch_id.endswith("-reduced"):
        return get_config(arch_id[: -len("-reduced")]).reduced()
    return registry.make("arch", arch_id)


def list_archs() -> List[str]:
    return list(_ARCH_MODULES)


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in _ARCH_MODULES}
