"""Architecture registry: ``get_config(arch_id)`` / ``list_archs()``."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401
    INPUT_SHAPES,
    InputShape,
    ModelConfig,
    supports_shape,
)

_ARCH_MODULES = {
    "falcon-mamba-7b": "falcon_mamba_7b",
    "llama3-405b": "llama3_405b",
    "mixtral-8x7b": "mixtral_8x7b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "mixtral-8x22b": "mixtral_8x22b",
    "starcoder2-15b": "starcoder2_15b",
    "qwen1.5-32b": "qwen1_5_32b",
    "musicgen-medium": "musicgen_medium",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "hymba-1.5b": "hymba_1_5b",
    "walle-mlp": "walle_mlp",
}

ASSIGNED_ARCHS = [a for a in _ARCH_MODULES if a != "walle-mlp"]


def get_config(arch_id: str) -> ModelConfig:
    if arch_id.endswith("-reduced"):
        return get_config(arch_id[: -len("-reduced")]).reduced()
    try:
        mod = importlib.import_module(
            f"repro.configs.{_ARCH_MODULES[arch_id]}")
    except KeyError:
        raise KeyError(
            f"unknown arch {arch_id!r}; choose from {sorted(_ARCH_MODULES)}")
    return mod.CONFIG


def list_archs() -> List[str]:
    return list(_ARCH_MODULES)


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in _ARCH_MODULES}
