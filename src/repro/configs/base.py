"""Model / run configuration dataclasses.

Every assigned architecture gets one ``<arch>.py`` in this package exporting
``CONFIG`` (the exact published configuration, cited) plus the registry here.
``ModelConfig.reduced()`` derives the CPU smoke-test variant (<=2 layers,
d_model <= 512, <= 4 experts) of the *same family* per the repro spec.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

FAMILIES = ("dense", "moe", "ssm", "hybrid", "audio", "vlm", "mlp")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    n_layers: int
    d_model: int
    vocab_size: int
    # --- attention ---
    n_heads: int = 0                    # 0 => attention-free (pure SSM)
    n_kv_heads: int = 0
    head_dim: int = 0                   # 0 => d_model // n_heads
    d_ff: int = 0                       # 0 => no MLP block (pure SSM)
    rope_theta: float = 10_000.0
    qkv_bias: bool = False              # Qwen1.5-style QKV bias
    sliding_window: int = 0             # 0 => full causal attention
    m_rope_sections: Tuple[int, ...] = ()   # Qwen2-VL M-RoPE (t, h, w) halves
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # --- SSM (Mamba1) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_dt_rank: int = 0                # 0 => ceil(d_model / 16)
    # --- hybrid (Hymba) ---
    n_meta_tokens: int = 0              # learned prefix tokens
    # --- modality frontend stub ---
    frontend: str = "none"              # none | audio_frames | vision_patches
    frontend_embeds: int = 0            # number of precomputed embeds supplied
    # --- misc ---
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    source: str = ""                    # citation for the configuration

    # ------------------------------------------------------------------ #
    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family!r}")
        if self.n_heads and self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.is_ssm and self.ssm_dt_rank == 0:
            object.__setattr__(self, "ssm_dt_rank",
                               math.ceil(self.d_model / 16))
        if self.m_rope_sections:
            assert sum(self.m_rope_sections) == self.head_dim // 2, (
                "M-RoPE sections must sum to head_dim/2")

    # ------------------------------------------------------------------ #
    @property
    def is_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def has_attention(self) -> bool:
        return self.n_heads > 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def d_inner(self) -> int:
        """Mamba inner width."""
        return self.ssm_expand * self.d_model

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    # ------------------------------------------------------------------ #
    def param_count(self) -> int:
        """Analytic parameter count (matches init_params; used for 6*N*D)."""
        d, v, L = self.d_model, self.vocab_size, self.n_layers
        total = v * d                                    # embed
        if not self.tie_embeddings:
            total += d * v                               # lm head
        total += d                                       # final norm
        per_layer = 0
        if self.has_attention:
            qd = self.n_heads * self.head_dim
            kvd = self.n_kv_heads * self.head_dim
            per_layer += d * qd + 2 * d * kvd + qd * d   # wq wk wv wo
            if self.qkv_bias:
                per_layer += qd + 2 * kvd
            per_layer += d                               # attn norm
        if self.d_ff:
            ff = 3 * d * self.d_ff                       # SwiGLU w1 w3 w2
            if self.is_moe:
                per_layer += self.n_experts * ff + d * self.n_experts  # router
            else:
                per_layer += ff
            per_layer += d                               # mlp norm
        if self.is_ssm:
            di, st, dtr = self.d_inner, self.ssm_state, self.ssm_dt_rank
            per_layer += d * 2 * di                      # in_proj
            per_layer += di * self.ssm_conv + di         # conv w + b
            per_layer += di * (dtr + 2 * st)             # x_proj
            per_layer += dtr * di + di                   # dt_proj + bias
            per_layer += di * st + di                    # A_log, D
            per_layer += di * d                          # out_proj
            if self.family == "ssm":
                per_layer += d                           # ssm norm
        if self.family == "hybrid":
            per_layer += 2 * d                           # fusion norms
        total += per_layer * L
        total += self.n_meta_tokens * d
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE top-k instead of all experts)."""
        if not self.is_moe:
            return self.param_count()
        ff = 3 * self.d_model * self.d_ff
        inactive = (self.n_experts - self.top_k) * ff * self.n_layers
        return self.param_count() - inactive

    # ------------------------------------------------------------------ #
    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: same family, tiny dims, CPU-runnable."""
        n_heads = min(self.n_heads, 4) if self.n_heads else 0
        n_kv = 0
        if self.n_kv_heads:
            # preserve the GQA ratio class: MHA stays MHA, GQA stays grouped
            n_kv = n_heads if self.n_kv_heads == self.n_heads else max(
                1, n_heads // 2)
        head_dim = 32 if n_heads else 0
        d_model = (n_heads * head_dim) if n_heads else 128
        sections = ()
        if self.m_rope_sections:
            h = head_dim // 2
            sections = (h - 2 * (h // 3), h // 3, h // 3)
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=2,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            ssm_dt_rank=8 if self.is_ssm else 0,
            sliding_window=min(self.sliding_window, 64)
            if self.sliding_window else 0,
            m_rope_sections=sections,
            n_meta_tokens=min(self.n_meta_tokens, 8),
            frontend_embeds=min(self.frontend_embeds, 8),
            dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One assigned workload shape."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def supports_shape(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """long_500k needs a sub-quadratic decode path (SSM state or SWA cache).

    Pure full-attention archs are skipped per spec (noted in DESIGN.md).
    """
    if shape.name != "long_500k":
        return True, ""
    if cfg.is_ssm or cfg.sliding_window:
        return True, ""
    return False, (f"{cfg.name} is pure full-attention: a 500k-deep dense KV "
                   "cache has no sub-quadratic path in this arch (skip per "
                   "spec; see DESIGN.md §4)")
