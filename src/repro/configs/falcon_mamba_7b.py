"""falcon-mamba-7b — pure Mamba1 (attention-free) LM. [arXiv:2410.05355]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    vocab_size=65024,
    d_ff=0,
    n_heads=0,
    n_kv_heads=0,
    ssm_state=16,
    ssm_expand=2,
    ssm_conv=4,
    source="arXiv:2410.05355 (Falcon Mamba: 64L d_model=4096 mamba1, "
           "state=16, vocab=65024)",
)
