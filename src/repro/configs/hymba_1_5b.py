"""hymba-1.5b — hybrid parallel attention + Mamba heads. [arXiv:2411.13676]

Each layer runs attention heads and an SSM head in parallel on the same
input and fuses the two normalised outputs (mean fusion, per the paper).
Meta tokens are learned prefix embeddings; SWA on the attention heads
(the paper's dominant layer type — see DESIGN.md §4).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    vocab_size=32001,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    ssm_state=16,
    ssm_expand=2,
    ssm_conv=4,
    sliding_window=2048,
    n_meta_tokens=128,
    source="arXiv:2411.13676 (Hymba-1.5B: 32L d_model=1600 25H GQA kv=5 "
           "d_ff=5504 vocab=32001, parallel attn+mamba heads, ssm_state=16, "
           "meta tokens, SWA)",
)
