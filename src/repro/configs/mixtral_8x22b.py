"""mixtral-8x22b — 8-expert top-2 MoE with SWA. [arXiv:2401.04088]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    vocab_size=32768,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    n_experts=8,
    top_k=2,
    sliding_window=4096,
    rope_theta=1_000_000.0,
    source="arXiv:2401.04088 (Mixtral family, 8x22B card: 56L d_model=6144 "
           "48H GQA kv=8 d_ff=16384 vocab=32768, 8 experts top-2, SWA)",
)
