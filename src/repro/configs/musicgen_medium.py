"""musicgen-medium — decoder-only LM over EnCodec tokens. [arXiv:2306.05284]

Backbone only per spec: the EnCodec/conv audio frontend is a stub —
``input_specs`` provides precomputed conditioning frame embeddings
(``frontend_embeds``). The 4-codebook delay pattern is simplified to a single
interleaved token stream over the 2048-entry codebook (see DESIGN.md §4).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    vocab_size=2048,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    frontend="audio_frames",
    frontend_embeds=64,         # conditioning frames prepended as embeds
    source="arXiv:2306.05284 (MusicGen-medium backbone: 48L d_model=1536 "
           "24H kv=24 d_ff=6144 vocab=2048 over EnCodec tokens)",
)
