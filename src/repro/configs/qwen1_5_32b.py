"""qwen1.5-32b — dense MHA-style (kv=40) with QKV bias. [hf:Qwen/Qwen1.5-0.5B]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    vocab_size=152064,
    n_heads=40,
    n_kv_heads=40,
    head_dim=128,
    d_ff=27392,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen1.5-0.5B model-card family (Qwen1.5-32B: 64L "
           "d_model=5120 40H kv=40 d_ff=27392 vocab=152064, QKV bias)",
)
