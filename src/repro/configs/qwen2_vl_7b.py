"""qwen2-vl-7b — VLM decoder with M-RoPE. [arXiv:2409.12191]

Backbone only per spec: the ViT vision encoder + projector is a stub —
``input_specs`` provides precomputed patch embeddings (``frontend_embeds``)
plus 3-component (t,h,w) M-RoPE position ids.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    vocab_size=152064,
    n_heads=28,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    m_rope_sections=(16, 24, 24),   # halves of head_dim/2 = 64 (t, h, w)
    rope_theta=1_000_000.0,
    frontend="vision_patches",
    frontend_embeds=256,            # precomputed ViT patch embeds prepended
    source="arXiv:2409.12191 (Qwen2-VL-7B backbone: 28L d_model=3584 28H GQA "
           "kv=4 d_ff=18944 vocab=152064, M-RoPE, dynamic resolution)",
)
