"""starcoder2-15b — dense GQA + RoPE code model. [arXiv:2402.19173]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    vocab_size=49152,
    n_heads=48,
    n_kv_heads=4,
    head_dim=128,
    d_ff=24576,
    rope_theta=100_000.0,
    source="arXiv:2402.19173 (StarCoder2-15B: 40L d_model=6144 48H GQA kv=4 "
           "d_ff=24576 vocab=49152, RoPE)",
)
