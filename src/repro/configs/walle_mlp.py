"""walle-mlp — the paper's own policy model.

WALL-E's experiments (MuJoCo HalfCheetah-v2, PPO) use a small Gaussian-MLP
policy + value network. This config drives the paper-faithful reproduction
(benchmarks/fig3..fig7) and examples/quickstart.py.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="walle-mlp",
    family="mlp",
    n_layers=2,          # hidden layers
    d_model=64,          # hidden width
    vocab_size=0,
    source="WALL-E (2019) §4: PPO Gaussian-MLP policy on HalfCheetah-v2",
)
