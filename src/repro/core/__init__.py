# The paper's primary contribution: WALL-E's parallel-sampler architecture
# (N rollout samplers + async agent/learner + policy & experience queues),
# behind a pluggable SamplerBackend seam with a fused single-dispatch engine.
#
# The user-facing entry point is now `repro.experiment.run(ExperimentSpec)`
# resolved through the unified registry (`repro.registry`); the re-exports
# below are kept as compatibility shims so historical imports
# (`from repro.core import SyncRunner, make_backend, ...`) keep working.
# `make_backend` delegates to the registry (kind "backend") — prefer
# `repro.registry.make("backend", ...)` or a spec in new code.
from repro.core import (  # noqa: F401
    backends,
    fused,
    orchestrator,
    queues,
    sampler,
    timing,
)
from repro.core.backends import (  # noqa: F401
    CollectStats,
    InlineBackend,
    ProcessBackend,
    SamplerBackend,
    ShardedBackend,
    ThreadedBackend,
    make_backend,
)
from repro.core.sampler import WorkerSpec  # noqa: F401
from repro.core.fused import FusedRunner, TrainState, make_fused_train_loop  # noqa: F401
from repro.core.orchestrator import (  # noqa: F401
    AsyncOrchestrator,
    IterationLog,
    SyncRunner,
)
from repro.core.queues import (  # noqa: F401
    Experience,
    ExperienceQueue,
    PolicyStore,
)
