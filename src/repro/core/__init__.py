# The paper's primary contribution: WALL-E's parallel-sampler architecture
# (N rollout samplers + async agent/learner + policy & experience queues).
from repro.core import orchestrator, queues, sampler, timing  # noqa: F401
from repro.core.orchestrator import (  # noqa: F401
    AsyncOrchestrator,
    IterationLog,
    SyncRunner,
)
from repro.core.queues import (  # noqa: F401
    Experience,
    ExperienceQueue,
    PolicyStore,
)
