"""Sampler backends — the pluggable experience-collection seam.

WALL-E's runtime layer separates *what* a sampler does (one jitted rollout,
``core/sampler.py``) from *how* N of them are scheduled. A
``SamplerBackend`` owns the sampler carries and produces, per iteration,
one merged trajectory plus per-sampler timing (DESIGN.md §2). Runners
(``core/orchestrator.py``) and the fused engine (``core/fused.py``) are
thin drivers over this protocol.

Backends:

* ``InlineBackend``   — the serial N-sampler sweep: each sampler's rollout
  runs back-to-back on the local device and is timed individually, so the
  critical path of a truly parallel deployment (max over samplers) can be
  reported from a single host.
* ``ThreadedBackend`` — the fan-out/join form of ``AsyncOrchestrator``'s
  sampler loops: each sampler's jitted rollout is dispatched from its own
  thread (JAX releases the GIL during device execution), then joined and
  merged.
* ``ShardedBackend``  — the accelerator-native form: ``shard_map`` places
  one sampler per ``data``-axis mesh slice; the trajectory is *born
  sharded* and never merged on host.
* ``ProcessBackend``  — the paper's actual deployment shape: N worker
  *processes* (own interpreter, own XLA client — no GIL or dispatch-queue
  contention with the learner), rebuilt from serializable ``WorkerSpec``s
  and fed through shared-memory transport (``core/ipc.py``). Trajectories
  merge in deterministic worker-index order, so ``process == inline``
  exactly for matched per-worker seeds (DESIGN.md §6).

Every backend is a context manager; ``close()`` releases whatever it
holds (thread pools, worker processes, shared memory) and is idempotent.
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, List, Optional, Protocol, Sequence

import jax

from repro import registry
from repro.data import trajectory


@dataclasses.dataclass
class CollectStats:
    """Per-iteration collection accounting shared by every backend."""
    per_sampler_seconds: List[float]
    samples: int
    respawns: int = 0        # cumulative supervised worker respawns
    active_workers: int = 0  # live fleet size (process backend only)

    @property
    def critical_path(self) -> float:
        """Max over samplers — what a parallel deployment would wait."""
        return max(self.per_sampler_seconds)

    @property
    def serial_equivalent(self) -> float:
        """Sum over samplers — what N=1 pays for the same experience."""
        return sum(self.per_sampler_seconds)


class SamplerBackend(Protocol):
    """collect(params) -> (merged_traj, stats); carries are backend-owned.
    ``close()`` releases backend-held resources (idempotent)."""

    num_samplers: int

    def collect(self, params: Any) -> tuple:
        ...

    def close(self) -> None:
        ...


class BackendCloseMixin:
    """Context-manager + no-op ``close`` shared by every backend, so
    ``experiment.run`` can unconditionally release any backend in its
    ``finally`` (threads, worker processes, shared memory — or nothing)."""

    def close(self) -> None:
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def timed_rollout(rollout: Callable, params: Any, carry: Any):
    """Run one jitted rollout to completion, returning (carry', traj, dt)."""
    t0 = time.perf_counter()
    carry, traj = rollout(params, carry)
    traj = jax.block_until_ready(traj)
    return carry, traj, time.perf_counter() - t0


def merge_trajs(trajs: Sequence[Any]) -> Any:
    return trajectory.merge(list(trajs)) if len(trajs) > 1 else trajs[0]


# ================================================================== inline
class InlineBackend(BackendCloseMixin):
    """Today's serial sweep: N logical samplers executed back-to-back."""

    def __init__(self, rollout: Callable, carries: List[Any]):
        self.rollout = jax.jit(rollout)
        self.carries = carries
        self.num_samplers = len(carries)

    def collect(self, params):
        trajs, times = [], []
        for i in range(self.num_samplers):
            self.carries[i], traj, dt = timed_rollout(
                self.rollout, params, self.carries[i])
            trajs.append(traj)
            times.append(dt)
        merged = merge_trajs(trajs)
        return merged, CollectStats(times, trajectory.num_samples(merged))


# ================================================================ threaded
class ThreadedBackend(BackendCloseMixin):
    """Fan-out/join over sampler threads (AsyncOrchestrator's sampler loop,
    made synchronous): each sampler dispatches its jitted rollout from its
    own thread; the critical path is genuinely the max over samplers."""

    def __init__(self, rollout: Callable, carries: List[Any],
                 max_workers: Optional[int] = None):
        self.rollout = jax.jit(rollout)
        self.carries = carries
        self.num_samplers = len(carries)
        self._pool = ThreadPoolExecutor(
            max_workers=max_workers or self.num_samplers)

    def _one(self, i: int, params):
        self.carries[i], traj, dt = timed_rollout(
            self.rollout, params, self.carries[i])
        return traj, dt

    def collect(self, params):
        futures = [self._pool.submit(self._one, i, params)
                   for i in range(self.num_samplers)]
        results = [f.result() for f in futures]
        trajs = [r[0] for r in results]
        times = [r[1] for r in results]
        merged = merge_trajs(trajs)
        return merged, CollectStats(times, trajectory.num_samples(merged))

    def close(self) -> None:
        self._pool.shutdown(wait=False)


# ================================================================= sharded
class ShardedBackend(BackendCloseMixin):
    """One sampler per ``data``-axis mesh slice via ``make_sharded_rollout``.

    The carry holds the *global* env batch; shard_map splits it so each
    slice runs an independent sampler and the trajectory arrays come back
    already concatenated on the (sharded) batch axis — no host merge. One
    dispatch covers all samplers, so per-sampler time equals the critical
    path and there is no serial/parallel gap to report.
    """

    def __init__(self, sharded_rollout: Callable, carry: Any, mesh,
                 data_axis: str = "data"):
        self.rollout = jax.jit(sharded_rollout)
        self.carry = carry
        self.mesh = mesh
        self.num_samplers = mesh.shape[data_axis]

    def collect(self, params):
        with jax.sharding.use_mesh(self.mesh) if hasattr(
                jax.sharding, "use_mesh") else self.mesh:
            self.carry, traj, dt = timed_rollout(
                self.rollout, params, self.carry)
        stats = CollectStats([dt], trajectory.num_samples(traj))
        return traj, stats


# ================================================================= process
class ProcessBackend(BackendCloseMixin):
    """N rollout worker *processes* behind the ``collect`` contract.

    Each worker owns its own interpreter and XLA client — rollouts never
    contend with the learner for the GIL or the dispatch queue, which is
    the paper's actual N-sampler-process deployment (and what inline/
    threaded only approximate from one process). Params go out through a
    versioned shared-memory channel (one publish per ``collect``, not one
    pickle per worker); trajectories come back through the shared-memory
    ring and merge **in worker-index order**, so with matched per-worker
    seeds the merged trajectory is exactly the inline backend's
    (DESIGN.md §6). With a ``supervisor`` attached (the default through
    ``repro.experiment``), a worker that dies mid-sweep is respawned
    from its ``WorkerSpec`` and its command re-issued instead of killing
    the run; without one, worker death or an in-worker exception
    surfaces as ``ipc.WorkerCrashed`` from ``collect``. ``close`` reaps
    everything.
    """

    def __init__(self, pool, supervisor=None):
        self.pool = pool
        self.supervisor = supervisor
        # command workers one at a time instead of broadcasting: on hosts
        # with fewer cores than workers this removes peer preemption from
        # the per-worker timings (see ProcessWorkerPool.collect) — the
        # benchmark harness flips it for steady-state measurement
        self.staggered = False

    @property
    def num_samplers(self) -> int:
        return self.pool.num_workers

    def collect(self, params):
        self.pool.publish(params)
        source = self.supervisor if self.supervisor is not None else self.pool
        trajs, times, _loops = source.collect(staggered=self.staggered)
        merged = merge_trajs(trajs)
        return merged, CollectStats(
            times, trajectory.num_samples(merged),
            respawns=(self.supervisor.respawns if self.supervisor else 0),
            active_workers=self.pool.num_workers)

    def close(self) -> None:
        # supervised pools tolerate worker death by design — don't let a
        # fault landing after the final collect resurface from close()
        self.pool.close(raise_on_crash=self.supervisor is None)


def _build_inline(*, rollout: Callable, carries: List[Any], **_ignored):
    return InlineBackend(rollout, carries)


def _build_threaded(*, rollout: Callable, carries: List[Any],
                    max_workers: Optional[int] = None, **_ignored):
    return ThreadedBackend(rollout, carries, max_workers)


def _build_sharded(*, carries: List[Any], env=None,
                   horizon: Optional[int] = None, mesh=None,
                   rollout: Optional[Callable] = None,
                   step_keys=None, tail_keys=None, **_ignored):
    """Mesh over the host's devices, one sampler per ``data`` slice.

    ``rollout`` here is the *unjitted* per-sampler rollout (the same one
    inline/threaded schedule); it is re-wrapped in shard_map with specs
    derived from ``step_keys``/``tail_keys`` (defaults: the PPO-family
    trajectory layout).
    """
    import numpy as np
    from jax.sharding import Mesh
    from repro.core import sampler as sampler_mod
    assert env is not None and horizon is not None
    batch = sum(c[1].shape[0] for c in carries)
    if mesh is None:
        devs = np.asarray(jax.devices())
        assert batch % len(devs) == 0, (
            f"sharded backend: global env batch {batch} not divisible "
            f"by the {len(devs)} available devices; adjust "
            f"--global-batch or pass an explicit mesh")
        mesh = Mesh(devs.reshape(len(devs), 1), ("data", "model"))
    else:
        assert batch % mesh.shape["data"] == 0, (
            f"sharded backend: global env batch {batch} not divisible "
            f"by mesh data axis {mesh.shape['data']}")
    keys = {}
    if step_keys is not None:
        keys["step_keys"] = tuple(step_keys)
    if tail_keys is not None:
        keys["tail_keys"] = tuple(tail_keys)
    sharded = sampler_mod.make_sharded_rollout(env, horizon, mesh,
                                               rollout=rollout, **keys)
    carry = jax.tree.map(
        lambda *xs: jax.numpy.concatenate(xs, axis=0), *carries)
    return ShardedBackend(sharded, carry, mesh)


def build_worker_pool(*, rollout: Callable, carries: List[Any],
                      worker_specs: Sequence[Any], params: Any,
                      slots_per_worker: int = 1,
                      active_workers: Optional[Sequence[int]] = None,
                      fault_plan=None):
    """Spawn a ``ProcessWorkerPool`` for ``worker_specs``.

    ``rollout``/``carries`` are the *parent-side* builds of the same spec
    — used only under ``eval_shape`` to size the shared-memory ring (no
    rollout runs here); ``params`` sizes the params channel. The pool is
    provisioned for all ``worker_specs`` but only ``active_workers``
    (default: all) start — the elastic headroom a supervisor grows into.
    """
    from repro.core import ipc
    traj_example = jax.eval_shape(
        lambda p, c: rollout(p, c)[1], params, carries[0])
    return ipc.ProcessWorkerPool(worker_specs, params, traj_example,
                                 slots_per_worker=slots_per_worker,
                                 active_workers=active_workers,
                                 fault_plan=fault_plan)


def _build_process(*, rollout: Callable, carries: List[Any],
                   worker_specs: Optional[Sequence[Any]] = None,
                   params: Any = None, fault_plan=None,
                   supervisor_cfg=None, **_ignored):
    assert worker_specs is not None and params is not None, (
        "the process backend is built from serializable WorkerSpecs plus "
        "the learner's params (to size the shared-memory channel); "
        "construct it through repro.experiment (backend='process')")
    pool = build_worker_pool(
        rollout=rollout, carries=carries, worker_specs=worker_specs,
        params=params, slots_per_worker=1, fault_plan=fault_plan)
    supervisor = None
    if supervisor_cfg is None or supervisor_cfg.max_respawns > 0:
        from repro.core.supervisor import WorkerSupervisor
        supervisor = WorkerSupervisor(pool, supervisor_cfg)
    return ProcessBackend(pool, supervisor=supervisor)


registry.register("backend", "inline", _build_inline)
registry.register("backend", "threaded", _build_threaded)
registry.register("backend", "sharded", _build_sharded)
registry.register("backend", "process", _build_process)


def make_backend(kind: str, rollout: Callable, carries: List[Any],
                 env=None, horizon: Optional[int] = None, mesh=None,
                 **kwargs):
    """Factory used by launch/train.py, examples and ``repro.experiment``.

    Thin shim over the unified registry (kind ``"backend"``): ``inline`` /
    ``threaded`` take the per-sampler ``carries`` list; ``sharded`` builds
    its mesh over the host's devices and a single global carry (the caller
    passes ``carries`` whose batches it concatenates). Extra ``kwargs``
    (e.g. ``step_keys``/``tail_keys`` for non-PPO trajectory layouts) are
    forwarded to the backend builder.
    """
    return registry.make("backend", kind, rollout=rollout, carries=carries,
                         env=env, horizon=horizon, mesh=mesh, **kwargs)
