"""Deterministic fault injection for the actor plane (DESIGN.md §10).

Robustness claims are only testable if failures are reproducible. A
``FaultPlan`` is a seeded schedule of worker failures: every rollout a
worker performs draws one uniform from a PRNG stream keyed by
``(plan seed, worker_id, incarnation, rollout counter)`` and maps it to
at most one fault. The stream key includes the worker's *incarnation*
(how many times it has been spawned), so a respawned worker replays a
fresh — but still deterministic — schedule instead of dying at the same
step forever, and the whole run's failure pattern is a pure function of
the plan.

Fault kinds (probabilities per rollout, evaluated in this order):

* ``kill``  — SIGKILL self *before* writing the trajectory: a clean
  death with no in-flight ring state.
* ``torn``  — die *mid-write*: bump the slot's seqlock to odd (write in
  progress), then SIGKILL. This is the failure mode that used to
  deadlock the consumer; the supervisor must detect the stuck header
  and reclaim the slot.
* ``hang``  — stop heartbeating and spin forever: a wedged-but-alive
  worker, detectable only through heartbeat age.
* ``delay`` — sleep ``delay_ms`` before the rollout: a straggler, not a
  failure; exercises timeout margins without tripping them.

The plan rides ``ExperimentSpec.faults`` (the CLI's ``--inject-faults``
spec string, e.g. ``"kill:0.2,torn:0.05"``) into every worker process,
and ``benchmarks/fault_bench.py`` sweeps kill rates with it.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

KINDS = ("kill", "torn", "hang", "delay")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Seeded per-rollout fault schedule (plain data; pickles to workers)."""

    seed: int = 0
    kill: float = 0.0           # P(SIGKILL self before writing)
    torn: float = 0.0           # P(die mid-write: seqlock left odd)
    hang: float = 0.0           # P(wedge: alive but never heartbeats again)
    delay: float = 0.0          # P(sleep delay_ms before the rollout)
    delay_ms: float = 50.0

    def __post_init__(self):
        total = self.kill + self.torn + self.hang + self.delay
        if total > 1.0:
            raise ValueError(
                f"fault probabilities sum to {total:.3f} > 1 "
                f"(kill={self.kill}, torn={self.torn}, hang={self.hang}, "
                f"delay={self.delay})")
        for kind in KINDS:
            if getattr(self, kind) < 0.0:
                raise ValueError(f"fault probability {kind} must be >= 0")

    @property
    def any(self) -> bool:
        return (self.kill + self.torn + self.hang + self.delay) > 0.0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Optional[dict]) -> Optional["FaultPlan"]:
        return None if d is None else cls(**d)

    @classmethod
    def parse(cls, text: Optional[str],
              seed: int = 0) -> Optional["FaultPlan"]:
        """Parse the CLI spec string: ``kind:prob`` pairs joined by commas
        — ``"kill:0.2,torn:0.05,delay:0.1:80,seed:7"`` (``delay`` takes an
        optional ``:ms`` suffix; ``seed`` overrides the default)."""
        if not text:
            return None
        kwargs: dict = {"seed": seed}
        for part in text.split(","):
            part = part.strip()
            if not part:
                continue
            name, _, rest = part.partition(":")
            if name == "seed":
                kwargs["seed"] = int(rest)
            elif name == "delay":
                prob, _, ms = rest.partition(":")
                kwargs["delay"] = float(prob)
                if ms:
                    kwargs["delay_ms"] = float(ms)
            elif name in ("kill", "torn", "hang"):
                kwargs[name] = float(rest)
            else:
                raise ValueError(
                    f"unknown fault kind {name!r} in --inject-faults "
                    f"spec {text!r}; choose from {KINDS} (+ 'seed')")
        return cls(**kwargs)


def decide(plan: Optional[FaultPlan], worker_id: int, incarnation: int,
           step: int) -> Optional[str]:
    """The fault (or None) worker ``worker_id`` suffers at rollout
    ``step`` of its ``incarnation``-th life. Pure: the same arguments
    always produce the same decision, on any host."""
    if plan is None or not plan.any:
        return None
    rng = np.random.default_rng(
        [int(plan.seed), int(worker_id), int(incarnation), int(step)])
    u = float(rng.random())
    for kind in KINDS:
        p = getattr(plan, kind)
        if u < p:
            return kind
        u -= p
    return None
