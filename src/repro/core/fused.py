"""The fused engine: collect -> GAE -> PPO, one dispatch per chunk.

The stepped runners pay a host<->device round-trip per sampler per
iteration (dispatch the rollout, block, merge, dispatch the update, block).
On the workloads the paper measures that dispatch overhead is pure loss —
rollout, GAE and the minibatched PPO update are all jittable already. The
fused engine rolls the *entire* iteration into the body of one
``lax.scan`` over ``chunk`` iterations under a single ``jit`` with donated
buffers, so the whole collect->learn loop stays resident on the device and
the host pays one dispatch per chunk instead of ~2N per iteration
(DESIGN.md §2).

With vector collection (``schedule.env_batch`` — the env plane,
DESIGN.md §7) the rollout inside the scan steps a device-resident
``VectorEnv`` batch through the fused ``env_step`` kernels, so env
stepping included, a whole collect->GAE->learn iteration is one donated
dispatch.

``make_fused_train_loop`` builds the raw jitted chunk function;
``FusedRunner`` wraps it in the runner interface (``run`` ->
``IterationLog`` list) so launch/examples/benchmarks treat it like any
other backend.
"""
from __future__ import annotations

import time
from functools import partial
from typing import Any, Callable, Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import sampler as sampler_mod
from repro.core.backends import BackendCloseMixin
from repro.data import trajectory


class TrainState(NamedTuple):
    """Everything the fused loop carries across iterations, device-side.

    ``plane_state`` is the experience plane's ``(buffer_state, key)`` —
    replay rings and sum-trees live *inside* the donated scan carry, so
    off-policy training updates its buffer in place on device across
    chunks with zero host round-trips.
    """
    params: Any
    opt_state: Any
    env_carry: Any
    plane_state: Any = None


def make_fused_train_loop(env, learn: Optional[Callable], horizon: int,
                          chunk: int,
                          rollout: Optional[Callable] = None,
                          train_step: Optional[Callable] = None) -> Callable:
    """Build ``train_chunk(state) -> (state', metrics)``.

    ``learn`` is a jittable ``(params, opt_state, traj) -> (params,
    opt_state, metrics)`` (e.g. ``make_mlp_learner``: GAE + epochs of
    minibatched PPO). One call runs ``chunk`` full collect->learn
    iterations on device; metrics come back stacked ``(chunk, ...)`` with
    per-iteration ``mean_return``. The state argument is donated, so
    params/optimizer/env buffers are updated in place across chunks.

    ``rollout`` defaults to the PPO-family ``make_env_rollout``; pass an
    ``Algorithm``'s rollout to fuse any algo's collect->learn iteration.
    Pass ``train_step`` (``algos.api.make_train_step``) instead of
    ``learn`` to fuse the whole experience plane — observe -> sample ->
    learn with ``state.plane_state`` threaded through the scan carry.
    """
    if rollout is None:
        rollout = sampler_mod.make_env_rollout(env, horizon)

    def one_iteration(state: TrainState, _):
        env_carry, traj = rollout(state.params, state.env_carry)
        if train_step is not None:
            params, opt_state, plane_state, metrics = train_step(
                state.params, state.opt_state, state.plane_state, traj)
        else:
            params, opt_state, metrics = learn(state.params,
                                               state.opt_state, traj)
            plane_state = state.plane_state
        metrics = dict(metrics)
        metrics["mean_return"] = trajectory.episode_returns(traj)
        return TrainState(params, opt_state, env_carry, plane_state), metrics

    @partial(jax.jit, donate_argnums=(0,))
    def train_chunk(state: TrainState):
        return jax.lax.scan(one_iteration, state, None, length=chunk)

    return train_chunk


class FusedRunner(BackendCloseMixin):
    """Runner-shaped driver over the fused loop; ``close`` is the
    mixin's no-op (nothing host-side to release).

    The fused engine has no host-visible collect/learn boundary — that is
    the point — so ``IterationLog.collect_time``/``collect_time_serial``
    are 0.0 and ``learn_time`` carries the whole fused iteration's share
    of the chunk's wall time (DESIGN.md §2).
    """

    def __init__(self, env, learn: Optional[Callable], params: Any,
                 opt_state: Any, env_carry: Any, horizon: int,
                 chunk: Optional[int] = None,
                 rollout: Optional[Callable] = None,
                 train_step: Optional[Callable] = None,
                 plane_state: Any = None):
        assert learn is not None or train_step is not None
        self.env = env
        self.learn = learn
        self.train_step = train_step
        self.horizon = horizon
        self.chunk = chunk
        self.rollout = rollout
        # the chunk fn donates its input state; copy so the caller's
        # params/opt_state/carry/plane buffers survive the first dispatch
        self.state = jax.tree.map(
            jnp.copy, TrainState(params, opt_state, env_carry, plane_state))
        self.num_samplers = 1
        self.logs: List = []
        self._loops: Dict[int, Callable] = {}
        self._samples_per_iter = sampler_mod.samples_per_rollout(
            env_carry[1].shape[0], horizon)      # obs is (B, obs_dim)
        from repro.core.timing import PhaseTimer
        self.timer = PhaseTimer()

    @property
    def params(self):
        return self.state.params

    @property
    def opt_state(self):
        return self.state.opt_state

    @property
    def plane_state(self):
        return self.state.plane_state

    @property
    def buffer_state(self):
        return (None if self.state.plane_state is None
                else self.state.plane_state[0])

    def _loop_for(self, chunk: int) -> Callable:
        if chunk not in self._loops:
            self._loops[chunk] = make_fused_train_loop(
                self.env, self.learn, self.horizon, chunk,
                rollout=self.rollout, train_step=self.train_step)
        return self._loops[chunk]

    def run(self, iterations: int) -> List:
        from repro.core.orchestrator import IterationLog, record_log
        done = 0
        while done < iterations:
            c = min(self.chunk or iterations, iterations - done)
            loop = self._loop_for(c)
            t0 = time.perf_counter()
            self.state, metrics = loop(self.state)
            jax.block_until_ready(self.state.params)
            per_iter = (time.perf_counter() - t0) / c
            returns = jax.device_get(metrics["mean_return"])
            for j in range(c):
                record_log(self.logs, self.timer, IterationLog(
                    iteration=done + j,
                    collect_time=0.0,
                    collect_time_serial=0.0,
                    learn_time=per_iter,
                    mean_return=float(returns[j]),
                    samples=self._samples_per_iter,
                ))
            done += c
        return self.logs
