"""The fused engine: collect -> GAE -> PPO, one dispatch per chunk.

The stepped runners pay a host<->device round-trip per sampler per
iteration (dispatch the rollout, block, merge, dispatch the update, block).
On the workloads the paper measures that dispatch overhead is pure loss —
rollout, GAE and the minibatched PPO update are all jittable already. The
fused engine rolls the *entire* iteration into the body of one
``lax.scan`` over ``chunk`` iterations under a single ``jit`` with donated
buffers, so the whole collect->learn loop stays resident on the device and
the host pays one dispatch per chunk instead of ~2N per iteration
(DESIGN.md §2).

With vector collection (``schedule.env_batch`` — the env plane,
DESIGN.md §7) the rollout inside the scan steps a device-resident
``VectorEnv`` batch through the fused ``env_step`` kernels, so env
stepping included, a whole collect->GAE->learn iteration is one donated
dispatch.

``make_fused_train_loop`` builds the raw jitted chunk function;
``FusedRunner`` wraps it in the runner interface (``run`` ->
``IterationLog`` list) so launch/examples/benchmarks treat it like any
other backend.
"""
from __future__ import annotations

import time
from functools import partial
from typing import Any, Callable, Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import sampler as sampler_mod
from repro.core.backends import BackendCloseMixin
from repro.data import trajectory


class TrainState(NamedTuple):
    """Everything the fused loop carries across iterations, device-side.

    ``plane_state`` is the experience plane's ``(buffer_state, key)`` —
    replay rings and sum-trees live *inside* the donated scan carry, so
    off-policy training updates its buffer in place on device across
    chunks with zero host round-trips.
    """
    params: Any
    opt_state: Any
    env_carry: Any
    plane_state: Any = None


def make_fused_train_loop(env, learn: Optional[Callable], horizon: int,
                          chunk: int,
                          rollout: Optional[Callable] = None,
                          train_step: Optional[Callable] = None) -> Callable:
    """Build ``train_chunk(state) -> (state', metrics)``.

    ``learn`` is a jittable ``(params, opt_state, traj) -> (params,
    opt_state, metrics)`` (e.g. ``make_mlp_learner``: GAE + epochs of
    minibatched PPO). One call runs ``chunk`` full collect->learn
    iterations on device; metrics come back stacked ``(chunk, ...)`` with
    per-iteration ``mean_return``. The state argument is donated, so
    params/optimizer/env buffers are updated in place across chunks.

    ``rollout`` defaults to the PPO-family ``make_env_rollout``; pass an
    ``Algorithm``'s rollout to fuse any algo's collect->learn iteration.
    Pass ``train_step`` (``algos.api.make_train_step``) instead of
    ``learn`` to fuse the whole experience plane — observe -> sample ->
    learn with ``state.plane_state`` threaded through the scan carry.
    """
    if rollout is None:
        rollout = sampler_mod.make_env_rollout(env, horizon)

    def one_iteration(state: TrainState, _):
        env_carry, traj = rollout(state.params, state.env_carry)
        if train_step is not None:
            params, opt_state, plane_state, metrics = train_step(
                state.params, state.opt_state, state.plane_state, traj)
        else:
            params, opt_state, metrics = learn(state.params,
                                               state.opt_state, traj)
            plane_state = state.plane_state
        metrics = dict(metrics)
        metrics["mean_return"] = trajectory.episode_returns(traj)
        return TrainState(params, opt_state, env_carry, plane_state), metrics

    @partial(jax.jit, donate_argnums=(0,))
    def train_chunk(state: TrainState):
        return jax.lax.scan(one_iteration, state, None, length=chunk)

    return train_chunk


class FusedRunner(BackendCloseMixin):
    """Runner-shaped driver over the fused loop; ``close`` is the
    mixin's no-op (nothing host-side to release).

    The fused engine has no host-visible collect/learn boundary — that is
    the point — so ``IterationLog.collect_time``/``collect_time_serial``
    are 0.0 and ``learn_time`` carries the whole fused iteration's share
    of the chunk's wall time (DESIGN.md §2).

    ``overlap=True`` trades the single fused dispatch for a
    double-buffered two-dispatch pipeline: collect and learn become
    separate donated jits so iteration k+1's rollout executes while
    iteration k's update runs on the learner mesh (DESIGN.md §11). The
    scan ``chunk`` is ignored in this mode — the host must see the
    collect/learn boundary to pipeline across it. Overlapped collects
    act with params one update behind; the consuming iteration's log
    stamps ``staleness=1.0`` and ``overlap_saved_s`` reports the learn
    time hidden under the collect.
    """

    def __init__(self, env, learn: Optional[Callable], params: Any,
                 opt_state: Any, env_carry: Any, horizon: int,
                 chunk: Optional[int] = None,
                 rollout: Optional[Callable] = None,
                 train_step: Optional[Callable] = None,
                 plane_state: Any = None,
                 overlap: bool = False):
        assert learn is not None or train_step is not None
        self.env = env
        self.learn = learn
        self.train_step = train_step
        self.horizon = horizon
        self.chunk = chunk
        self.rollout = rollout
        self.overlap = overlap
        self._overlap_fns_cache = None
        self._overlap_clock = None        # created on first overlapped run;
        self._overlap_done = 0            # warmup is per-runner, not per
        #                                   run() call
        # the chunk fn donates its input state; copy so the caller's
        # params/opt_state/carry/plane buffers survive the first dispatch
        self.state = jax.tree.map(
            jnp.copy, TrainState(params, opt_state, env_carry, plane_state))
        self.num_samplers = 1
        self.logs: List = []
        self._loops: Dict[int, Callable] = {}
        self._samples_per_iter = sampler_mod.samples_per_rollout(
            env_carry[1].shape[0], horizon)      # obs is (B, obs_dim)
        from repro.core.timing import PhaseTimer
        self.timer = PhaseTimer()

    @property
    def params(self):
        return self.state.params

    @property
    def opt_state(self):
        return self.state.opt_state

    @property
    def plane_state(self):
        return self.state.plane_state

    @property
    def buffer_state(self):
        return (None if self.state.plane_state is None
                else self.state.plane_state[0])

    def _loop_for(self, chunk: int) -> Callable:
        if chunk not in self._loops:
            self._loops[chunk] = make_fused_train_loop(
                self.env, self.learn, self.horizon, chunk,
                rollout=self.rollout, train_step=self.train_step)
        return self._loops[chunk]

    # ----------------------------------------------------------- overlap
    def _overlap_fns(self):
        """(collect_fn, learn_fn) for the pipelined mode.

        ``collect_fn`` donates the env carry (serial chain); ``learn_fn``
        donates opt_state / plane_state / the consumed trajectory —
        params are NOT donated, the concurrent collect still reads
        them — and computes ``mean_return`` inside the trace, before
        the trajectory buffer is reclaimed for iteration k+2.
        """
        if self._overlap_fns_cache is not None:
            return self._overlap_fns_cache
        rollout = self.rollout or sampler_mod.make_env_rollout(
            self.env, self.horizon)
        train_step, learn = self.train_step, self.learn

        def learn_body(params, opt_state, plane_state, traj):
            if train_step is not None:
                params, opt_state, plane_state, metrics = train_step(
                    params, opt_state, plane_state, traj)
            else:
                params, opt_state, metrics = learn(params, opt_state, traj)
            metrics = dict(metrics)
            metrics["mean_return"] = trajectory.episode_returns(traj)
            return params, opt_state, plane_state, metrics

        self._overlap_fns_cache = (
            jax.jit(rollout, donate_argnums=(1,)),
            jax.jit(learn_body, donate_argnums=(1, 2, 3)))
        return self._overlap_fns_cache

    _OVERLAP_WARMUP = 2         # it 0 pays compilation, it 1 gives learn_ref

    def _run_overlapped(self, iterations: int) -> List:
        from repro.core.orchestrator import (
            IterationLog, OverlapClock, record_log, tree_ready)
        collect_fn, learn_fn = self._overlap_fns()
        if self._overlap_clock is None:
            self._overlap_clock = OverlapClock()
        clock = self._overlap_clock
        params, opt_state, env_carry, plane_state = self.state
        done0 = len(self.logs)

        t0 = time.perf_counter()
        env_carry, traj = collect_fn(params, env_carry)
        jax.block_until_ready(traj)
        collect_dur = time.perf_counter() - t0      # prologue collect
        stale = 0.0

        for it in range(iterations):
            data_dur, data_stale = collect_dur, stale
            t0 = time.perf_counter()
            out = learn_fn(params, opt_state, plane_state, traj)
            traj = None
            saved = 0.0
            warm, self._overlap_done = (self._overlap_done,
                                        self._overlap_done + 1)
            if warm < self._OVERLAP_WARMUP:
                # serial: block the learn, then collect with fresh params
                jax.block_until_ready(out[0])
                window = time.perf_counter() - t0
                if warm > 0:        # iteration 0 includes compilation
                    clock.note_serial(window)
                params, opt_state, plane_state, metrics = out
                if it + 1 < iterations:
                    tc = time.perf_counter()
                    env_carry, traj = collect_fn(params, env_carry)
                    jax.block_until_ready(traj)
                    collect_dur, stale = time.perf_counter() - tc, 0.0
            else:
                # pipelined: the collect acts with the pre-update params
                # while the dispatched learn runs on the learner mesh
                if it + 1 < iterations:
                    tc = time.perf_counter()
                    env_carry, traj = collect_fn(params, env_carry)
                    jax.block_until_ready(traj)
                    next_dur = time.perf_counter() - tc
                    saved = clock.saved(next_dur, tree_ready(out[0]))
                    collect_dur, stale = next_dur, 1.0
                params, opt_state, plane_state, metrics = out
                jax.block_until_ready(params)
                window = time.perf_counter() - t0
            record_log(self.logs, self.timer, IterationLog(
                iteration=done0 + it,
                collect_time=data_dur,
                collect_time_serial=data_dur,
                learn_time=max(0.0, window - saved),
                mean_return=float(metrics["mean_return"]),
                samples=self._samples_per_iter,
                staleness=data_stale,
                overlap_saved_s=saved,
            ))
        self.state = TrainState(params, opt_state, env_carry, plane_state)
        return self.logs

    def run(self, iterations: int) -> List:
        from repro.core.orchestrator import IterationLog, record_log
        if self.overlap:
            return self._run_overlapped(iterations)
        done = 0
        while done < iterations:
            c = min(self.chunk or iterations, iterations - done)
            loop = self._loop_for(c)
            t0 = time.perf_counter()
            self.state, metrics = loop(self.state)
            jax.block_until_ready(self.state.params)
            per_iter = (time.perf_counter() - t0) / c
            returns = jax.device_get(metrics["mean_return"])
            for j in range(c):
                record_log(self.logs, self.timer, IterationLog(
                    iteration=done + j,
                    collect_time=0.0,
                    collect_time_serial=0.0,
                    learn_time=per_iter,
                    mean_return=float(returns[j]),
                    samples=self._samples_per_iter,
                ))
            done += c
        return self.logs
