"""The fused engine: collect -> GAE -> PPO, one dispatch per chunk.

The stepped runners pay a host<->device round-trip per sampler per
iteration (dispatch the rollout, block, merge, dispatch the update, block).
On the workloads the paper measures that dispatch overhead is pure loss —
rollout, GAE and the minibatched PPO update are all jittable already. The
fused engine rolls the *entire* iteration into the body of one
``lax.scan`` over ``chunk`` iterations under a single ``jit`` with donated
buffers, so the whole collect->learn loop stays resident on the device and
the host pays one dispatch per chunk instead of ~2N per iteration
(DESIGN.md §2).

``make_fused_train_loop`` builds the raw jitted chunk function;
``FusedRunner`` wraps it in the runner interface (``run`` ->
``IterationLog`` list) so launch/examples/benchmarks treat it like any
other backend.
"""
from __future__ import annotations

import time
from functools import partial
from typing import Any, Callable, Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import sampler as sampler_mod
from repro.data import trajectory


class TrainState(NamedTuple):
    """Everything the fused loop carries across iterations, device-side."""
    params: Any
    opt_state: Any
    env_carry: Any


def make_fused_train_loop(env, learn: Callable, horizon: int,
                          chunk: int,
                          rollout: Optional[Callable] = None) -> Callable:
    """Build ``train_chunk(state) -> (state', metrics)``.

    ``learn`` is a jittable ``(params, opt_state, traj) -> (params,
    opt_state, metrics)`` (e.g. ``make_mlp_learner``: GAE + epochs of
    minibatched PPO). One call runs ``chunk`` full collect->learn
    iterations on device; metrics come back stacked ``(chunk, ...)`` with
    per-iteration ``mean_return``. The state argument is donated, so
    params/optimizer/env buffers are updated in place across chunks.

    ``rollout`` defaults to the PPO-family ``make_env_rollout``; pass an
    ``Algorithm``'s rollout to fuse any algo's collect->learn iteration.
    """
    if rollout is None:
        rollout = sampler_mod.make_env_rollout(env, horizon)

    def one_iteration(state: TrainState, _):
        env_carry, traj = rollout(state.params, state.env_carry)
        params, opt_state, metrics = learn(state.params, state.opt_state,
                                           traj)
        metrics = dict(metrics)
        metrics["mean_return"] = trajectory.episode_returns(traj)
        return TrainState(params, opt_state, env_carry), metrics

    @partial(jax.jit, donate_argnums=(0,))
    def train_chunk(state: TrainState):
        return jax.lax.scan(one_iteration, state, None, length=chunk)

    return train_chunk


class FusedRunner:
    """Runner-shaped driver over the fused loop.

    The fused engine has no host-visible collect/learn boundary — that is
    the point — so ``IterationLog.collect_time``/``collect_time_serial``
    are 0.0 and ``learn_time`` carries the whole fused iteration's share
    of the chunk's wall time (DESIGN.md §2).
    """

    def __init__(self, env, learn: Callable, params: Any, opt_state: Any,
                 env_carry: Any, horizon: int,
                 chunk: Optional[int] = None,
                 rollout: Optional[Callable] = None):
        self.env = env
        self.learn = learn
        self.horizon = horizon
        self.chunk = chunk
        self.rollout = rollout
        # the chunk fn donates its input state; copy so the caller's
        # params/opt_state/carry buffers survive the first dispatch
        self.state = jax.tree.map(jnp.copy,
                                  TrainState(params, opt_state, env_carry))
        self.num_samplers = 1
        self.logs: List = []
        self._loops: Dict[int, Callable] = {}
        self._samples_per_iter = sampler_mod.samples_per_rollout(
            env_carry[1].shape[0], horizon)      # obs is (B, obs_dim)
        from repro.core.timing import PhaseTimer
        self.timer = PhaseTimer()

    @property
    def params(self):
        return self.state.params

    @property
    def opt_state(self):
        return self.state.opt_state

    def _loop_for(self, chunk: int) -> Callable:
        if chunk not in self._loops:
            self._loops[chunk] = make_fused_train_loop(
                self.env, self.learn, self.horizon, chunk,
                rollout=self.rollout)
        return self._loops[chunk]

    def run(self, iterations: int) -> List:
        from repro.core.orchestrator import IterationLog, record_log
        done = 0
        while done < iterations:
            c = min(self.chunk or iterations, iterations - done)
            loop = self._loop_for(c)
            t0 = time.perf_counter()
            self.state, metrics = loop(self.state)
            jax.block_until_ready(self.state.params)
            per_iter = (time.perf_counter() - t0) / c
            returns = jax.device_get(metrics["mean_return"])
            for j in range(c):
                record_log(self.logs, self.timer, IterationLog(
                    iteration=done + j,
                    collect_time=0.0,
                    collect_time_serial=0.0,
                    learn_time=per_iter,
                    mean_return=float(returns[j]),
                    samples=self._samples_per_iter,
                ))
            done += c
        return self.logs
