"""Shared-memory IPC for the actor plane (DESIGN.md §6).

WALL-E's sampler parallelism is *process*-level: N rollout workers, each
owning its own Python interpreter and XLA client, feed one learner. The
transport here moves trajectories and policy parameters across the
process boundary without pickling arrays per iteration:

* ``ShmRing`` — a slotted trajectory ring: one
  ``multiprocessing.shared_memory`` block per trajectory leaf (numpy
  views, zero-copy on the writer side) plus seqlock-style slot headers
  (sequence counter: odd = write in progress, even = stable; an ``ack``
  counter lets the producer block until its previous slot was consumed).
* ``ParamsChannel`` — a versioned params cell generalizing
  ``core.queues.PolicyStore`` across processes: the learner publishes
  flattened param leaves into fixed shared blocks; workers poll a version
  word and copy only when it changed, so params cross the boundary once
  per *publish*, not once per rollout.
* ``ProcessWorkerPool`` — spawns N workers (``spawn`` start method; no
  closures cross the boundary — each worker rebuilds its jitted rollout
  from a serializable ``core.sampler.WorkerSpec`` purely via the
  registry), drives them in lock-step (``collect``) or free-running mode
  (``start_freerun``/``next_experience``), surfaces worker crashes as
  ``WorkerCrashed``, and reaps everything on ``close``.

Memory-ordering note: the seqlock headers are consistency *checks*; the
ordering guarantee producers rely on is the command/result queue
handshake (a pipe write/read pair is a full barrier), so the protocol
does not depend on fenced stores into the mmap.
"""
from __future__ import annotations

import atexit
import dataclasses
import json
import os
import queue as _queue
import time
import traceback
import uuid
from multiprocessing import shared_memory
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

# slot header layout: int64 words per slot ...
_H_SEQ, _H_ACK, _H_VERSION, _H_WORKER = 0, 1, 2, 3
_HDR_I = 4
# ... plus float64 words per slot
_H_COLLECT_S, _H_LOOP_S = 0, 1
_HDR_F = 2


class WorkerCrashed(RuntimeError):
    """A rollout worker process died or raised; message carries details."""


# Resource-tracker note: Python 3.10 registers every ``SharedMemory``
# with the resource tracker even when attaching (``create=False``). That
# is benign here — worker processes are spawned by ``multiprocessing`` and
# therefore share the *parent's* tracker, whose cache is a name-keyed set:
# a child's attach-registration is a no-op add, and the parent's ``unlink``
# unregisters the name exactly once. (Explicitly unregistering in children
# would instead strip the parent's registration and raise KeyErrors in the
# tracker at shutdown.)


@dataclasses.dataclass(frozen=True)
class LeafSpec:
    """Shape/dtype of one pytree leaf inside a shared block (picklable)."""
    key: str
    shape: Tuple[int, ...]
    dtype: str


@dataclasses.dataclass(frozen=True)
class RingSpec:
    """Everything a fresh process needs to attach to a ``ShmRing``."""
    prefix: str
    slots: int
    leaves: Tuple[LeafSpec, ...]


def _leaf_specs(example: Dict[str, Any]) -> Tuple[LeafSpec, ...]:
    """Sorted-key leaf specs from a dict of arrays/ShapeDtypeStructs."""
    return tuple(
        LeafSpec(key=k, shape=tuple(example[k].shape),
                 dtype=np.dtype(example[k].dtype).str)
        for k in sorted(example))


class ShmRing:
    """Slotted trajectory ring over one shared block per trajectory leaf.

    Slot ``s`` of leaf ``k`` is the numpy view ``self.views[k][s]``; the
    header block carries per-slot ``(seq, ack, policy_version, worker_id)``
    int64 words and ``(collect_seconds, loop_seconds)`` float64 words.
    Writers bump ``seq`` to odd before touching the payload and to even
    after; readers copy then re-check ``seq``. ``ack`` is written by the
    consumer (``ack(slot)``) so a producer can wait until its previous
    write was drained (``is_free``) — the ring's only backpressure.
    """

    def __init__(self, spec: RingSpec, create: bool):
        self.spec = spec
        self._shms: List[shared_memory.SharedMemory] = []
        self.views: Dict[str, np.ndarray] = {}
        for i, leaf in enumerate(spec.leaves):
            nbytes = (spec.slots * int(np.prod(leaf.shape, dtype=np.int64))
                      * np.dtype(leaf.dtype).itemsize)
            shm = self._open(f"{spec.prefix}-l{i}", create, max(nbytes, 8))
            self.views[leaf.key] = np.ndarray(
                (spec.slots, *leaf.shape), dtype=leaf.dtype, buffer=shm.buf)
        hdr_bytes = spec.slots * (_HDR_I * 8 + _HDR_F * 8)
        shm = self._open(f"{spec.prefix}-hdr", create, hdr_bytes)
        self._hdr_i = np.ndarray((spec.slots, _HDR_I), dtype=np.int64,
                                 buffer=shm.buf, offset=0)
        self._hdr_f = np.ndarray((spec.slots, _HDR_F), dtype=np.float64,
                                 buffer=shm.buf,
                                 offset=spec.slots * _HDR_I * 8)
        if create:
            self._hdr_i.fill(0)
            self._hdr_f.fill(0.0)

    def _open(self, name: str, create: bool,
              size: int) -> shared_memory.SharedMemory:
        shm = shared_memory.SharedMemory(
            name=name, create=create, size=size if create else 0)
        self._shms.append(shm)
        return shm

    @classmethod
    def create(cls, example: Dict[str, Any], slots: int,
               prefix: str) -> "ShmRing":
        return cls(RingSpec(prefix=prefix, slots=slots,
                            leaves=_leaf_specs(example)), create=True)

    @classmethod
    def attach(cls, spec: RingSpec) -> "ShmRing":
        return cls(spec, create=False)

    # ------------------------------------------------------------- producer
    def write(self, slot: int, traj: Dict[str, np.ndarray], *,
              worker_id: int, policy_version: int,
              collect_seconds: float, loop_seconds: float) -> None:
        seq = int(self._hdr_i[slot, _H_SEQ])
        self._hdr_i[slot, _H_SEQ] = seq + 1          # odd: write in progress
        for leaf in self.spec.leaves:
            self.views[leaf.key][slot][...] = traj[leaf.key]
        self._hdr_i[slot, _H_VERSION] = policy_version
        self._hdr_i[slot, _H_WORKER] = worker_id
        self._hdr_f[slot, _H_COLLECT_S] = collect_seconds
        self._hdr_f[slot, _H_LOOP_S] = loop_seconds
        self._hdr_i[slot, _H_SEQ] = seq + 2          # even: stable

    def is_free(self, slot: int) -> bool:
        """True when the consumer acked everything written to ``slot``."""
        return int(self._hdr_i[slot, _H_ACK]) == int(
            self._hdr_i[slot, _H_SEQ])

    # ------------------------------------------------------------- consumer
    def read(self, slot: int) -> Tuple[Dict[str, np.ndarray], Dict[str, Any]]:
        """Copy one slot out; retries (bounded) on a torn seqlock read."""
        for _ in range(1000):
            s1 = int(self._hdr_i[slot, _H_SEQ])
            if s1 % 2:                                # writer mid-flight
                time.sleep(1e-4)
                continue
            traj = {leaf.key: np.array(self.views[leaf.key][slot])
                    for leaf in self.spec.leaves}
            meta = {
                "policy_version": int(self._hdr_i[slot, _H_VERSION]),
                "worker_id": int(self._hdr_i[slot, _H_WORKER]),
                "collect_seconds": float(self._hdr_f[slot, _H_COLLECT_S]),
                "loop_seconds": float(self._hdr_f[slot, _H_LOOP_S]),
            }
            if int(self._hdr_i[slot, _H_SEQ]) == s1:
                return traj, meta
        raise WorkerCrashed(
            f"trajectory ring slot {slot} never stabilized (torn seqlock "
            f"read 1000x) — a worker is stuck mid-write")

    def ack(self, slot: int) -> None:
        self._hdr_i[slot, _H_ACK] = self._hdr_i[slot, _H_SEQ]

    # ------------------------------------------------------------ lifecycle
    def close(self, unlink: bool = False) -> None:
        # drop numpy views before closing the mmaps they point into
        self.views = {}
        self._hdr_i = self._hdr_f = None
        for shm in self._shms:
            try:
                shm.close()
                if unlink:
                    shm.unlink()
            except FileNotFoundError:
                pass
        self._shms = []


@dataclasses.dataclass(frozen=True)
class ChannelSpec:
    """Attach info for a ``ParamsChannel`` (picklable).

    Also JSON round-trippable (``to_json``/``from_json``): worker
    processes receive the spec over the spawn boundary, but a *serving*
    replica (``repro.serve``) may be launched independently of the
    learner — the learner drops the spec as a handoff file and the
    replica attaches from it (``launch/serve_policy.py
    --channel-spec``).
    """
    prefix: str
    leaves: Tuple[LeafSpec, ...]

    def to_json(self) -> str:
        return json.dumps({
            "prefix": self.prefix,
            "leaves": [dataclasses.asdict(l) for l in self.leaves],
        })

    @classmethod
    def from_json(cls, text: str) -> "ChannelSpec":
        d = json.loads(text)
        return cls(prefix=d["prefix"], leaves=tuple(
            LeafSpec(key=l["key"], shape=tuple(l["shape"]),
                     dtype=l["dtype"]) for l in d["leaves"]))


class ParamsChannel:
    """Versioned cross-process params cell — ``PolicyStore`` over shm.

    One shared block per flattened param leaf plus a single seqlock word:
    ``publish`` bumps it to odd, overwrites every leaf, bumps to even;
    ``version == seq // 2`` counts publishes. Readers (``read``) spin
    until the version moves past ``min_version``, copy, and re-check —
    so workers always act with the freshest published policy (possibly
    stale, never torn) and pay the copy only when it actually changed.
    """

    def __init__(self, spec: ChannelSpec, create: bool):
        self.spec = spec
        self._shms: List[shared_memory.SharedMemory] = []
        self._views: List[np.ndarray] = []
        for i, leaf in enumerate(spec.leaves):
            nbytes = (int(np.prod(leaf.shape, dtype=np.int64))
                      * np.dtype(leaf.dtype).itemsize)
            shm = self._open(f"{spec.prefix}-l{i}", create, max(nbytes, 8))
            self._views.append(np.ndarray(leaf.shape, dtype=leaf.dtype,
                                          buffer=shm.buf))
        shm = self._open(f"{spec.prefix}-hdr", create, 8)
        self._hdr = np.ndarray((1,), dtype=np.int64, buffer=shm.buf)
        if create:
            self._hdr[0] = 0

    def _open(self, name: str, create: bool,
              size: int) -> shared_memory.SharedMemory:
        shm = shared_memory.SharedMemory(
            name=name, create=create, size=size if create else 0)
        self._shms.append(shm)
        return shm

    @classmethod
    def create(cls, leaves: Sequence[np.ndarray],
               prefix: str) -> "ParamsChannel":
        spec = ChannelSpec(prefix=prefix, leaves=tuple(
            LeafSpec(key=str(i), shape=tuple(x.shape),
                     dtype=np.dtype(x.dtype).str)
            for i, x in enumerate(leaves)))
        return cls(spec, create=True)

    @classmethod
    def attach(cls, spec: ChannelSpec) -> "ParamsChannel":
        return cls(spec, create=False)

    @property
    def version(self) -> int:
        return int(self._hdr[0]) // 2

    def publish(self, leaves: Sequence[np.ndarray]) -> int:
        if len(leaves) != len(self._views):
            raise ValueError(
                f"params channel holds {len(self._views)} leaves, "
                f"publish got {len(leaves)}")
        seq = int(self._hdr[0])
        self._hdr[0] = seq + 1
        for view, leaf in zip(self._views, leaves):
            view[...] = leaf
        self._hdr[0] = seq + 2
        return (seq + 2) // 2

    def read(self, min_version: int = 0, last_version: int = -1,
             should_stop: Optional[Callable[[], bool]] = None,
             poll: float = 1e-4
             ) -> Tuple[Optional[List[np.ndarray]], int]:
        """Block until ``version >= min_version``; return
        ``(leaf_copies, version)`` — leaves are ``None`` when the version
        equals ``last_version`` (nothing new to copy) or when
        ``should_stop()`` fired (version reported as -1)."""
        while True:
            s1 = int(self._hdr[0])
            if s1 % 2 == 0 and s1 // 2 >= min_version:
                version = s1 // 2
                if version == last_version:
                    return None, version
                out = [np.array(v) for v in self._views]
                if int(self._hdr[0]) == s1:
                    return out, version
                continue                              # torn read: retry
            if should_stop is not None and should_stop():
                return None, -1
            time.sleep(poll)

    def close(self, unlink: bool = False) -> None:
        self._views = []
        self._hdr = None
        for shm in self._shms:
            try:
                shm.close()
                if unlink:
                    shm.unlink()
            except FileNotFoundError:
                pass
        self._shms = []


# ======================================================= the worker process
def _worker_main(spec_dict: Dict[str, Any], ring_spec: RingSpec,
                 chan_spec: ChannelSpec, worker_id: int, slot_base: int,
                 num_slots: int, cmd_q, res_q) -> None:
    """Entry point of one rollout worker process.

    Rebuilds env/algo/rollout from the serialized ``WorkerSpec`` purely
    via the registry (nothing else crossed the boundary), then serves:

      ("collect", v) — one rollout under params version >= v, write slot,
                       report;  the lock-step mode ``ProcessBackend`` uses
      ("freerun", v) — roll continuously with the freshest published
                       params, blocking only when the ring slot has not
                       been consumed; the ``AsyncOrchestrator`` mode
      ("stop",)      — exit cleanly

    Any exception is reported upstream as ("error", id, traceback) and
    surfaces in the parent as ``WorkerCrashed``.
    """
    try:
        # spread workers round-robin over the host's cores: deterministic
        # placement avoids the migration thrash the kernel scheduler adds
        # when workers outnumber cores (a worker never fights more peers
        # than ceil(N / cores) for its core); a no-op gain when cores >= N
        if hasattr(os, "sched_setaffinity"):
            try:
                cores = sorted(os.sched_getaffinity(0))
                os.sched_setaffinity(
                    0, {cores[worker_id % len(cores)]})
            except OSError:
                pass
        import jax
        import jax.numpy as jnp

        from repro.core.sampler import WorkerSpec

        spec = WorkerSpec.from_dict(spec_dict)
        rollout, carry, params_template = spec.build()
        rollout = jax.jit(rollout)
        t_leaves, treedef = jax.tree_util.tree_flatten(params_template)
        ring = ShmRing.attach(ring_spec)
        chan = ParamsChannel.attach(chan_spec)
        if len(t_leaves) != len(chan.spec.leaves):
            raise RuntimeError(
                f"worker {worker_id}: rebuilt params have "
                f"{len(t_leaves)} leaves, channel carries "
                f"{len(chan.spec.leaves)} — WorkerSpec and learner params "
                f"disagree")
        res_q.put(("ready", worker_id))

        params, last_version = None, -1
        freerunning, counter, stop = False, 0, False
        while not stop:
            if freerunning:
                try:
                    cmd = cmd_q.get_nowait()
                except _queue.Empty:
                    cmd = ("step", 0)
            else:
                cmd = cmd_q.get()
            op = cmd[0]
            if op == "stop":
                break
            if op == "freerun":
                freerunning = True
                continue
            # op is "collect" (lock-step) or "step" (free-running)
            min_version = cmd[1] if len(cmd) > 1 else 0
            t_loop0 = time.perf_counter()
            np_leaves, version = chan.read(min_version=min_version,
                                           last_version=last_version)
            if np_leaves is not None:
                params = treedef.unflatten(
                    [jnp.asarray(x) for x in np_leaves])
                last_version = version
            t0 = time.perf_counter()
            carry, traj = rollout(params, carry)
            traj = jax.block_until_ready(traj)
            dt = time.perf_counter() - t0
            traj_np = {k: np.asarray(v) for k, v in traj.items()}
            slot = slot_base + (counter % num_slots)
            while not ring.is_free(slot):      # learner behind: back off
                try:
                    nxt = cmd_q.get(timeout=0.002)
                    if nxt[0] == "stop":
                        stop = True
                        break
                except _queue.Empty:
                    pass
            if stop:
                break
            loop_dt = time.perf_counter() - t_loop0
            ring.write(slot, traj_np, worker_id=worker_id,
                       policy_version=last_version, collect_seconds=dt,
                       loop_seconds=loop_dt)
            res_q.put(("traj", worker_id, slot, last_version, dt,
                       time.perf_counter() - t_loop0))
            counter += 1
        ring.close()
        chan.close()
    except Exception:
        try:
            res_q.put(("error", worker_id, traceback.format_exc()))
        except Exception:
            pass


# ============================================================ the worker pool
class ProcessWorkerPool:
    """N rollout worker processes + the shared-memory transport between
    them and this (learner) process.

    Construction publishes the initial params (version 1), spawns the
    workers and blocks until every one reports ready — a worker that dies
    while importing/building surfaces immediately as ``WorkerCrashed``.

    Two driving modes:

    * ``collect()`` — lock-step: broadcast one ("collect", version)
      command, await N results, return per-worker trajectories **in
      worker-index order** (the determinism rule that makes
      ``process == inline`` exact for matched per-worker seeds).
    * ``start_freerun()`` + ``next_experience()`` — the async mode:
      workers roll continuously against the freshest published params;
      the learner drains finished slots as ``core.queues.Experience``
      records. Backpressure is the ring itself (``slots_per_worker``
      unconsumed rollouts per worker, then the worker blocks), so
      nothing is ever dropped.

    Workers are daemonic and additionally reaped by an ``atexit`` hook,
    so Ctrl-C in the learner never leaves orphan samplers behind.
    """

    def __init__(self, worker_specs: Sequence[Any], params: Any,
                 traj_example: Dict[str, Any], slots_per_worker: int = 1,
                 start_timeout: float = 300.0,
                 collect_timeout: float = 600.0):
        import jax
        import multiprocessing as mp

        self.num_workers = len(worker_specs)
        self.slots_per_worker = int(slots_per_worker)
        self.collect_timeout = collect_timeout
        self._closed = False
        self._freerunning = False
        ctx = mp.get_context("spawn")
        prefix = f"walle-{os.getpid()}-{uuid.uuid4().hex[:6]}"
        leaves = [np.asarray(jax.device_get(x))
                  for x in jax.tree_util.tree_leaves(params)]
        self.channel = ParamsChannel.create(leaves, prefix + "-p")
        self.version = self.channel.publish(leaves)
        self.ring = ShmRing.create(
            traj_example, self.num_workers * self.slots_per_worker,
            prefix + "-t")
        self._cmd = [ctx.Queue() for _ in range(self.num_workers)]
        self._res = ctx.Queue()
        self._procs = [
            ctx.Process(
                target=_worker_main, name=f"walle-worker-{i}", daemon=True,
                args=(spec.to_dict(), self.ring.spec, self.channel.spec,
                      i, i * self.slots_per_worker, self.slots_per_worker,
                      self._cmd[i], self._res))
            for i, spec in enumerate(worker_specs)
        ]
        # Children inherit the environment at spawn; adjust it around
        # start() only (the parent's own, already-initialized client is
        # unaffected):
        #  * rollout workers are host-side sampler processes — default
        #    them to the CPU client unless a platform is pinned explicitly
        #  * limit each worker's XLA CPU intra-op pool to one thread: N
        #    workers x one multi-threaded eigen pool oversubscribes small
        #    hosts and *slows* collection as N grows (bitwise-neutral for
        #    rollout-sized ops — asserted by the process==inline parity
        #    tests, which run the parent multi-threaded)
        saved = {k: os.environ.get(k) for k in ("JAX_PLATFORMS",
                                                "XLA_FLAGS")}
        if saved["JAX_PLATFORMS"] is None:
            os.environ["JAX_PLATFORMS"] = "cpu"
        flags = saved["XLA_FLAGS"] or ""
        if "intra_op_parallelism_threads" not in flags:
            os.environ["XLA_FLAGS"] = (
                flags + " --xla_cpu_multi_thread_eigen=false "
                "intra_op_parallelism_threads=1").strip()
        try:
            for p in self._procs:
                p.start()
        finally:
            for k, v in saved.items():
                if v is None:
                    os.environ.pop(k, None)
                else:
                    os.environ[k] = v
        atexit.register(self.close)
        try:
            ready = set()
            while len(ready) < self.num_workers:
                msg = self._get(timeout=start_timeout)
                if msg[0] == "ready":
                    ready.add(msg[1])
        except BaseException:
            self.close()
            raise

    # ------------------------------------------------------------- plumbing
    def _check_alive(self) -> None:
        dead = [(i, p.exitcode) for i, p in enumerate(self._procs)
                if not p.is_alive()]
        if dead:
            raise WorkerCrashed(
                "rollout worker(s) died: " + ", ".join(
                    f"#{i} (exitcode={code})" for i, code in dead))

    def _get(self, timeout: float):
        """Next result-queue message; raises ``WorkerCrashed`` on worker
        error/death and ``TimeoutError`` past ``timeout``."""
        deadline = time.monotonic() + timeout
        while True:
            try:
                msg = self._res.get(timeout=0.25)
            except _queue.Empty:
                self._check_alive()
                if time.monotonic() > deadline:
                    raise TimeoutError(
                        f"no worker result within {timeout:.0f}s")
                continue
            if msg[0] == "error":
                raise WorkerCrashed(
                    f"rollout worker #{msg[1]} raised:\n{msg[2]}")
            return msg

    def _read_slot(self, slot: int):
        traj, meta = self.ring.read(slot)
        self.ring.ack(slot)
        return traj, meta

    # ------------------------------------------------------------ lock-step
    def publish(self, params: Any) -> int:
        import jax
        self.version = self.channel.publish(
            [np.asarray(jax.device_get(x))
             for x in jax.tree_util.tree_leaves(params)])
        return self.version

    def collect(self, staggered: bool = False
                ) -> Tuple[List[Dict[str, np.ndarray]], List[float],
                           List[float]]:
        """One lock-step sweep: every worker rolls once under the current
        params version; trajectories come back in worker-index order.

        ``staggered=True`` commands workers one at a time, awaiting each
        result before waking the next. On hosts with fewer cores than
        workers the default broadcast makes every worker's self-timed
        rollout include preemption by its peers (they time-slice the same
        cores), so the per-worker times — and the critical-path throughput
        derived from them — measure scheduler contention, not sampler
        work. Staggering serializes the sweep so each worker runs
        uncontended, recovering the per-sampler steady-state timing the
        inline backend's serial sweep reports (DESIGN.md §2's
        methodology). Trajectories, merge order and determinism are
        identical either way — only the wall-clock overlap changes.
        """
        if self._closed:
            raise RuntimeError("worker pool is closed")
        if self._freerunning:
            raise RuntimeError(
                "pool is free-running (async mode); lock-step collect() "
                "would interleave with unsolicited rollouts")
        version = self.channel.version
        got: Dict[int, Tuple[int, float, float]] = {}
        if staggered:
            for i in range(self.num_workers):
                self._cmd[i].put(("collect", version))
                _, wid, slot, _v, dt, loop_dt = self._get(
                    self.collect_timeout)
                got[wid] = (slot, dt, loop_dt)
        else:
            for q in self._cmd:
                q.put(("collect", version))
            while len(got) < self.num_workers:
                _, wid, slot, _v, dt, loop_dt = self._get(
                    self.collect_timeout)
                got[wid] = (slot, dt, loop_dt)
        trajs, times, loops = [], [], []
        for i in range(self.num_workers):        # deterministic merge order
            slot, dt, loop_dt = got[i]
            traj, _meta = self._read_slot(slot)
            trajs.append(traj)
            times.append(dt)
            loops.append(loop_dt)
        return trajs, times, loops

    # ------------------------------------------------------------- freerun
    def start_freerun(self) -> None:
        if self._freerunning:
            return
        self._freerunning = True
        for q in self._cmd:
            q.put(("freerun",))

    def next_experience(self, timeout: float = 1.0):
        """Drain one finished rollout as ``(Experience, loop_seconds)``;
        ``None`` if nothing finished within ``timeout``."""
        from repro.core.queues import Experience
        try:
            _, wid, slot, version, dt, _loop = self._get(timeout)
        except TimeoutError:
            return None
        traj, meta = self._read_slot(slot)
        return (Experience(traj=traj, policy_version=version,
                           sampler_id=wid, collect_seconds=dt),
                meta["loop_seconds"])

    # ------------------------------------------------------------ lifecycle
    def close(self) -> None:
        """Stop, join (terminate stragglers) and unlink all shared state.
        Idempotent; also runs from ``atexit`` so Ctrl-C reaps workers."""
        if self._closed:
            return
        self._closed = True
        for q in self._cmd:
            try:
                q.put_nowait(("stop",))
            except Exception:
                pass
        for p in self._procs:
            p.join(timeout=3.0)
        for p in self._procs:
            if p.is_alive():
                p.terminate()
        for p in self._procs:
            p.join(timeout=3.0)
        for q in [*self._cmd, self._res]:
            try:
                q.close()
                q.cancel_join_thread()
            except Exception:
                pass
        self.ring.close(unlink=True)
        self.channel.close(unlink=True)
        try:
            atexit.unregister(self.close)
        except Exception:
            pass

    def __enter__(self) -> "ProcessWorkerPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
