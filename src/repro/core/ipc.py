"""Shared-memory IPC for the actor plane (DESIGN.md §6, §10).

WALL-E's sampler parallelism is *process*-level: N rollout workers, each
owning its own Python interpreter and XLA client, feed one learner. The
transport here moves trajectories and policy parameters across the
process boundary without pickling arrays per iteration:

* ``ShmRing`` — a slotted trajectory ring: one
  ``multiprocessing.shared_memory`` block per trajectory leaf (numpy
  views, zero-copy on the writer side) plus seqlock-style slot headers
  (sequence counter: odd = write in progress, even = stable; an ``ack``
  counter lets the producer block until its previous slot was consumed).
  Writers stamp their pid into the header *before* touching the payload,
  so a slot left mid-write by a dead worker names its writer; ``read``
  is deadline-bounded (``RingSlotStuck``) and ``reclaim`` repairs such
  slots instead of deadlocking the consumer.
* ``ParamsChannel`` — a versioned params cell generalizing
  ``core.queues.PolicyStore`` across processes: the learner publishes
  flattened param leaves into fixed shared blocks; workers poll a version
  word and copy only when it changed, so params cross the boundary once
  per *publish*, not once per rollout.
* ``Heartbeat`` — one monotonic-clock timestamp word per worker slot in
  shared memory. Workers stamp it every loop; the supervisor reads
  ``age`` to distinguish a wedged-but-alive worker (process up, beats
  stopped) from a merely slow one. CLOCK_MONOTONIC is system-wide on
  Linux, so cross-process timestamps are directly comparable.
* ``ProcessWorkerPool`` — spawns workers (``spawn`` start method; no
  closures cross the boundary — each worker rebuilds its jitted rollout
  from a serializable ``core.sampler.WorkerSpec`` purely via the
  registry), drives them in lock-step (``collect``) or free-running mode
  (``start_freerun``/``next_experience``), surfaces worker crashes as
  ``WorkerCrashed``, and reaps everything on ``close``. The pool is
  *elastic*: it is provisioned for ``max_workers`` specs/slots up front
  but only the ``active`` subset runs; ``grow``/``shrink``/``respawn``
  re-use the pre-sized ring and params channel, so resizing never
  reallocates shared memory. ``core.supervisor.WorkerSupervisor`` layers
  failure detection and respawn policy on top of the primitives exposed
  here (``poll_msg``/``dead_workers``/``heartbeat_age``/
  ``reclaim_worker_slots``/``read_slot_checked``).

Memory-ordering note: the seqlock headers are consistency *checks*; the
ordering guarantee producers rely on is the command/result queue
handshake (a pipe write/read pair is a full barrier), so the protocol
does not depend on fenced stores into the mmap.
"""
from __future__ import annotations

import atexit
import collections
import contextlib
import dataclasses
import json
import os
import queue as _queue
import signal
import sys
import time
import traceback
import uuid
from multiprocessing import shared_memory
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

# slot header layout: int64 words per slot ...
_H_SEQ, _H_ACK, _H_VERSION, _H_WORKER, _H_PID = 0, 1, 2, 3, 4
_HDR_I = 5
# ... plus float64 words per slot
_H_COLLECT_S, _H_LOOP_S = 0, 1
_HDR_F = 2


class WorkerCrashed(RuntimeError):
    """A rollout worker process died or raised; message carries details."""


class RingSlotStuck(WorkerCrashed):
    """A ring slot's seqlock never stabilized within the read deadline —
    its writer almost certainly died mid-write. Carries ``slot``,
    ``writer_pid``, ``worker_id`` and the stuck ``seq`` so a supervisor
    can reclaim exactly what is stuck."""

    def __init__(self, msg: str, *, slot: int, writer_pid: int,
                 worker_id: int, seq: int):
        super().__init__(msg)
        self.slot = slot
        self.writer_pid = writer_pid
        self.worker_id = worker_id
        self.seq = seq


class StaleSlotMessage(RuntimeError):
    """A queued trajectory message references a slot whose seqlock moved
    past the message's recorded ``seq`` — the slot was reclaimed and
    rewritten after the original writer died. The message must be
    discarded, never read: consuming it would double-count the slot's
    *new* contents."""


# Resource-tracker note: Python 3.10 registers every ``SharedMemory``
# with the resource tracker even when attaching (``create=False``). That
# is benign here — worker processes are spawned by ``multiprocessing`` and
# therefore share the *parent's* tracker, whose cache is a name-keyed set:
# a child's attach-registration is a no-op add, and the parent's ``unlink``
# unregisters the name exactly once. (Explicitly unregistering in children
# would instead strip the parent's registration and raise KeyErrors in the
# tracker at shutdown.)


@dataclasses.dataclass(frozen=True)
class LeafSpec:
    """Shape/dtype of one pytree leaf inside a shared block (picklable)."""
    key: str
    shape: Tuple[int, ...]
    dtype: str


@dataclasses.dataclass(frozen=True)
class RingSpec:
    """Everything a fresh process needs to attach to a ``ShmRing``."""
    prefix: str
    slots: int
    leaves: Tuple[LeafSpec, ...]


def _leaf_specs(example: Dict[str, Any]) -> Tuple[LeafSpec, ...]:
    """Sorted-key leaf specs from a dict of arrays/ShapeDtypeStructs."""
    return tuple(
        LeafSpec(key=k, shape=tuple(example[k].shape),
                 dtype=np.dtype(example[k].dtype).str)
        for k in sorted(example))


class ShmRing:
    """Slotted trajectory ring over one shared block per trajectory leaf.

    Slot ``s`` of leaf ``k`` is the numpy view ``self.views[k][s]``; the
    header block carries per-slot ``(seq, ack, policy_version, worker_id,
    writer_pid)`` int64 words and ``(collect_seconds, loop_seconds)``
    float64 words. Writers bump ``seq`` to odd and stamp their identity
    before touching the payload, and bump ``seq`` to even after; readers
    copy then re-check ``seq``. ``ack`` is written by the consumer
    (``ack(slot)``) so a producer can wait until its previous write was
    drained (``is_free``) — the ring's only backpressure.

    Failure repair: a writer that dies mid-write leaves ``seq`` odd
    forever. ``read`` gives up after ``timeout`` with ``RingSlotStuck``
    (naming slot, writer pid and seqlock state), and ``reclaim`` makes
    such a slot writable again without ever presenting torn payload data
    to the consumer.
    """

    def __init__(self, spec: RingSpec, create: bool):
        self.spec = spec
        self._shms: List[shared_memory.SharedMemory] = []
        self.views: Dict[str, np.ndarray] = {}
        for i, leaf in enumerate(spec.leaves):
            nbytes = (spec.slots * int(np.prod(leaf.shape, dtype=np.int64))
                      * np.dtype(leaf.dtype).itemsize)
            shm = self._open(f"{spec.prefix}-l{i}", create, max(nbytes, 8))
            self.views[leaf.key] = np.ndarray(
                (spec.slots, *leaf.shape), dtype=leaf.dtype, buffer=shm.buf)
        hdr_bytes = spec.slots * (_HDR_I * 8 + _HDR_F * 8)
        shm = self._open(f"{spec.prefix}-hdr", create, hdr_bytes)
        self._hdr_i = np.ndarray((spec.slots, _HDR_I), dtype=np.int64,
                                 buffer=shm.buf, offset=0)
        self._hdr_f = np.ndarray((spec.slots, _HDR_F), dtype=np.float64,
                                 buffer=shm.buf,
                                 offset=spec.slots * _HDR_I * 8)
        if create:
            self._hdr_i.fill(0)
            self._hdr_f.fill(0.0)

    def _open(self, name: str, create: bool,
              size: int) -> shared_memory.SharedMemory:
        shm = shared_memory.SharedMemory(
            name=name, create=create, size=size if create else 0)
        self._shms.append(shm)
        return shm

    @classmethod
    def create(cls, example: Dict[str, Any], slots: int,
               prefix: str) -> "ShmRing":
        return cls(RingSpec(prefix=prefix, slots=slots,
                            leaves=_leaf_specs(example)), create=True)

    @classmethod
    def attach(cls, spec: RingSpec) -> "ShmRing":
        return cls(spec, create=False)

    # ------------------------------------------------------------- producer
    def write(self, slot: int, traj: Dict[str, np.ndarray], *,
              worker_id: int, policy_version: int,
              collect_seconds: float, loop_seconds: float) -> int:
        """Seqlocked write of one trajectory; returns the slot's new
        (even) ``seq`` — the writer reports it alongside the slot index
        so the consumer can verify the slot still holds *this* write."""
        seq = int(self._hdr_i[slot, _H_SEQ])
        self._hdr_i[slot, _H_SEQ] = seq + 1          # odd: write in progress
        # identity first: a writer that dies mid-payload is still named
        self._hdr_i[slot, _H_WORKER] = worker_id
        self._hdr_i[slot, _H_PID] = os.getpid()
        for leaf in self.spec.leaves:
            self.views[leaf.key][slot][...] = traj[leaf.key]
        self._hdr_i[slot, _H_VERSION] = policy_version
        self._hdr_f[slot, _H_COLLECT_S] = collect_seconds
        self._hdr_f[slot, _H_LOOP_S] = loop_seconds
        self._hdr_i[slot, _H_SEQ] = seq + 2          # even: stable
        return seq + 2

    def begin_torn_write(self, slot: int, worker_id: int) -> None:
        """Start a write (seq to odd, identity stamped) and never finish
        it — the fault-injection hook behind ``FaultPlan``'s ``torn``
        kind: the worker calls this then SIGKILLs itself, leaving exactly
        the stuck-mid-write header a real mid-write death leaves."""
        seq = int(self._hdr_i[slot, _H_SEQ])
        self._hdr_i[slot, _H_SEQ] = seq + 1
        self._hdr_i[slot, _H_WORKER] = worker_id
        self._hdr_i[slot, _H_PID] = os.getpid()

    def is_free(self, slot: int) -> bool:
        """True when the consumer acked everything written to ``slot``."""
        return int(self._hdr_i[slot, _H_ACK]) == int(
            self._hdr_i[slot, _H_SEQ])

    def seq(self, slot: int) -> int:
        return int(self._hdr_i[slot, _H_SEQ])

    # ------------------------------------------------------------- consumer
    def read(self, slot: int, timeout: float = 5.0
             ) -> Tuple[Dict[str, np.ndarray], Dict[str, Any]]:
        """Copy one slot out; retries on a torn seqlock read but gives up
        after ``timeout`` seconds with ``RingSlotStuck`` instead of
        spinning forever behind a dead writer."""
        deadline = time.monotonic() + timeout
        while True:
            s1 = int(self._hdr_i[slot, _H_SEQ])
            if s1 % 2 == 0:                           # stable: copy out
                traj = {leaf.key: np.array(self.views[leaf.key][slot])
                        for leaf in self.spec.leaves}
                meta = {
                    "policy_version": int(self._hdr_i[slot, _H_VERSION]),
                    "worker_id": int(self._hdr_i[slot, _H_WORKER]),
                    "collect_seconds": float(
                        self._hdr_f[slot, _H_COLLECT_S]),
                    "loop_seconds": float(self._hdr_f[slot, _H_LOOP_S]),
                }
                if int(self._hdr_i[slot, _H_SEQ]) == s1:
                    return traj, meta
            if time.monotonic() > deadline:
                pid = int(self._hdr_i[slot, _H_PID])
                wid = int(self._hdr_i[slot, _H_WORKER])
                raise RingSlotStuck(
                    f"trajectory ring slot {slot} stuck mid-write for "
                    f"{timeout:.1f}s: seqlock seq={s1} "
                    f"({'odd = write in progress' if s1 % 2 else 'kept moving'}), "
                    f"writer pid {pid} (worker #{wid}) — the writer likely "
                    f"died mid-write; the slot must be reclaimed, not read",
                    slot=slot, writer_pid=pid, worker_id=wid, seq=s1)
            time.sleep(1e-4)

    def ack(self, slot: int) -> None:
        self._hdr_i[slot, _H_ACK] = self._hdr_i[slot, _H_SEQ]

    def reclaim(self, slot: int) -> Optional[str]:
        """Make a dead worker's slot writable again. Returns what was
        found: ``"torn"`` (seqlock odd — the writer died mid-write; the
        payload is garbage and is *not* surfaced), ``"unread"`` (a stable
        write nobody will ever consume — its result message was lost with
        the producer), or ``None`` (slot already free). Only call for
        slots whose writer is known dead and whose pending result
        messages have been drained — reclaiming a live writer's slot
        races its write."""
        seq = int(self._hdr_i[slot, _H_SEQ])
        ack = int(self._hdr_i[slot, _H_ACK])
        if seq % 2:                       # died mid-write: finish the seq
            self._hdr_i[slot, _H_SEQ] = seq + 1
            self._hdr_i[slot, _H_ACK] = seq + 1
            return "torn"
        if ack != seq:                    # stable but orphaned
            self._hdr_i[slot, _H_ACK] = seq
            return "unread"
        return None

    # ------------------------------------------------------------ lifecycle
    def close(self, unlink: bool = False) -> None:
        # drop numpy views before closing the mmaps they point into
        self.views = {}
        self._hdr_i = self._hdr_f = None
        for shm in self._shms:
            try:
                shm.close()
                if unlink:
                    shm.unlink()
            except FileNotFoundError:
                pass
        self._shms = []


class Heartbeat:
    """One shared monotonic-clock timestamp per worker slot.

    Workers ``beat(i)`` every service-loop pass (including inside
    backpressure waits); the supervisor's ``age(i)`` is the seconds since
    worker ``i`` last beat — ``inf`` before the first beat. The parent
    beats on behalf of a worker at spawn so import/jit warmup never reads
    as a hang. A single jitted rollout cannot beat mid-flight, so hang
    timeouts must exceed the longest legitimate rollout (DESIGN.md §10).
    """

    def __init__(self, name: str, slots: int = 0, create: bool = False):
        self.name = name
        self._shm = shared_memory.SharedMemory(
            name=name, create=create, size=slots * 8 if create else 0)
        # attach side derives capacity from the (page-rounded) block size
        self._view = np.ndarray((self._shm.size // 8,), dtype=np.float64,
                                buffer=self._shm.buf)
        if create:
            self._view.fill(0.0)

    def beat(self, i: int) -> None:
        self._view[i] = time.monotonic()

    def age(self, i: int) -> float:
        t = float(self._view[i])
        return float("inf") if t == 0.0 else time.monotonic() - t

    def close(self, unlink: bool = False) -> None:
        self._view = None
        try:
            self._shm.close()
            if unlink:
                self._shm.unlink()
        except FileNotFoundError:
            pass


@dataclasses.dataclass(frozen=True)
class ChannelSpec:
    """Attach info for a ``ParamsChannel`` (picklable).

    Also JSON round-trippable (``to_json``/``from_json``): worker
    processes receive the spec over the spawn boundary, but a *serving*
    replica (``repro.serve``) may be launched independently of the
    learner — the learner drops the spec as a handoff file and the
    replica attaches from it (``launch/serve_policy.py
    --channel-spec``).
    """
    prefix: str
    leaves: Tuple[LeafSpec, ...]

    def to_json(self) -> str:
        return json.dumps({
            "prefix": self.prefix,
            "leaves": [dataclasses.asdict(l) for l in self.leaves],
        })

    @classmethod
    def from_json(cls, text: str) -> "ChannelSpec":
        d = json.loads(text)
        return cls(prefix=d["prefix"], leaves=tuple(
            LeafSpec(key=l["key"], shape=tuple(l["shape"]),
                     dtype=l["dtype"]) for l in d["leaves"]))


class ParamsChannel:
    """Versioned cross-process params cell — ``PolicyStore`` over shm.

    One shared block per flattened param leaf plus a single seqlock word:
    ``publish`` bumps it to odd, overwrites every leaf, bumps to even;
    ``version == seq // 2`` counts publishes. Readers (``read``) spin
    until the version moves past ``min_version``, copy, and re-check —
    so workers always act with the freshest published policy (possibly
    stale, never torn) and pay the copy only when it actually changed.
    """

    def __init__(self, spec: ChannelSpec, create: bool):
        self.spec = spec
        self._shms: List[shared_memory.SharedMemory] = []
        self._views: List[np.ndarray] = []
        for i, leaf in enumerate(spec.leaves):
            nbytes = (int(np.prod(leaf.shape, dtype=np.int64))
                      * np.dtype(leaf.dtype).itemsize)
            shm = self._open(f"{spec.prefix}-l{i}", create, max(nbytes, 8))
            self._views.append(np.ndarray(leaf.shape, dtype=leaf.dtype,
                                          buffer=shm.buf))
        shm = self._open(f"{spec.prefix}-hdr", create, 8)
        self._hdr = np.ndarray((1,), dtype=np.int64, buffer=shm.buf)
        if create:
            self._hdr[0] = 0

    def _open(self, name: str, create: bool,
              size: int) -> shared_memory.SharedMemory:
        shm = shared_memory.SharedMemory(
            name=name, create=create, size=size if create else 0)
        self._shms.append(shm)
        return shm

    @classmethod
    def create(cls, leaves: Sequence[np.ndarray],
               prefix: str) -> "ParamsChannel":
        spec = ChannelSpec(prefix=prefix, leaves=tuple(
            LeafSpec(key=str(i), shape=tuple(x.shape),
                     dtype=np.dtype(x.dtype).str)
            for i, x in enumerate(leaves)))
        return cls(spec, create=True)

    @classmethod
    def attach(cls, spec: ChannelSpec) -> "ParamsChannel":
        return cls(spec, create=False)

    @property
    def version(self) -> int:
        return int(self._hdr[0]) // 2

    def publish(self, leaves: Sequence[np.ndarray]) -> int:
        if len(leaves) != len(self._views):
            raise ValueError(
                f"params channel holds {len(self._views)} leaves, "
                f"publish got {len(leaves)}")
        seq = int(self._hdr[0])
        self._hdr[0] = seq + 1
        for view, leaf in zip(self._views, leaves):
            view[...] = leaf
        self._hdr[0] = seq + 2
        return (seq + 2) // 2

    def read(self, min_version: int = 0, last_version: int = -1,
             should_stop: Optional[Callable[[], bool]] = None,
             poll: float = 1e-4
             ) -> Tuple[Optional[List[np.ndarray]], int]:
        """Block until ``version >= min_version``; return
        ``(leaf_copies, version)`` — leaves are ``None`` when the version
        equals ``last_version`` (nothing new to copy) or when
        ``should_stop()`` fired (version reported as -1)."""
        while True:
            s1 = int(self._hdr[0])
            if s1 % 2 == 0 and s1 // 2 >= min_version:
                version = s1 // 2
                if version == last_version:
                    return None, version
                out = [np.array(v) for v in self._views]
                if int(self._hdr[0]) == s1:
                    return out, version
                continue                              # torn read: retry
            if should_stop is not None and should_stop():
                return None, -1
            time.sleep(poll)

    def close(self, unlink: bool = False) -> None:
        self._views = []
        self._hdr = None
        for shm in self._shms:
            try:
                shm.close()
                if unlink:
                    shm.unlink()
            except FileNotFoundError:
                pass
        self._shms = []


@contextlib.contextmanager
def _worker_env():
    """Environment adjustments around ``Process.start()`` only (children
    inherit the environment at spawn; the parent's own, already-
    initialized client is unaffected):

    * rollout workers are host-side sampler processes — default them to
      the CPU client unless a platform is pinned explicitly
    * limit each worker's XLA CPU intra-op pool to one thread: N workers
      x one multi-threaded eigen pool oversubscribes small hosts and
      *slows* collection as N grows (bitwise-neutral for rollout-sized
      ops — asserted by the process==inline parity tests, which run the
      parent multi-threaded)
    """
    saved = {k: os.environ.get(k) for k in ("JAX_PLATFORMS", "XLA_FLAGS")}
    if saved["JAX_PLATFORMS"] is None:
        os.environ["JAX_PLATFORMS"] = "cpu"
    flags = saved["XLA_FLAGS"] or ""
    if "intra_op_parallelism_threads" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_cpu_multi_thread_eigen=false "
            "intra_op_parallelism_threads=1").strip()
    try:
        yield
    finally:
        for k, v in saved.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v


# ======================================================= the worker process
def _worker_main(spec_dict: Dict[str, Any], ring_spec: RingSpec,
                 chan_spec: ChannelSpec, hb_name: str, worker_id: int,
                 incarnation: int, slot_base: int, num_slots: int,
                 fault_plan_dict: Optional[Dict[str, Any]], cmd_q,
                 res_q) -> None:
    """Entry point of one rollout worker process.

    Rebuilds env/algo/rollout from the serialized ``WorkerSpec`` purely
    via the registry (nothing else crossed the boundary), then serves:

      ("collect", v) — one rollout under params version >= v, write slot,
                       report;  the lock-step mode ``ProcessBackend`` uses
      ("freerun", v) — roll continuously with the freshest published
                       params, blocking only when the ring slot has not
                       been consumed; the ``AsyncOrchestrator`` mode
      ("stop",)      — exit cleanly

    Trajectory reports carry the slot's post-write seqlock value:
    ("traj", id, slot, seq, version, collect_s, loop_s). The consumer
    matches seq against the live header before reading, which is what
    makes slot reclamation safe — a message from a dead incarnation can
    never alias a respawned worker's fresh write.

    ``incarnation`` counts this worker id's spawns; it keys the fault
    plan's PRNG stream (a respawned worker draws a fresh deterministic
    schedule) and is otherwise inert. The worker stamps ``hb_name``'s
    heartbeat slot every service-loop pass so a supervisor can tell
    wedged from slow.

    Any exception is reported upstream as ("error", id, traceback) and
    surfaces in the parent as ``WorkerCrashed``.
    """
    try:
        # spread workers round-robin over the host's cores: deterministic
        # placement avoids the migration thrash the kernel scheduler adds
        # when workers outnumber cores (a worker never fights more peers
        # than ceil(N / cores) for its core); a no-op gain when cores >= N
        if hasattr(os, "sched_setaffinity"):
            try:
                cores = sorted(os.sched_getaffinity(0))
                os.sched_setaffinity(
                    0, {cores[worker_id % len(cores)]})
            except OSError:
                pass
        import jax
        import jax.numpy as jnp

        from repro.core.faults import FaultPlan, decide
        from repro.core.sampler import WorkerSpec

        plan = FaultPlan.from_dict(fault_plan_dict)
        spec = WorkerSpec.from_dict(spec_dict)
        rollout, carry, params_template = spec.build()
        rollout = jax.jit(rollout)
        t_leaves, treedef = jax.tree_util.tree_flatten(params_template)
        ring = ShmRing.attach(ring_spec)
        chan = ParamsChannel.attach(chan_spec)
        hb = Heartbeat(hb_name)
        if len(t_leaves) != len(chan.spec.leaves):
            raise RuntimeError(
                f"worker {worker_id}: rebuilt params have "
                f"{len(t_leaves)} leaves, channel carries "
                f"{len(chan.spec.leaves)} — WorkerSpec and learner params "
                f"disagree")
        hb.beat(worker_id)
        res_q.put(("ready", worker_id))

        params, last_version = None, -1
        freerunning, counter, stop = False, 0, False
        while not stop:
            hb.beat(worker_id)
            if freerunning:
                try:
                    cmd = cmd_q.get_nowait()
                except _queue.Empty:
                    cmd = ("step", 0)
            else:
                try:                     # bounded waits keep the beat alive
                    cmd = cmd_q.get(timeout=0.25)
                except _queue.Empty:
                    continue
            op = cmd[0]
            if op == "stop":
                break
            if op == "freerun":
                freerunning = True
                continue
            # op is "collect" (lock-step) or "step" (free-running)
            fault = decide(plan, worker_id, incarnation, counter)
            if fault == "kill":          # clean death: nothing in flight
                os.kill(os.getpid(), signal.SIGKILL)
            elif fault == "hang":        # wedged: alive, beats never again
                while True:
                    time.sleep(0.05)
            elif fault == "delay":       # straggler, not a failure
                time.sleep(plan.delay_ms / 1e3)
            min_version = cmd[1] if len(cmd) > 1 else 0
            t_loop0 = time.perf_counter()
            np_leaves, version = chan.read(min_version=min_version,
                                           last_version=last_version)
            if np_leaves is not None:
                params = treedef.unflatten(
                    [jnp.asarray(x) for x in np_leaves])
                last_version = version
            t0 = time.perf_counter()
            carry, traj = rollout(params, carry)
            traj = jax.block_until_ready(traj)
            dt = time.perf_counter() - t0
            traj_np = {k: np.asarray(v) for k, v in traj.items()}
            slot = slot_base + (counter % num_slots)
            while not ring.is_free(slot):      # learner behind: back off
                hb.beat(worker_id)
                try:
                    nxt = cmd_q.get(timeout=0.002)
                    if nxt[0] == "stop":
                        stop = True
                        break
                except _queue.Empty:
                    pass
            if stop:
                break
            loop_dt = time.perf_counter() - t_loop0
            if fault == "torn":          # die mid-write: seqlock left odd
                ring.begin_torn_write(slot, worker_id)
                os.kill(os.getpid(), signal.SIGKILL)
            seq = ring.write(slot, traj_np, worker_id=worker_id,
                             policy_version=last_version,
                             collect_seconds=dt, loop_seconds=loop_dt)
            res_q.put(("traj", worker_id, slot, seq, last_version, dt,
                       time.perf_counter() - t_loop0))
            counter += 1
        ring.close()
        chan.close()
        hb.close()
    except Exception:
        try:
            res_q.put(("error", worker_id, traceback.format_exc()))
        except Exception:
            pass


# ============================================================ the worker pool
class ProcessWorkerPool:
    """Rollout worker processes + the shared-memory transport between
    them and this (learner) process.

    The pool is provisioned for ``max_workers = len(worker_specs)``
    workers up front — ring slots, heartbeat slots and per-worker specs
    all exist from construction — but only the ``active`` subset
    (``active_workers``, default: all) is actually running. Construction
    publishes the initial params (version 1), spawns the active workers
    and blocks until every one reports ready — a worker that dies while
    importing/building surfaces immediately as ``WorkerCrashed``.

    Two driving modes:

    * ``collect()`` — lock-step: broadcast one ("collect", version)
      command, await N results, return per-worker trajectories **in
      worker-index order** (the determinism rule that makes
      ``process == inline`` exact for matched per-worker seeds).
    * ``start_freerun()`` + ``next_experience()`` — the async mode:
      workers roll continuously against the freshest published params;
      the learner drains finished slots as ``core.queues.Experience``
      records. Backpressure is the ring itself (``slots_per_worker``
      unconsumed rollouts per worker, then the worker blocks), so
      nothing is ever dropped.

    Fleet primitives (``respawn``/``grow``/``shrink``/``kill_worker``,
    ``poll_msg``/``drain_pending``/``dead_workers``/``heartbeat_age``,
    ``reclaim_worker_slots``/``read_slot_checked``) are mechanism only —
    *when* to respawn, back off, or resize is
    ``core.supervisor.WorkerSupervisor`` policy.

    Workers are daemonic and additionally reaped by an ``atexit`` hook,
    so Ctrl-C in the learner never leaves orphan samplers behind.
    ``close`` distinguishes workers it stopped itself from workers that
    crashed during shutdown: the latter raise ``WorkerCrashed`` chained
    (via ``__cause__``) onto any crash already surfaced, and never mask
    an exception already propagating.
    """

    def __init__(self, worker_specs: Sequence[Any], params: Any,
                 traj_example: Dict[str, Any], slots_per_worker: int = 1,
                 start_timeout: float = 300.0,
                 collect_timeout: float = 600.0,
                 active_workers: Optional[Sequence[int]] = None,
                 fault_plan: Optional[Any] = None):
        import jax
        import multiprocessing as mp

        self.max_workers = len(worker_specs)
        self._specs = list(worker_specs)
        self.slots_per_worker = int(slots_per_worker)
        self.collect_timeout = collect_timeout
        self.fault_plan = fault_plan
        self._closed = False
        self._freerunning = False
        self._stash: collections.deque = collections.deque()
        self._terminated: set = set()       # wids we stopped on purpose
        self._crash_surfaced: set = set()   # crashes already raised
        self._last_crash: Optional[WorkerCrashed] = None
        self._ctx = mp.get_context("spawn")
        prefix = f"walle-{os.getpid()}-{uuid.uuid4().hex[:6]}"
        leaves = [np.asarray(jax.device_get(x))
                  for x in jax.tree_util.tree_leaves(params)]
        self.channel = ParamsChannel.create(leaves, prefix + "-p")
        self.version = self.channel.publish(leaves)
        self.ring = ShmRing.create(
            traj_example, self.max_workers * self.slots_per_worker,
            prefix + "-t")
        self.heartbeat = Heartbeat(prefix + "-hb", self.max_workers,
                                   create=True)
        self._res = self._ctx.Queue()
        self._cmd: List[Optional[Any]] = [None] * self.max_workers
        self._procs: List[Optional[Any]] = [None] * self.max_workers
        self._retired: List[Any] = []       # cmd queues of dead incarnations
        self._incarnation = [0] * self.max_workers
        self.active: List[int] = sorted(
            active_workers if active_workers is not None
            else range(self.max_workers))
        if not self.active:
            raise ValueError("worker pool needs at least one active worker")
        if self.active[0] < 0 or self.active[-1] >= self.max_workers:
            raise ValueError(
                f"active_workers {self.active} out of range for "
                f"{self.max_workers} specs")
        self._atexit = lambda: self.close(raise_on_crash=False)
        atexit.register(self._atexit)
        try:
            for i in self.active:
                self._spawn(i)
            ready = set()
            while len(ready) < len(self.active):
                msg = self._get(timeout=start_timeout)
                if msg[0] == "ready":
                    ready.add(msg[1])
        except BaseException:
            self.close(raise_on_crash=False)
            raise

    # ---------------------------------------------------------------- sizing
    @property
    def num_workers(self) -> int:
        return len(self.active)

    # ------------------------------------------------------------- plumbing
    def _spawn(self, i: int) -> None:
        """(Re)start worker ``i`` under a fresh incarnation: new command
        queue (the old one may hold commands consumed-but-unexecuted by
        the dead incarnation), heartbeat pre-beaten by the parent so
        import/jit warmup never reads as a hang."""
        if self._cmd[i] is not None:
            self._retired.append(self._cmd[i])
        self._incarnation[i] += 1
        q = self._ctx.Queue()
        self._cmd[i] = q
        self.heartbeat.beat(i)
        plan_dict = (self.fault_plan.to_dict()
                     if self.fault_plan is not None else None)
        p = self._ctx.Process(
            target=_worker_main, name=f"walle-worker-{i}", daemon=True,
            args=(self._specs[i].to_dict(), self.ring.spec,
                  self.channel.spec, self.heartbeat.name, i,
                  self._incarnation[i], i * self.slots_per_worker,
                  self.slots_per_worker, plan_dict, q, self._res))
        self._procs[i] = p
        with _worker_env():
            p.start()

    def _check_alive(self) -> None:
        dead = [(i, self._procs[i].exitcode) for i in self.active
                if self._procs[i] is not None
                and not self._procs[i].is_alive()]
        if dead:
            for i, _ in dead:
                self._crash_surfaced.add(i)
            err = WorkerCrashed(
                "rollout worker(s) died: " + ", ".join(
                    f"#{i} (exitcode={code})" for i, code in dead))
            self._last_crash = err
            raise err

    def _get(self, timeout: float):
        """Next result-queue message (stashed messages first); raises
        ``WorkerCrashed`` on worker error/death and ``TimeoutError`` past
        ``timeout``."""
        deadline = time.monotonic() + timeout
        while True:
            if self._stash:
                msg = self._stash.popleft()
            else:
                try:
                    msg = self._res.get(timeout=0.25)
                except _queue.Empty:
                    self._check_alive()
                    if time.monotonic() > deadline:
                        raise TimeoutError(
                            f"no worker result within {timeout:.0f}s")
                    continue
            if msg[0] == "error":
                err = WorkerCrashed(
                    f"rollout worker #{msg[1]} raised:\n{msg[2]}")
                self._crash_surfaced.add(msg[1])
                self._last_crash = err
                raise err
            return msg

    def _read_slot(self, slot: int):
        traj, meta = self.ring.read(slot)
        self.ring.ack(slot)
        return traj, meta

    # ----------------------------------------------- supervisor primitives
    def poll_msg(self, timeout: float = 0.25):
        """One raw result message (stash first) or ``None`` on timeout.
        No liveness check, no error translation — supervisor's job."""
        if self._stash:
            return self._stash.popleft()
        try:
            return self._res.get(timeout=timeout)
        except _queue.Empty:
            return None

    def drain_pending(self) -> None:
        """Move every already-queued result message into the stash. A
        producer SIGKILLed mid-``put`` can leave a partially-pickled
        message; deserialization errors end the drain (nothing after a
        torn message is trustworthy this pass — the next drain retries)."""
        while True:
            try:
                self._stash.append(self._res.get_nowait())
            except _queue.Empty:
                return
            except Exception:
                return

    def dead_workers(self) -> List[Tuple[int, Optional[int]]]:
        """Active workers whose process has exited: [(wid, exitcode)]."""
        return [(i, self._procs[i].exitcode) for i in self.active
                if self._procs[i] is not None
                and not self._procs[i].is_alive()]

    def heartbeat_age(self, i: int) -> float:
        return self.heartbeat.age(i)

    def kill_worker(self, i: int) -> None:
        """SIGKILL worker ``i`` (wedged workers ignore gentler signals)."""
        p = self._procs[i]
        if p is not None and p.is_alive():
            p.kill()
        if p is not None:
            p.join(timeout=5.0)

    def respawn(self, i: int) -> None:
        """Replace worker ``i`` with a fresh incarnation of the same
        ``WorkerSpec`` (same seed — a deterministic restart; only the
        fault stream differs, keyed by incarnation). Re-enters freerun
        if the pool is free-running. The caller reclaims slots *before*
        respawning (``reclaim_worker_slots``) so the new incarnation is
        never blocked by its predecessor's unacked writes."""
        self.kill_worker(i)
        self._spawn(i)
        if self._freerunning:
            self._cmd[i].put(("freerun",))

    def reclaim_worker_slots(self, i: int) -> List[Tuple[int, str]]:
        """Repair dead worker ``i``'s ring slots, *except* slots with a
        pending ("traj", ...) message — those hold completed rollouts the
        supervisor will still consume (seq-checked). Returns
        [(slot, kind)] for what was actually reclaimed."""
        self.drain_pending()
        pending = {m[2] for m in self._stash
                   if m[0] == "traj" and m[1] == i}
        out = []
        base = i * self.slots_per_worker
        for slot in range(base, base + self.slots_per_worker):
            if slot in pending:
                continue
            kind = self.ring.reclaim(slot)
            if kind is not None:
                out.append((slot, kind))
        return out

    def read_slot_checked(self, slot: int, seq: int):
        """Read+ack ``slot`` only if its seqlock still equals ``seq`` (the
        value the reporting message recorded at write time); otherwise the
        slot was reclaimed/rewritten after its writer died and the message
        is stale — raise ``StaleSlotMessage`` so the caller discards it
        instead of double-consuming the slot's new contents."""
        cur = self.ring.seq(slot)
        if cur != seq:
            raise StaleSlotMessage(
                f"ring slot {slot}: message recorded seq {seq} but the "
                f"slot is now at seq {cur} — reclaimed and rewritten "
                f"since; discarding the stale message")
        return self._read_slot(slot)

    def send(self, wid: int, cmd: Tuple) -> None:
        self._cmd[wid].put(cmd)

    # --------------------------------------------------------------- sizing
    def grow(self) -> Optional[int]:
        """Activate the lowest inactive worker id (its spec, ring slots
        and heartbeat slot were provisioned at construction). Returns the
        id, or ``None`` at capacity. The new worker reads the current
        params from the channel on its first rollout — joiners are never
        behind by more than one publish."""
        inactive = [i for i in range(self.max_workers)
                    if i not in self.active]
        if not inactive:
            return None
        i = inactive[0]
        self._terminated.discard(i)
        self._crash_surfaced.discard(i)
        for slot in range(i * self.slots_per_worker,
                          (i + 1) * self.slots_per_worker):
            self.ring.reclaim(slot)
        self._spawn(i)
        self.active = sorted(self.active + [i])
        if self._freerunning:
            self._cmd[i].put(("freerun",))
        return i

    def shrink(self) -> Optional[int]:
        """Deactivate the highest active worker id (stop, join, terminate
        stragglers). Returns the id, or ``None`` at the floor of one."""
        if len(self.active) <= 1:
            return None
        i = self.active[-1]
        self.active = self.active[:-1]
        self._terminated.add(i)
        try:
            self._cmd[i].put_nowait(("stop",))
        except Exception:
            pass
        p = self._procs[i]
        if p is not None:
            p.join(timeout=5.0)
            if p.is_alive():
                p.terminate()
                p.join(timeout=3.0)
        # release anything it left unconsumed so a later grow() starts clean
        for slot in range(i * self.slots_per_worker,
                          (i + 1) * self.slots_per_worker):
            self.ring.reclaim(slot)
        return i

    # ------------------------------------------------------------ lock-step
    def publish(self, params: Any) -> int:
        import jax
        self.version = self.channel.publish(
            [np.asarray(jax.device_get(x))
             for x in jax.tree_util.tree_leaves(params)])
        return self.version

    def collect(self, staggered: bool = False
                ) -> Tuple[List[Dict[str, np.ndarray]], List[float],
                           List[float]]:
        """One lock-step sweep: every active worker rolls once under the
        current params version; trajectories come back in worker-index
        order.

        ``staggered=True`` commands workers one at a time, awaiting each
        result before waking the next. On hosts with fewer cores than
        workers the default broadcast makes every worker's self-timed
        rollout include preemption by its peers (they time-slice the same
        cores), so the per-worker times — and the critical-path throughput
        derived from them — measure scheduler contention, not sampler
        work. Staggering serializes the sweep so each worker runs
        uncontended, recovering the per-sampler steady-state timing the
        inline backend's serial sweep reports (DESIGN.md §2's
        methodology). Trajectories, merge order and determinism are
        identical either way — only the wall-clock overlap changes.
        """
        if self._closed:
            raise RuntimeError("worker pool is closed")
        if self._freerunning:
            raise RuntimeError(
                "pool is free-running (async mode); lock-step collect() "
                "would interleave with unsolicited rollouts")
        version = self.channel.version
        got: Dict[int, Tuple[int, int, float, float]] = {}
        if staggered:
            for i in self.active:
                self._cmd[i].put(("collect", version))
                wid, entry = self._next_traj(self.collect_timeout)
                got[wid] = entry
        else:
            for i in self.active:
                self._cmd[i].put(("collect", version))
            while len(got) < len(self.active):
                wid, entry = self._next_traj(self.collect_timeout)
                got[wid] = entry
        trajs, times, loops = [], [], []
        for i in self.active:                    # deterministic merge order
            slot, seq, dt, loop_dt = got[i]
            traj, _meta = self.read_slot_checked(slot, seq)
            trajs.append(traj)
            times.append(dt)
            loops.append(loop_dt)
        return trajs, times, loops

    def _next_traj(self, timeout: float):
        """Next ("traj", ...) message as (wid, (slot, seq, dt, loop_dt));
        skips stray ("ready", ...) reports from respawned workers."""
        deadline = time.monotonic() + timeout
        while True:
            msg = self._get(max(1e-3, deadline - time.monotonic()))
            if msg[0] != "traj":
                continue
            _, wid, slot, seq, _v, dt, loop_dt = msg
            return wid, (slot, seq, dt, loop_dt)

    # ------------------------------------------------------------- freerun
    def start_freerun(self) -> None:
        if self._freerunning:
            return
        self._freerunning = True
        for i in self.active:
            self._cmd[i].put(("freerun",))

    def next_experience(self, timeout: float = 1.0):
        """Drain one finished rollout as ``(Experience, loop_seconds)``;
        ``None`` if nothing finished within ``timeout``."""
        from repro.core.queues import Experience
        deadline = time.monotonic() + timeout
        while True:
            try:
                msg = self._get(max(1e-3, deadline - time.monotonic()))
            except TimeoutError:
                return None
            if msg[0] != "traj":
                if time.monotonic() > deadline:
                    return None
                continue
            _, wid, slot, seq, version, dt, _loop = msg
            traj, meta = self.read_slot_checked(slot, seq)
            return (Experience(traj=traj, policy_version=version,
                               sampler_id=wid, collect_seconds=dt),
                    meta["loop_seconds"])

    # ------------------------------------------------------------ lifecycle
    def close(self, raise_on_crash: bool = True) -> None:
        """Stop, join (terminate stragglers) and unlink all shared state.
        Idempotent; also runs from ``atexit`` so Ctrl-C reaps workers.

        Workers found already dead with a nonzero exitcode — that we did
        not stop ourselves and whose crash was not already surfaced as a
        ``WorkerCrashed`` — crashed *during shutdown*. When nothing else
        is propagating, that raises ``WorkerCrashed`` (chained onto the
        earlier crash via ``__cause__`` when one exists); when an
        exception is already in flight, close stays silent so it never
        masks the original error."""
        if self._closed:
            return
        self._closed = True
        for i in self.active:
            if self._cmd[i] is not None:
                try:
                    self._cmd[i].put_nowait(("stop",))
                except Exception:
                    pass
        procs = [(i, p) for i, p in enumerate(self._procs) if p is not None]
        for _, p in procs:
            p.join(timeout=3.0)
        for i, p in procs:
            if p.is_alive():
                self._terminated.add(i)
                p.terminate()
        for _, p in procs:
            p.join(timeout=3.0)
        shutdown_crashes = [
            (i, p.exitcode) for i, p in procs
            if p.exitcode not in (0, None)
            and i not in self._terminated
            and i not in self._crash_surfaced]
        for q in [q for q in self._cmd if q is not None] + self._retired + [
                self._res]:
            try:
                q.close()
                q.cancel_join_thread()
            except Exception:
                pass
        self.ring.close(unlink=True)
        self.channel.close(unlink=True)
        self.heartbeat.close(unlink=True)
        try:
            atexit.unregister(self._atexit)
        except Exception:
            pass
        if (shutdown_crashes and raise_on_crash
                and sys.exc_info()[1] is None):
            err = WorkerCrashed(
                "rollout worker(s) crashed during shutdown: " + ", ".join(
                    f"#{i} (exitcode={code})"
                    for i, code in shutdown_crashes))
            if self._last_crash is not None:
                raise err from self._last_crash
            raise err

    def __enter__(self) -> "ProcessWorkerPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
