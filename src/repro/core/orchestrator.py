"""WALL-E's agent processor: runners as thin drivers over sampler backends.

* ``SyncRunner`` — collect (via a ``SamplerBackend``) -> learn -> repeat.
  With the default ``InlineBackend`` and ``num_samplers=1`` this is exactly
  the paper's N=1 baseline; with N > 1 per-sampler critical-path time is
  still measurable on a single host (see DESIGN.md §2 on measurement).
* ``AsyncOrchestrator`` — the paper's architecture: N sampler threads
  generating experience with the freshest published policy (possibly
  stale), a learner thread consuming the experience queue and publishing
  new parameters to the policy store. Device work stays jitted; threads
  orchestrate, matching the paper's process roles.

Both runners assemble their ``IterationLog`` through the same helpers
(``timed_learn`` + ``assemble_log``) so the collect/learn accounting that
feeds Figs 4-7 has exactly one definition.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax

from repro.core.backends import (
    BackendCloseMixin,
    InlineBackend,
    SamplerBackend,
    merge_trajs,
    timed_rollout,
)
from repro.core.queues import Experience, ExperienceQueue, PolicyStore
from repro.core.timing import PhaseTimer
from repro.data import trajectory


@dataclasses.dataclass
class IterationLog:
    iteration: int
    collect_time: float          # critical-path (parallel) collection time
    collect_time_serial: float   # sum over samplers (1-process equivalent)
    learn_time: float
    mean_return: float
    samples: int
    staleness: float = 0.0       # params-staleness: mean (learner version -
                                 # version the sampler acted with)
    queue_drops: int = 0         # async: cumulative experiences dropped on
                                 # queue overflow (backpressure signal)
    worker_utilization: float = 1.0   # fraction of worker wall time spent
                                      # actually rolling out (vs waiting on
                                      # params/slots); < 1 only measurable
                                      # for free-running process workers
    respawns: int = 0            # cumulative supervised worker respawns
    active_workers: int = 0      # pool size this iteration (elastic mode)
    overlap_saved_s: float = 0.0  # overlap pipeline: wall-clock hidden by
                                  # running this learn under the next
                                  # collect, vs the serial schedule (0 on
                                  # serial iterations; under overlap,
                                  # learn_time is the *exposed* learn cost
                                  # so collect+learn+saved ~= serial wall)

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


# ====================================================== shared helpers
def _maybe_jit_step(train_step: Optional[Callable]) -> Optional[Callable]:
    """Runners jit the plane step themselves — except a mesh step that
    manages its own jit and input placement (``ShardedLearner`` with
    D > 1 sets ``self_jitted``): re-jitting it would infer device
    placement from the arguments, and a device-0 trajectory next to
    FSDP-sharded params is an incompatible-devices error."""
    if train_step is None:
        return None
    if getattr(getattr(train_step, "__self__", None), "self_jitted", False):
        return train_step
    return jax.jit(train_step)


def timed_learn(learn: Callable, params, opt_state, merged):
    """One jitted learner update, blocked and timed."""
    t0 = time.perf_counter()
    params, opt_state, metrics = learn(params, opt_state, merged)
    jax.block_until_ready(params)
    return params, opt_state, metrics, time.perf_counter() - t0


def timed_train_step(train_step: Callable, params, opt_state, plane_state,
                     merged):
    """One jitted plane step (observe -> sample -> learn), blocked and
    timed; buffer state stays device-resident inside ``plane_state``."""
    t0 = time.perf_counter()
    params, opt_state, plane_state, metrics = train_step(
        params, opt_state, plane_state, merged)
    jax.block_until_ready(params)
    return params, opt_state, plane_state, metrics, time.perf_counter() - t0


def assemble_log(iteration: int, per_sampler_seconds: Sequence[float],
                 learn_time: float, merged, samples: Optional[int] = None,
                 staleness: float = 0.0,
                 queue_drops: int = 0,
                 worker_utilization: float = 1.0,
                 respawns: int = 0,
                 active_workers: int = 0,
                 overlap_saved_s: float = 0.0) -> IterationLog:
    """The single definition of per-iteration accounting (sync + async)."""
    return IterationLog(
        iteration=iteration,
        collect_time=max(per_sampler_seconds),
        collect_time_serial=sum(per_sampler_seconds),
        learn_time=learn_time,
        mean_return=float(trajectory.episode_returns(merged)),
        samples=(samples if samples is not None
                 else trajectory.num_samples(merged)),
        staleness=staleness,
        queue_drops=queue_drops,
        worker_utilization=worker_utilization,
        respawns=respawns,
        active_workers=active_workers,
        overlap_saved_s=overlap_saved_s,
    )


def tree_ready(tree) -> bool:
    """True iff every device array in ``tree`` has finished computing
    (``jax.Array.is_ready``) — a non-blocking probe used by the overlap
    pipeline to tell whether the in-flight learn was still running when
    the concurrent collect finished."""
    try:
        return all(bool(leaf.is_ready()) for leaf in jax.tree.leaves(tree)
                   if hasattr(leaf, "is_ready"))
    except Exception:
        return False


class OverlapClock:
    """Accounting for the double-buffered pipeline (DESIGN.md §11).

    ``overlap_saved_s`` is the learn wall-clock hidden under the
    concurrent collect, i.e. serial schedule minus pipelined schedule
    for this iteration. Two cases at the moment the collect returns:

    * the learn is **not** finished -> it ran under the entire collect,
      so the hidden portion is the whole collect duration;
    * the learn **is** finished -> the hidden portion is the learn's own
      duration, estimated by ``learn_ref`` — the fastest *serial* learn
      observed during warmup (post-compilation, so it is a clean
      reference), capped by the collect duration.
    """

    def __init__(self):
        self.learn_ref: Optional[float] = None

    def note_serial(self, learn_s: float) -> None:
        self.learn_ref = (learn_s if self.learn_ref is None
                          else min(self.learn_ref, learn_s))

    def saved(self, collect_s: float, learn_ready: bool) -> float:
        if not learn_ready:
            return collect_s
        ref = self.learn_ref if self.learn_ref is not None else collect_s
        return min(ref, collect_s)


def record_log(logs: List[IterationLog], timer: PhaseTimer,
               log: IterationLog) -> None:
    logs.append(log)
    timer.add("collect", log.collect_time)
    timer.add("learn", log.learn_time)


# ================================================================== sync
class SyncRunner(BackendCloseMixin):
    """collect (backend) -> learn -> repeat.

    Backward-compatible construction: pass ``(rollout, learn, params,
    opt_state, carries, num_samplers)`` and an ``InlineBackend`` is built —
    or pass ``backend=`` (any ``SamplerBackend``) and leave ``rollout`` /
    ``carries`` as None.

    Experience plane: pass ``train_step=`` (``algos.api.make_train_step``)
    plus its initial ``plane_state=(buffer_state, key)`` and the runner
    drives the composed observe -> sample -> learn step instead of raw
    ``learn``, owning the buffer state explicitly (``self.plane_state`` /
    ``self.buffer_state``) — it never hides inside ``opt_state``.

    Overlap (``overlap=True``, requires ``train_step``): after two serial
    warmup iterations (compile + a clean learn reference), each learn is
    *dispatched* without blocking and the **next** iteration's collect
    runs while it executes on the learner mesh — the collect acts with
    one-version-stale params (stamped ``staleness=1.0`` on the iteration
    that consumes it), and ``IterationLog.overlap_saved_s`` reports the
    learn time hidden under the collect (DESIGN.md §11).

    ``pin_params=True`` maintains a *second*, device-0 copy of the params
    for collection: an FSDP-sharded learn result fed straight to the
    single-device rollout would recompile it as a partitioned SPMD
    computation across the learner mesh (and under overlap put the
    collect on the very devices the learn is using). ``self.params``
    itself stays mesh-resident — it must match the mesh-committed
    opt_state at the next learn dispatch — so only the rollout reads the
    pinned copy.
    """

    def __init__(self, rollout: Optional[Callable],
                 learn: Optional[Callable],
                 params: Any, opt_state: Any,
                 carries: Optional[List[Any]] = None,
                 num_samplers: Optional[int] = None, *,
                 backend: Optional[SamplerBackend] = None,
                 train_step: Optional[Callable] = None,
                 plane_state: Any = None,
                 overlap: bool = False,
                 pin_params: bool = False):
        if backend is None:
            assert rollout is not None and carries is not None
            backend = InlineBackend(rollout, carries)
        if num_samplers is not None:
            assert backend.num_samplers == num_samplers
        assert learn is not None or train_step is not None
        self.backend = backend
        self.learn = jax.jit(learn) if learn is not None else None
        self._train_step = _maybe_jit_step(train_step)
        self.plane_state = plane_state
        self.params = params
        self.opt_state = opt_state
        self.num_samplers = backend.num_samplers
        if overlap and train_step is None:
            raise ValueError(
                "overlap=True requires the experience-plane train_step "
                "(the raw learn path has no buffer to double-buffer)")
        self.overlap = overlap
        self.pin_params = pin_params
        self._collect_params = None       # device-0 copy (pin_params mode)
        self._overlap_clock = OverlapClock()
        self._overlap_done = 0            # pipeline-lifetime iteration
        #                                   count: warmup is paid once per
        #                                   runner, not once per run() call
        self.timer = PhaseTimer()
        self.logs: List[IterationLog] = []

    @property
    def buffer_state(self):
        return None if self.plane_state is None else self.plane_state[0]

    def _pin(self) -> None:
        if self.pin_params:
            self._collect_params = jax.device_put(self.params,
                                                  jax.devices()[0])

    def _rollout_params(self):
        return (self._collect_params if self._collect_params is not None
                else self.params)

    def run(self, iterations: int) -> List[IterationLog]:
        if self.overlap:
            return self._run_overlapped(iterations)
        for it in range(iterations):
            merged, stats = self.backend.collect(self._rollout_params())
            if self._train_step is not None:
                (self.params, self.opt_state, self.plane_state, _,
                 learn_time) = timed_train_step(
                     self._train_step, self.params, self.opt_state,
                     self.plane_state, merged)
            else:
                self.params, self.opt_state, _, learn_time = timed_learn(
                    self.learn, self.params, self.opt_state, merged)
            self._pin()
            record_log(self.logs, self.timer,
                       assemble_log(it, stats.per_sampler_seconds,
                                    learn_time, merged, stats.samples,
                                    respawns=stats.respawns,
                                    active_workers=stats.active_workers))
        return self.logs

    # ----------------------------------------------------------- overlap
    _OVERLAP_WARMUP = 2     # it 0 pays compilation, it 1 gives learn_ref

    def _run_overlapped(self, iterations: int) -> List[IterationLog]:
        """Double-buffered pipeline: dispatch iteration k's learn, run
        iteration k+1's collect while it executes, then block. The first
        ``_OVERLAP_WARMUP`` iterations stay fully serial, so short runs
        (``iterations <= warmup``) are identical to ``overlap=False``.
        Numerics are unchanged vs serial except that overlapped collects
        act with params one learn behind (staleness 1.0 on the consuming
        iteration's log) — the same staleness the async orchestrator
        already accounts for."""
        clock = self._overlap_clock
        pending = None          # (merged, stats, staleness) pre-collected
        for it in range(iterations):
            if pending is None:
                merged, stats = self.backend.collect(self._rollout_params())
                stale = 0.0
            else:
                merged, stats, stale = pending
                pending = None
            warm, self._overlap_done = (self._overlap_done,
                                        self._overlap_done + 1)
            if warm < self._OVERLAP_WARMUP:
                (self.params, self.opt_state, self.plane_state, _,
                 learn_time) = timed_train_step(
                     self._train_step, self.params, self.opt_state,
                     self.plane_state, merged)
                if warm > 0:    # iteration 0 includes compilation
                    clock.note_serial(learn_time)
                self._pin()
                record_log(self.logs, self.timer,
                           assemble_log(it, stats.per_sampler_seconds,
                                        learn_time, merged, stats.samples,
                                        staleness=stale,
                                        respawns=stats.respawns,
                                        active_workers=stats.active_workers))
                continue
            # dispatch the learn; do NOT block — self.params still refers
            # to the pre-update arrays, which is exactly the one-version-
            # stale policy the pipelined collect is specified to act with
            t0 = time.perf_counter()
            out = self._train_step(self.params, self.opt_state,
                                   self.plane_state, merged)
            saved = 0.0
            if it + 1 < iterations:
                # _rollout_params() was last pinned *before* this learn
                # dispatched — the one-version-stale policy by construction
                nxt, nstats = self.backend.collect(self._rollout_params())
                saved = clock.saved(max(nstats.per_sampler_seconds),
                                    tree_ready(out[0]))
                pending = (nxt, nstats, 1.0)
            self.params, self.opt_state, self.plane_state, _ = out
            jax.block_until_ready(self.params)
            window = time.perf_counter() - t0
            self._pin()
            # window spans the overlapped collect; subtracting the hidden
            # portion leaves the *exposed* learn cost, so per iteration
            # collect_time + learn_time + overlap_saved_s ~= serial wall
            record_log(self.logs, self.timer,
                       assemble_log(it, stats.per_sampler_seconds,
                                    max(0.0, window - saved), merged,
                                    stats.samples, staleness=stale,
                                    respawns=stats.respawns,
                                    active_workers=stats.active_workers,
                                    overlap_saved_s=saved))
        return self.logs

    def close(self) -> None:
        """Release the backend (thread pools, worker processes, shm)."""
        close = getattr(self.backend, "close", None)
        if close is not None:        # pre-protocol custom backends
            close()


# ================================================================= async
class AsyncOrchestrator(BackendCloseMixin):
    """The paper's architecture (Fig 2): N sampler threads + learner thread.

    Sampler i loop:  params <- PolicyStore (latest, maybe stale)
                     traj   <- jitted rollout
                     ExperienceQueue.put(traj, version)
    Learner loop:    drain >= min_batches experiences
                     params <- jitted PPO update
                     PolicyStore.publish(params)

    Two sampler substrates: the in-process form above (threads + host
    queues), and — pass ``pool=`` (an ``ipc.ProcessWorkerPool``) — true
    worker *processes* collecting continuously into the shared-memory
    trajectory ring while this process's learner drains it. In pool mode
    the policy queue is the shared-memory ``ParamsChannel`` (one publish
    per update, no pickling), backpressure is the ring itself (a worker
    blocks once its slots are unconsumed — nothing is dropped), and
    ``IterationLog`` additionally reports ``worker_utilization`` (rollout
    time / worker loop wall time, windowed per iteration).

    Robustness (DESIGN.md §10): pass ``supervisor=`` (a
    ``core.supervisor.WorkerSupervisor`` over the same pool) and worker
    death/hangs are detected and respawned mid-run instead of killing
    the learner, with ``autoscale`` nudging the fleet size against the
    utilization band between updates. Pass ``staleness=`` (an enabled
    ``algos.staleness.StalenessConfig``) and every consumed trajectory
    is stamped with its params-version gap for the algo-side
    importance-weighted correction; disabled (default) attaches nothing.
    """

    def __init__(self, rollout: Optional[Callable],
                 learn: Optional[Callable],
                 params: Any, opt_state: Any, carries: Optional[List[Any]],
                 num_samplers: int, min_batches_per_update: int = 1,
                 queue_size: int = 64, *,
                 train_step: Optional[Callable] = None,
                 plane_state: Any = None, pool=None,
                 supervisor=None, staleness=None):
        self.pool = pool
        self.supervisor = supervisor      # core.supervisor.WorkerSupervisor
        self.staleness = staleness        # algos.staleness.StalenessConfig
        if pool is None:
            assert rollout is not None and carries is not None
            self.rollout = jax.jit(rollout)
        else:
            self.rollout = None
            num_samplers = pool.num_workers
        assert learn is not None or train_step is not None
        self.learn = jax.jit(learn) if learn is not None else None
        self._train_step = _maybe_jit_step(train_step)
        self.plane_state = plane_state
        self.store = PolicyStore(params)
        self.expq = ExperienceQueue(maxsize=queue_size)
        self.opt_state = opt_state
        self.carries = carries
        self.num_samplers = num_samplers
        self.min_batches = min_batches_per_update
        self.timer = PhaseTimer()
        self.logs: List[IterationLog] = []
        self._stop = threading.Event()

    @property
    def buffer_state(self):
        return None if self.plane_state is None else self.plane_state[0]

    def _attach_gap(self, traj, gap: float, np_mod):
        """Stamp the params-version gap onto every timestep of one
        trajectory (a (T, B) float32 leaf keyed ``staleness_gap``) so the
        algo-side correction can weight it after merging. Only called
        when staleness correction is enabled — with it off no key is
        added and every bitwise-parity guarantee is untouched."""
        ref = traj["rewards"]
        traj = dict(traj)
        traj["staleness_gap"] = np_mod.full(
            ref.shape[:2], float(max(0.0, gap)), dtype="float32")
        return traj

    # ------------------------------------------------------------ threads
    def _sampler_loop(self, i: int) -> None:
        while not self._stop.is_set():
            params, version = self.store.read()
            self.carries[i], traj, dt = timed_rollout(
                self.rollout, params, self.carries[i])
            # on overflow the experience is dropped and counted
            # (ExperienceQueue.drop_count -> IterationLog.queue_drops)
            if (not self.expq.put(Experience(traj, version, i, dt),
                                  timeout=5.0)
                    and self._stop.is_set()):
                return

    def _learner_loop(self, updates: int) -> None:
        import queue as _q
        for it in range(updates):
            exps: List[Experience] = []
            t_wait0 = time.perf_counter()
            while len(exps) < self.min_batches and not self._stop.is_set():
                try:
                    exps.append(self.expq.get(self.store.version,
                                              timeout=1.0))
                except _q.Empty:
                    continue
            if self._stop.is_set() and not exps:
                return
            wait = time.perf_counter() - t_wait0
            if self.staleness is not None and self.staleness.enabled:
                import jax.numpy as jnp
                trajs = [self._attach_gap(
                    e.traj, self.store.version - e.policy_version, jnp)
                    for e in exps]
            else:
                trajs = [e.traj for e in exps]
            merged = merge_trajs(trajs)
            params, _ = self.store.read()
            if self._train_step is not None:
                (params, self.opt_state, self.plane_state, _,
                 learn_time) = timed_train_step(
                     self._train_step, params, self.opt_state,
                     self.plane_state, merged)
            else:
                params, self.opt_state, _, learn_time = timed_learn(
                    self.learn, params, self.opt_state, merged)
            self.store.publish(params)
            record_log(self.logs, self.timer,
                       assemble_log(it, [e.collect_seconds for e in exps],
                                    learn_time, merged,
                                    staleness=self.expq.mean_staleness(),
                                    queue_drops=self.expq.drop_count))
            self.timer.add("collect_wait", wait)

    # ------------------------------------------------- process-pool learner
    def _learner_loop_pool(self, updates: int, deadline: float) -> None:
        """Drain the shared-memory ring while worker processes free-run.
        Returns early (like the thread path's learner join) once
        ``deadline`` passes with workers alive but unproductive.

        Accounting is *windowed per iteration* (not cumulative over the
        run): ``staleness`` and ``worker_utilization`` reflect only the
        experiences consumed for *this* update, so the log tracks the
        live fleet — a worker dying and being respawned mid-run shows up
        in that iteration's numbers instead of being averaged away over
        the whole history. With a supervisor attached, draining,
        failure handling and (between iterations) elastic resizing all
        route through it."""
        import numpy as _np
        it0 = len(self.logs)
        source = self.supervisor if self.supervisor is not None else self.pool
        stale_on = self.staleness is not None and self.staleness.enabled
        for it in range(updates):
            exps, gaps = [], []
            collect_s = loop_s = 0.0         # this iteration's window only
            t_wait0 = time.perf_counter()
            while len(exps) < self.min_batches and not self._stop.is_set():
                if time.monotonic() > deadline:
                    return
                got = source.next_experience(timeout=1.0)
                if got is None:
                    continue
                exp, loop_dt = got
                exps.append(exp)
                collect_s += exp.collect_seconds
                loop_s += loop_dt
                gaps.append(max(0, self.pool.version - exp.policy_version))
            if self._stop.is_set() and not exps:
                return
            wait = time.perf_counter() - t_wait0
            trajs = [e.traj for e in exps]
            if stale_on:
                trajs = [self._attach_gap(t, g, _np)
                         for t, g in zip(trajs, gaps)]
            merged = merge_trajs(
                [{k: jax.numpy.asarray(v) for k, v in t.items()}
                 for t in trajs])
            params, _ = self.store.read()
            if self._train_step is not None:
                (params, self.opt_state, self.plane_state, _,
                 learn_time) = timed_train_step(
                     self._train_step, params, self.opt_state,
                     self.plane_state, merged)
            else:
                params, self.opt_state, _, learn_time = timed_learn(
                    self.learn, params, self.opt_state, merged)
            self.store.publish(params)
            self.pool.publish(params)
            util = collect_s / loop_s if loop_s > 0 else 1.0
            record_log(self.logs, self.timer,
                       assemble_log(it0 + it,
                                    [e.collect_seconds for e in exps],
                                    learn_time, merged,
                                    staleness=float(sum(gaps) / len(gaps)),
                                    worker_utilization=util,
                                    respawns=(self.supervisor.respawns
                                              if self.supervisor else 0),
                                    active_workers=self.pool.num_workers))
            self.timer.add("collect_wait", wait)
            if self.supervisor is not None:
                self.supervisor.autoscale(util)

    # ---------------------------------------------------------------- run
    def run(self, updates: int, timeout: float = 600.0) -> List[IterationLog]:
        if self.pool is not None:
            # worker processes are the sampler concurrency; the learner
            # runs right here (Ctrl-C propagates, experiment.run reaps);
            # the timeout bounds a wedged-but-alive worker exactly like
            # the thread path's learner join
            self.pool.start_freerun()
            self._learner_loop_pool(updates, time.monotonic() + timeout)
            return self.logs
        samplers = [threading.Thread(target=self._sampler_loop, args=(i,),
                                     daemon=True)
                    for i in range(self.num_samplers)]
        learner = threading.Thread(target=self._learner_loop,
                                   args=(updates,), daemon=True)
        for t in samplers:
            t.start()
        learner.start()
        learner.join(timeout=timeout)
        self._stop.set()
        for t in samplers:
            t.join(timeout=5.0)
        return self.logs

    def close(self) -> None:
        """Stop sampler threads / reap worker processes (idempotent).

        With a supervisor attached, worker death is a tolerated,
        recovered-from event — a fault or crash landing between the last
        drained experience and shutdown must not resurface as a spurious
        ``WorkerCrashed`` from ``close``.
        """
        self._stop.set()
        if self.pool is not None:
            self.pool.close(raise_on_crash=self.supervisor is None)

    @property
    def params(self):
        return self.store.read()[0]
