"""WALL-E's agent processor: synchronous baseline + asynchronous runtime.

* ``SyncRunner`` — the N=1 architecture of the paper's comparison (also
  runs N logical samplers back-to-back so per-sampler critical-path time
  can be measured on a single host; see DESIGN.md §2 on measurement).
* ``AsyncOrchestrator`` — the paper's architecture: N sampler threads
  generating experience with the freshest published policy (possibly
  stale), a learner thread consuming the experience queue and publishing
  new parameters to the policy store. Device work stays jitted; threads
  orchestrate, matching the paper's process roles.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core.queues import Experience, ExperienceQueue, PolicyStore
from repro.core.timing import PhaseTimer
from repro.data import trajectory


@dataclasses.dataclass
class IterationLog:
    iteration: int
    collect_time: float          # critical-path (parallel) collection time
    collect_time_serial: float   # sum over samplers (1-process equivalent)
    learn_time: float
    mean_return: float
    samples: int
    staleness: float = 0.0

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


# ================================================================== sync
class SyncRunner:
    """Collect (N samplers, serially timed) -> learn -> repeat.

    With ``num_samplers=1`` this is exactly the paper's baseline. With
    N > 1 it executes each sampler's work back-to-back, recording each
    sampler's wall time; ``collect_time`` reports the max (the critical
    path a truly parallel deployment would see) and
    ``collect_time_serial`` the sum (what N=1 pays for the same samples).
    """

    def __init__(self, rollout: Callable, learn: Callable,
                 params: Any, opt_state: Any, carries: List[Any],
                 num_samplers: int):
        assert len(carries) == num_samplers
        self.rollout = jax.jit(rollout)
        self.learn = jax.jit(learn)
        self.params = params
        self.opt_state = opt_state
        self.carries = carries
        self.num_samplers = num_samplers
        self.timer = PhaseTimer()
        self.logs: List[IterationLog] = []

    def run(self, iterations: int) -> List[IterationLog]:
        for it in range(iterations):
            per_sampler: List[float] = []
            trajs = []
            for i in range(self.num_samplers):
                t0 = time.perf_counter()
                self.carries[i], traj = self.rollout(self.params,
                                                     self.carries[i])
                traj = jax.block_until_ready(traj)
                per_sampler.append(time.perf_counter() - t0)
                trajs.append(traj)
            merged = trajectory.merge(trajs) if len(trajs) > 1 else trajs[0]
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self.learn(
                self.params, self.opt_state, merged)
            jax.block_until_ready(self.params)
            learn_time = time.perf_counter() - t0
            ret = float(trajectory.episode_returns(merged))
            log = IterationLog(
                iteration=it,
                collect_time=max(per_sampler),
                collect_time_serial=sum(per_sampler),
                learn_time=learn_time,
                mean_return=ret,
                samples=trajectory.num_samples(merged),
            )
            self.logs.append(log)
            self.timer.add("collect", log.collect_time)
            self.timer.add("learn", learn_time)
        return self.logs


# ================================================================= async
class AsyncOrchestrator:
    """The paper's architecture (Fig 2): N sampler threads + learner thread.

    Sampler i loop:  params <- PolicyStore (latest, maybe stale)
                     traj   <- jitted rollout
                     ExperienceQueue.put(traj, version)
    Learner loop:    drain >= min_batches experiences
                     params <- jitted PPO update
                     PolicyStore.publish(params)
    """

    def __init__(self, rollout: Callable, learn: Callable,
                 params: Any, opt_state: Any, carries: List[Any],
                 num_samplers: int, min_batches_per_update: int = 1,
                 queue_size: int = 64):
        self.rollout = jax.jit(rollout)
        self.learn = jax.jit(learn)
        self.store = PolicyStore(params)
        self.expq = ExperienceQueue(maxsize=queue_size)
        self.opt_state = opt_state
        self.carries = carries
        self.num_samplers = num_samplers
        self.min_batches = min_batches_per_update
        self.timer = PhaseTimer()
        self.logs: List[IterationLog] = []
        self._stop = threading.Event()

    # ------------------------------------------------------------ threads
    def _sampler_loop(self, i: int) -> None:
        while not self._stop.is_set():
            params, version = self.store.read()
            t0 = time.perf_counter()
            self.carries[i], traj = self.rollout(params, self.carries[i])
            traj = jax.block_until_ready(traj)
            dt = time.perf_counter() - t0
            try:
                self.expq.put(Experience(traj, version, i, dt), timeout=5.0)
            except Exception:
                if self._stop.is_set():
                    return

    def _learner_loop(self, updates: int) -> None:
        import queue as _q
        for it in range(updates):
            exps: List[Experience] = []
            t_wait0 = time.perf_counter()
            while len(exps) < self.min_batches and not self._stop.is_set():
                try:
                    exps.append(self.expq.get(self.store.version,
                                              timeout=1.0))
                except _q.Empty:
                    continue
            if self._stop.is_set() and not exps:
                return
            wait = time.perf_counter() - t_wait0
            trajs = [e.traj for e in exps]
            merged = (trajectory.merge(trajs) if len(trajs) > 1
                      else trajs[0])
            t0 = time.perf_counter()
            params, _ = self.store.read()
            params, self.opt_state, metrics = self.learn(
                params, self.opt_state, merged)
            jax.block_until_ready(params)
            learn_time = time.perf_counter() - t0
            self.store.publish(params)
            collect = max(e.collect_seconds for e in exps)
            log = IterationLog(
                iteration=it,
                collect_time=collect,
                collect_time_serial=sum(e.collect_seconds for e in exps),
                learn_time=learn_time,
                mean_return=float(trajectory.episode_returns(merged)),
                samples=sum(trajectory.num_samples(t) for t in trajs),
                staleness=self.expq.mean_staleness(),
            )
            self.logs.append(log)
            self.timer.add("collect_wait", wait)
            self.timer.add("learn", learn_time)

    # ---------------------------------------------------------------- run
    def run(self, updates: int, timeout: float = 600.0) -> List[IterationLog]:
        samplers = [threading.Thread(target=self._sampler_loop, args=(i,),
                                     daemon=True)
                    for i in range(self.num_samplers)]
        learner = threading.Thread(target=self._learner_loop,
                                   args=(updates,), daemon=True)
        for t in samplers:
            t.start()
        learner.start()
        learner.join(timeout=timeout)
        self._stop.set()
        for t in samplers:
            t.join(timeout=5.0)
        return self.logs

    @property
    def params(self):
        return self.store.read()[0]
