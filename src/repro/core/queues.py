"""WALL-E's two queues, host-side.

* ``PolicyStore`` — the *policy queue*, implemented as a versioned
  latest-wins cell ("primed": samplers always read the freshest params and
  may therefore act with a stale policy; staleness is version-tracked).
* ``ExperienceQueue`` — bounded FIFO carrying ``Experience`` records
  (trajectory + the policy version that generated it + timing metadata)
  from samplers to the learner.

On a TPU mesh the queues dissolve into collectives (DESIGN.md §2); these
classes exist for the paper-faithful async runtime and its measurements.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, List, Optional, Tuple


class PolicyStore:
    """Versioned latest-wins parameter cell (the 'primed' policy queue)."""

    def __init__(self, params: Any, version: int = 0):
        self._lock = threading.Lock()
        self._params = params
        self._version = version
        self.publish_count = 0

    def publish(self, params: Any) -> int:
        with self._lock:
            self._params = params
            self._version += 1
            self.publish_count += 1
            return self._version

    def read(self) -> Tuple[Any, int]:
        with self._lock:
            return self._params, self._version

    @property
    def version(self) -> int:
        with self._lock:
            return self._version


@dataclasses.dataclass
class Experience:
    traj: Any                 # dict of (T, B, ...) arrays
    policy_version: int       # version the sampler acted with
    sampler_id: int
    collect_seconds: float    # sampler-side wall time for this rollout
    enqueue_time: float = dataclasses.field(default_factory=time.perf_counter)


class ExperienceQueue:
    """Bounded FIFO with staleness and overflow-drop accounting.

    ``drop_count`` counts experiences lost because the queue stayed full
    past the producer's timeout — the async runtime's backpressure signal
    (samplers outrunning the learner), surfaced per iteration as
    ``IterationLog.queue_drops`` so it is measurable instead of invisible.
    """

    def __init__(self, maxsize: int = 64):
        self._q: "queue.Queue[Experience]" = queue.Queue(maxsize=maxsize)
        self.put_count = 0
        self.drop_count = 0
        self.staleness: List[int] = []
        self.queue_wait: List[float] = []

    def put(self, exp: Experience, timeout: Optional[float] = None) -> bool:
        """Enqueue; on overflow (still full after ``timeout``) drop the
        experience, count it, and return False."""
        try:
            self._q.put(exp, timeout=timeout)
        except queue.Full:
            self.drop_count += 1
            return False
        self.put_count += 1
        return True

    def get(self, learner_version: int, timeout: Optional[float] = None
            ) -> Experience:
        exp = self._q.get(timeout=timeout)
        self.staleness.append(learner_version - exp.policy_version)
        self.queue_wait.append(time.perf_counter() - exp.enqueue_time)
        return exp

    def drain(self, learner_version: int, max_items: int) -> List[Experience]:
        """Non-blocking drain of up to ``max_items`` queued experiences."""
        items = []
        while len(items) < max_items:
            try:
                items.append(self.get(learner_version, timeout=0.0))
            except queue.Empty:
                break
        return items

    def qsize(self) -> int:
        return self._q.qsize()

    def mean_staleness(self) -> float:
        return (sum(self.staleness) / len(self.staleness)
                if self.staleness else 0.0)
