"""Rollout samplers — WALL-E's N parallel sampler processors, JAX-native.

Three granularities of "parallel sampler":

* ``make_env_rollout`` — one sampler: a ``vmap``-batched environment swept
  ``T`` steps with ``lax.scan`` under the current policy. This is the unit
  of work one WALL-E sampler process performs per iteration.
* ``make_sharded_rollout`` — the TPU-native form: ``shard_map`` places one
  sampler per ``data``-axis mesh slice; trajectories are *born sharded* and
  the learner consumes them in place (the experience queue becomes zero
  movement; see DESIGN.md §2).
* ``make_lm_rollout`` — the sequence-model sampler: autoregressive decode
  against a synthetic reward model (``envs.lm_env``), i.e. the RLHF-style
  workload whose inner step ``decode_32k``/``long_500k`` lower.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.envs.base import Env, auto_reset
from repro.models import mlp_policy, transformer


# ============================================================ env sampler
def batched_reset(env: Env, key, batch: int):
    states, obs = jax.vmap(env.reset)(jax.random.split(key, batch))
    return states, obs


def batched_step(env: Env) -> Callable:
    """Batched step+auto-reset: ``step(state, actions, keys)`` over
    ``(B,)``-leading leaves.

    A ``VectorEnv`` supplies its fused fast-path (``batched_step``
    attribute — one kernel dispatch for the whole batch); a plain ``Env``
    gets the historical ``vmap(auto_reset(env))``, byte-for-byte the
    rollout bodies' previous inline expression. The two are
    bitwise-identical for matched keys (``tests/test_vector_env.py``), so
    which one a rollout traces is a scheduling choice, not a numerical
    one.
    """
    fast = getattr(env, "batched_step", None)
    if fast is not None:
        return fast
    step_fn = auto_reset(env)

    def step(state, actions, keys):
        return jax.vmap(step_fn)(state, actions, keys)

    return step


def make_env_rollout(env: Env, horizon: int) -> Callable:
    """Build ``rollout(params, carry, step_keys) -> (carry', traj)``.

    carry = (env_state pytree (B,...), obs (B,obs_dim), keys (B,) PRNG).
    traj arrays are time-major ``(T, B, ...)``; includes ``last_value``.
    Pure and jit/shard_map-compatible.
    """
    step_batch = batched_step(env)

    def rollout(params, carry, _unused=None):
        def body(carry, _):
            env_state, obs, keys = carry
            splits = jax.vmap(lambda k: jax.random.split(k, 3))(keys)
            keys2, ka, ke = splits[:, 0], splits[:, 1], splits[:, 2]
            actions, logp = jax.vmap(
                mlp_policy.sample_action, in_axes=(None, 0, 0))(
                    params, obs, ka)
            values = mlp_policy.value_apply(params, obs)
            env_state2, obs2, rewards, dones = step_batch(
                env_state, actions, ke)
            out = {"obs": obs, "actions": actions, "rewards": rewards,
                   "dones": dones, "logp": logp, "values": values}
            return (env_state2, obs2, keys2), out

        carry, traj = jax.lax.scan(body, carry, None, length=horizon)
        traj["last_value"] = mlp_policy.value_apply(params, carry[1])
        return carry, traj

    return rollout


def init_env_carry(env: Env, key, batch: int):
    k_reset, k_keys = jax.random.split(key)
    states, obs = batched_reset(env, k_reset, batch)
    keys = jax.random.split(k_keys, batch)
    return (states, obs, keys)


def make_algo_rollout(algo, env: Env, horizon: int) -> Callable:
    """Algorithm-generic rollout: actions come from ``algo.act``.

    ``algo.act(params, obs, key) -> (action, extras)`` is vmapped over the
    env batch; per-step ``extras`` (e.g. behaviour logp) land in the traj
    under their own keys. Off-policy algos (``algo.needs_next_obs``) get
    ``next_obs`` recorded so the learner can build replay transitions;
    ``algo.rollout_tail`` appends end-of-rollout values (e.g. the GAE
    bootstrap). Same carry/traj layout as ``make_env_rollout``, so every
    backend schedules it unchanged.
    """
    step_batch = batched_step(env)
    needs_next_obs = bool(getattr(algo, "needs_next_obs", False))

    def rollout(params, carry, _unused=None):
        def body(carry, _):
            env_state, obs, keys = carry
            splits = jax.vmap(lambda k: jax.random.split(k, 3))(keys)
            keys2, ka, ke = splits[:, 0], splits[:, 1], splits[:, 2]
            actions, extras = jax.vmap(
                algo.act, in_axes=(None, 0, 0))(params, obs, ka)
            env_state2, obs2, rewards, dones = step_batch(
                env_state, actions, ke)
            out = {"obs": obs, "actions": actions, "rewards": rewards,
                   "dones": dones, **extras}
            if needs_next_obs:
                out["next_obs"] = obs2
            return (env_state2, obs2, keys2), out

        carry, traj = jax.lax.scan(body, carry, None, length=horizon)
        traj.update(algo.rollout_tail(params, carry[1]))
        return carry, traj

    return rollout


# ====================================================== sharded (TPU) form
def make_sharded_rollout(env: Env, horizon: int, mesh,
                         data_axes=("data",), rollout: Callable = None,
                         step_keys: Tuple[str, ...] = ("obs", "actions",
                                                       "rewards", "dones",
                                                       "logp", "values"),
                         tail_keys: Tuple[str, ...] = ("last_value",)
                         ) -> Callable:
    """One WALL-E sampler per ``data``-axis slice via shard_map.

    Params are replicated (the policy broadcast = the paper's policy queue);
    env state / trajectories are sharded on the batch axis and never leave
    their shard — the learner's pjit consumes them with identical sharding.

    ``rollout`` defaults to the PPO-family ``make_env_rollout``; pass an
    algorithm rollout (``make_algo_rollout``) plus its ``step_keys`` /
    ``tail_keys`` to shard any algorithm's trajectory layout.
    """
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import shard_map_compat

    if rollout is None:
        rollout = make_env_rollout(env, horizon)
    batch_spec = P(data_axes)                      # leading dim = env batch
    carry_spec = (batch_spec, batch_spec, batch_spec)
    # trajectory arrays are time-major (T, B, ...): batch is dim 1
    traj_spec = {k: P(None, data_axes) for k in step_keys}
    traj_spec.update({k: batch_spec for k in tail_keys})

    sharded = shard_map_compat(
        lambda p, c: rollout(p, c),
        mesh,
        (P(), carry_spec),
        (carry_spec, traj_spec),
    )
    return sharded


# ============================================================== LM sampler
def make_lm_rollout(cfg, lmenv, gen_len: int) -> Callable:
    """Sequence-policy sampler: prefill the prompt, then decode ``gen_len``
    tokens (the experience-collection inner loop), scoring with the token
    reward model. Returns time-major traj compatible with the PPO learner.
    """

    def rollout(params, prompt: jnp.ndarray, key) -> Dict[str, jnp.ndarray]:
        B, P = prompt.shape
        state, logits = transformer.prefill(cfg, params, prompt,
                                            gen_budget=gen_len)

        def body(carry, key_t):
            state, logits = carry
            tok = jax.random.categorical(key_t, logits)          # (B,)
            logp = jnp.take_along_axis(jax.nn.log_softmax(logits, -1),
                                       tok[:, None], axis=-1)[:, 0]
            state, logits2 = transformer.decode_step(cfg, params, state,
                                                     tok[:, None])
            return (state, logits2), (tok, logp)

        keys = jax.random.split(key, gen_len)
        (state, _), (tokens, logps) = jax.lax.scan(body, (state, logits),
                                                   keys)
        tokens = tokens.T                                       # (B, T)
        logps = logps.T
        rewards = lmenv.token_rewards(tokens)
        return {
            "tokens": tokens, "logp": logps, "rewards": rewards,
            "prompt": prompt,
        }

    return rollout


# ========================================================== the worker spec
@dataclasses.dataclass(frozen=True)
class WorkerSpec:
    """Everything a fresh *process* needs to become one rollout worker.

    Plain data only — registry names plus JSON-safe kwargs — so the spec
    pickles across a ``spawn`` boundary and the worker rebuilds its env,
    algorithm, jitted rollout and carry purely via the registry
    (``build``); no closures, params or tracers ever cross. ``seed`` is
    the *per-worker* seed (the parent passes ``schedule.seed + i``), so a
    process worker's carry is bitwise the carry the inline backend would
    have built for sampler ``i`` — the root of the ``process == inline``
    determinism rule (DESIGN.md §6).
    """
    env: str
    algo: str
    horizon: int
    batch: int                      # per-worker env batch
    seed: int                       # per-worker: schedule.seed + worker_id
    kernels: str = "auto"
    env_kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    algo_kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "WorkerSpec":
        return cls(**d)

    def build(self):
        """Rebuild ``(rollout, carry, params_template)`` in this process.

        ``rollout`` is the algorithm's unjitted rollout (callers jit it);
        ``carry`` the worker's initial env carry; ``params_template`` a
        freshly-initialized params pytree whose *structure* (not values)
        lets the worker unflatten leaves read from a ``ParamsChannel``.
        Sets the kernel-plane mode first so everything traced here sees
        the spec's implementation choice.
        """
        from repro import kernels as kernels_mod
        from repro import registry
        kernels_mod.set_kernel_mode(self.kernels)
        env = registry.make("env", self.env, **dict(self.env_kwargs))
        algo = registry.make("algo", self.algo, **dict(self.algo_kwargs))
        rollout = algo.make_rollout(env, self.horizon)
        carry = init_env_carry(env, jax.random.PRNGKey(self.seed),
                               self.batch)
        params, _ = algo.init(jax.random.PRNGKey(self.seed), env)
        return rollout, carry, params


# ===================================================== sample-count helper
def samples_per_rollout(batch: int, horizon: int) -> int:
    return batch * horizon


def split_batch(global_batch: int, num_samplers: int) -> int:
    """Per-sampler env batch (the paper divides 20000 samples across N).

    Raises ``ValueError`` when the split is not exact — silently
    truncating would collect fewer samples than the schedule promised.
    """
    if num_samplers < 1:
        raise ValueError(f"num_samplers={num_samplers} must be >= 1")
    if global_batch < 1:
        raise ValueError(f"global_batch={global_batch} must be >= 1")
    if global_batch % num_samplers != 0:
        lower = (global_batch // num_samplers) * num_samplers
        upper = lower + num_samplers
        raise ValueError(
            f"global_batch={global_batch} is not divisible by "
            f"num_samplers={num_samplers}; every sampler must get an "
            f"equal env batch — adjust global_batch (nearest multiples: "
            + (f"{lower} or {upper}" if lower >= num_samplers
               else f"{upper}") + ")")
    return global_batch // num_samplers
