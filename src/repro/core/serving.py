"""Batched token serving with a request queue — WALL-E's queues, serving
edition.

The same decoupling the paper applies to RL experience collection applies
to inference: a bounded **request queue** feeds a fixed-width slot batch;
the jitted decode step advances all slots together; a slot that hits EOS
stops emitting (its tail steps are wasted work, counted in the stats).

Scheduling is **wave-based**: a new wave of requests is admitted when the
current wave finishes. Per-slot continuous refill needs per-slot cache
positions (each sequence at a different depth); the decode state keeps one
shared position counter, so that upgrade — forced-decoding prompt injection
into a live batch — is noted as the next step in DESIGN.md §7 rather than
half-implemented here. Fixed shapes mean request churn never recompiles.
"""
from __future__ import annotations

import dataclasses
import queue
import time
from typing import Any, List, Optional

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.serve.stats import ServingStats


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: Any                     # (prompt_len,) int32
    max_new_tokens: int
    enqueue_time: float = dataclasses.field(default_factory=time.perf_counter)


@dataclasses.dataclass
class Completion:
    request_id: int
    tokens: List[int]
    latency: float
    queue_wait: float


class SlotServer:
    """Fixed-width, wave-scheduled batch server over ``decode_step``."""

    def __init__(self, cfg, params, *, slots: int, prompt_len: int,
                 max_new_tokens: int, eos_id: Optional[int] = None,
                 seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.prompt_len = prompt_len
        self.budget = max_new_tokens
        self.eos_id = eos_id
        self.queue: "queue.Queue[Request]" = queue.Queue()
        self.completions: List[Completion] = []
        # the shared serving-stats schema (serve/stats.py) — the same
        # accounting the RL PolicyServer reports; wasted_slot_steps
        # (EOS'd/padded slots riding out the wave) lives here now
        self.stats = ServingStats(slots=slots)
        self.decode_steps = 0
        self._key = jax.random.PRNGKey(seed)

        def step(params, state, tokens, key):
            state, logits = transformer.decode_step(cfg, params, state,
                                                    tokens)
            nxt = jax.random.categorical(key, logits)
            return state, nxt

        self._step = jax.jit(step)
        self._prefill = jax.jit(
            lambda params, toks: transformer.prefill(
                cfg, params, toks, gen_budget=max_new_tokens))

    def submit(self, req: Request) -> None:
        assert req.prompt.shape == (self.prompt_len,), (
            f"prompt must be left-padded to {self.prompt_len}")
        self.queue.put(req)

    # ------------------------------------------------------------- wave
    def _run_wave(self, wave: List[Request]) -> None:
        pad = self.slots - len(wave)
        prompts = [r.prompt for r in wave] + [
            jnp.zeros((self.prompt_len,), jnp.int32)] * pad
        start = time.perf_counter()
        state, logits = self._prefill(self.params, jnp.stack(prompts))
        self._key, k = jax.random.split(self._key)
        tokens = jax.random.categorical(k, logits)[:, None]

        emitted: List[List[int]] = [[] for _ in wave]
        done = [False] * len(wave)
        budget = min(self.budget, max(r.max_new_tokens for r in wave))
        for _ in range(budget):
            # occupancy accounting: a slot emitting this step is occupied;
            # already-EOS'd slots riding out the wave and the padded tail
            # accrue wasted_slot_steps (a slot emitting its *final* token
            # this step still counts as occupied)
            self.stats.observe_batch(len(wave) - sum(done))
            host = [int(t) for t in tokens[:, 0]]
            for i, req in enumerate(wave):
                if done[i]:
                    continue
                emitted[i].append(host[i])
                if (len(emitted[i]) >= req.max_new_tokens
                        or (self.eos_id is not None
                            and host[i] == self.eos_id)):
                    done[i] = True
            if all(done):
                break
            self._key, k = jax.random.split(self._key)
            state, nxt = self._step(self.params, state, tokens, k)
            tokens = nxt[:, None]
            self.decode_steps += 1
        now = time.perf_counter()
        for i, req in enumerate(wave):
            self.completions.append(Completion(
                request_id=req.request_id,
                tokens=emitted[i],
                latency=now - start,
                queue_wait=start - req.enqueue_time,
            ))
            # shared-schema latency is end-to-end (enqueue -> done);
            # Completion.latency stays wave-relative for compatibility
            self.stats.observe(latency_s=now - req.enqueue_time,
                               queue_wait_s=start - req.enqueue_time)

    @property
    def wasted_slot_steps(self) -> int:
        """EOS'd/padded slot-steps — now kept by the shared stats."""
        return self.stats.wasted_slot_steps

    def snapshot(self) -> dict:
        """The serving-stats schema shared with ``serve.PolicyServer``
        (``serve/stats.py``) — p50/p99 latency, queue wait, batch
        occupancy and the once-internal ``wasted_slot_steps``."""
        return self.stats.snapshot()

    # -------------------------------------------------------------- run
    def run(self) -> List[Completion]:
        """Serve until the queue is drained."""
        while True:
            wave: List[Request] = []
            while len(wave) < self.slots:
                try:
                    wave.append(self.queue.get_nowait())
                except queue.Empty:
                    break
            if not wave:
                break
            self._run_wave(wave)
        return self.completions
