"""Actor-fleet supervision: failure detection, respawn, elastic sizing
(DESIGN.md §10).

``ProcessWorkerPool`` exposes fleet *mechanism* (spawn/kill/respawn,
heartbeat ages, slot reclamation, seq-checked slot reads);
``WorkerSupervisor`` is the *policy* layered on top — Parallel Actors
and Learners' (PAPERS.md) restartable-actor-component, scoped to one
host:

* **Detection** — three independent signals, all bounded in time: the
  process exited (``dead_workers``), the worker reported a Python
  exception (an ``("error", ...)`` message), or the worker is alive but
  its heartbeat stopped (``heartbeat_age > hang_timeout`` — a wedged
  worker, which is then SIGKILLed into the dead case). The supervisor
  never blocks forever on the result queue: every wait is a bounded
  poll interleaved with these checks.
* **Recovery** — the dead worker's ring slots are reclaimed (torn
  seqlocks repaired, orphaned writes released; completed rollouts whose
  result message already arrived are kept and consumed normally), then
  the worker is respawned from its serializable ``WorkerSpec`` under
  exponential backoff. A per-worker *consecutive*-failure counter (reset
  by any successful rollout) enforces the crash-loop budget: more than
  ``max_respawns`` failures in a row raises ``WorkerCrashed`` — a worker
  that dies every time it runs is a bug, not an outage.
* **Exactly-once consumption** — trajectory messages carry the slot's
  post-write seqlock value; ``read_slot_checked`` refuses a message
  whose slot has since been reclaimed and rewritten
  (``StaleSlotMessage`` -> counted discard). No trajectory is consumed
  twice, and none that was *reported* is lost.
* **Elastic sizing** — ``autoscale`` nudges the active set toward a
  ``worker_utilization`` band between iterations: utilization above
  ``util_high`` means samplers are the bottleneck -> ``grow``; below
  ``util_low`` they idle on backpressure -> ``shrink``. One step per
  call, ``resize_cooldown`` iterations apart, clamped to
  [``min_workers``, ``max_workers``]. Joiners read the current params
  from the already-provisioned ``ParamsChannel`` on their first rollout.

The supervisor mirrors the pool's two driving modes (``collect`` for
lock-step, ``next_experience`` for free-run) so backends and the async
orchestrator swap it in without restructuring.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, List, Optional, Tuple

from repro.core.ipc import (
    ProcessWorkerPool,
    RingSlotStuck,
    StaleSlotMessage,
    WorkerCrashed,
)


@dataclasses.dataclass(frozen=True)
class SupervisorConfig:
    """Respawn, hang-detection and elastic-resize policy knobs."""

    max_respawns: int = 3        # consecutive failures per worker before
                                 # the crash-loop budget raises
    backoff_base: float = 0.25   # backoff = min(base * 2^(n-1), max)
    backoff_max: float = 5.0
    hang_timeout: float = 120.0  # heartbeat age that declares a hang; must
                                 # exceed the longest legitimate rollout
    min_workers: Optional[int] = None   # autoscale floor (None: no shrink
                                        # below 1 / elastic off)
    max_workers: Optional[int] = None   # autoscale ceiling (None: pool
                                        # provisioning is the ceiling)
    util_low: float = 0.5        # shrink below this utilization ...
    util_high: float = 0.9       # ... grow above this
    resize_cooldown: int = 2     # iterations between resize steps

    @property
    def elastic(self) -> bool:
        return self.min_workers is not None or self.max_workers is not None


@dataclasses.dataclass(frozen=True)
class SupervisorEvent:
    """One supervision decision, for logs/tests: kind is ``respawn`` /
    ``grow`` / ``shrink``."""
    kind: str
    worker_id: int
    time: float
    detail: str


class WorkerSupervisor:
    """Failure-detection + respawn + elastic-resize policy over a
    ``ProcessWorkerPool`` (see module docstring for the protocol)."""

    def __init__(self, pool: ProcessWorkerPool,
                 cfg: Optional[SupervisorConfig] = None):
        self.pool = pool
        self.cfg = cfg or SupervisorConfig()
        self.events: List[SupervisorEvent] = []
        self.respawns = 0            # lifetime respawn count
        self.slots_reclaimed = 0
        self.stale_discards = 0      # messages dropped by the seq check
        self.recovery_s: List[float] = []   # death-detected -> respawned
        self._consec: dict = {}      # wid -> consecutive failures
        self._cooldown = 0

    # ----------------------------------------------------------- recovery
    def _respawn(self, wid: int, reason: str) -> None:
        """Reclaim + respawn worker ``wid``, enforcing backoff and the
        crash-loop budget. Raises ``WorkerCrashed`` when the budget is
        exhausted."""
        t0 = time.monotonic()
        n = self._consec.get(wid, 0) + 1
        self._consec[wid] = n
        if n > self.cfg.max_respawns:
            self.pool._crash_surfaced.add(wid)   # close() must not re-raise
            err = WorkerCrashed(
                f"rollout worker #{wid} is crash-looping: {n} consecutive "
                f"failures (crash-loop budget max_respawns="
                f"{self.cfg.max_respawns}); last failure: {reason}")
            self.pool._last_crash = err
            raise err
        backoff = min(self.cfg.backoff_base * (2.0 ** (n - 1)),
                      self.cfg.backoff_max)
        time.sleep(backoff)
        reclaimed = self.pool.reclaim_worker_slots(wid)
        self.slots_reclaimed += len(reclaimed)
        self.pool.respawn(wid)
        self.respawns += 1
        self.recovery_s.append(time.monotonic() - t0)
        self.events.append(SupervisorEvent(
            "respawn", wid, time.monotonic(),
            f"{reason}; backoff {backoff:.2f}s; incarnation "
            f"{self.pool._incarnation[wid]}; reclaimed slots {reclaimed}"))

    def _sweep_failures(self, on_dead) -> None:
        """Check every bounded-time failure signal once; route each dead
        worker through ``on_dead(wid, reason)``."""
        for wid, code in self.pool.dead_workers():
            on_dead(wid, f"process exited (exitcode={code})")
        for wid in list(self.pool.active):
            age = self.pool.heartbeat_age(wid)
            if age > self.cfg.hang_timeout:
                self.pool.kill_worker(wid)
                on_dead(wid, f"hung: no heartbeat for {age:.1f}s "
                             f"(hang_timeout={self.cfg.hang_timeout:.0f}s)")

    def _has_pending_traj(self, wid: int) -> bool:
        self.pool.drain_pending()
        return any(m[0] == "traj" and m[1] == wid
                   for m in self.pool._stash)

    # ---------------------------------------------------------- lock-step
    def collect(self, staggered: bool = False
                ) -> Tuple[List[Any], List[float], List[float]]:
        """Supervised lock-step sweep: same contract as
        ``ProcessWorkerPool.collect`` (one trajectory per active worker,
        worker-index merge order), but a worker that dies mid-sweep is
        respawned and its command re-issued — unless its completed
        rollout already reached the result queue, in which case that
        result is consumed and nothing is re-run (exactly-once)."""
        pool = self.pool
        if pool._freerunning:
            raise RuntimeError(
                "pool is free-running (async mode); lock-step collect() "
                "would interleave with unsolicited rollouts")
        version = pool.channel.version
        got = {}

        def on_dead(wid: int, reason: str) -> None:
            self._respawn(wid, reason)
            if wid not in got and not self._has_pending_traj(wid):
                pool.send(wid, ("collect", version))

        def gather_one(deadline: float) -> None:
            while True:
                if time.monotonic() > deadline:
                    raise TimeoutError(
                        f"no worker result within "
                        f"{pool.collect_timeout:.0f}s (supervised collect)")
                msg = pool.poll_msg(timeout=0.25)
                if msg is None:
                    self._sweep_failures(on_dead)
                    continue
                if msg[0] == "ready":
                    continue
                if msg[0] == "error":
                    on_dead(msg[1], f"raised:\n{msg[2]}")
                    continue
                _, wid, slot, seq, _v, dt, loop_dt = msg
                self._consec[wid] = 0
                if wid in got:           # duplicate: free the slot, drop
                    try:
                        pool.read_slot_checked(slot, seq)
                    except (StaleSlotMessage, RingSlotStuck):
                        pass
                    self.stale_discards += 1
                    continue
                got[wid] = (slot, seq, dt, loop_dt)
                return

        targets = list(pool.active)
        if staggered:
            for i in targets:
                pool.send(i, ("collect", version))
                gather_one(time.monotonic() + pool.collect_timeout)
        else:
            for i in targets:
                pool.send(i, ("collect", version))
            deadline = time.monotonic() + pool.collect_timeout
            while len(got) < len(targets):
                gather_one(deadline)
        trajs, times, loops = [], [], []
        for i in targets:                    # deterministic merge order
            slot, seq, dt, loop_dt = got[i]
            traj, _meta = pool.read_slot_checked(slot, seq)
            trajs.append(traj)
            times.append(dt)
            loops.append(loop_dt)
        return trajs, times, loops

    # ------------------------------------------------------------ freerun
    def next_experience(self, timeout: float = 1.0):
        """Supervised drain of one free-run rollout: same contract as
        ``ProcessWorkerPool.next_experience`` (``(Experience,
        loop_seconds)`` or ``None`` on timeout), with death/hang sweeps
        between polls, stale-message discards, and stuck-slot
        reclamation instead of a consumer hang."""
        from repro.core.queues import Experience
        pool = self.pool

        def on_dead(wid: int, reason: str) -> None:
            # respawn re-enters freerun by itself (pool._freerunning)
            self._respawn(wid, reason)

        deadline = time.monotonic() + timeout
        while True:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                return None
            msg = pool.poll_msg(timeout=min(0.25, remaining))
            if msg is None:
                self._sweep_failures(on_dead)
                continue
            if msg[0] == "ready":
                continue
            if msg[0] == "error":
                on_dead(msg[1], f"raised:\n{msg[2]}")
                continue
            _, wid, slot, seq, version, dt, _loop = msg
            self._consec[wid] = 0
            try:
                traj, meta = pool.read_slot_checked(slot, seq)
            except StaleSlotMessage:
                self.stale_discards += 1
                continue
            except RingSlotStuck as e:
                # a fresh torn write landed on this exact slot between the
                # seq check and the read; repair it and move on — the
                # writer's death will surface on the next sweep
                if pool.ring.reclaim(e.slot) is not None:
                    self.slots_reclaimed += 1
                continue
            return (Experience(traj=traj, policy_version=version,
                               sampler_id=wid, collect_seconds=dt),
                    meta["loop_seconds"])

    # ---------------------------------------------------------- elasticity
    def autoscale(self, utilization: float) -> Optional[Tuple[str, int]]:
        """One bounded resize step toward the utilization band; returns
        ``("grow"|"shrink", wid)`` or ``None``. Call between iterations
        with the latest ``IterationLog.worker_utilization``."""
        cfg = self.cfg
        if not cfg.elastic:
            return None
        if self._cooldown > 0:
            self._cooldown -= 1
            return None
        lo = max(1, cfg.min_workers or 1)
        hi = min(self.pool.max_workers,
                 cfg.max_workers or self.pool.max_workers)
        active = len(self.pool.active)
        if utilization > cfg.util_high and active < hi:
            wid = self.pool.grow()
            if wid is not None:
                self._cooldown = cfg.resize_cooldown
                self.events.append(SupervisorEvent(
                    "grow", wid, time.monotonic(),
                    f"utilization {utilization:.2f} > {cfg.util_high} "
                    f"({active} -> {active + 1} workers)"))
                return ("grow", wid)
        elif utilization < cfg.util_low and active > lo:
            wid = self.pool.shrink()
            if wid is not None:
                self._cooldown = cfg.resize_cooldown
                self.events.append(SupervisorEvent(
                    "shrink", wid, time.monotonic(),
                    f"utilization {utilization:.2f} < {cfg.util_low} "
                    f"({active} -> {active - 1} workers)"))
                return ("shrink", wid)
        return None
