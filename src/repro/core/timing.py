"""Phase timers for the collection-vs-learning split (paper Figs 4-7)."""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from typing import Dict, List


@dataclasses.dataclass
class PhaseTimer:
    """Accumulates wall-clock per named phase, per iteration."""
    records: Dict[str, List[float]] = dataclasses.field(
        default_factory=lambda: defaultdict(list))

    def time(self, phase: str):
        timer = self

        class _Ctx:
            def __enter__(self):
                self.t0 = time.perf_counter()
                return self

            def __exit__(self, *exc):
                timer.records[phase].append(time.perf_counter() - self.t0)

        return _Ctx()

    def add(self, phase: str, seconds: float) -> None:
        self.records[phase].append(seconds)

    def total(self, phase: str) -> float:
        return sum(self.records.get(phase, []))

    def mean(self, phase: str) -> float:
        r = self.records.get(phase, [])
        return sum(r) / len(r) if r else 0.0

    def fractions(self) -> Dict[str, float]:
        totals = {k: self.total(k) for k in self.records}
        denom = sum(totals.values()) or 1.0
        return {k: v / denom for k, v in totals.items()}

    def summary(self) -> Dict[str, Dict[str, float]]:
        return {k: {"total": self.total(k), "mean": self.mean(k),
                    "count": len(v)} for k, v in self.records.items()}
