from repro.data import buffers, replay, trajectory  # noqa: F401
