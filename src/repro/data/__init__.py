from repro.data import replay, trajectory  # noqa: F401
