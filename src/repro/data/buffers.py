"""The experience plane: device-resident experience buffers.

One ``ExperienceBuffer`` protocol, three jittable implementations,
registered under the registry kind ``"buffer"`` and selected per
experiment via ``ExperimentSpec.buffer`` / ``buffer_kwargs``:

* ``fifo``        — on-policy trajectory pass-through: the latest merged
  trajectory *is* the buffer contents. ``add`` replaces, ``sample``
  returns it verbatim, so an on-policy learner sees exactly the batch the
  backends collected (``ppo`` × ``inline`` stays bitwise-identical to the
  pre-plane path).
* ``uniform``     — the classic replay ring (``data/replay.py``),
  generalized with n-step returns: trajectories are flattened into
  transitions at ``add`` time, rewards are aggregated over ``n_step``
  steps and each stored transition carries its own bootstrap
  ``discounts`` (= gamma^n, or 0 past a terminal), so learners never need
  to know ``n``.
* ``prioritized`` — proportional prioritized replay (Schaul et al.,
  2015): a sum-tree (stored as a tuple of per-level arrays, all jittable)
  supports O(log capacity) stratified sampling by priority;
  ``sample`` returns importance weights (beta-corrected, normalized to
  max 1) and slot ``indices`` so the learner can feed TD errors back
  through ``update_priorities``.

All state is a pytree of fixed-shape device arrays, so buffer state can
live inside a donated ``lax.scan`` carry (the fused engine), flow through
jitted train steps without host round-trips (sync/async), and ride
mesh-sharded trajectories (the sharded backend). See DESIGN.md §4.

Invariant (shared with ``data/replay.py``): sampling an *empty* buffer is
a caller error. The composed train step (``algos.api.make_train_step``)
always observes a trajectory before sampling, so ``size >= 1`` holds by
construction; ``replay.sample`` raises eagerly when called outside jit
with ``size == 0``.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro import registry
from repro.data import replay
from repro.kernels.replay_ring import ring_gather
from repro.kernels.sum_tree import (  # noqa: F401  (re-exported API)
    SumTree,
    sumtree_build,
    sumtree_find,
    sumtree_find_batch,
    sumtree_update,
)


@runtime_checkable
class ExperienceBuffer(Protocol):
    """Pure-function buffer: state in, state out — owned by the runner.

    ``kind`` is ``"trajectory"`` (the sampled batch is a whole trajectory,
    for on-policy learners) or ``"transitions"`` (flat replay minibatches,
    for off-policy learners); ``experiment.build`` validates algo/buffer
    compatibility through it.
    """

    name: str
    kind: str

    def init(self, example: Any) -> Any:
        """Allocate zeroed device storage shaped like ``example``."""
        ...

    def add(self, state: Any, traj: Dict[str, jnp.ndarray]) -> Any:
        """Absorb one collected trajectory batch. Jittable."""
        ...

    def sample(self, state: Any, key) -> Dict[str, jnp.ndarray]:
        """Draw one learner batch. Jittable."""
        ...

    def update_priorities(self, state: Any, indices, priorities) -> Any:
        """Feed per-sample TD errors back (no-op unless prioritized)."""
        ...


# ==================================================== n-step preprocessing
def nstep_transitions(traj: Dict[str, jnp.ndarray], n_step: int,
                      gamma: float) -> Dict[str, jnp.ndarray]:
    """Flatten a time-major trajectory into n-step transitions.

    Input arrays are ``(T, B, ...)`` with keys ``obs/actions/rewards/
    dones/next_obs``. For each start ``t <= T - n`` the transition carries

        rewards    = sum_{k<n} gamma^k * r_{t+k}   (truncated at a done)
        next_obs   = next_obs_{t+n-1}
        discounts  = gamma^n if no done inside the window else 0

    so the learner's bootstrap is always ``rewards + discounts * Q(next)``
    regardless of ``n``. The last ``n - 1`` steps of the trajectory have
    no full window and are dropped (their experience returns in the next
    iteration's overlap-free window). Output arrays are flat
    ``((T-n+1)*B, ...)``.
    """
    T = traj["rewards"].shape[0]
    if n_step < 1 or n_step > T:
        raise ValueError(
            f"n_step={n_step} must be in [1, horizon={T}]")
    Tn = T - n_step + 1
    rewards = jnp.zeros_like(traj["rewards"][:Tn], dtype=jnp.float32)
    notdone = jnp.ones_like(rewards)
    for k in range(n_step):
        rewards = rewards + (gamma ** k) * notdone * traj["rewards"][k:k + Tn]
        notdone = notdone * (1.0 - traj["dones"][k:k + Tn]
                             .astype(jnp.float32))
    out = {
        "obs": traj["obs"][:Tn],
        "actions": traj["actions"][:Tn],
        "rewards": rewards,
        "next_obs": traj["next_obs"][n_step - 1:n_step - 1 + Tn],
        "discounts": (gamma ** n_step) * notdone,
    }
    if "staleness_w" in traj:       # per-transition staleness weight rides
        out["staleness_w"] = traj["staleness_w"][:Tn]   # its start step
    return {k: v.reshape((-1,) + v.shape[2:]) for k, v in out.items()}


def transition_storage_example(example: Dict[str, jnp.ndarray]
                               ) -> Dict[str, jnp.ndarray]:
    """Normalize a per-transition example to the stored schema: ``dones``
    dissolves into per-transition ``discounts`` at add time."""
    out = {k: v for k, v in example.items() if k != "dones"}
    out.setdefault("discounts",
                   jnp.zeros(example["rewards"].shape, jnp.float32))
    return out


# ===================================================================== fifo
class FifoBuffer:
    """On-policy pass-through: the buffer *is* the latest trajectory.

    ``add`` replaces the stored trajectory wholesale and ``sample``
    returns it untouched — the identity schedule, which keeps on-policy
    learners bitwise-identical to the pre-plane direct ``learn(traj)``
    path while still flowing through the same plane seam (and the same
    donated scan carry under the fused engine).
    """

    name = "fifo"
    kind = "trajectory"
    passthrough = True          # train step may skip the PRNG/scan machinery

    def init(self, example):
        return jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype), example)

    def add(self, state, traj):
        return traj

    def sample(self, state, key):
        return state

    def update_priorities(self, state, indices, priorities):
        return state


# ================================================================== uniform
class UniformBuffer:
    """Uniform replay ring with n-step returns — DDPG's old in-``opt_state``
    ring, promoted to a first-class runner-owned buffer."""

    name = "uniform"
    kind = "transitions"
    passthrough = False

    def __init__(self, capacity: int = 50_000, batch_size: int = 128,
                 n_step: int = 1, gamma: float = 0.99):
        self.capacity = int(capacity)
        self.batch_size = int(batch_size)
        self.n_step = int(n_step)
        self.gamma = float(gamma)

    def init(self, example: Dict[str, jnp.ndarray]) -> replay.ReplayState:
        return replay.init_replay(self.capacity,
                                  transition_storage_example(example))

    def add(self, state: replay.ReplayState, traj) -> replay.ReplayState:
        return replay.add_batch(state,
                                nstep_transitions(traj, self.n_step,
                                                  self.gamma))

    def sample(self, state: replay.ReplayState, key
               ) -> Dict[str, jnp.ndarray]:
        idx = replay.sample_indices(state, key, self.batch_size)
        batch = ring_gather(state.storage, idx)
        batch["indices"] = idx
        batch["weights"] = jnp.ones((self.batch_size,), jnp.float32)
        return batch

    def update_priorities(self, state, indices, priorities):
        return state


# ============================================================== prioritized
# SumTree and its build/find/update live in the kernel plane
# (``repro.kernels.sum_tree``): a pure-JAX reference plus fused Pallas
# descent/update kernels behind one dispatcher. They are re-exported
# above so this module remains the buffer-facing API.
class PrioritizedState(NamedTuple):
    ring: replay.ReplayState     # storage + write index + filled size
    tree: SumTree                # leaf i = priority_i ** alpha
    max_priority: jnp.ndarray    # running max of raw (pre-alpha) priority


class PrioritizedBuffer:
    """Proportional prioritized replay with importance-weighted sampling.

    New transitions enter at the running max priority (so they are seen at
    least once); ``sample`` draws stratified masses over the sum-tree and
    returns ``weights`` ``(N * P(i))^-beta / max`` plus ``indices``;
    learners return per-sample ``priorities`` (|TD error|) from ``learn``
    and the train step routes them into ``update_priorities``.

    ``capacity`` is rounded up to the next power of two (the tree wants a
    complete binary layout; unfilled slots carry zero mass and are never
    drawn).
    """

    name = "prioritized"
    kind = "transitions"
    passthrough = False

    def __init__(self, capacity: int = 50_000, batch_size: int = 128,
                 n_step: int = 1, gamma: float = 0.99,
                 alpha: float = 0.6, beta: float = 0.4, eps: float = 1e-6):
        self.capacity = 1 << (int(capacity) - 1).bit_length()
        self.batch_size = int(batch_size)
        self.n_step = int(n_step)
        self.gamma = float(gamma)
        self.alpha = float(alpha)
        self.beta = float(beta)
        self.eps = float(eps)

    def init(self, example: Dict[str, jnp.ndarray]) -> PrioritizedState:
        ring = replay.init_replay(self.capacity,
                                  transition_storage_example(example))
        tree = sumtree_build(jnp.zeros((self.capacity,), jnp.float32))
        return PrioritizedState(ring, tree, jnp.ones((), jnp.float32))

    def add(self, state: PrioritizedState, traj) -> PrioritizedState:
        flat = nstep_transitions(traj, self.n_step, self.gamma)
        n = flat["rewards"].shape[0]
        idx = (state.ring.index + jnp.arange(n)) % self.capacity
        ring = replay.add_batch(state.ring, flat)
        tree = sumtree_update(
            state.tree, idx,
            jnp.full((n,), state.max_priority ** self.alpha))
        return PrioritizedState(ring, tree, state.max_priority)

    def sample(self, state: PrioritizedState, key
               ) -> Dict[str, jnp.ndarray]:
        replay.ensure_nonempty(state.ring)
        B = self.batch_size
        total = state.tree.total
        # one key, one stratified draw: a single (B,) uniform covers every
        # equal slice of the total mass, and the whole batch descends the
        # tree together (one vectorized gather per level — no per-sample
        # vmap machinery, no extra PRNG traffic inside the jitted step)
        u = (jnp.arange(B, dtype=jnp.float32)
             + jax.random.uniform(key, (B,))) / B
        idx = sumtree_find_batch(state.tree, u * total)
        idx = jnp.minimum(idx, jnp.maximum(state.ring.size, 1) - 1)
        probs = state.tree.levels[0][idx] / jnp.maximum(total, self.eps)
        weights = (jnp.maximum(state.ring.size, 1).astype(jnp.float32)
                   * jnp.maximum(probs, self.eps)) ** (-self.beta)
        batch = ring_gather(state.ring.storage, idx)
        batch["indices"] = idx
        batch["weights"] = weights / jnp.max(weights)
        return batch

    def update_priorities(self, state: PrioritizedState, indices,
                          priorities) -> PrioritizedState:
        p = jnp.abs(priorities) + self.eps
        tree = sumtree_update(state.tree, indices, p ** self.alpha)
        return PrioritizedState(state.ring, tree,
                                jnp.maximum(state.max_priority, jnp.max(p)))


registry.register("buffer", "fifo", FifoBuffer)
registry.register("buffer", "uniform", UniformBuffer)
registry.register("buffer", "prioritized", PrioritizedBuffer)
