"""Uniform replay buffer (ring, preallocated, jittable) — DDPG substrate.

The scatter-insert and minibatch-gather hot paths dispatch through the
kernel plane (``repro.kernels.replay_ring``): with the ref selection —
the CPU default — they are the historical XLA scatter/gather bit for
bit; on TPU (``--kernels auto``/``pallas``) each becomes one fused
Pallas launch per storage leaf.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.replay_ring import ring_gather, ring_insert


class ReplayState(NamedTuple):
    storage: Dict[str, jnp.ndarray]   # each (capacity, ...)
    index: jnp.ndarray                # next write slot
    size: jnp.ndarray                 # filled entries


def init_replay(capacity: int, example: Dict[str, jnp.ndarray]) -> ReplayState:
    storage = {k: jnp.zeros((capacity,) + v.shape[1:], v.dtype)
               for k, v in example.items()}
    return ReplayState(storage, jnp.zeros((), jnp.int32),
                       jnp.zeros((), jnp.int32))


def add_batch(state: ReplayState, batch: Dict[str, jnp.ndarray]
              ) -> ReplayState:
    """Insert (N, ...) transitions at the ring head (wraps around)."""
    cap = next(iter(state.storage.values())).shape[0]
    n = next(iter(batch.values())).shape[0]
    storage = ring_insert(state.storage, batch, state.index)
    return ReplayState(storage, (state.index + n) % cap,
                       jnp.minimum(state.size + n, cap))


def ensure_nonempty(state: ReplayState) -> None:
    """Eager form of the sampling invariant: callers must ``add_batch``
    before sampling (``size >= 1``). An empty ring used to silently yield
    zero-filled slot-0 transitions; outside a trace the violation now
    raises, and under jit the index clamp in ``sample_indices`` keeps
    draws in ``[0, max(size, 1))`` so the documented invariant is the
    only defense — the composed train step
    (``algos.api.make_train_step``) upholds it by always observing a
    trajectory before sampling."""
    if not isinstance(state.size, jax.core.Tracer) and int(state.size) == 0:
        raise ValueError(
            "sample() on an empty replay buffer — add_batch at least one "
            "transition first (an empty ring would yield zero-filled "
            "slot-0 transitions)")


def sample_indices(state: ReplayState, key, batch_size: int) -> jnp.ndarray:
    """Uniform slot indices over the filled prefix (guarded; the one
    index-draw both ``sample`` and the plane's uniform buffer use)."""
    ensure_nonempty(state)
    return jax.random.randint(key, (batch_size,), 0,
                              jnp.maximum(state.size, 1))


def sample(state: ReplayState, key, batch_size: int
           ) -> Dict[str, jnp.ndarray]:
    """Draw ``batch_size`` uniform transitions from the filled prefix."""
    idx = sample_indices(state, key, batch_size)
    return ring_gather(state.storage, idx)
