"""Uniform replay buffer (ring, preallocated, jittable) — DDPG substrate."""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class ReplayState(NamedTuple):
    storage: Dict[str, jnp.ndarray]   # each (capacity, ...)
    index: jnp.ndarray                # next write slot
    size: jnp.ndarray                 # filled entries


def init_replay(capacity: int, example: Dict[str, jnp.ndarray]) -> ReplayState:
    storage = {k: jnp.zeros((capacity,) + v.shape[1:], v.dtype)
               for k, v in example.items()}
    return ReplayState(storage, jnp.zeros((), jnp.int32),
                       jnp.zeros((), jnp.int32))


def add_batch(state: ReplayState, batch: Dict[str, jnp.ndarray]
              ) -> ReplayState:
    """Insert (N, ...) transitions at the ring head (wraps around)."""
    cap = next(iter(state.storage.values())).shape[0]
    n = next(iter(batch.values())).shape[0]
    idx = (state.index + jnp.arange(n)) % cap
    storage = {k: state.storage[k].at[idx].set(batch[k])
               for k in state.storage}
    return ReplayState(storage, (state.index + n) % cap,
                       jnp.minimum(state.size + n, cap))


def sample(state: ReplayState, key, batch_size: int
           ) -> Dict[str, jnp.ndarray]:
    idx = jax.random.randint(key, (batch_size,), 0,
                             jnp.maximum(state.size, 1))
    return {k: v[idx] for k, v in state.storage.items()}
