"""On-policy trajectory containers.

A trajectory batch is a dict of time-major arrays ``(T, B, ...)`` produced
by one sampler rollout — the unit that flows through WALL-E's experience
queue. Helpers here merge/slice them for the learner.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp

REQUIRED_KEYS = ("obs", "actions", "rewards", "dones", "logp", "values")


def validate(traj: Dict[str, jnp.ndarray]) -> None:
    for k in REQUIRED_KEYS:
        if k not in traj:
            raise KeyError(f"trajectory missing key {k!r}")
    T, B = traj["rewards"].shape[:2]
    for k in REQUIRED_KEYS:
        if traj[k].shape[:2] != (T, B):
            raise ValueError(
                f"{k} has shape {traj[k].shape}, expected leading ({T},{B})")


def merge(trajs: List[Dict[str, jnp.ndarray]]) -> Dict[str, jnp.ndarray]:
    """Concatenate sampler outputs along the batch axis (queue drain)."""
    out = {}
    for k in trajs[0]:
        axis = 0 if trajs[0][k].ndim == 0 else (
            0 if k == "last_value" and trajs[0][k].ndim == 1 else 1)
        if k == "last_value":
            out[k] = jnp.concatenate([t[k] for t in trajs], axis=0)
        else:
            out[k] = jnp.concatenate([t[k] for t in trajs], axis=1)
    return out


def num_samples(traj: Dict[str, jnp.ndarray]) -> int:
    T, B = traj["rewards"].shape[:2]
    return T * B


def episode_returns(traj: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Mean undiscounted return of episodes *completed* inside the batch."""
    rew, dones = traj["rewards"], traj["dones"].astype(bool)

    def per_env(r, d):
        def step(carry, xs):
            acc, total, count = carry
            ri, di = xs
            acc = acc + ri
            total = jnp.where(di, total + acc, total)
            count = jnp.where(di, count + 1, count)
            acc = jnp.where(di, 0.0, acc)
            return (acc, total, count), None

        (acc, total, count), _ = jax.lax.scan(step, (0.0, 0.0, 0), (r, d))
        return total, count

    totals, counts = jax.vmap(per_env, in_axes=1)(rew, dones)
    n = jnp.maximum(jnp.sum(counts), 1)
    return jnp.sum(totals) / n
