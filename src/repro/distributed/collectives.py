"""Explicit cross-shard primitives (shard_map) for the serving path.

``flash_decode_shardmap`` is the hand-written form of the flash-decoding
combine that GSPMD derives implicitly from the seq-sharded KV cache: each
``model`` shard computes streaming-softmax stats (acc, m, l) over its KV
slice, and the shards combine with a max/psum pair — numerically identical
to a single-device softmax (tests/test_collectives.py proves it). Useful
when you want the collective schedule pinned rather than left to the
partitioner, and as the reference semantics for the decode_attention
Pallas kernel's cross-chip composition.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def _local_stats(q, k, v, valid):
    """q (B,H,hd); k/v (B,Sl,K,hd); valid (Sl,) -> (acc, m, l) f32."""
    B, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, K, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg, k,
                   preferred_element_type=jnp.float32) * (hd ** -0.5)
    s = jnp.where(valid[None, None, None, :], s, -jnp.inf)
    m = jnp.max(s, axis=-1)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.where(valid[None, None, None, :], jnp.exp(s - m_safe[..., None]),
                  0.0)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bkgs,bskh->bkgh", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return acc, jnp.where(jnp.isfinite(m), m, -jnp.inf), l


def flash_decode_shardmap(mesh: Mesh, axis: str = "model"):
    """Build ``f(q, k_cache, v_cache, valid) -> o`` with the KV cache
    sharded along its sequence dim over ``axis``.

    q (B,H,hd) replicated over ``axis``; k/v (B,Sc,K,hd) seq-sharded;
    valid (Sc,) seq-sharded. Output (B,H,hd) replicated.
    """

    def local(q, k, v, valid):
        acc, m, l = _local_stats(q, k, v, valid)
        g_m = jax.lax.pmax(m, axis)                      # global row max
        m_safe = jnp.where(jnp.isfinite(g_m), g_m, 0.0)
        scale = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        num = jax.lax.psum(acc * scale[..., None], axis)
        den = jax.lax.psum(l * scale, axis)
        den = jnp.where(den == 0.0, 1.0, den)
        o = (num / den[..., None]).astype(q.dtype)
        B, K, G, hd = o.shape
        return o.reshape(B, K * G, hd)

    in_specs = (P(), P(None, axis, None, None), P(None, axis, None, None),
                P(axis))
    from repro.distributed.sharding import shard_map_compat
    return shard_map_compat(local, mesh, in_specs, P())
