"""Sharding-constraint context: logical-axis hints inside model code.

The model is written mesh-agnostically; the launcher activates a
``ShardingCtx`` and the model's ``constrain(x, ...logical axes...)`` calls
become ``with_sharding_constraint`` (no-ops when no context is active, so
smoke tests and single-device runs are untouched).

Logical axes (the Megatron-TP + sequence-parallel layout, DESIGN.md §5):
  batch  -> (pod, data)     one WALL-E sampler per data slice
  seq    -> model           sequence-parallel residual stream
  heads  -> model           flat q/k/v projection dim (always divisible)
  dff    -> model           MLP hidden
  dinner -> model           SSM channels
  vocab  -> model           logits
Every placement passes through the divisibility fallback (replicate, never
pad).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import sharding as sh

_ACTIVE: Optional["ShardingCtx"] = None


@dataclasses.dataclass(frozen=True)
class ShardingCtx:
    mesh: Mesh
    mode: str = "train"          # "train" (FSDP x TP) | "serve" (resident)

    def axes_for(self, logical: Optional[str]):
        if logical is None:
            return None
        if logical == "batch":
            return sh.batch_axes(self.mesh)
        return ("model",)


def get() -> Optional[ShardingCtx]:
    return _ACTIVE


def mode() -> str:
    return _ACTIVE.mode if _ACTIVE is not None else "train"


@contextlib.contextmanager
def use_mesh(mesh: Mesh, mode: str = "train"):
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = ShardingCtx(mesh, mode)
    try:
        yield _ACTIVE
    finally:
        _ACTIVE = prev


def constrain(x, *logical, keep_unspecified: bool = False):
    """Apply a sharding constraint by logical dim names.

    ``logical`` entries: axis name, None (= force-replicated), or "?"
    (leave unconstrained — only meaningful with ``keep_unspecified``).
    """
    ctx = _ACTIVE
    if ctx is None:
        return x
    assert len(logical) == x.ndim, (logical, x.shape)
    spec = []
    for size, name in zip(x.shape, logical):
        if name is None or name == "?":
            spec.append(None)
            continue
        spec.append(sh.shard_axes(size, ctx.axes_for(name), ctx.mesh))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*spec)))


def constrain_spec(x, spec: P):
    """Raw PartitionSpec constraint (uneven sharding allowed — GSPMD pads).

    Used for attention-head placement where head counts rarely divide the
    model axis; padding waste beats 16x replication (DESIGN.md §5).
    """
    ctx = _ACTIVE
    if ctx is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def model_axis_size() -> int:
    ctx = _ACTIVE
    return ctx.mesh.shape["model"] if ctx is not None else 1


def gather_weight(w, kind: str):
    """Materialise a 2-D-sharded weight in its compute layout (fsdp dim
    gathered) — in the *storage dtype*. Without this XLA-CPU converts bf16
    weights to f32 and then all-gathers, doubling FSDP traffic
    (EXPERIMENTS.md §Perf, llama3-405b train iteration 2). Train layout
    only; serve layout contracts along the model axis and wants no gather.

    kind: "col" (Din fsdp, Dout model) or "row" (Din model, Dout fsdp).
    """
    ctx = _ACTIVE
    if ctx is None or ctx.mode != "train" or w.ndim != 2:
        return w
    spec = P(None, "model") if kind == "col" else P("model", None)
    return jax.lax.with_sharding_constraint(w, NamedSharding(ctx.mesh, spec))
