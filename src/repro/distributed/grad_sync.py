"""Trace-time gradient synchronisation + microbatch accumulation context.

The multi-device learner (``distributed/learner.py``) wraps an algorithm's
train step in ``shard_map`` with the batch sharded along the mesh's data
axes. Every algorithm update routes its gradient computation through
``value_and_grad`` below instead of calling ``jax.value_and_grad``
directly; outside a sharded trace the call is *exactly*
``jax.value_and_grad`` (bitwise — the D=1 guarantee), while inside it

* optionally splits the per-shard batch into M microbatches and
  accumulates gradients with a ``lax.scan`` (gradient accumulation so the
  global batch scales past per-device memory), and
* combines gradients across shards with a single ``lax.pmean`` per loss —
  the one psum all-reduce of the replicated schedule (DESIGN.md §9).

Because the pmean'd gradients and the replicated params are identical on
every shard, global-norm clipping and the optimizer update are recomputed
identically per shard and params *stay* replicated without any further
collective.

FSDP mode (DESIGN.md §11): when the learner activates the context with an
``FsdpInfo``, params and Adam moments are *stored* sharded along the fsdp
axes (ZeRO-3) and the schedule changes shape:

* the learner body all-gathers sharded param leaves to full at entry
  (``gather_params`` — per-layer tiled all-gathers), so algorithm code
  sees full params unchanged (target networks, polyak, forward passes);
* ``value_and_grad`` reduce-scatters the gradient of every sharded leaf
  (``psum_scatter`` along the leaf's storage dim) instead of pmean'ing
  it, so each shard ends the loss holding exactly its slice of the mean
  gradient — same bytes on the wire as the all-reduce, but what lands is
  the *storage* layout;
* Adam moments never leave their shard: the moment update and the delta
  are computed on the local gradient slice, which is the FSDP memory win
  (``optim/adam.py``);
* ``apply_updates`` all-gathers the local *update* slices back to full
  (``expand_like``) so the in-body params stay full, and the body exit
  slices params back to storage layout (``shard_params``).

Sharded-vs-replicated is decided *host-side* from full shapes
(``learner.ShardedLearner``) and carried here as shape-keyed tables —
inside the trace a local slice's shape alone cannot tell you whether it
was scattered (divisibility of the full dim is what decided).

The context is module-global and trace-scoped (same pattern as
``distributed/context.py``): ``learner.py`` enters ``activate`` inside the
shard_map body, so only the wrapped trace sees it.
"""
from __future__ import annotations

import contextlib
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import _key


class FsdpInfo(NamedTuple):
    """Host-side description of the FSDP storage layout for one learner.

    Keys are ``(terminal leaf name, shape)`` — the layout rule
    (``sharding.fsdp_leaf_dim``) depends only on those, so lookups work
    on any subtree an algorithm hands us (``params["critic"]``, grads of
    a loss over a sub-module) without threading tree paths around.
    ``learner.ShardedLearner`` verifies at build time that no replicated
    leaf's key collides with a sharded leaf's *local* key (degrading the
    sharded leaf to replicated otherwise), so each table is unambiguous.
    """
    axes: Tuple[str, ...]                       # fsdp mesh axes (pod, data)
    size: int                                   # product of axis sizes
    full_table: Dict[Tuple[str, tuple], int]    # (name, full shape) -> dim
    local_table: Dict[Tuple[str, tuple], int]   # (name, local shape) -> dim


class _GradSyncCtx(NamedTuple):
    axes: Optional[Tuple[str, ...]]   # mesh axes to pmean over (None: off)
    microbatches: int                 # M accumulation steps (1: off)
    fsdp: Optional[FsdpInfo] = None   # sharded param storage (None: off)


_ACTIVE: Optional[_GradSyncCtx] = None


@contextlib.contextmanager
def activate(axes: Optional[Tuple[str, ...]], microbatches: int = 1,
             fsdp: Optional[FsdpInfo] = None):
    """Enter the sync context for the duration of a (traced) train step."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = _GradSyncCtx(tuple(axes) if axes else None,
                           max(1, int(microbatches)), fsdp)
    try:
        yield _ACTIVE
    finally:
        _ACTIVE = prev


def active() -> Optional[_GradSyncCtx]:
    return _ACTIVE


def reduce_axes() -> Optional[Tuple[str, ...]]:
    """Mesh axes the current trace must reduce batch statistics over
    (e.g. advantage normalisation), or None outside a sharded trace."""
    return _ACTIVE.axes if _ACTIVE is not None else None


def fsdp_active() -> Optional[FsdpInfo]:
    """The active FSDP layout, or None (replicated schedule / no trace)."""
    return _ACTIVE.fsdp if _ACTIVE is not None else None


def sync(tree):
    """pmean a gradient pytree across the active axes (no-op otherwise).

    For gradients computed outside :func:`value_and_grad` — e.g. SAC's
    temperature gradient.
    """
    if _ACTIVE is None or not _ACTIVE.axes:
        return tree
    axes = _ACTIVE.axes
    return jax.tree.map(lambda g: jax.lax.pmean(g, axes), tree)


# ------------------------------------------------------- FSDP reshaping
def _name(path) -> str:
    return _key(path[-1]) if path else ""


def gather_params(tree):
    """Entry all-gather: storage-layout (sharded) leaves -> full leaves.

    One tiled ``all_gather`` per sharded leaf — the per-layer gather of
    the FSDP schedule; replicated leaves pass through untouched.
    """
    f = fsdp_active()
    if f is None:
        return tree

    def one(path, x):
        dim = f.local_table.get((_name(path), tuple(x.shape)))
        if dim is None:
            return x
        return jax.lax.all_gather(x, f.axes, axis=dim, tiled=True)

    return jax.tree_util.tree_map_with_path(one, tree)


def shard_params(tree):
    """Exit slice: full leaves -> this shard's storage slice (free — a
    local dynamic-slice at the linear fsdp index, no collective)."""
    f = fsdp_active()
    if f is None:
        return tree

    def one(path, x):
        dim = f.full_table.get((_name(path), tuple(x.shape)))
        if dim is None:
            return x
        idx = jax.lax.axis_index(f.axes)
        local = x.shape[dim] // f.size
        return jax.lax.dynamic_slice_in_dim(x, idx * local, local, axis=dim)

    return jax.tree_util.tree_map_with_path(one, tree)


def expand_like(u, p):
    """All-gather a storage-layout leaf ``u`` up to ``p``'s full shape.

    The scattered dim is inferred by comparing against ``p`` (the full
    reference): FSDP shards exactly one dim, so at most one dim differs.
    No-op outside FSDP or when the shapes already agree.
    """
    f = fsdp_active()
    if f is None or u.shape == p.shape:
        return u
    dims = [d for d in range(u.ndim) if u.shape[d] != p.shape[d]]
    if len(dims) != 1 or u.shape[dims[0]] * f.size != p.shape[dims[0]]:
        raise ValueError(
            f"expand_like: {u.shape} is not a {f.size}-way fsdp slice "
            f"of {p.shape}")
    return jax.lax.all_gather(u, f.axes, axis=dims[0], tiled=True)


def localize_like(p, g):
    """Slice a full leaf ``p`` down to ``g``'s storage-layout shape (the
    inverse of :func:`expand_like` — e.g. weight-decay's param term next
    to a scattered gradient). No-op outside FSDP or on equal shapes."""
    f = fsdp_active()
    if f is None or p.shape == g.shape:
        return p
    dims = [d for d in range(p.ndim) if p.shape[d] != g.shape[d]]
    if len(dims) != 1 or g.shape[dims[0]] * f.size != p.shape[dims[0]]:
        raise ValueError(
            f"localize_like: {g.shape} is not a {f.size}-way fsdp slice "
            f"of {p.shape}")
    dim = dims[0]
    idx = jax.lax.axis_index(f.axes)
    return jax.lax.dynamic_slice_in_dim(
        p, idx * g.shape[dim], g.shape[dim], axis=dim)


def fsdp_sumsq(tree):
    """Global sum-of-squares of a mixed-layout gradient tree.

    Replicated leaves are identical on every shard (they were pmean'd) so
    their square-sums add locally; scattered leaves each hold a disjoint
    slice, so their local square-sums are combined with one ``psum`` over
    the fsdp axes. Feeds ``optim.clip.global_norm`` under FSDP.
    """
    f = fsdp_active()
    repl, shard = [], []

    def one(path, x):
        s = jnp.sum(jnp.square(x.astype(jnp.float32)))
        if f.local_table.get((_name(path), tuple(x.shape))) is not None:
            shard.append(s)
        else:
            repl.append(s)

    jax.tree_util.tree_map_with_path(one, tree)
    total = sum(repl) if repl else jnp.zeros((), jnp.float32)
    if shard:
        total = total + jax.lax.psum(sum(shard), f.axes)
    return total


def _combine_aux(stacked, mb: int):
    """Fold microbatch-stacked aux back to full-batch shape.

    Leaves stacked as ``(M,)`` (per-microbatch scalars, e.g. loss terms)
    are averaged; leaves ``(M, mb, ...)`` (per-sample vectors, e.g. TD
    errors feeding priorities) are concatenated back to ``(M*mb, ...)`` so
    downstream code sees the same layout as the unsliced loss would
    produce.
    """
    def one(x):
        if x.ndim >= 2 and x.shape[1] == mb:
            return x.reshape((x.shape[0] * mb,) + x.shape[2:])
        return jnp.mean(x, axis=0)

    return jax.tree.map(one, stacked)


def value_and_grad(loss_fn, params, batch, has_aux: bool = False):
    """``jax.value_and_grad(loss_fn, has_aux)(params, batch)`` routed
    through the active sync context.

    ``loss_fn(params, batch)`` must mean-reduce its loss over the batch's
    leading axis so microbatch/shard averaging composes exactly. Returns
    ``(out, grads)`` with the same contract as ``jax.value_and_grad``.
    Under FSDP the gradient of every sharded-storage leaf comes back
    **reduce-scattered** (this shard's slice of the cross-shard mean);
    replicated leaves keep the pmean.
    """
    ctx = _ACTIVE
    m = ctx.microbatches if ctx is not None else 1
    if m <= 1:
        out, grads = jax.value_and_grad(loss_fn, has_aux=has_aux)(
            params, batch)
    else:
        n = max(x.shape[0] for x in jax.tree.leaves(batch) if x.ndim)
        if n % m:
            raise ValueError(
                f"microbatch accumulation needs the per-shard batch ({n}) "
                f"divisible by learner_microbatches ({m})")
        mb = n // m

        def one_micro(carry, i):
            # leaves without the batch's leading dim (PRNG keys, scalars)
            # pass through whole
            sl = jax.tree.map(
                lambda x: jax.lax.dynamic_slice_in_dim(x, i * mb, mb)
                if x.ndim and x.shape[0] == n else x,
                batch)
            o, g = jax.value_and_grad(loss_fn, has_aux=has_aux)(params, sl)
            return carry, (o, g)

        _, (outs, grads) = jax.lax.scan(one_micro, 0, jnp.arange(m))
        grads = jax.tree.map(lambda g: jnp.mean(g, axis=0), grads)
        if has_aux:
            loss, aux = outs
            out = (jnp.mean(loss), _combine_aux(aux, mb))
        else:
            out = jnp.mean(outs)
    if ctx is not None and ctx.axes:
        if ctx.fsdp is not None:
            f = ctx.fsdp

            def reduce(path, g):
                dim = f.full_table.get((_name(path), tuple(g.shape)))
                if dim is None:
                    return jax.lax.pmean(g, ctx.axes)
                # mean over shards, landed in storage layout: one
                # reduce-scatter instead of the all-reduce
                return jax.lax.psum_scatter(
                    g, ctx.axes, scatter_dimension=dim, tiled=True) / f.size

            grads = jax.tree_util.tree_map_with_path(reduce, grads)
        else:
            grads = jax.tree.map(
                lambda g: jax.lax.pmean(g, ctx.axes), grads)
    return out, grads
