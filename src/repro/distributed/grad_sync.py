"""Trace-time gradient synchronisation + microbatch accumulation context.

The multi-device learner (``distributed/learner.py``) wraps an algorithm's
train step in ``shard_map`` with the batch sharded along the mesh's data
axes. Every algorithm update routes its gradient computation through
``value_and_grad`` below instead of calling ``jax.value_and_grad``
directly; outside a sharded trace the call is *exactly*
``jax.value_and_grad`` (bitwise — the D=1 guarantee), while inside it

* optionally splits the per-shard batch into M microbatches and
  accumulates gradients with a ``lax.scan`` (gradient accumulation so the
  global batch scales past per-device memory), and
* combines gradients across shards with a single ``lax.pmean`` per loss —
  the one psum all-reduce of the schedule (DESIGN.md §9).

Because the pmean'd gradients and the replicated params are identical on
every shard, global-norm clipping and the optimizer update are recomputed
identically per shard and params *stay* replicated without any further
collective.

The context is module-global and trace-scoped (same pattern as
``distributed/context.py``): ``learner.py`` enters ``activate`` inside the
shard_map body, so only the wrapped trace sees it.
"""
from __future__ import annotations

import contextlib
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class _GradSyncCtx(NamedTuple):
    axes: Optional[Tuple[str, ...]]   # mesh axes to pmean over (None: off)
    microbatches: int                 # M accumulation steps (1: off)


_ACTIVE: Optional[_GradSyncCtx] = None


@contextlib.contextmanager
def activate(axes: Optional[Tuple[str, ...]], microbatches: int = 1):
    """Enter the sync context for the duration of a (traced) train step."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = _GradSyncCtx(tuple(axes) if axes else None,
                           max(1, int(microbatches)))
    try:
        yield _ACTIVE
    finally:
        _ACTIVE = prev


def active() -> Optional[_GradSyncCtx]:
    return _ACTIVE


def reduce_axes() -> Optional[Tuple[str, ...]]:
    """Mesh axes the current trace must reduce batch statistics over
    (e.g. advantage normalisation), or None outside a sharded trace."""
    return _ACTIVE.axes if _ACTIVE is not None else None


def sync(tree):
    """pmean a gradient pytree across the active axes (no-op otherwise).

    For gradients computed outside :func:`value_and_grad` — e.g. SAC's
    temperature gradient.
    """
    if _ACTIVE is None or not _ACTIVE.axes:
        return tree
    axes = _ACTIVE.axes
    return jax.tree.map(lambda g: jax.lax.pmean(g, axes), tree)


def _combine_aux(stacked, mb: int):
    """Fold microbatch-stacked aux back to full-batch shape.

    Leaves stacked as ``(M,)`` (per-microbatch scalars, e.g. loss terms)
    are averaged; leaves ``(M, mb, ...)`` (per-sample vectors, e.g. TD
    errors feeding priorities) are concatenated back to ``(M*mb, ...)`` so
    downstream code sees the same layout as the unsliced loss would
    produce.
    """
    def one(x):
        if x.ndim >= 2 and x.shape[1] == mb:
            return x.reshape((x.shape[0] * mb,) + x.shape[2:])
        return jnp.mean(x, axis=0)

    return jax.tree.map(one, stacked)


def value_and_grad(loss_fn, params, batch, has_aux: bool = False):
    """``jax.value_and_grad(loss_fn, has_aux)(params, batch)`` routed
    through the active sync context.

    ``loss_fn(params, batch)`` must mean-reduce its loss over the batch's
    leading axis so microbatch/shard averaging composes exactly. Returns
    ``(out, grads)`` with the same contract as ``jax.value_and_grad``.
    """
    ctx = _ACTIVE
    m = ctx.microbatches if ctx is not None else 1
    if m <= 1:
        out, grads = jax.value_and_grad(loss_fn, has_aux=has_aux)(
            params, batch)
    else:
        n = max(x.shape[0] for x in jax.tree.leaves(batch) if x.ndim)
        if n % m:
            raise ValueError(
                f"microbatch accumulation needs the per-shard batch ({n}) "
                f"divisible by learner_microbatches ({m})")
        mb = n // m

        def one_micro(carry, i):
            # leaves without the batch's leading dim (PRNG keys, scalars)
            # pass through whole
            sl = jax.tree.map(
                lambda x: jax.lax.dynamic_slice_in_dim(x, i * mb, mb)
                if x.ndim and x.shape[0] == n else x,
                batch)
            o, g = jax.value_and_grad(loss_fn, has_aux=has_aux)(params, sl)
            return carry, (o, g)

        _, (outs, grads) = jax.lax.scan(one_micro, 0, jnp.arange(m))
        grads = jax.tree.map(lambda g: jnp.mean(g, axis=0), grads)
        if has_aux:
            loss, aux = outs
            out = (jnp.mean(loss), _combine_aux(aux, mb))
        else:
            out = jnp.mean(outs)
    if ctx is not None and ctx.axes:
        grads = jax.tree.map(lambda g: jax.lax.pmean(g, ctx.axes), grads)
    return out, grads
