"""The multi-device learner plane: shard_map data-parallel training.

``ShardedLearner`` wraps any shardable ``Algorithm``'s composed train
step (``algos.api.make_train_step``) in ``shard_map_compat`` over a
learner mesh:

* trajectories / replay minibatches shard along the mesh's batch axes
  (``pod``+``data`` — each data slice consumes one collection slice);
* by default params and optimizer state stay **replicated**: every
  gradient inside the step is pmean'd across shards by the ``grad_sync``
  context, so the (identical) clip + optimizer update is recomputed per
  shard and replication is preserved without a post-step broadcast — one
  psum all-reduce per loss is the entire collective schedule;
* with ``fsdp=True`` params and Adam moments are instead **stored
  sharded** along the fsdp axes per the ``_param_spec`` layout rules
  (``sharding.fsdp_leaf_dim`` — weight contracting dims on
  ``pod``+``data``, non-divisible leaves replicated): the body
  all-gathers param leaves per layer at entry, ``grad_sync``
  reduce-scatters each sharded leaf's gradient into storage layout,
  moments update fully locally, and the body exit slices params back to
  their shards (DESIGN.md §11);
* ``pods > 1`` splits the shard count over a ``(pod, data, model)`` mesh
  — the same axis names as ``launch.mesh.make_production_mesh``'s
  multi-pod mesh, so the identical step lowers across the DCN boundary;
* buffer state rides the plane sharded (``replay_sharded``): per-shard
  rings / sum-trees with a psum'd global root, so off-policy algorithms
  sample without a gather;
* gradient-accumulation microbatching (``microbatches > 1``) scans the
  per-shard batch in M slices inside ``grad_sync.value_and_grad``, so the
  global batch scales past per-device memory.

The wrapped step has the exact ``(params, opt_state, plane, traj)``
signature every runner drives, so inline/threaded/process backends and
the fused scan carry thread it through unchanged — selection happens
once, in ``experiment.build`` (``Schedule.learner_devices`` /
``train.py --learner-devices``). With ``learner_devices=1`` the build
bypasses this module entirely (bitwise guarantee); a 1-device mesh
through this wrapper is also bitwise (tests), since every collective is
over a singleton axis. ``fsdp=False`` leaves the replicated schedule
bitwise-untouched.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.algos.api import make_train_step
from repro.distributed import grad_sync
from repro.distributed.replay_sharded import shard_buffer
from repro.distributed.sharding import (
    _key,
    axes_size,
    batch_axes,
    fsdp_leaf_dim,
    shard_map_compat,
)


def learner_mesh(num_devices: int, pods: int = 1,
                 offset: int = 0) -> Mesh:
    """A ``(data, model)`` — or, with ``pods > 1``, ``(pod, data,
    model)`` — mesh over ``num_devices`` devices starting at ``offset``
    (``launch.mesh.make_learner_mesh``)."""
    from repro.launch.mesh import make_learner_mesh
    return make_learner_mesh(num_devices, pods=pods, offset=offset)


def _local_shape(shape: tuple, dim: int, n: int) -> tuple:
    return shape[:dim] + (shape[dim] // n,) + shape[dim + 1:]


class ShardedLearner:
    """Builds and owns the shard_map-wrapped train step.

    ``train_step`` is a drop-in for ``make_train_step(algo, buffer)``;
    ``buffer`` (possibly wrapped sharded) must be used for plane init so
    sharded leaves are allocated at global (tiled) size.
    """

    def __init__(self, algo, buffer, num_devices: int = 1,
                 microbatches: int = 1, mesh: Optional[Mesh] = None,
                 fsdp: bool = False, pods: int = 1, offset: int = 0):
        self.algo = algo
        self.microbatches = max(1, int(microbatches))
        if mesh is None and num_devices > 1:
            mesh = learner_mesh(num_devices, pods=pods, offset=offset)
        self.mesh = mesh
        self.axes: Tuple[str, ...] = batch_axes(mesh) if mesh else ()
        self.num_shards = axes_size(mesh, self.axes) if mesh else 1
        self.fsdp = bool(fsdp) and self.num_shards > 1
        if self.num_shards > 1 and not getattr(algo, "shardable", False):
            raise ValueError(
                f"algorithm {getattr(algo, 'name', algo)!r} does not "
                f"support the sharded learner (shardable=False)")
        if self.num_shards > 1:
            self.buffer = shard_buffer(buffer, self.num_shards, self.axes)
        else:
            self.buffer = buffer
        self._step = make_train_step(algo, self.buffer)
        self._wrapped = None
        self._jitted = None
        self._shardings = None
        self._fsdp_info: Optional[grad_sync.FsdpInfo] = None
        # runners must NOT re-jit a mesh step that manages its own jit +
        # input placement (orchestrator._maybe_jit_step reads this): a
        # plain jit would infer device placement from the arguments, and
        # mixing a device-0 trajectory with mesh-sharded params/opt-state
        # is exactly the incompatible-devices error placement preempts
        self.self_jitted = self.num_shards > 1

    # ------------------------------------------------------------- specs
    def _traj_spec(self, tree):
        """Batch-axis specs by trajectory layout: step keys are time-major
        ``(T, B, ...)`` (batch = dim 1), tail keys are ``(B, ...)``."""
        tail = set(getattr(self.algo, "tail_keys", ()) or ())
        return {k: (P(self.axes) if k in tail else P(None, self.axes))
                for k in tree}

    def _plane_spec(self, buf_state):
        if hasattr(self.buffer, "state_spec"):
            return self.buffer.state_spec(buf_state)
        return self._traj_spec(buf_state)          # fifo: stored trajectory

    # -------------------------------------------------------- FSDP layout
    def fsdp_layout(self, params) -> dict:
        """``(leaf name, full shape) -> storage dim`` for every sharded
        param leaf, per ``sharding.fsdp_leaf_dim`` over the full shapes.

        Two degradations keep shape-keyed in-trace lookups unambiguous
        (a local slice's shape alone can't prove it was scattered):

        * two leaves sharing ``(name, shape)`` but resolving to different
          dims are both replicated (cannot happen for the registered RL
          param trees — the rule keys on terminal name + shape — but the
          layout must stay sound for any tree);
        * a sharded leaf whose *local* key would collide with a
          replicated leaf's key is replicated instead.
        """
        entries = {}            # (name, full shape) -> dim | None
        n = self.num_shards

        def collect(path, leaf):
            key = (_key(path[-1]), tuple(leaf.shape))
            dim = fsdp_leaf_dim(path, leaf, self.mesh)
            if key in entries and entries[key] != dim:
                dim = None      # conflicting rules: replicate
            entries[key] = dim

        jax.tree_util.tree_map_with_path(collect, params)
        changed = True
        while changed:
            changed = False
            repl = {k for k, d in entries.items() if d is None}
            for (name, shape), dim in list(entries.items()):
                if dim is None:
                    continue
                if (name, _local_shape(shape, dim, n)) in repl:
                    entries[(name, shape)] = None
                    changed = True
        return {k: d for k, d in entries.items() if d is not None}

    def _fsdp_tables(self, params) -> grad_sync.FsdpInfo:
        full = self.fsdp_layout(params)
        local = {(nm, _local_shape(shp, d, self.num_shards)): d
                 for (nm, shp), d in full.items()}
        return grad_sync.FsdpInfo(axes=self.axes, size=self.num_shards,
                                  full_table=full, local_table=local)

    def _storage_spec(self, info: Optional[grad_sync.FsdpInfo], tree):
        """Per-leaf PartitionSpecs for params/opt-state storage. Moments
        share the params' leaf names and shapes, so the same table gives
        each Adam moment exactly its param's layout; everything else
        (step counters, non-matching leaves) is replicated ``P()``."""
        if info is None:
            return P()

        def one(path, leaf):
            dim = info.full_table.get((_key(path[-1]), tuple(leaf.shape)))
            if dim is None:
                return P()
            parts = [None] * len(leaf.shape)
            parts[dim] = self.axes if len(self.axes) > 1 else self.axes[0]
            return P(*parts)

        return jax.tree_util.tree_map_with_path(one, tree)

    # -------------------------------------------------------------- step
    def _build(self, params, opt_state, plane, traj):
        buf_spec = self._plane_spec(plane[0])
        plane_spec = (buf_spec, P())               # sample key replicated
        traj_spec = self._traj_spec(traj)
        axes = self.axes
        micro = self.microbatches
        step = self._step
        info = self._fsdp_tables(params) if self.fsdp else None
        self._fsdp_info = info
        pspec = self._storage_spec(info, params)
        ospec = self._storage_spec(info, opt_state)

        def local_step(params, opt_state, plane, traj):
            with grad_sync.activate(axes, micro, fsdp=info):
                if info is not None:
                    # per-layer all-gather: algorithm code sees full
                    # params (target nets, polyak, forward passes);
                    # moments stay local through the whole step
                    params = grad_sync.gather_params(params)
                params, opt_state, plane, metrics = step(
                    params, opt_state, plane, traj)
                if info is not None:
                    params = grad_sync.shard_params(params)
            # scalar diagnostics; per-sample priorities were already
            # consumed inside the step by update_priorities
            metrics = jax.tree.map(
                lambda x: jax.lax.pmean(x, axes), metrics)
            return params, opt_state, plane, metrics

        self._shardings = tuple(
            self._sharding_tree(s, t)
            for s, t in zip((pspec, ospec, plane_spec, traj_spec),
                            (params, opt_state, plane, traj)))
        wrapped = shard_map_compat(
            local_step, self.mesh,
            (pspec, ospec, plane_spec, traj_spec),
            (pspec, ospec, plane_spec, P()))
        self._jitted = jax.jit(wrapped)
        return wrapped

    def _sharding_tree(self, spec, tree):
        """Per-leaf ``NamedSharding``s for one argument: either broadcast
        a single ``P`` over the tree or map a matching spec tree."""
        if isinstance(spec, P):
            return jax.tree.map(
                lambda _: NamedSharding(self.mesh, spec), tree)
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), spec,
                            is_leaf=lambda x: isinstance(x, P))

    def train_step(self, params, opt_state, plane, traj):
        if self.num_shards <= 1:
            # microbatch-accumulation only: no mesh, no collectives
            with grad_sync.activate(None, self.microbatches):
                return self._step(params, opt_state, plane, traj)
        if self._wrapped is None:
            self._wrapped = self._build(params, opt_state, plane, traj)
        if isinstance(jax.tree.leaves(params)[0], jax.core.Tracer):
            # inside a caller's trace (the fused scan): the whole
            # iteration is one computation and the mesh placement is
            # exactly what we want — pass straight through
            return self._wrapped(params, opt_state, plane, traj)
        # eager (runner) path: place every input onto its mesh sharding
        # first — params/opt-state/plane already match after the first
        # step (no-op), the freshly collected trajectory is a real
        # device-0 -> mesh transfer — then run the cached jit; placement
        # rather than jit inference is what lets a device-0 trajectory
        # coexist with FSDP-sharded params
        params, opt_state, plane, traj = (
            jax.device_put(a, s)
            for a, s in zip((params, opt_state, plane, traj),
                            self._shardings))
        params, opt_state, plane, metrics = self._jitted(
            params, opt_state, plane, traj)
        # hand the (re-assembled) params back to the default device:
        # collection (inline/threaded rollout jit, process-worker
        # publish) is single-device, and a mesh-committed params array
        # would recompile the rollout as a partitioned SPMD computation
        # (pathological on forced host devices). Opt state (and under
        # FSDP its sharded moments) stays mesh-resident — only the
        # rollout needs host-side params. Under FSDP the runner keeps a
        # separate pinned copy instead (pin_params), so sharded params
        # stay sharded here.
        if not self.fsdp:
            params = jax.device_put(params, jax.devices()[0])
        return params, opt_state, plane, metrics
