"""The multi-device learner plane: shard_map data-parallel training.

``ShardedLearner`` wraps any shardable ``Algorithm``'s composed train
step (``algos.api.make_train_step``) in ``shard_map_compat`` over a
learner mesh:

* trajectories / replay minibatches shard along the mesh's batch axes
  (``pod``+``data`` — each data slice consumes one collection slice);
* params and optimizer state stay **replicated**: every gradient inside
  the step is pmean'd across shards by the ``grad_sync`` context, so the
  (identical) clip + optimizer update is recomputed per shard and
  replication is preserved without a post-step broadcast — one psum
  all-reduce per loss is the entire collective schedule;
* buffer state rides the plane sharded (``replay_sharded``): per-shard
  rings / sum-trees with a psum'd global root, so off-policy algorithms
  sample without a gather;
* gradient-accumulation microbatching (``microbatches > 1``) scans the
  per-shard batch in M slices inside ``grad_sync.value_and_grad``, so the
  global batch scales past per-device memory.

The wrapped step has the exact ``(params, opt_state, plane, traj)``
signature every runner drives, so inline/threaded/process backends and
the fused scan carry thread it through unchanged — selection happens
once, in ``experiment.build`` (``Schedule.learner_devices`` /
``train.py --learner-devices``). With ``learner_devices=1`` the build
bypasses this module entirely (bitwise guarantee); a 1-device mesh
through this wrapper is also bitwise (tests), since every collective is
over a singleton axis.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.algos.api import make_train_step
from repro.distributed import grad_sync
from repro.distributed.replay_sharded import shard_buffer
from repro.distributed.sharding import (
    axes_size,
    batch_axes,
    shard_map_compat,
)


def learner_mesh(num_devices: int) -> Mesh:
    """A ``(data, model)`` mesh over the first ``num_devices`` devices —
    the same layout ``core.backends`` builds for the sharded sampler."""
    devs = jax.devices()
    if num_devices > len(devs):
        raise ValueError(
            f"learner_devices={num_devices} but only {len(devs)} JAX "
            f"device(s) are visible; on CPU set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={num_devices} "
            f"before importing jax")
    return Mesh(np.asarray(devs[:num_devices]).reshape(num_devices, 1),
                ("data", "model"))


class ShardedLearner:
    """Builds and owns the shard_map-wrapped train step.

    ``train_step`` is a drop-in for ``make_train_step(algo, buffer)``;
    ``buffer`` (possibly wrapped sharded) must be used for plane init so
    sharded leaves are allocated at global (tiled) size.
    """

    def __init__(self, algo, buffer, num_devices: int = 1,
                 microbatches: int = 1, mesh: Optional[Mesh] = None):
        self.algo = algo
        self.microbatches = max(1, int(microbatches))
        if mesh is None and num_devices > 1:
            mesh = learner_mesh(num_devices)
        self.mesh = mesh
        self.axes: Tuple[str, ...] = batch_axes(mesh) if mesh else ()
        self.num_shards = axes_size(mesh, self.axes) if mesh else 1
        if self.num_shards > 1 and not getattr(algo, "shardable", False):
            raise ValueError(
                f"algorithm {getattr(algo, 'name', algo)!r} does not "
                f"support the sharded learner (shardable=False)")
        if self.num_shards > 1:
            self.buffer = shard_buffer(buffer, self.num_shards, self.axes)
        else:
            self.buffer = buffer
        self._step = make_train_step(algo, self.buffer)
        self._wrapped = None

    # ------------------------------------------------------------- specs
    def _traj_spec(self, tree):
        """Batch-axis specs by trajectory layout: step keys are time-major
        ``(T, B, ...)`` (batch = dim 1), tail keys are ``(B, ...)``."""
        tail = set(getattr(self.algo, "tail_keys", ()) or ())
        return {k: (P(self.axes) if k in tail else P(None, self.axes))
                for k in tree}

    def _plane_spec(self, buf_state):
        if hasattr(self.buffer, "state_spec"):
            return self.buffer.state_spec(buf_state)
        return self._traj_spec(buf_state)          # fifo: stored trajectory

    # -------------------------------------------------------------- step
    def _build(self, plane, traj):
        buf_spec = self._plane_spec(plane[0])
        plane_spec = (buf_spec, P())               # sample key replicated
        traj_spec = self._traj_spec(traj)
        axes = self.axes
        micro = self.microbatches
        step = self._step

        def local_step(params, opt_state, plane, traj):
            with grad_sync.activate(axes, micro):
                params, opt_state, plane, metrics = step(
                    params, opt_state, plane, traj)
            # scalar diagnostics; per-sample priorities were already
            # consumed inside the step by update_priorities
            metrics = jax.tree.map(
                lambda x: jax.lax.pmean(x, axes), metrics)
            return params, opt_state, plane, metrics

        return shard_map_compat(
            local_step, self.mesh,
            (P(), P(), plane_spec, traj_spec),
            (P(), P(), plane_spec, P()))

    def train_step(self, params, opt_state, plane, traj):
        if self.num_shards <= 1:
            # microbatch-accumulation only: no mesh, no collectives
            with grad_sync.activate(None, self.microbatches):
                return self._step(params, opt_state, plane, traj)
        if self._wrapped is None:
            self._wrapped = self._build(plane, traj)
        params, opt_state, plane, metrics = self._wrapped(
            params, opt_state, plane, traj)
        if not isinstance(jax.tree.leaves(params)[0], jax.core.Tracer):
            # hand the replicated params back to the default device:
            # collection (inline/threaded rollout jit, process-worker
            # publish) is single-device, and a mesh-committed params
            # array would recompile the rollout as a partitioned SPMD
            # computation (pathological on forced host devices). Inside
            # a fused trace the whole iteration is one computation and
            # the mesh placement is exactly what we want, so traced
            # params pass through untouched.
            params = jax.device_put(params, jax.devices()[0])
        return params, opt_state, plane, metrics
