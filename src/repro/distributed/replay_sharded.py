"""Sharding-aware experience buffers: per-shard rings and sum-trees whose
*sampled distribution* is provably identical to the single-buffer
reference.

These wrappers run **inside** the sharded learner's ``shard_map`` body:
``add``/``sample``/``update_priorities`` see the *local* (per-shard)
buffer state and the local trajectory slice, and communicate only through
``psum``-family collectives. ``axes`` is whatever the learner mesh's
batch axes are — ``("data",)`` single-pod or ``("pod", "data")`` on a
multi-pod mesh: ``shard_index`` linearises the axes major-to-minor to
match how ``shard_map`` splits a dim sharded over the same tuple, and
every collective takes the tuple, so the plane spans the pod axis with
no code difference. ``init`` is the one host-side entry point —
it allocates the local state and tiles the sharded leaves ``D``× into the
global arrays the plane carries between steps (``state_spec`` describes
which leaves those are).

Layout
------
* **uniform** — the ring shards along batch: shard ``s`` owns global
  slots ``[s*C_loc, (s+1)*C_loc)`` where ``C_loc = capacity / D``. Every
  shard adds the same number of transitions per iteration (its
  ``B/D``-wide trajectory slice), so the write index and fill size stay
  replicated-by-construction and never need a collective.
* **prioritized** — one sum-tree per shard over its ``C_loc`` leaves,
  with the global root materialised by a psum of the local totals. With
  capacity and ``D`` powers of two, each shard's tree is *exactly* a
  depth-``log2 D`` subtree of the reference global tree, so the global
  stratified descent factors exactly: the first ``log2 D`` comparisons
  pick the shard whose cumulative-mass interval contains the draw, and
  the remaining comparisons are the local descent. Sampling therefore
  stays O(log C_loc) per shard and the drawn leaf distribution is
  *identical* (not just equal in expectation) to the single-tree
  reference over the same leaf masses, up to float-boundary ulps in the
  interval comparisons — ``tests/test_replay_sharded.py`` checks exact
  index equality against the reference tree.

Sampling protocol (prioritized)
-------------------------------
Every shard holds the replicated sample key, so all of them compute the
same ``B`` stratified masses over the global total. Each mass is owned by
the one shard whose prefix interval ``[P_s, P_{s+1})`` contains it (the
last shard absorbs the ``m >= P_D`` float edge); owners run the local
descent, and the full batch is reassembled by a masked psum (exact:
every row is one owner's value plus zeros). Each shard then slices rows
``[s*B/D, (s+1)*B/D)`` as its learn minibatch. Priority feedback inverts
the routing: the (replicated) all-gathered ``(indices, priorities)``
update only the leaves a shard owns, via ``sumtree_update_masked``.

With D=1 every collective is over a singleton axis and every mask is
all-True, so both wrappers reduce bitwise to their references.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.data import replay
from repro.data.buffers import (
    PrioritizedBuffer,
    PrioritizedState,
    SumTree,
    UniformBuffer,
    sumtree_find_batch,
)
from repro.kernels.replay_ring import ring_gather
from repro.kernels.sum_tree import sumtree_update_masked


# ============================================================ collectives
def shard_index(axes: Tuple[str, ...]) -> jnp.ndarray:
    """This shard's linear index over ``axes``, matching how shard_map
    splits a leading dim sharded over the same axes (major-to-minor)."""
    idx = jnp.zeros((), jnp.int32)
    for a in axes:
        idx = idx * jax.lax.psum(1, a) + jax.lax.axis_index(a)
    return idx


def gather_scalars(x, my, num_shards: int, axes) -> jnp.ndarray:
    """All-gather one scalar per shard into a replicated ``(D,)`` vector
    (one-hot place + psum — the only collective primitive we need)."""
    place = jnp.where(jnp.arange(num_shards) == my, x,
                      jnp.zeros((), x.dtype))
    return jax.lax.psum(place, axes)


def gather_rows(x, my, num_shards: int, axes) -> jnp.ndarray:
    """All-gather per-shard ``(k, ...)`` blocks into replicated
    ``(D*k, ...)`` (shard-major row order)."""
    k = x.shape[0]
    buf = jnp.zeros((num_shards * k,) + x.shape[1:], x.dtype)
    buf = jax.lax.dynamic_update_slice_in_dim(buf, x, my * k, axis=0)
    return jax.lax.psum(buf, axes)


def _assemble(rows: Dict[str, jnp.ndarray], owned, axes):
    """Merge per-shard candidate rows into the replicated batch: each row
    is psum(owner's value + zeros elsewhere) — exact, not approximate."""
    def one(x):
        mask = owned.reshape(owned.shape + (1,) * (x.ndim - 1))
        return jax.lax.psum(jnp.where(mask, x, jnp.zeros((), x.dtype)),
                            axes)
    return {k: one(v) for k, v in rows.items()}


def _my_slice(x, my, block: int):
    return jax.lax.dynamic_slice_in_dim(x, my * block, block, axis=0)


# ========================================================== uniform shards
class ShardedUniformBuffer:
    """Uniform replay ring sharded along batch (see module docstring)."""

    name = "uniform"
    kind = "transitions"
    passthrough = False

    def __init__(self, inner: UniformBuffer, num_shards: int,
                 axes: Tuple[str, ...]):
        if inner.capacity % num_shards:
            raise ValueError(
                f"buffer capacity {inner.capacity} must divide evenly "
                f"over {num_shards} learner shards")
        if inner.batch_size % num_shards:
            raise ValueError(
                f"buffer batch_size {inner.batch_size} must divide evenly "
                f"over {num_shards} learner shards")
        self.inner = inner
        self.num_shards = int(num_shards)
        self.axes = tuple(axes)
        self.local_capacity = inner.capacity // num_shards
        self.local = UniformBuffer(self.local_capacity, inner.batch_size,
                                   inner.n_step, inner.gamma)
        self.batch_size = inner.batch_size

    # ---- host side: global (tiled) plane state + its PartitionSpecs
    def init(self, example) -> replay.ReplayState:
        local = self.local.init(example)
        tile = lambda x: jnp.concatenate([x] * self.num_shards, axis=0)
        return replay.ReplayState(jax.tree.map(tile, local.storage),
                                  local.index, local.size)

    def state_spec(self, state: replay.ReplayState) -> replay.ReplayState:
        data = P(self.axes)
        return replay.ReplayState(
            {k: data for k in state.storage}, P(), P())

    # ---- shard_map body: local state in, local state out
    def add(self, state, traj):
        return self.local.add(state, traj)

    def sample(self, state: replay.ReplayState, key
               ) -> Dict[str, jnp.ndarray]:
        d = self.num_shards
        b = self.batch_size
        my = shard_index(self.axes)
        size = jnp.maximum(state.size, 1)          # replicated (symmetric)
        # one replicated draw over the D*size global slots; D=1 reduces
        # bitwise to replay.sample_indices
        draw = jax.random.randint(key, (b,), 0, d * size)
        owner = draw // size
        loc = draw % size
        rows = ring_gather(state.storage, loc)
        rows = _assemble(rows, owner == my, self.axes)
        bl = b // d
        batch = {k: _my_slice(v, my, bl) for k, v in rows.items()}
        batch["indices"] = _my_slice(draw, my, bl)
        batch["weights"] = jnp.ones((bl,), jnp.float32)
        return batch

    def update_priorities(self, state, indices, priorities):
        return state


# ====================================================== prioritized shards
class ShardedPrioritizedBuffer:
    """Per-shard sum-trees with a psum'd global root (module docstring)."""

    name = "prioritized"
    kind = "transitions"
    passthrough = False

    def __init__(self, inner: PrioritizedBuffer, num_shards: int,
                 axes: Tuple[str, ...]):
        if num_shards & (num_shards - 1):
            raise ValueError(
                f"prioritized replay shards over a power-of-two learner "
                f"count (got {num_shards}) so each shard's tree is a "
                f"complete subtree of the reference")
        if inner.capacity % num_shards:
            raise ValueError(
                f"buffer capacity {inner.capacity} must divide evenly "
                f"over {num_shards} learner shards")
        if inner.batch_size % num_shards:
            raise ValueError(
                f"buffer batch_size {inner.batch_size} must divide evenly "
                f"over {num_shards} learner shards")
        self.inner = inner
        self.num_shards = int(num_shards)
        self.axes = tuple(axes)
        self.local_capacity = inner.capacity // num_shards
        self.local = PrioritizedBuffer(
            self.local_capacity, inner.batch_size, inner.n_step,
            inner.gamma, inner.alpha, inner.beta, inner.eps)
        self.batch_size = inner.batch_size

    # ---- host side
    def init(self, example) -> PrioritizedState:
        local = self.local.init(example)
        tile = lambda x: jnp.concatenate([x] * self.num_shards, axis=0)
        ring = replay.ReplayState(jax.tree.map(tile, local.ring.storage),
                                  local.ring.index, local.ring.size)
        tree = SumTree(tuple(tile(lv) for lv in local.tree.levels))
        return PrioritizedState(ring, tree, local.max_priority)

    def state_spec(self, state: PrioritizedState) -> PrioritizedState:
        data = P(self.axes)
        ring = replay.ReplayState(
            {k: data for k in state.ring.storage}, P(), P())
        tree = SumTree(tuple(data for _ in state.tree.levels))
        return PrioritizedState(ring, tree, P())

    # ---- shard_map body
    def add(self, state, traj):
        # max_priority is replicated (updates are computed from the
        # replicated all-gathered priorities), so entering new transitions
        # at it needs no collective
        return self.local.add(state, traj)

    def sample(self, state: PrioritizedState, key
               ) -> Dict[str, jnp.ndarray]:
        d = self.num_shards
        b = self.batch_size
        local = self.local
        my = shard_index(self.axes)
        replay.ensure_nonempty(state.ring)
        totals = gather_scalars(state.tree.total, my, d, self.axes)
        t_tot = jnp.sum(totals)
        prefix = jnp.cumsum(totals) - totals       # shard mass offsets P_s
        off = prefix[my]
        # the reference's stratified draw, replicated on every shard
        u = (jnp.arange(b, dtype=jnp.float32)
             + jax.random.uniform(key, (b,))) / b
        m = u * t_tot
        is_last = my == (d - 1)
        owned = (m >= off) & ((m < off + totals[my]) | is_last)
        # local descent on the mass relative to this shard's interval —
        # exactly the tail of the global tree's root descent
        idx = sumtree_find_batch(state.tree, jnp.maximum(m - off, 0.0))
        idx = jnp.minimum(idx, jnp.maximum(state.ring.size, 1) - 1)
        probs = state.tree.levels[0][idx] / jnp.maximum(t_tot, local.eps)
        n_glob = (d * jnp.maximum(state.ring.size, 1)).astype(jnp.float32)
        weights = (n_glob * jnp.maximum(probs, local.eps)) ** (-local.beta)

        rows = ring_gather(state.ring.storage, idx)
        rows = _assemble(rows, owned, self.axes)
        w_all = jax.lax.psum(jnp.where(owned, weights, 0.0), self.axes)
        idx_glob = my * self.local_capacity + idx
        idx_all = jax.lax.psum(jnp.where(owned, idx_glob, 0), self.axes)

        bl = b // d
        batch = {k: _my_slice(v, my, bl) for k, v in rows.items()}
        batch["indices"] = _my_slice(idx_all, my, bl)
        batch["weights"] = _my_slice(w_all / jnp.max(w_all), my, bl)
        return batch

    def update_priorities(self, state: PrioritizedState, indices,
                          priorities) -> PrioritizedState:
        d = self.num_shards
        local = self.local
        my = shard_index(self.axes)
        bl = indices.shape[0]
        idx_all = gather_rows(indices, my, d, self.axes)
        p_all = gather_rows(priorities, my, d, self.axes)
        p = jnp.abs(p_all) + local.eps
        owner = idx_all // self.local_capacity
        tree = sumtree_update_masked(
            state.tree, idx_all % self.local_capacity,
            p ** local.alpha, owner == my)
        return PrioritizedState(state.ring, tree,
                                jnp.maximum(state.max_priority, jnp.max(p)))


def shard_buffer(buffer, num_shards: int, axes: Tuple[str, ...]):
    """Wrap a transitions buffer for the sharded learner (fifo/trajectory
    buffers shard positionally and pass through unchanged)."""
    if getattr(buffer, "kind", None) != "transitions":
        return buffer
    if isinstance(buffer, PrioritizedBuffer):
        return ShardedPrioritizedBuffer(buffer, num_shards, axes)
    if isinstance(buffer, UniformBuffer):
        return ShardedUniformBuffer(buffer, num_shards, axes)
    raise ValueError(
        f"no sharded form for buffer {getattr(buffer, 'name', buffer)!r}")
