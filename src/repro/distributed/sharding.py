"""Logical-axis sharding rules with divisibility fallback.

Placement policy (MaxText-style 2-D sharding, adapted per DESIGN.md §5):

* weight matrices: contracting/input dim -> FSDP axes (``pod``+``data``),
  output dim -> ``model`` (Megatron column-parallel); the reverse for
  output projections (row-parallel), so weights are ~world-way sharded.
* batch dims of activations / trajectories -> ``pod``+``data`` (each data
  slice is one WALL-E sampler).
* decode KV caches: sequence dim -> ``model`` (flash-decoding: each model
  shard owns a KV slice; XLA's distributed softmax does the m/l combine).
* SSM states: d_inner -> ``model``.

Every placement goes through ``shard_axes`` which *falls back to
replication* (returns a smaller axis set or None) when the dim is not
divisible — never silent padding; the dry-run records the choice.
"""
from __future__ import annotations

import math
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# ---------------------------------------------------------------- compat
def shard_map_compat(f, mesh, in_specs, out_specs):
    """shard_map across JAX versions: >=0.5 exposes
    ``jax.shard_map(check_vma=...)``; 0.4.x has the experimental module
    with ``check_rep``. Replication checking is disabled either way (the
    rollout/serving bodies use collectives the checker can't follow)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


# ------------------------------------------------------------------ axes
def fsdp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return fsdp_axes(mesh)


def axes_size(mesh: Mesh, axes: Sequence[str]) -> int:
    return math.prod(mesh.shape[a] for a in axes) if axes else 1


def shard_axes(size: int, axes: Sequence[str], mesh: Mesh
               ) -> Optional[Tuple[str, ...]]:
    """Largest prefix-reduced axis set that divides ``size`` (else None)."""
    axes = tuple(axes)
    candidates = [axes]
    if len(axes) > 1:
        candidates += [axes[1:], axes[:1]]
    for cand in candidates:
        n = axes_size(mesh, cand)
        if n > 1 and size % n == 0:
            return cand if len(cand) > 1 else cand[0]
    return None


# ------------------------------------------------------------ param specs
_COLUMN = {"wq", "wk", "wv", "w1", "w3", "in_proj", "x_proj", "dt_proj",
           "router"}
_ROW = {"wo", "w2", "out_proj"}
_MODEL_VEC = {"bq", "bk", "bv", "conv_b", "dt_bias", "D"}


def _param_spec(path, leaf, cfg, mesh: Mesh, mode: str = "train") -> P:
    """mode="train": FSDP x TP 2-D layout (optimizer state shards with it).
    mode="serve": the decode-fleet layout — contracting dim on `model` so
    single-token matmuls psum tiny activations instead of streaming weight
    shards (EXPERIMENTS.md §Perf, llama3-405b x decode_32k). A disaggregated
    deployment reshards the checkpoint once when loading the decode fleet.
    """
    names = [_key(p) for p in path]
    name = names[-1]
    shape = leaf.shape
    fs = fsdp_axes(mesh)
    in_layers = "layers" in names
    lead = (None,) if in_layers else ()
    dims = shape[1:] if in_layers else shape

    def fsdp(n):
        return shard_axes(n, fs, mesh)

    def model(n):
        return shard_axes(n, ("model",), mesh)

    col_in, col_out = (fsdp, model) if mode == "train" else (model, fsdp)
    row_in, row_out = (model, fsdp) if mode == "train" else (fsdp, model)

    if "embed" in names and name == "table":
        if mode == "serve":
            return P(fsdp(shape[0]), model(shape[1]))
        return P(model(shape[0]), fsdp(shape[1]))
    if "lm_head" in names and name == "w":
        if mode == "serve":
            return P(model(shape[0]), fsdp(shape[1]))
        return P(fsdp(shape[0]), model(shape[1]))
    if "value_head" in names or name == "scale" or name == "meta_tokens":
        return P()
    if name in _COLUMN and len(dims) == 2:
        return P(*lead, col_in(dims[0]), col_out(dims[1]))
    if name in _ROW and len(dims) == 2:
        return P(*lead, row_in(dims[0]), row_out(dims[1]))
    # MoE expert weights: 2-D sharded storage (D on fsdp, F on model); the
    # block explicitly re-gathers the D shards per layer so the expert
    # einsums run fully local (EXPERIMENTS.md §Perf, mixtral iteration 2).
    # Serve layout: contracting dim on `model` — decode psums tiny buffers.
    if name in ("w1", "w3") and len(dims) == 3:        # MoE (E, D, F)
        if mode == "serve":
            return P(*lead, None, model(dims[1]), fsdp(dims[2]))
        return P(*lead, None, fsdp(dims[1]), model(dims[2]))
    if name == "w2" and len(dims) == 3:                # MoE (E, F, D)
        if mode == "serve":
            return P(*lead, None, fsdp(dims[1]), model(dims[2]))
        return P(*lead, None, model(dims[1]), fsdp(dims[2]))
    if name == "conv_w":                               # (W, Di)
        return P(*lead, None, model(dims[1]))
    if name == "A_log":                                # (Di, N)
        return P(*lead, model(dims[0]), None)
    if name in _MODEL_VEC and len(dims) == 1:
        return P(*lead, model(dims[0]))
    # RL MLP dense weights (models/mlp_policy, ddpg/sac actor+critic
    # stacks): generic ``w`` is (in, out) with ``x @ w`` contraction, so
    # the contracting dim goes on the fsdp axes (ZeRO-3 storage layout);
    # 1-D biases / log_std stay replicated — they are tiny and the
    # per-layer all-gather schedule never pays for them.
    if name == "w" and len(dims) == 2:
        return P(*lead, col_in(dims[0]), col_out(dims[1]))
    if name == "b":                                    # generic bias
        return P(*lead, *([None] * len(dims)))
    return P(*lead, *([None] * len(dims)))


def _key(p) -> str:
    for attr in ("key", "name", "idx"):
        if hasattr(p, attr):
            return str(getattr(p, attr))
    return str(p)


def fsdp_leaf_dim(path, leaf, mesh: Mesh) -> Optional[int]:
    """Which dim of this leaf the train-mode ``_param_spec`` layout puts on
    the **full** fsdp axis product — the learner plane's FSDP storage rule.

    Returns the dim index, or None when the leaf stays replicated. Unlike
    raw ``_param_spec`` (whose ``shard_axes`` may fall back to a *subset*
    of the fsdp axes when only that subset divides the dim), the learner
    shards over all of ``("pod", "data")`` or not at all: a uniform shard
    count keeps the gather / reduce-scatter schedule identical for every
    sharded leaf, and partial-divisibility falls back to replicated
    exactly as the plain non-divisible case does.
    """
    fs = fsdp_axes(mesh)
    n = axes_size(mesh, fs)
    if n <= 1:
        return None
    spec = _param_spec(path, leaf, None, mesh, "train")
    full = fs if len(fs) > 1 else fs[0]
    for d, entry in enumerate(spec):
        if entry == full:
            return d
    return None


def param_specs(cfg, params_shape: Any, mesh: Mesh, mode: str = "train"):
    """PartitionSpec pytree matching ``init_params`` output.

    ``params_shape`` may be real params or ``jax.eval_shape`` structs.
    """
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _param_spec(path, leaf, cfg, mesh, mode),
        params_shape)


# ------------------------------------------------------------ batch specs
def batch_spec(size: int, mesh: Mesh) -> P:
    return P(shard_axes(size, batch_axes(mesh), mesh))


def train_batch_specs(cfg, batch_shapes: dict, mesh: Mesh) -> dict:
    """Specs for the PPO train batch dict (tokens/targets/... (B,S))."""
    out = {}
    for k, v in batch_shapes.items():
        shape = v.shape
        if k == "positions" and len(shape) == 3:       # (3, B, S) M-RoPE
            out[k] = P(None, batch_spec(shape[1], mesh)[0], None)
        else:
            b = batch_spec(shape[0], mesh)[0]
            out[k] = P(b, *([None] * (len(shape) - 1)))
    return out


def decode_state_specs(cfg, state_shapes: dict, mesh: Mesh) -> dict:
    """Specs for the decode cache/state dict (flash-decoding layout)."""
    out = {}
    for k, v in state_shapes.items():
        shape = v.shape
        if k in ("k", "v"):            # (L, B, Sc, K, hd): seq -> model
            b = batch_spec(shape[1], mesh)[0]
            out[k] = P(None, b, shard_axes(shape[2], ("model",), mesh),
                       None, None)
        elif k == "conv":              # (L, B, W, Di)
            b = batch_spec(shape[1], mesh)[0]
            out[k] = P(None, b, None,
                       shard_axes(shape[3], ("model",), mesh))
        elif k == "ssm":               # (L, B, Di, N)
            b = batch_spec(shape[1], mesh)[0]
            out[k] = P(None, b, shard_axes(shape[2], ("model",), mesh),
                       None)
        else:                          # pos scalar / cache_pos (Sc,)
            out[k] = P(*([None] * len(shape)))
    return out


def to_shardings(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
