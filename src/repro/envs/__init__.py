from repro import registry
from repro.envs import cartpole, cheetah, lm_env, pendulum  # noqa: F401
from repro.envs.base import (  # noqa: F401
    Env,
    auto_reset,
    auto_reset_batch,
)
from repro.envs.vector import VectorEnv  # noqa: F401

registry.register("env", "pendulum", pendulum.make)
registry.register("env", "cartpole", cartpole.make)
registry.register("env", "cheetah", cheetah.make)


def make(name: str, **kwargs) -> Env:
    """Build a registered env; ``kwargs`` go to its ``make`` (e.g.
    ``max_episode_steps``, ``reward_scale``, ``dtype``)."""
    return registry.make("env", name, **kwargs)
