from repro.envs import cartpole, cheetah, lm_env, pendulum  # noqa: F401
from repro.envs.base import Env, auto_reset  # noqa: F401

_REGISTRY = {
    "pendulum": pendulum.make,
    "cartpole": cartpole.make,
    "cheetah": cheetah.make,
}


def make(name: str) -> Env:
    try:
        return _REGISTRY[name]()
    except KeyError:
        raise KeyError(f"unknown env {name!r}; choose from {sorted(_REGISTRY)}")
