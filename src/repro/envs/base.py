"""Pure-JAX environment API.

Environments are stateless pytree-in / pytree-out so they can be ``vmap``-ed
into sampler batches and ``lax.scan``-ed into rollouts — the JAX-native
equivalent of WALL-E's per-process environment copies. All functions operate
on a *single* environment; batching is applied from outside, either by
``vmap`` (``auto_reset``) or by the env's own batched fast-path
(``auto_reset_batch`` — the device-resident ``VectorEnv`` plane, where
B=1k–100k instances are one batched state pytree and the step+auto-reset
runs as a fused kernel; see ``envs/vector.py`` and DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

EnvState = Any


@dataclasses.dataclass(frozen=True)
class Env:
    """A bundle of pure functions describing one environment.

    ``batch_step``, when provided, is the batched fused step+auto-reset
    fast-path: ``(state, actions, keys, reset_state, reset_obs) ->
    (state', obs, rewards, dones)`` over ``(B,)``-leading leaves, with
    the auto-reset select already applied against the given reset
    candidates. It dispatches through the ``env_step`` kernel family
    (``kernels/env_step``), so ``--kernels pallas`` runs the whole env
    step as one Pallas kernel. Envs without one fall back to
    ``vmap(step)`` + a single batched ``where`` (``auto_reset_batch``).
    """
    name: str
    obs_dim: int
    act_dim: int
    reset: Callable[[jax.Array], Tuple[EnvState, jnp.ndarray]]
    step: Callable[[EnvState, jnp.ndarray, jax.Array],
                   Tuple[EnvState, jnp.ndarray, jnp.ndarray, jnp.ndarray]]
    max_episode_steps: int = 1000
    batch_step: Optional[Callable] = None


def auto_reset(env: Env):
    """Wrap ``env.step`` so ``done`` episodes restart transparently — the
    sampler never stalls (WALL-E samplers run episodes back-to-back)."""

    def step(state, action, key):
        k_step, k_reset = jax.random.split(key)
        next_state, obs, reward, done = env.step(state, action, k_step)
        reset_state, reset_obs = env.reset(k_reset)
        next_state = jax.tree.map(lambda r, n: jnp.where(done, r, n),
                                  reset_state, next_state)
        obs = jnp.where(done, reset_obs, obs)
        return next_state, obs, reward, done

    return step


def select_reset_batch(done, reset_state, reset_obs, state, obs):
    """Batched auto-reset select: one leafwise ``where`` over the whole
    batch (``done (B,)`` broadcast up each leaf's trailing dims) instead
    of a vmapped per-instance tree select. Bitwise-identical to
    ``vmap`` of ``auto_reset``'s select (regression-tested)."""

    def pick(r, n):
        mask = done.reshape(done.shape + (1,) * (n.ndim - done.ndim))
        return jnp.where(mask, r, n)

    state = jax.tree.map(pick, reset_state, state)
    obs = pick(reset_obs, obs)
    return state, obs


def auto_reset_batch(env: Env):
    """Batched analog of ``auto_reset``: ``step(state, actions, keys) ->
    (state', obs, rewards, dones)`` over ``(B,)``-leading leaves with
    per-instance PRNG ``keys (B,)``.

    The key split and reset draw mirror ``auto_reset`` exactly (vmapped,
    so per-instance key chains are unchanged); the physics step + select
    take the batched fast-path — the env's fused ``batch_step`` kernel
    when it has one, else ``vmap(env.step)`` followed by a *single*
    ``where`` over the batch. Either way the result is bitwise-identical
    to ``vmap(auto_reset(env))`` for matched keys, so swapping a sampler
    from the vmapped interface to this one is a scheduling change, not a
    numerical one (the ``VectorEnv`` parity tests pin this).
    """
    batch_step = env.batch_step

    def step(state, actions, keys):
        splits = jax.vmap(jax.random.split)(keys)
        k_step, k_reset = splits[:, 0], splits[:, 1]
        reset_state, reset_obs = jax.vmap(env.reset)(k_reset)
        if batch_step is not None:
            return batch_step(state, actions, k_step, reset_state,
                              reset_obs)
        next_state, obs, rewards, dones = jax.vmap(env.step)(
            state, actions, k_step)
        next_state, obs = select_reset_batch(dones, reset_state, reset_obs,
                                             next_state, obs)
        return next_state, obs, rewards, dones

    return step
