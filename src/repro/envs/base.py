"""Pure-JAX environment API.

Environments are stateless pytree-in / pytree-out so they can be ``vmap``-ed
into sampler batches and ``lax.scan``-ed into rollouts — the JAX-native
equivalent of WALL-E's per-process environment copies. All functions operate
on a *single* environment; batching is always applied from outside (vmap),
so ``done`` is a scalar inside ``step``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

EnvState = Any


@dataclasses.dataclass(frozen=True)
class Env:
    """A bundle of pure functions describing one environment."""
    name: str
    obs_dim: int
    act_dim: int
    reset: Callable[[jax.Array], Tuple[EnvState, jnp.ndarray]]
    step: Callable[[EnvState, jnp.ndarray, jax.Array],
                   Tuple[EnvState, jnp.ndarray, jnp.ndarray, jnp.ndarray]]
    max_episode_steps: int = 1000


def auto_reset(env: Env):
    """Wrap ``env.step`` so ``done`` episodes restart transparently — the
    sampler never stalls (WALL-E samplers run episodes back-to-back)."""

    def step(state, action, key):
        k_step, k_reset = jax.random.split(key)
        next_state, obs, reward, done = env.step(state, action, k_step)
        reset_state, reset_obs = env.reset(k_reset)
        next_state = jax.tree.map(lambda r, n: jnp.where(done, r, n),
                                  reset_state, next_state)
        obs = jnp.where(done, reset_obs, obs)
        return next_state, obs, reward, done

    return step
