"""Continuous-action cart-pole balance, pure JAX.

Classic cart-pole physics (Barto-Sutton-Anderson) with a continuous force
action in [-1, 1] * 10 N; reward 1 per step upright minus a small control
cost. Episodes end on pole fall, track exit, or ``max_episode_steps``.

``make`` takes per-env kwargs through the registry and follows the same
dtype conventions as ``pendulum`` (float32 observations/rewards by
default, explicit ``dtype`` override, int32 step counter, bool done).

The step physics live in ``kernels/env_step/ref.py`` (moved verbatim);
this module wires them into the ``Env`` bundle and builds the fused
``batch_step`` the ``VectorEnv`` plane dispatches through
``kernels/env_step/ops.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.envs.base import Env
from repro.kernels.env_step import ops as env_step_ops
from repro.kernels.env_step import ref as env_step_ref
from repro.kernels.env_step.ref import (  # noqa: F401  (historical names)
    CARTPOLE_DT as DT,
    CARTPOLE_FORCE_MAX as FORCE_MAX,
    CARTPOLE_GRAVITY as GRAVITY,
    CARTPOLE_L_POLE as L_POLE,
    CARTPOLE_M_CART as M_CART,
    CARTPOLE_M_POLE as M_POLE,
    CARTPOLE_TH_LIMIT as TH_LIMIT,
    CARTPOLE_X_LIMIT as X_LIMIT,
)


def make(max_episode_steps: int = 500, reward_scale: float = 1.0,
         force_max: float = FORCE_MAX, dtype=jnp.float32) -> Env:
    dtype = jnp.dtype(dtype)
    reward_scale = float(reward_scale)
    params = dict(max_episode_steps=max_episode_steps,
                  reward_scale=reward_scale, force_max=force_max)

    def obs(state):
        return env_step_ref.cartpole_obs(state, dtype)

    def reset(key):
        vals = jax.random.uniform(key, (4,), minval=-0.05, maxval=0.05)
        state = (vals[0], vals[1], vals[2], vals[3],
                 jnp.zeros((), jnp.int32))
        return state, obs(state)

    def step(state, action, key):
        del key
        return env_step_ref.cartpole_step(state, action, dtype=dtype,
                                          **params)

    def batch_step(state, actions, keys, reset_state, reset_obs,
                   impl=None):
        del keys
        return env_step_ops.env_step("cartpole", state, actions,
                                     reset_state, reset_obs, dtype=dtype,
                                     impl=impl, **params)

    return Env(name="cartpole", obs_dim=4, act_dim=1,
               reset=reset, step=step,
               max_episode_steps=max_episode_steps,
               batch_step=batch_step)
