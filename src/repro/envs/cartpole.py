"""Continuous-action cart-pole balance, pure JAX.

Classic cart-pole physics (Barto-Sutton-Anderson) with a continuous force
action in [-1, 1] * 10 N; reward 1 per step upright minus a small control
cost. Episodes end on pole fall, track exit, or ``max_episode_steps``.

``make`` takes per-env kwargs through the registry and follows the same
dtype conventions as ``pendulum`` (float32 observations/rewards by
default, explicit ``dtype`` override, int32 step counter, bool done).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.envs.base import Env

GRAVITY = 9.8
M_CART = 1.0
M_POLE = 0.1
L_POLE = 0.5          # half-length
FORCE_MAX = 10.0
DT = 0.02
X_LIMIT = 2.4
TH_LIMIT = 12 * jnp.pi / 180


def make(max_episode_steps: int = 500, reward_scale: float = 1.0,
         force_max: float = FORCE_MAX, dtype=jnp.float32) -> Env:
    dtype = jnp.dtype(dtype)
    reward_scale = float(reward_scale)

    def obs(state):
        x, xdot, th, thdot, _ = state
        return jnp.stack([x, xdot, th, thdot]).astype(dtype)

    def reset(key):
        vals = jax.random.uniform(key, (4,), minval=-0.05, maxval=0.05)
        state = (vals[0], vals[1], vals[2], vals[3],
                 jnp.zeros((), jnp.int32))
        return state, obs(state)

    def step(state, action, key):
        del key
        x, xdot, th, thdot, t = state
        force = jnp.clip(action[0], -1.0, 1.0) * force_max
        total_m = M_CART + M_POLE
        pm_l = M_POLE * L_POLE
        costh, sinth = jnp.cos(th), jnp.sin(th)
        temp = (force + pm_l * thdot ** 2 * sinth) / total_m
        th_acc = ((GRAVITY * sinth - costh * temp)
                  / (L_POLE * (4.0 / 3.0 - M_POLE * costh ** 2 / total_m)))
        x_acc = temp - pm_l * th_acc * costh / total_m
        x = x + DT * xdot
        xdot = xdot + DT * x_acc
        th = th + DT * thdot
        thdot = thdot + DT * th_acc
        t = t + 1
        state = (x, xdot, th, thdot, t)
        fell = (jnp.abs(x) > X_LIMIT) | (jnp.abs(th) > TH_LIMIT)
        done = fell | (t >= max_episode_steps)
        reward = 1.0 - 0.01 * action[0] ** 2 - 1.0 * fell
        if reward_scale != 1.0:
            reward = reward * reward_scale
        return state, obs(state), reward.astype(dtype), done

    return Env(name="cartpole", obs_dim=4, act_dim=1,
               reset=reset, step=step,
               max_episode_steps=max_episode_steps)
