"""Planar "cheetah-like" locomotion, pure JAX.

A 6-joint planar chain with damped torque-driven joint dynamics and a gait
reward (forward velocity minus control cost), standing in for MuJoCo
HalfCheetah-v2 — the paper's benchmark task — since MuJoCo binaries are
unavailable here (DESIGN.md §2). Forward velocity arises from coordinated
out-of-phase joint motion (adjacent-joint phase coupling), so the optimal
policy must discover a gait, qualitatively like HalfCheetah.

Observation (14-d): 6 joint angles, 6 joint velocities, body velocity, body
pitch. Action: 6 joint torques in [-1, 1]. Reward: vx - 0.1 * ||a||^2.

``make`` takes per-env kwargs through the registry and follows the same
dtype conventions as ``pendulum`` (float32 observations/rewards by
default, explicit ``dtype`` override, int32 step counter, bool done).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.envs.base import Env

N_JOINTS = 6
DT = 0.05
DAMPING = 1.5
STIFFNESS = 4.0
GEAR = 6.0
COUPLING = 0.8


def make(max_episode_steps: int = 1000, reward_scale: float = 1.0,
         ctrl_cost: float = 0.1, dtype=jnp.float32) -> Env:
    dtype = jnp.dtype(dtype)
    reward_scale = float(reward_scale)

    def obs(state):
        th, om, vx, pitch, _ = state
        return jnp.concatenate(
            [th, om, jnp.stack([vx, pitch])]).astype(dtype)

    def reset(key):
        k1, k2 = jax.random.split(key)
        th = jax.random.uniform(k1, (N_JOINTS,), minval=-0.1, maxval=0.1)
        om = jax.random.uniform(k2, (N_JOINTS,), minval=-0.1, maxval=0.1)
        state = (th, om, jnp.zeros(()), jnp.zeros(()),
                 jnp.zeros((), jnp.int32))
        return state, obs(state)

    def step(state, action, key):
        del key
        th, om, vx, pitch, t = state
        a = jnp.clip(action, -1.0, 1.0)
        # joint dynamics: torque-driven damped oscillators, neighbour-coupled
        neighbour = COUPLING * (jnp.roll(th, 1) - th)
        om = om + DT * (GEAR * a - DAMPING * om - STIFFNESS * th + neighbour)
        th = th + DT * om
        # gait thrust: adjacent joints moving out of phase push the body
        thrust = jnp.mean(jnp.sin(th[:-1] - th[1:]) * (om[:-1] - om[1:]))
        vx = 0.9 * vx + DT * (8.0 * thrust)
        pitch = 0.95 * pitch + 0.05 * jnp.mean(th)
        t = t + 1
        reward = vx - ctrl_cost * jnp.sum(a ** 2)
        if reward_scale != 1.0:
            reward = reward * reward_scale
        done = t >= max_episode_steps
        state = (th, om, vx, pitch, t)
        return state, obs(state), reward.astype(dtype), done

    return Env(name="cheetah", obs_dim=2 * N_JOINTS + 2, act_dim=N_JOINTS,
               reset=reset, step=step,
               max_episode_steps=max_episode_steps)
