"""Planar "cheetah-like" locomotion, pure JAX.

A 6-joint planar chain with damped torque-driven joint dynamics and a gait
reward (forward velocity minus control cost), standing in for MuJoCo
HalfCheetah-v2 — the paper's benchmark task — since MuJoCo binaries are
unavailable here (DESIGN.md §2). Forward velocity arises from coordinated
out-of-phase joint motion (adjacent-joint phase coupling), so the optimal
policy must discover a gait, qualitatively like HalfCheetah.

Observation (14-d): 6 joint angles, 6 joint velocities, body velocity, body
pitch. Action: 6 joint torques in [-1, 1]. Reward: vx - 0.1 * ||a||^2.

``make`` takes per-env kwargs through the registry and follows the same
dtype conventions as ``pendulum`` (float32 observations/rewards by
default, explicit ``dtype`` override, int32 step counter, bool done).

The step physics live in ``kernels/env_step/ref.py`` (moved verbatim);
this module wires them into the ``Env`` bundle and builds the fused
``batch_step`` the ``VectorEnv`` plane dispatches through
``kernels/env_step/ops.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.envs.base import Env
from repro.kernels.env_step import ops as env_step_ops
from repro.kernels.env_step import ref as env_step_ref
from repro.kernels.env_step.ref import (  # noqa: F401  (historical names)
    CHEETAH_COUPLING as COUPLING,
    CHEETAH_DAMPING as DAMPING,
    CHEETAH_DT as DT,
    CHEETAH_GEAR as GEAR,
    CHEETAH_N_JOINTS as N_JOINTS,
    CHEETAH_STIFFNESS as STIFFNESS,
)


def make(max_episode_steps: int = 1000, reward_scale: float = 1.0,
         ctrl_cost: float = 0.1, dtype=jnp.float32) -> Env:
    dtype = jnp.dtype(dtype)
    reward_scale = float(reward_scale)
    params = dict(max_episode_steps=max_episode_steps,
                  reward_scale=reward_scale, ctrl_cost=ctrl_cost)

    def obs(state):
        return env_step_ref.cheetah_obs(state, dtype)

    def reset(key):
        k1, k2 = jax.random.split(key)
        th = jax.random.uniform(k1, (N_JOINTS,), minval=-0.1, maxval=0.1)
        om = jax.random.uniform(k2, (N_JOINTS,), minval=-0.1, maxval=0.1)
        state = (th, om, jnp.zeros(()), jnp.zeros(()),
                 jnp.zeros((), jnp.int32))
        return state, obs(state)

    def step(state, action, key):
        del key
        return env_step_ref.cheetah_step(state, action, dtype=dtype,
                                         **params)

    def batch_step(state, actions, keys, reset_state, reset_obs,
                   impl=None):
        del keys
        return env_step_ops.env_step("cheetah", state, actions,
                                     reset_state, reset_obs, dtype=dtype,
                                     impl=impl, **params)

    return Env(name="cheetah", obs_dim=2 * N_JOINTS + 2, act_dim=N_JOINTS,
               reset=reset, step=step,
               max_episode_steps=max_episode_steps,
               batch_step=batch_step)
