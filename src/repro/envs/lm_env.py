"""Token-generation environment — the RLHF-style instantiation of WALL-E.

The "environment" for a sequence-model policy: the policy emits tokens
autoregressively (experience collection = decode), and a fixed synthetic
reward model scores them. The reward model is a random-but-fixed per-token
preference table plus a repetition penalty — cheap, deterministic, and
learnable, which is all the framework-level experiments need.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LMEnv:
    vocab_size: int
    episode_len: int
    reward_table: jnp.ndarray        # (V,) fixed per-token reward
    repeat_penalty: float = 0.5

    def token_rewards(self, tokens: jnp.ndarray) -> jnp.ndarray:
        """tokens (B, T) -> per-token rewards (B, T)."""
        base = self.reward_table[tokens]
        rep = jnp.concatenate(
            [jnp.zeros_like(tokens[:, :1], dtype=bool),
             tokens[:, 1:] == tokens[:, :-1]], axis=1)
        return base - self.repeat_penalty * rep.astype(jnp.float32)


def make(vocab_size: int, episode_len: int = 32, seed: int = 0) -> LMEnv:
    key = jax.random.PRNGKey(seed)
    table = 0.5 * jax.random.normal(key, (vocab_size,))
    return LMEnv(vocab_size=vocab_size, episode_len=episode_len,
                 reward_table=table)
