"""Pendulum swing-up (classic control), pure JAX.

Dynamics and reward follow the canonical Gym Pendulum-v1; used as the fast
CPU stand-in for the paper's MuJoCo task in tests and examples.

``make`` accepts per-env kwargs (episode horizon, reward scale, dtype) —
the registry seam passes ``ExperimentSpec.env_kwargs`` straight through.
Defaults reproduce the historical constants bitwise.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.envs.base import Env

MAX_SPEED = 8.0
MAX_TORQUE = 2.0
DT = 0.05
G = 10.0
M = 1.0
L = 1.0


def _angle_norm(x):
    return ((x + jnp.pi) % (2 * jnp.pi)) - jnp.pi


def make(max_episode_steps: int = 200, reward_scale: float = 1.0,
         max_torque: float = MAX_TORQUE, dtype=jnp.float32) -> Env:
    dtype = jnp.dtype(dtype)
    reward_scale = float(reward_scale)

    def obs(state):
        th, thdot, _ = state
        return jnp.stack([jnp.cos(th), jnp.sin(th),
                          thdot / MAX_SPEED]).astype(dtype)

    def reset(key):
        k1, k2 = jax.random.split(key)
        th = jax.random.uniform(k1, (), minval=-jnp.pi, maxval=jnp.pi)
        thdot = jax.random.uniform(k2, (), minval=-1.0, maxval=1.0)
        state = (th, thdot, jnp.zeros((), jnp.int32))
        return state, obs(state)

    def step(state, action, key):
        del key
        th, thdot, t = state
        u = jnp.clip(action[0], -max_torque, max_torque)
        cost = _angle_norm(th) ** 2 + 0.1 * thdot ** 2 + 0.001 * u ** 2
        thdot = thdot + (3 * G / (2 * L) * jnp.sin(th)
                         + 3.0 / (M * L ** 2) * u) * DT
        thdot = jnp.clip(thdot, -MAX_SPEED, MAX_SPEED)
        th = th + thdot * DT
        t = t + 1
        state = (th, thdot, t)
        done = t >= max_episode_steps
        reward = -cost
        if reward_scale != 1.0:
            reward = reward * reward_scale
        return state, obs(state), reward.astype(dtype), done

    return Env(name="pendulum", obs_dim=3, act_dim=1,
               reset=reset, step=step,
               max_episode_steps=max_episode_steps)
