"""Pendulum swing-up (classic control), pure JAX.

Dynamics and reward follow the canonical Gym Pendulum-v1; used as the fast
CPU stand-in for the paper's MuJoCo task in tests and examples.

``make`` accepts per-env kwargs (episode horizon, reward scale, dtype) —
the registry seam passes ``ExperimentSpec.env_kwargs`` straight through.
Defaults reproduce the historical constants bitwise.

The step physics live in ``kernels/env_step/ref.py`` (moved verbatim, so
the single-instance oracle and the batched/Pallas fast-paths share one
set of expressions); this module wires them into the ``Env`` bundle and
builds the fused ``batch_step`` the ``VectorEnv`` plane dispatches
through ``kernels/env_step/ops.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.envs.base import Env
from repro.kernels.env_step import ops as env_step_ops
from repro.kernels.env_step import ref as env_step_ref
from repro.kernels.env_step.ref import (  # noqa: F401  (historical names)
    PENDULUM_DT as DT,
    PENDULUM_G as G,
    PENDULUM_L as L,
    PENDULUM_M as M,
    PENDULUM_MAX_SPEED as MAX_SPEED,
    PENDULUM_MAX_TORQUE as MAX_TORQUE,
)

_angle_norm = env_step_ref._angle_norm


def make(max_episode_steps: int = 200, reward_scale: float = 1.0,
         max_torque: float = MAX_TORQUE, dtype=jnp.float32) -> Env:
    dtype = jnp.dtype(dtype)
    reward_scale = float(reward_scale)
    params = dict(max_episode_steps=max_episode_steps,
                  reward_scale=reward_scale, max_torque=max_torque)

    def obs(state):
        return env_step_ref.pendulum_obs(state, dtype)

    def reset(key):
        k1, k2 = jax.random.split(key)
        th = jax.random.uniform(k1, (), minval=-jnp.pi, maxval=jnp.pi)
        thdot = jax.random.uniform(k2, (), minval=-1.0, maxval=1.0)
        state = (th, thdot, jnp.zeros((), jnp.int32))
        return state, obs(state)

    def step(state, action, key):
        del key
        return env_step_ref.pendulum_step(state, action, dtype=dtype,
                                          **params)

    def batch_step(state, actions, keys, reset_state, reset_obs,
                   impl=None):
        del keys
        return env_step_ops.env_step("pendulum", state, actions,
                                     reset_state, reset_obs, dtype=dtype,
                                     impl=impl, **params)

    return Env(name="pendulum", obs_dim=3, act_dim=1,
               reset=reset, step=step,
               max_episode_steps=max_episode_steps,
               batch_step=batch_step)
