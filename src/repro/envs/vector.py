"""Device-resident vectorised environments (the env plane, DESIGN.md §7).

``VectorEnv`` presents B = 1k–100k instances of a single-instance ``Env``
as *one* batched object: state is one pytree with ``(B,)``-leading leaves,
stepping is one fused step+auto-reset over the whole batch, and each
instance keeps its own PRNG chain. It replaces outside-in
``vmap(auto_reset(env))`` as the sampler's env interface when
``ExperimentSpec``/``train.py --env-batch`` selects vector collection —
the fast-path dispatches through the ``env_step`` kernel family, so with
``--kernels pallas`` the whole batched step runs as one Pallas kernel
with state resident in VMEM.

The batched step is bitwise-identical to ``vmap(auto_reset(env))`` for
matched keys (pinned by ``tests/test_vector_env.py``), so vector
collection at ``env_batch == global_batch`` reproduces the legacy
single-sampler inline run exactly.
"""
from __future__ import annotations

import jax

from repro.envs.base import Env, auto_reset_batch


class VectorEnv:
    """B instances of ``env`` as one batched state pytree.

    Duck-types the ``Env`` bundle (``name``/``obs_dim``/``act_dim``/
    ``reset``/``step``/``max_episode_steps``), so registry consumers and
    ``init_env_carry`` treat it as an env; samplers detect the extra
    ``batched_step`` attribute and swap their per-instance ``vmap`` for
    the fused batch path.
    """

    def __init__(self, env: Env, batch: int):
        batch = int(batch)
        if batch < 1:
            raise ValueError(f"VectorEnv batch={batch} must be >= 1")
        self.env = env
        self.batch = batch
        self.name = env.name
        self.obs_dim = env.obs_dim
        self.act_dim = env.act_dim
        self.max_episode_steps = env.max_episode_steps
        self.reset = env.reset          # single-instance (vmapped by carry init)
        self.step = env.step            # single-instance (oracle/debug path)
        self.batch_step = env.batch_step
        # step(state, actions, keys) -> (state', obs, rewards, dones),
        # auto-reset fused; all leaves (B,)-leading.
        self.batched_step = auto_reset_batch(env)

    def init_carry(self, key):
        """Batched reset: ``(states, obs, keys)`` for ``self.batch``
        instances — the rollout carry layout every sampler backend uses."""
        k_reset, k_keys = jax.random.split(key)
        states, obs = jax.vmap(self.env.reset)(
            jax.random.split(k_reset, self.batch))
        keys = jax.random.split(k_keys, self.batch)
        return states, obs, keys

    def __repr__(self):
        return f"VectorEnv({self.name}, batch={self.batch})"
