"""Functional env wrappers: observation/reward normalization.

State (running mean/var) is carried explicitly in the rollout carry so the
wrappers stay pure and shard_map-able — each WALL-E sampler shard keeps its
own statistics, and ``merge_norm_states`` combines them (Chan et al.
parallel-variance) when the learner wants global normalization.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class RunningNorm(NamedTuple):
    mean: jnp.ndarray
    var: jnp.ndarray
    count: jnp.ndarray


def init_norm(dim: int) -> RunningNorm:
    return RunningNorm(jnp.zeros((dim,)), jnp.ones((dim,)),
                       jnp.asarray(0.0))


def update_norm(state: RunningNorm, batch: jnp.ndarray) -> RunningNorm:
    """Welford batch update. batch (N, dim)."""
    b_mean = jnp.mean(batch, axis=0)
    b_var = jnp.var(batch, axis=0)
    b_count = batch.shape[0]
    delta = b_mean - state.mean
    tot = state.count + b_count
    mean = state.mean + delta * b_count / tot
    m_a = state.var * state.count
    m_b = b_var * b_count
    m2 = m_a + m_b + delta ** 2 * state.count * b_count / tot
    return RunningNorm(mean, m2 / tot, tot)


def merge_norm_states(a: RunningNorm, b: RunningNorm) -> RunningNorm:
    """Combine two shards' statistics (parallel variance)."""
    delta = b.mean - a.mean
    tot = a.count + b.count
    mean = a.mean + delta * b.count / tot
    m2 = a.var * a.count + b.var * b.count \
        + delta ** 2 * a.count * b.count / tot
    return RunningNorm(mean, m2 / tot, tot)


def normalize_obs(state: RunningNorm, obs: jnp.ndarray,
                  clip: float = 10.0) -> jnp.ndarray:
    return jnp.clip((obs - state.mean) / jnp.sqrt(state.var + 1e-8),
                    -clip, clip)
