"""The unified experiment API: one declarative spec, one entry point.

    from repro.experiment import ExperimentSpec, run

    result = run(ExperimentSpec(env="pendulum", algo="sac",
                                buffer="prioritized",
                                backend="threaded"))
    for log in result.logs: ...

``ExperimentSpec`` names every choice an experiment makes — env, algo,
buffer, backend, runtime, model and schedule — as registry keys plus
plain data, so a spec serialises losslessly (``to_dict``/``from_dict``
round-trip) and a checkpoint's metadata alone reproduces its run.
``build`` resolves the spec through the unified registry
(``repro.registry``) into a runner; ``run`` builds and drives it.
``launch/train.py``, ``examples/*`` and ``benchmarks/*`` all delegate
here, which is what makes every algorithm (ppo/trpo/ddpg/sac) available
on every backend (inline/threaded/sharded/process) and runtime
(sync/async/fused) through one seam.

The actor plane: ``backend="process"`` (optionally
``schedule.num_workers``) collects with true worker *processes* — each
rebuilt from a serializable ``WorkerSpec`` with its own XLA client,
fed through shared-memory transport (``core/ipc.py``); with
``runtime="async"`` the workers free-run into the shared trajectory
ring while the learner drains it (DESIGN.md §6).

The experience plane: ``buffer`` selects how collected experience is
stored and re-sampled (``fifo`` trajectory pass-through for on-policy
algos; ``uniform`` / ``prioritized`` replay for off-policy ones —
``buffer_kwargs`` carries capacity/batch_size/n_step/...). ``build``
composes algo + buffer into one jittable train step
(``algos.api.make_train_step``) and hands the runner the initial
``plane_state = (buffer_state, key)``; the runner owns it explicitly, so
``result.runner.buffer_state`` is inspectable and ``opt_state`` stays
purely the optimizer's.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax

from repro import kernels as kernels_mod
from repro import registry
from repro.algos.api import make_train_step
from repro.core import sampler as sampler_mod
from repro.core.backends import make_backend, merge_trajs
from repro.core.fused import FusedRunner
from repro.core.orchestrator import AsyncOrchestrator, IterationLog, SyncRunner
from repro.envs.vector import VectorEnv

RUNTIMES = ("sync", "async", "fused")

# fold_in tag deriving the plane's sampling key from the schedule seed —
# distinct from the params key (PRNGKey(seed)) and every sampler carry
# key (PRNGKey(seed + i))
_PLANE_KEY_TAG = 0xB0FF


@dataclasses.dataclass(frozen=True)
class Schedule:
    """How much work, split how — the experiment's loop shape."""
    num_samplers: int = 4
    global_batch: int = 16
    horizon: int = 128
    iterations: int = 10
    seed: int = 0
    chunk: Optional[int] = None           # fused runtime: iters per dispatch
    min_batches_per_update: int = 1       # async runtime: learner drain size
    num_workers: Optional[int] = None     # process backend: worker-process
    #                                       count (None: num_samplers —
    #                                       worker i matches sampler i, the
    #                                       process == inline seed rule)
    env_batch: Optional[int] = None       # vector collection: B env
    #                                       instances as one device-resident
    #                                       VectorEnv batch with one carry —
    #                                       overrides the num_samplers ×
    #                                       global_batch split (DESIGN.md §7)
    learner_devices: Optional[int] = None  # shard_map data-parallel learner
    #                                       over D devices (None/1: the
    #                                       single-device path, bitwise
    #                                       unchanged — DESIGN.md §9)
    learner_microbatches: int = 1         # gradient-accumulation slices per
    #                                       (per-shard) batch
    fsdp: bool = False                    # shard params + Adam moments over
    #                                       the learner mesh's fsdp axes per
    #                                       the _param_spec layout rules
    #                                       (requires learner_devices > 1;
    #                                       off: replicated, bitwise
    #                                       unchanged — DESIGN.md §11)
    overlap: bool = False                 # double-buffered pipeline: run
    #                                       iteration k+1's collect while
    #                                       iteration k's learn executes
    #                                       (sync/fused runtimes; async
    #                                       already overlaps by design)
    learner_pods: int = 1                 # split the learner shards over a
    #                                       (pod, data, model) mesh — the
    #                                       multi-pod production axis names,
    #                                       so the step lowers across the
    #                                       DCN boundary (DESIGN.md §11)
    max_respawns: int = 3                 # process backend: crash-loop
    #                                       budget per worker (consecutive
    #                                       failures before the run fails;
    #                                       0 disables supervision entirely
    #                                       — DESIGN.md §10)
    min_workers: Optional[int] = None     # async process: elastic fleet
    max_workers: Optional[int] = None     # floor/ceiling; setting either
    #                                       enables utilization-band
    #                                       autoscaling (the pool is
    #                                       provisioned to max_workers,
    #                                       starts at num_workers)


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """One experiment, fully resolved: registry names + plain data."""
    env: str = "pendulum"
    algo: str = "ppo"
    backend: str = "inline"               # inline | threaded | sharded
    #                                       | process
    runtime: str = "sync"                 # sync | async | fused
    buffer: Optional[str] = None          # fifo | uniform | prioritized
    #                                       (None: the algo's default)
    kernels: str = "auto"                 # ref | pallas | auto — which
    #                                       kernel-plane implementation the
    #                                       hot-loop ops trace (DESIGN.md §5)
    model: Dict[str, Any] = dataclasses.field(default_factory=dict)
    schedule: Schedule = dataclasses.field(default_factory=Schedule)
    env_kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    algo_kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    buffer_kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    staleness: Optional[Any] = None       # staleness correction for async
    #                                       learning: a mode name
    #                                       ("decay"/"vtrace"), a dict, or a
    #                                       StalenessConfig; None/"off"
    #                                       keeps the historical bitwise
    #                                       path (DESIGN.md §10)
    faults: Optional[str] = None          # fault-injection schedule for
    #                                       process workers, e.g.
    #                                       "kill:0.2,torn:0.05" —
    #                                       deterministic per (seed, worker,
    #                                       incarnation, step)

    def __post_init__(self):
        # normalize StalenessConfig to its dict form so to_dict/from_dict
        # round-trips through plain data (specs must serialize losslessly)
        if dataclasses.is_dataclass(self.staleness) and not isinstance(
                self.staleness, type):
            object.__setattr__(self, "staleness",
                               self.staleness.to_dict())

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ExperimentSpec":
        d = dict(d)
        sched = d.get("schedule", {})
        if not isinstance(sched, Schedule):
            d["schedule"] = Schedule(**sched)
        return cls(**d)


@dataclasses.dataclass
class ExperimentResult:
    spec: ExperimentSpec
    logs: List[IterationLog]
    runner: Any

    @property
    def params(self):
        return self.runner.params


def _resolve_buffer(spec: ExperimentSpec, algo):
    """Buffer name -> instance, validated against the algo's batch diet."""
    name = spec.buffer or getattr(algo, "default_buffer", "fifo")
    if not registry.contains("buffer", name):
        raise KeyError(f"unknown buffer {name!r}; choose from "
                       f"{list(registry.choices('buffer'))}")
    kwargs = dict(spec.buffer_kwargs)
    on_policy = bool(getattr(algo, "on_policy", True))
    buffer = registry.make("buffer", name, **kwargs)
    if on_policy and buffer.kind != "trajectory":
        raise ValueError(
            f"algo {spec.algo!r} is on-policy and learns from whole "
            f"trajectories; buffer {name!r} serves flat transition "
            f"minibatches — use buffer='fifo'")
    if not on_policy and buffer.kind != "transitions":
        raise ValueError(
            f"algo {spec.algo!r} is off-policy and learns from replay "
            f"minibatches; buffer {name!r} passes trajectories through — "
            f"use buffer='uniform' or 'prioritized'")
    # one source of truth for the discount: the buffer's n-step transform
    # bakes gamma into per-transition ``discounts``, so its gamma must be
    # the algorithm's — a second knob would silently win over algo_kwargs
    algo_gamma = getattr(getattr(algo, "cfg", None), "gamma", None)
    if buffer.kind == "transitions" and algo_gamma is not None:
        if "gamma" in kwargs:
            raise ValueError(
                "set the discount through algo_kwargs={'gamma': ...} — "
                "the buffer derives its n-step discount from the "
                "algorithm's gamma, so buffer_kwargs['gamma'] would "
                "silently diverge from it")
        buffer.gamma = float(algo_gamma)
    return buffer


def _validate_learner(spec: ExperimentSpec, algo, sched: Schedule,
                      devices: int, vector: bool):
    """Shape/compatibility checks for the multi-device learner, eager and
    pointed (the shard_map errors they preempt are cryptic)."""
    if sched.fsdp and devices <= 1:
        raise ValueError(
            "schedule.fsdp shards params/opt-state across the learner "
            "mesh; it requires learner_devices > 1 (a 1-device run has "
            "nothing to shard — and stays on the bitwise single-device "
            "path)")
    if sched.learner_pods > 1 and devices <= 1:
        raise ValueError(
            "schedule.learner_pods splits the learner shards over a "
            "(pod, data, model) mesh; it requires learner_devices > 1")
    if devices <= 1:
        return
    if sched.learner_pods > 1 and devices % sched.learner_pods:
        raise ValueError(
            f"learner_pods={sched.learner_pods} must divide "
            f"learner_devices={devices}")
    if not getattr(algo, "shardable", False):
        raise ValueError(
            f"algo {spec.algo!r} does not support learner_devices > 1 "
            f"(shardable=False — its gradients bypass grad_sync)")
    if spec.runtime == "async":
        n = (sched.num_workers or sched.num_samplers
             ) if spec.backend == "process" else sched.num_samplers
        batch = sched.min_batches_per_update * (sched.global_batch // n)
    elif vector:
        batch = sched.env_batch
    else:
        batch = sched.global_batch
    if batch % devices:
        raise ValueError(
            f"the learner-side batch ({batch}) must divide evenly over "
            f"learner_devices={devices}")


def _traj_zeros(rollout, params, carries):
    """Zeroed merged-trajectory pytree (the fifo buffer's storage shape),
    via ``eval_shape`` so no rollout actually runs."""
    shapes = jax.eval_shape(
        lambda p, cs: merge_trajs([rollout(p, c)[1] for c in cs]),
        params, list(carries))
    return jax.tree.map(
        lambda s: jax.numpy.zeros(s.shape, s.dtype), shapes)


def build(spec: ExperimentSpec):
    """Resolve a spec into a runner (without driving it).

    Construction mirrors the historical ``launch/train.py`` wiring
    exactly — same PRNG key derivation (params from ``seed``, sampler i's
    carry from ``seed + i``, the fused global carry from ``seed``) — so
    ``ppo`` × ``inline`` is bitwise-identical to the pre-refactor
    ``SyncRunner`` path.
    """
    if spec.runtime not in RUNTIMES:
        raise ValueError(
            f"unknown runtime {spec.runtime!r}; choose from {RUNTIMES}")
    if not registry.contains("backend", spec.backend):
        raise KeyError(f"unknown backend {spec.backend!r}; choose from "
                       f"{list(registry.choices('backend'))}")
    # runtimes that schedule collection themselves cannot honor a backend
    # choice — reject specs that would otherwise silently misdescribe the
    # run in checkpoint metadata
    if spec.runtime == "fused" and spec.backend != "inline":
        raise ValueError(
            f"runtime 'fused' fuses collection into the train loop; "
            f"backend must be 'inline' (got {spec.backend!r})")
    if spec.runtime == "async" and spec.backend not in ("threaded",
                                                        "process"):
        raise ValueError(
            f"runtime 'async' runs free-running samplers — threads "
            f"(backend='threaded') or worker processes collecting into "
            f"the shared-memory ring (backend='process'); got "
            f"{spec.backend!r}")
    from repro.algos.staleness import StalenessConfig
    stale_cfg = StalenessConfig.parse(spec.staleness)
    if stale_cfg.enabled and spec.runtime != "async":
        raise ValueError(
            f"staleness correction reweights samples by the params-version "
            f"gap the async runtime stamps onto experience; under "
            f"runtime={spec.runtime!r} that gap is identically zero — use "
            f"runtime='async' or staleness='off'")
    if spec.faults and spec.backend != "process":
        raise ValueError(
            f"fault injection kills/hangs worker *processes*; backend must "
            f"be 'process' (got {spec.backend!r})")
    env = registry.make("env", spec.env, **dict(spec.env_kwargs))
    sched = spec.schedule
    if ((sched.min_workers is not None or sched.max_workers is not None)
            and not (spec.runtime == "async"
                     and spec.backend == "process")):
        raise ValueError(
            "elastic sizing (schedule.min_workers/max_workers) grows and "
            "shrinks a free-running worker-process fleet; it requires "
            "runtime='async' with backend='process'")
    vector = sched.env_batch is not None
    if vector:
        # vector collection: the whole batch is ONE device-resident
        # VectorEnv — there is no per-sampler split to hand a process
        # pool or a mesh, so backends built around that split are
        # rejected rather than silently collecting a different shape
        if spec.runtime != "fused" and spec.backend not in ("inline",
                                                            "threaded"):
            raise ValueError(
                f"schedule.env_batch selects vector collection (one "
                f"VectorEnv batch, a single carry); backend must be "
                f"'inline' or 'threaded' (got {spec.backend!r} — "
                f"'process'/'sharded' split the batch across samplers; "
                f"use num_samplers × global_batch for those)")
        env = VectorEnv(env, sched.env_batch)
    algo = registry.make("algo", spec.algo,
                         **{**dict(spec.model), **dict(spec.algo_kwargs)})
    # before buffer/train-step composition: transition_example and the
    # composed learner both key off algo.staleness.enabled
    algo.enable_staleness(stale_cfg)
    buffer = _resolve_buffer(spec, algo)
    # kernel-plane selection is read at trace time: set it after all
    # other validation (set_kernel_mode itself validates-then-mutates, so
    # a rejected spec never leaves the mode changed) and before anything
    # below is traced, so the whole runner sees one
    # consistent implementation (the default, ``auto``, resolves to the
    # bitwise-stable refs off-TPU). The mode is process-global — a runner
    # built here but first *traced* after another build() is traced under
    # that later spec's mode; drive runners before building the next spec
    # (``run`` does) when their ``kernels`` differ.
    kernels_mod.set_kernel_mode(spec.kernels)
    params, opt_state = algo.init(jax.random.PRNGKey(sched.seed), env)
    rollout = algo.make_rollout(env, sched.horizon)
    learner_devices = int(sched.learner_devices or 1)
    learner_micro = int(sched.learner_microbatches or 1)
    if sched.overlap and spec.runtime == "async":
        raise ValueError(
            "schedule.overlap pipelines the sync/fused loop; the async "
            "runtime's free-running samplers already overlap collect "
            "with learn by construction — drop overlap or use "
            "runtime='sync'")
    _validate_learner(spec, algo, sched, learner_devices, vector)
    if learner_devices > 1 or learner_micro > 1:
        from repro.distributed.learner import ShardedLearner
        learner = ShardedLearner(algo, buffer,
                                 num_devices=learner_devices,
                                 microbatches=learner_micro,
                                 fsdp=sched.fsdp, pods=sched.learner_pods,
                                 # under overlap the learner mesh starts at
                                 # device 1 whenever devices allow, so the
                                 # pipelined collect (device 0) and the
                                 # learn genuinely execute concurrently
                                 offset=1 if sched.overlap else 0)
        # the (possibly sharded) wrapper allocates the plane below —
        # sharded ring/tree leaves tiled to global size
        buffer = learner.buffer
        train_step = learner.train_step
    else:
        # learner_devices in (None, 1): the historical single-device
        # composition, untouched (the bitwise guarantee)
        train_step = make_train_step(algo, buffer)
    # a mesh-resident (or FSDP-sharded) learn result must come back to the
    # rollout's device between steps once the runner loop has a reason to
    # care which device params live on (jit of the wrapped step means the
    # learner's own device_put branch never fires under the runners)
    pin_params = learner_devices > 1 and (sched.fsdp or sched.overlap)
    plane_key = jax.random.fold_in(jax.random.PRNGKey(sched.seed),
                                   _PLANE_KEY_TAG)

    def plane_for(carries):
        if buffer.kind == "transitions":
            example = algo.transition_example(env)
        else:
            example = _traj_zeros(rollout, params, carries)
        return (buffer.init(example), plane_key)

    if spec.runtime == "fused":
        carry = sampler_mod.init_env_carry(
            env, jax.random.PRNGKey(sched.seed),
            sched.env_batch if vector else sched.global_batch)
        return FusedRunner(env, None, params, opt_state, carry,
                           horizon=sched.horizon, chunk=sched.chunk,
                           rollout=rollout, train_step=train_step,
                           plane_state=plane_for([carry]),
                           overlap=sched.overlap)

    # process backend: worker count may be named separately
    # (schedule.num_workers); worker i inherits sampler i's seed, so the
    # process backend is exactly inline with the same N (DESIGN.md §6)
    n_samplers = sched.num_samplers
    if spec.backend == "process":
        n_samplers = sched.num_workers or sched.num_samplers
    if vector:
        # one carry holding the whole VectorEnv batch, seeded PRNGKey(seed)
        # — exactly the carry inline num_samplers=1 / global_batch=B would
        # build, so vector env_batch=B reproduces that run bitwise
        n_samplers, per = 1, sched.env_batch
    else:
        per = sampler_mod.split_batch(sched.global_batch, n_samplers)
    carries = [
        sampler_mod.init_env_carry(env, jax.random.PRNGKey(sched.seed + i),
                                   per)
        for i in range(n_samplers)
    ]
    extra: Dict[str, Any] = {}
    sup_cfg = None
    if spec.backend == "process":
        from repro.core.faults import FaultPlan
        from repro.core.supervisor import SupervisorConfig
        min_w = sched.min_workers if sched.min_workers is not None else 1
        max_w = sched.max_workers if sched.max_workers is not None \
            else n_samplers
        if not (1 <= min_w <= n_samplers <= max_w):
            raise ValueError(
                f"elastic bounds must satisfy 1 <= min_workers({min_w}) "
                f"<= num_workers({n_samplers}) <= max_workers({max_w})")
        sup_cfg = SupervisorConfig(
            max_respawns=sched.max_respawns,
            min_workers=sched.min_workers, max_workers=sched.max_workers)
        worker_algo_kwargs = {**dict(spec.model), **dict(spec.algo_kwargs)}
        extra = {
            "params": params,
            # specs (and ring slots) are provisioned for max_workers
            # upfront; only the first n_samplers start — growth respawns
            # a pre-sized spec, it never reallocates shared memory
            "worker_specs": [
                sampler_mod.WorkerSpec(
                    env=spec.env, algo=spec.algo, horizon=sched.horizon,
                    batch=per, seed=sched.seed + i, kernels=spec.kernels,
                    env_kwargs=dict(spec.env_kwargs),
                    algo_kwargs=worker_algo_kwargs)
                for i in range(max_w)
            ],
            "fault_plan": FaultPlan.parse(spec.faults, seed=sched.seed)
            if spec.faults else None,
        }
    if spec.runtime == "async":
        if spec.backend == "process":
            from repro.core.backends import build_worker_pool
            from repro.core.supervisor import WorkerSupervisor
            # 2 slots per worker: one being drained, one being filled —
            # continuous collection without unbounded queueing
            pool = build_worker_pool(rollout=rollout, carries=carries,
                                     slots_per_worker=2,
                                     active_workers=list(range(n_samplers)),
                                     **extra)
            supervisor = (WorkerSupervisor(pool, sup_cfg)
                          if sup_cfg.max_respawns > 0 or sup_cfg.elastic
                          else None)
            return AsyncOrchestrator(
                None, None, params, opt_state, None, n_samplers,
                min_batches_per_update=sched.min_batches_per_update,
                train_step=train_step, plane_state=plane_for(carries),
                pool=pool, supervisor=supervisor, staleness=stale_cfg)
        return AsyncOrchestrator(
            rollout, None, params, opt_state, carries,
            n_samplers,
            min_batches_per_update=sched.min_batches_per_update,
            train_step=train_step, plane_state=plane_for(carries),
            staleness=stale_cfg)
    if sup_cfg is not None:
        extra["supervisor_cfg"] = sup_cfg
    backend = make_backend(spec.backend, rollout, carries,
                           env=env, horizon=sched.horizon,
                           step_keys=algo.step_keys,
                           tail_keys=algo.tail_keys, **extra)
    return SyncRunner(None, None, params, opt_state, backend=backend,
                      train_step=train_step, plane_state=plane_for(carries),
                      overlap=sched.overlap, pin_params=pin_params)


def run(spec: ExperimentSpec,
        iterations: Optional[int] = None) -> ExperimentResult:
    """The single entry point: build the spec's runner and drive it.

    The runner is closed in a ``finally`` — sampler threads, worker
    processes and shared-memory blocks are released even when the run
    raises or is interrupted (Ctrl-C reaps process workers). Results
    (params, logs, buffer state) stay readable after close.
    """
    runner = build(spec)
    try:
        logs = runner.run(iterations if iterations is not None
                          else spec.schedule.iterations)
    finally:
        close = getattr(runner, "close", None)
        if close is not None:
            close()
    return ExperimentResult(spec=spec, logs=logs, runner=runner)
