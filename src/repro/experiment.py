"""The unified experiment API: one declarative spec, one entry point.

    from repro.experiment import ExperimentSpec, run

    result = run(ExperimentSpec(env="pendulum", algo="trpo",
                                backend="threaded"))
    for log in result.logs: ...

``ExperimentSpec`` names every choice an experiment makes — env, algo,
backend, runtime, model and schedule — as registry keys plus plain data,
so a spec serialises losslessly (``to_dict``/``from_dict`` round-trip) and
a checkpoint's metadata alone reproduces its run. ``build`` resolves the
spec through the unified registry (``repro.registry``) into a runner;
``run`` builds and drives it. ``launch/train.py``, ``examples/*`` and
``benchmarks/*`` all delegate here, which is what makes every algorithm
(ppo/trpo/ddpg) available on every backend (inline/threaded/sharded) and
runtime (sync/async/fused) through one seam.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax

from repro import registry
from repro.core import sampler as sampler_mod
from repro.core.backends import make_backend
from repro.core.fused import FusedRunner
from repro.core.orchestrator import AsyncOrchestrator, IterationLog, SyncRunner

RUNTIMES = ("sync", "async", "fused")


@dataclasses.dataclass(frozen=True)
class Schedule:
    """How much work, split how — the experiment's loop shape."""
    num_samplers: int = 4
    global_batch: int = 16
    horizon: int = 128
    iterations: int = 10
    seed: int = 0
    chunk: Optional[int] = None           # fused runtime: iters per dispatch
    min_batches_per_update: int = 1       # async runtime: learner drain size


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """One experiment, fully resolved: registry names + plain data."""
    env: str = "pendulum"
    algo: str = "ppo"
    backend: str = "inline"               # inline | threaded | sharded
    runtime: str = "sync"                 # sync | async | fused
    model: Dict[str, Any] = dataclasses.field(default_factory=dict)
    schedule: Schedule = dataclasses.field(default_factory=Schedule)
    env_kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    algo_kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ExperimentSpec":
        d = dict(d)
        sched = d.get("schedule", {})
        if not isinstance(sched, Schedule):
            d["schedule"] = Schedule(**sched)
        return cls(**d)


@dataclasses.dataclass
class ExperimentResult:
    spec: ExperimentSpec
    logs: List[IterationLog]
    runner: Any

    @property
    def params(self):
        return self.runner.params


def build(spec: ExperimentSpec):
    """Resolve a spec into a runner (without driving it).

    Construction mirrors the historical ``launch/train.py`` wiring
    exactly — same PRNG key derivation (params from ``seed``, sampler i's
    carry from ``seed + i``, the fused global carry from ``seed``) — so
    ``ppo`` × ``inline`` is bitwise-identical to the pre-refactor
    ``SyncRunner`` path.
    """
    if spec.runtime not in RUNTIMES:
        raise ValueError(
            f"unknown runtime {spec.runtime!r}; choose from {RUNTIMES}")
    if not registry.contains("backend", spec.backend):
        raise KeyError(f"unknown backend {spec.backend!r}; choose from "
                       f"{list(registry.choices('backend'))}")
    # runtimes that schedule collection themselves cannot honor a backend
    # choice — reject specs that would otherwise silently misdescribe the
    # run in checkpoint metadata
    if spec.runtime == "fused" and spec.backend != "inline":
        raise ValueError(
            f"runtime 'fused' fuses collection into the train loop; "
            f"backend must be 'inline' (got {spec.backend!r})")
    if spec.runtime == "async" and spec.backend != "threaded":
        raise ValueError(
            f"runtime 'async' runs free-running sampler threads — its "
            f"collection discipline is 'threaded'; set "
            f"backend='threaded' (got {spec.backend!r})")
    env = registry.make("env", spec.env, **dict(spec.env_kwargs))
    algo = registry.make("algo", spec.algo,
                         **{**dict(spec.model), **dict(spec.algo_kwargs)})
    sched = spec.schedule
    params, opt_state = algo.init(jax.random.PRNGKey(sched.seed), env)
    rollout = algo.make_rollout(env, sched.horizon)

    if spec.runtime == "fused":
        carry = sampler_mod.init_env_carry(
            env, jax.random.PRNGKey(sched.seed), sched.global_batch)
        return FusedRunner(env, algo.learn, params, opt_state, carry,
                           horizon=sched.horizon, chunk=sched.chunk,
                           rollout=rollout)

    per = sampler_mod.split_batch(sched.global_batch, sched.num_samplers)
    carries = [
        sampler_mod.init_env_carry(env, jax.random.PRNGKey(sched.seed + i),
                                   per)
        for i in range(sched.num_samplers)
    ]
    if spec.runtime == "async":
        return AsyncOrchestrator(
            rollout, algo.learn, params, opt_state, carries,
            sched.num_samplers,
            min_batches_per_update=sched.min_batches_per_update)
    backend = make_backend(spec.backend, rollout, carries,
                           env=env, horizon=sched.horizon,
                           step_keys=algo.step_keys,
                           tail_keys=algo.tail_keys)
    return SyncRunner(None, algo.learn, params, opt_state, backend=backend)


def run(spec: ExperimentSpec,
        iterations: Optional[int] = None) -> ExperimentResult:
    """The single entry point: build the spec's runner and drive it."""
    runner = build(spec)
    logs = runner.run(iterations if iterations is not None
                      else spec.schedule.iterations)
    return ExperimentResult(spec=spec, logs=logs, runner=runner)
