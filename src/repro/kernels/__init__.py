"""The kernel plane: TPU Pallas kernels for every hot path, each behind
a ref/pallas dispatcher.

Two workload groups share one layout (``<name>_pallas``-style kernel +
``ops.py`` dispatcher + ``ref.py`` pure-jnp oracle per subpackage):

* LM sampler hot-spots — ``flash_attention``, ``decode_attention``,
  ``selective_scan`` (validated by allclose sweeps).
* RL hot-loop families — ``gae``, ``sum_tree``, ``replay_ring``,
  ``env_step`` (validated by *exact*-parity sweeps; the ref selection is
  the bitwise baseline the rest of the suite is stated against).

The RL families are registered under the registry kind ``"kernel"``
(``registry.make("kernel", "gae")`` returns the family's ops namespace;
``registry.choices("kernel")`` enumerates them — how the benchmarks and
docs discover the plane). Which implementation a dispatcher traces is a
process-global mode (``select.set_kernel_mode``; ``ref``/``pallas``/
``auto``) spec'd per experiment via ``ExperimentSpec.kernels`` and
``launch/train.py --kernels``. See DESIGN.md §5.
"""
from repro import registry
from repro.kernels import select  # noqa: F401
from repro.kernels import (  # noqa: F401
    decode_attention,
    env_step,
    flash_attention,
    gae,
    replay_ring,
    selective_scan,
    sum_tree,
)
from repro.kernels.select import (  # noqa: F401
    kernel_mode,
    resolve,
    set_kernel_mode,
)

registry.register("kernel", "gae", lambda: gae)
registry.register("kernel", "sum_tree", lambda: sum_tree)
registry.register("kernel", "replay_ring", lambda: replay_ring)
registry.register("kernel", "env_step", lambda: env_step)
