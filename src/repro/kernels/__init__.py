# TPU Pallas kernels for the sampler's compute hot-spots (the experience-
# collection half of WALL-E). Each subpackage: <name>.py (pallas_call +
# BlockSpec VMEM tiling), ops.py (jit'd wrapper in model layout), ref.py
# (pure-jnp oracle used by the allclose test sweeps).
from repro.kernels import (  # noqa: F401
    decode_attention,
    flash_attention,
    selective_scan,
)
