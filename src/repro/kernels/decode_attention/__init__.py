from repro.kernels.decode_attention.decode_attention import (  # noqa: F401
    decode_attention,
)
from repro.kernels.decode_attention.ops import (  # noqa: F401
    decode_attention_op,
)
from repro.kernels.decode_attention.ref import decode_ref  # noqa: F401
