"""Flash-decoding kernel for TPU (Pallas): one query row vs. a KV cache.

The sampler's decode hot-spot (``decode_32k`` / ``long_500k``). The KV
cache is streamed through VMEM in ``kv_block``-sized tiles along the
sequential last grid axis, with the running (m, l, acc) for the single
query row kept in VMEM scratch — a decode-specialised FlashAttention where
the Q tile degenerates to one row per (batch, head) grid cell.

Slot validity (ring-buffer caches may hold stale or unwritten slots) comes
in as an int32 mask streamed with the same tiling as K/V.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, kv_block: int, num_kv_blocks: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)                 # (1, hd)
    k = k_ref[0, 0].astype(jnp.float32)                 # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    mask = valid_ref[0] > 0                             # (1, bk)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)                     # (1, bk)

    m_prev = m_ref[...]                                 # (1, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.where(mask, jnp.exp(s - m_safe), 0.0)
    alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = (acc_ref[...] * alpha
                    + jax.lax.dot(p, v, preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     valid: jnp.ndarray, *, kv_block: int = 512,
                     interpret: bool = True) -> jnp.ndarray:
    """q (B,H,hd); k/v (B,K,Sc,hd); valid (Sc,) bool. Returns (B,H,hd)."""
    B, H, hd = q.shape
    _, K, Sc, _ = k.shape
    assert H % K == 0
    G = H // K
    kv_block = min(kv_block, Sc)
    assert Sc % kv_block == 0, (Sc, kv_block)
    nk = Sc // kv_block
    valid2 = valid.astype(jnp.int32).reshape(1, Sc)

    kernel = functools.partial(_kernel, scale=1.0 / math.sqrt(hd),
                               kv_block=kv_block, num_kv_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, hd), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, kv_block, hd),
                         lambda b, h, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, kv_block, hd),
                         lambda b, h, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, kv_block), lambda b, h, ik: (0, ik)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, hd), lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, 1, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, hd), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q[:, :, None, :], k, v, valid2)
    return out[:, :, 0, :]
