"""jit'd public wrapper for the decode-attention kernel (model layout)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import decode_attention


@functools.partial(jax.jit, static_argnames=("kv_block", "interpret"))
def decode_attention_op(q: jnp.ndarray, k_cache: jnp.ndarray,
                        v_cache: jnp.ndarray, valid: jnp.ndarray, *,
                        kv_block: int = 512,
                        interpret: bool = True) -> jnp.ndarray:
    """Model layout: q (B,K,G,hd), cache (B,Sc,K,hd) -> (B,K,G,hd)."""
    B, K, G, hd = q.shape
    qh = q.reshape(B, K * G, hd)
    kh = jnp.transpose(k_cache, (0, 2, 1, 3))
    vh = jnp.transpose(v_cache, (0, 2, 1, 3))
    o = decode_attention(qh, kh, vh, valid, kv_block=kv_block,
                         interpret=interpret)
    return o.reshape(B, K, G, hd)
