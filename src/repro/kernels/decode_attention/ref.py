"""Pure-jnp oracle for decode attention (mirrors models.attention.decode)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
               valid: jnp.ndarray) -> jnp.ndarray:
    """q (B,H,hd); k/v (B,K,Sc,hd); valid (Sc,). Returns (B,H,hd)."""
    B, H, hd = q.shape
    K = k.shape[1]
    G = H // K
    kr = jnp.repeat(k, G, axis=1).astype(jnp.float32)
    vr = jnp.repeat(v, G, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32), kr) / jnp.sqrt(
        hd).astype(jnp.float32)
    s = jnp.where(valid[None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bhsd->bhd", p, vr).astype(q.dtype)
