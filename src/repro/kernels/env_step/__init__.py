from repro.kernels.env_step import env_step_pallas, ops, ref  # noqa: F401
from repro.kernels.env_step.ops import ENV_NAMES, env_step  # noqa: F401
