"""Fused env-step + auto-reset Pallas kernels.

One kernel per physics env (pendulum / cartpole / cheetah): the whole
per-step pipeline — physics update, reward, termination test, and the
auto-reset select against precomputed reset candidates — runs over
``(B,)`` tiles with every state leaf resident in VMEM, replacing the
~15 separate elementwise XLA ops the batched reference lowers to with
one launch per step. The batch lives on the *lane* axis (blocks are
``(leaf_rank, b_block)`` with state scalars as ``(1, b_block)`` rows),
so B=1k–100k instances stream through in ``b_block``-wide tiles.

Each kernel body evaluates *exactly* the reference expressions
(``ref.<env>_step_batch_ref``) in the same order; parity tests assert
EXACT equality on int/bool leaves, the auto-reset select, and the full
pendulum/cheetah trees, and a measured few-ulp bound on cartpole's f32
arithmetic — XLA CPU FMA-contracts per fusion context, so two
differently-shaped compilations of the *same* ops (the ``(B,)`` ref vs
the tiled interpreted kernel) are not bitwise-stable against each
other; strict-rounding recomputation sides with the kernel where they
disagree. Reset candidates are inputs
(reset
sampling needs ``jax.random``; ``envs.base.auto_reset_batch`` draws them
outside) and ``done`` is returned as an int32 0/1 mask (the dispatcher
restores bool) — booleans stay internal to the kernel.

No scratch buffers or TPU-specific memory spaces are used, so the same
kernel bodies lower via Mosaic on TPU and Triton on GPU
(``kernels/select.py`` compiles Pallas on both; interpret mode remains
the CPU correctness harness).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.env_step import ref as R


def _pad_lanes(x: jnp.ndarray, bp: int) -> jnp.ndarray:
    """(k, B) -> (k, bp) zero-padded on the lane (batch) axis."""
    return jnp.pad(x, ((0, 0), (0, bp - x.shape[1])))


def _rows(bp, *xs):
    """Each (B,) array -> one (1, bp) lane row."""
    return [_pad_lanes(x[None, :], bp) for x in xs]


# ================================================================ pendulum
def _pendulum_kernel(th_ref, td_ref, t_ref, a_ref,
                     rth_ref, rtd_ref, rt_ref, robs_ref,
                     oth_ref, otd_ref, ot_ref, oobs_ref, orew_ref,
                     odone_ref, *, max_episode_steps, reward_scale,
                     max_torque):
    th, thdot, t = th_ref[...], td_ref[...], t_ref[...]
    u = jnp.clip(a_ref[...], -max_torque, max_torque)
    cost = R._angle_norm(th) ** 2 + 0.1 * thdot ** 2 + 0.001 * u ** 2
    thdot = thdot + (3 * R.PENDULUM_G / (2 * R.PENDULUM_L) * jnp.sin(th)
                     + 3.0 / (R.PENDULUM_M * R.PENDULUM_L ** 2) * u) \
        * R.PENDULUM_DT
    thdot = jnp.clip(thdot, -R.PENDULUM_MAX_SPEED, R.PENDULUM_MAX_SPEED)
    th = th + thdot * R.PENDULUM_DT
    t = t + 1
    done = t >= max_episode_steps
    reward = -cost
    if reward_scale != 1.0:
        reward = reward * reward_scale
    obs = jnp.concatenate([jnp.cos(th), jnp.sin(th),
                           thdot / R.PENDULUM_MAX_SPEED], axis=0)
    oth_ref[...] = jnp.where(done, rth_ref[...], th)
    otd_ref[...] = jnp.where(done, rtd_ref[...], thdot)
    ot_ref[...] = jnp.where(done, rt_ref[...], t)
    oobs_ref[...] = jnp.where(done, robs_ref[...], obs)
    orew_ref[...] = reward
    odone_ref[...] = done.astype(jnp.int32)


def pendulum_step_pallas(state, actions, reset_state, reset_obs, *,
                         max_episode_steps, reward_scale, max_torque,
                         b_block: int = 512, interpret: bool = True):
    th, thdot, t = state
    rth, rtd, rt = reset_state
    B = th.shape[0]
    b_block = min(b_block, B)
    nb = pl.cdiv(B, b_block)
    bp = nb * b_block

    ins = _rows(bp, th, thdot, t, actions[:, 0], rth, rtd, rt)
    ins.append(_pad_lanes(reset_obs.T, bp))                    # (3, bp)

    row = pl.BlockSpec((1, b_block), lambda bi: (0, bi))
    obs_spec = pl.BlockSpec((3, b_block), lambda bi: (0, bi))
    kernel = functools.partial(_pendulum_kernel,
                               max_episode_steps=max_episode_steps,
                               reward_scale=reward_scale,
                               max_torque=max_torque)
    f32 = jax.ShapeDtypeStruct((1, bp), jnp.float32)
    i32 = jax.ShapeDtypeStruct((1, bp), jnp.int32)
    oth, otd, ot, oobs, orew, odone = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[row] * 7 + [obs_spec],
        out_specs=[row, row, row, obs_spec, row, row],
        out_shape=[f32, f32, i32,
                   jax.ShapeDtypeStruct((3, bp), jnp.float32), f32, i32],
        interpret=interpret,
    )(*ins)
    return ((oth[0, :B], otd[0, :B], ot[0, :B]), oobs[:, :B].T,
            orew[0, :B], odone[0, :B].astype(bool))


# ================================================================ cartpole
def _cartpole_kernel(x_ref, xd_ref, th_ref, td_ref, t_ref, a_ref,
                     rx_ref, rxd_ref, rth_ref, rtd_ref, rt_ref, robs_ref,
                     ox_ref, oxd_ref, oth_ref, otd_ref, ot_ref, oobs_ref,
                     orew_ref, odone_ref, *, max_episode_steps,
                     reward_scale, force_max):
    x, xdot, th, thdot, t = (x_ref[...], xd_ref[...], th_ref[...],
                             td_ref[...], t_ref[...])
    a0 = a_ref[...]
    force = jnp.clip(a0, -1.0, 1.0) * force_max
    total_m = R.CARTPOLE_M_CART + R.CARTPOLE_M_POLE
    pm_l = R.CARTPOLE_M_POLE * R.CARTPOLE_L_POLE
    costh, sinth = jnp.cos(th), jnp.sin(th)
    temp = (force + pm_l * thdot ** 2 * sinth) / total_m
    th_acc = ((R.CARTPOLE_GRAVITY * sinth - costh * temp)
              / (R.CARTPOLE_L_POLE
                 * (4.0 / 3.0 - R.CARTPOLE_M_POLE * costh ** 2 / total_m)))
    x_acc = temp - pm_l * th_acc * costh / total_m
    x = x + R.CARTPOLE_DT * xdot
    xdot = xdot + R.CARTPOLE_DT * x_acc
    th = th + R.CARTPOLE_DT * thdot
    thdot = thdot + R.CARTPOLE_DT * th_acc
    t = t + 1
    fell = ((jnp.abs(x) > R.CARTPOLE_X_LIMIT)
            | (jnp.abs(th) > R.CARTPOLE_TH_LIMIT))
    done = fell | (t >= max_episode_steps)
    reward = 1.0 - 0.01 * a0 ** 2 - 1.0 * fell
    if reward_scale != 1.0:
        reward = reward * reward_scale
    obs = jnp.concatenate([x, xdot, th, thdot], axis=0)
    ox_ref[...] = jnp.where(done, rx_ref[...], x)
    oxd_ref[...] = jnp.where(done, rxd_ref[...], xdot)
    oth_ref[...] = jnp.where(done, rth_ref[...], th)
    otd_ref[...] = jnp.where(done, rtd_ref[...], thdot)
    ot_ref[...] = jnp.where(done, rt_ref[...], t)
    oobs_ref[...] = jnp.where(done, robs_ref[...], obs)
    orew_ref[...] = reward
    odone_ref[...] = done.astype(jnp.int32)


def cartpole_step_pallas(state, actions, reset_state, reset_obs, *,
                         max_episode_steps, reward_scale, force_max,
                         b_block: int = 512, interpret: bool = True):
    x, xdot, th, thdot, t = state
    rx, rxd, rth, rtd, rt = reset_state
    B = x.shape[0]
    b_block = min(b_block, B)
    nb = pl.cdiv(B, b_block)
    bp = nb * b_block

    ins = _rows(bp, x, xdot, th, thdot, t, actions[:, 0],
                rx, rxd, rth, rtd, rt)
    ins.append(_pad_lanes(reset_obs.T, bp))                    # (4, bp)

    row = pl.BlockSpec((1, b_block), lambda bi: (0, bi))
    obs_spec = pl.BlockSpec((4, b_block), lambda bi: (0, bi))
    kernel = functools.partial(_cartpole_kernel,
                               max_episode_steps=max_episode_steps,
                               reward_scale=reward_scale,
                               force_max=force_max)
    f32 = jax.ShapeDtypeStruct((1, bp), jnp.float32)
    i32 = jax.ShapeDtypeStruct((1, bp), jnp.int32)
    ox, oxd, oth, otd, ot, oobs, orew, odone = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[row] * 11 + [obs_spec],
        out_specs=[row, row, row, row, row, obs_spec, row, row],
        out_shape=[f32, f32, f32, f32, i32,
                   jax.ShapeDtypeStruct((4, bp), jnp.float32), f32, i32],
        interpret=interpret,
    )(*ins)
    return ((ox[0, :B], oxd[0, :B], oth[0, :B], otd[0, :B], ot[0, :B]),
            oobs[:, :B].T, orew[0, :B], odone[0, :B].astype(bool))


# ================================================================= cheetah
def _cheetah_kernel(th_ref, om_ref, vx_ref, pi_ref, t_ref, a_ref,
                    rth_ref, rom_ref, rvx_ref, rpi_ref, rt_ref, robs_ref,
                    oth_ref, oom_ref, ovx_ref, opi_ref, ot_ref, oobs_ref,
                    orew_ref, odone_ref, *, max_episode_steps,
                    reward_scale, ctrl_cost):
    th, om = th_ref[...], om_ref[...]                       # (6, b)
    vx, pitch, t = vx_ref[...], pi_ref[...], t_ref[...]     # (1, b)
    a = jnp.clip(a_ref[...], -1.0, 1.0)
    # jnp.roll(th, 1, axis=0) written as a concatenate so the body stays
    # lowerable on every Pallas backend; identical values
    rolled = jnp.concatenate([th[-1:], th[:-1]], axis=0)
    neighbour = R.CHEETAH_COUPLING * (rolled - th)
    om = om + R.CHEETAH_DT * (R.CHEETAH_GEAR * a - R.CHEETAH_DAMPING * om
                              - R.CHEETAH_STIFFNESS * th + neighbour)
    th = th + R.CHEETAH_DT * om
    thrust = jnp.mean(jnp.sin(th[:-1] - th[1:]) * (om[:-1] - om[1:]),
                      axis=0, keepdims=True)
    vx = 0.9 * vx + R.CHEETAH_DT * (8.0 * thrust)
    pitch = 0.95 * pitch + 0.05 * jnp.mean(th, axis=0, keepdims=True)
    t = t + 1
    reward = vx - ctrl_cost * jnp.sum(a ** 2, axis=0, keepdims=True)
    if reward_scale != 1.0:
        reward = reward * reward_scale
    done = t >= max_episode_steps
    obs = jnp.concatenate([th, om, vx, pitch], axis=0)      # (14, b)
    oth_ref[...] = jnp.where(done, rth_ref[...], th)
    oom_ref[...] = jnp.where(done, rom_ref[...], om)
    ovx_ref[...] = jnp.where(done, rvx_ref[...], vx)
    opi_ref[...] = jnp.where(done, rpi_ref[...], pitch)
    ot_ref[...] = jnp.where(done, rt_ref[...], t)
    oobs_ref[...] = jnp.where(done, robs_ref[...], obs)
    orew_ref[...] = reward
    odone_ref[...] = done.astype(jnp.int32)


def cheetah_step_pallas(state, actions, reset_state, reset_obs, *,
                        max_episode_steps, reward_scale, ctrl_cost,
                        b_block: int = 512, interpret: bool = True):
    th, om, vx, pitch, t = state
    rth, rom, rvx, rpi, rt = reset_state
    B = vx.shape[0]
    NJ = th.shape[1]
    b_block = min(b_block, B)
    nb = pl.cdiv(B, b_block)
    bp = nb * b_block

    ins = [_pad_lanes(th.T, bp), _pad_lanes(om.T, bp)]
    ins += _rows(bp, vx, pitch, t)
    ins += [_pad_lanes(actions.T, bp),
            _pad_lanes(rth.T, bp), _pad_lanes(rom.T, bp)]
    ins += _rows(bp, rvx, rpi, rt)
    ins.append(_pad_lanes(reset_obs.T, bp))                 # (14, bp)

    row = pl.BlockSpec((1, b_block), lambda bi: (0, bi))
    jnt = pl.BlockSpec((NJ, b_block), lambda bi: (0, bi))
    obs_spec = pl.BlockSpec((2 * NJ + 2, b_block), lambda bi: (0, bi))
    kernel = functools.partial(_cheetah_kernel,
                               max_episode_steps=max_episode_steps,
                               reward_scale=reward_scale,
                               ctrl_cost=ctrl_cost)
    f32 = jax.ShapeDtypeStruct((1, bp), jnp.float32)
    i32 = jax.ShapeDtypeStruct((1, bp), jnp.int32)
    jf32 = jax.ShapeDtypeStruct((NJ, bp), jnp.float32)
    oth, oom, ovx, opi, ot, oobs, orew, odone = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[jnt, jnt, row, row, row, jnt, jnt, jnt, row, row, row,
                  obs_spec],
        out_specs=[jnt, jnt, row, row, row, obs_spec, row, row],
        out_shape=[jf32, jf32, f32, f32, i32,
                   jax.ShapeDtypeStruct((2 * NJ + 2, bp), jnp.float32),
                   f32, i32],
        interpret=interpret,
    )(*ins)
    return ((oth[:, :B].T, oom[:, :B].T, ovx[0, :B], opi[0, :B],
             ot[0, :B]), oobs[:, :B].T, orew[0, :B],
            odone[0, :B].astype(bool))


STEP_BATCH_PALLAS = {
    "pendulum": pendulum_step_pallas,
    "cartpole": cartpole_step_pallas,
    "cheetah": cheetah_step_pallas,
}
