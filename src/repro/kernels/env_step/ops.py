"""Dispatching public op for the env-step kernel family.

``env_step(name, ...)`` is the one batched, auto-reset-fused environment
step the env plane drives (``envs.base.auto_reset_batch`` via each env's
``batch_step`` closure). It accepts the reference layout — state leaves
batched on their leading ``(B,)`` axis, actions ``(B, act_dim)``, reset
candidates in the same layout — and selects the implementation through
``kernels.select`` (``impl=`` overrides per call):

* ref    — ``ref.<env>_step_batch_ref``: the envs' historical physics
  expressions batched + a single ``where`` over the batch. The CPU
  default, and bitwise-identical to ``vmap`` of the single-instance
  step under ``auto_reset``.
* pallas — the fused step+auto-reset kernel (``env_step_pallas``),
  interpret mode off-accelerator.

The kernels are float32-only (the envs' default dtype); experiments
running an env under another dtype fall back to the ref path so the
dispatcher never changes numerics, only scheduling.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.kernels import select
from repro.kernels.env_step import env_step_pallas, ref

ENV_NAMES: Tuple[str, ...] = tuple(ref.STEP_BATCH_REF)


def env_step(name: str, state, actions, reset_state, reset_obs, *,
             dtype=jnp.float32, impl: Optional[str] = None, **params):
    """Fused batched physics step + auto-reset select for env ``name``.

    Returns ``(next_state, obs, rewards, dones)`` with the reset
    candidates substituted leafwise wherever ``dones`` is set (rewards
    stay the terminal transition's — the ``auto_reset`` contract).
    ``params`` are the env's static ``make`` kwargs (horizon, scales).
    """
    if name not in ref.STEP_BATCH_REF:
        raise KeyError(f"no env_step kernels for env {name!r}; "
                       f"choose from {sorted(ref.STEP_BATCH_REF)}")
    impl_name, interpret = select.resolve(impl)
    if impl_name == "pallas" and jnp.dtype(dtype) == jnp.float32:
        return env_step_pallas.STEP_BATCH_PALLAS[name](
            state, actions, reset_state, reset_obs,
            interpret=interpret, **params)
    return ref.STEP_BATCH_REF[name](
        state, actions, reset_state, reset_obs, dtype=dtype, **params)
