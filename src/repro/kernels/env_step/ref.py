"""Pure-JAX oracles for the env-step kernel family.

Two layers per environment, both stated against the historical
``envs/{pendulum,cartpole,cheetah}.py`` physics:

* ``<env>_step`` / ``<env>_obs`` — the *single-instance* step, moved
  verbatim from the env modules (which now delegate here, so the
  constants and expressions have exactly one home and every existing
  bitwise guarantee — ``ppo`` × ``inline`` legacy identity, ``fused ==
  stepped`` — is untouched).
* ``<env>_step_batch_ref`` — the batched reference the Pallas kernels
  are parity-tested against (exact int/bool + select + full
  pendulum/cheetah trees; a few ulps on cartpole f32 arithmetic — the
  XLA CPU fusion-context FMA bound, see ``env_step_pallas``): the same
  expressions over ``(B,)``
  state arrays, fused with the auto-reset select (one ``where`` over the
  batch instead of a vmapped per-instance select). ``jax.vmap`` of the
  single-instance step + ``auto_reset`` is bitwise-identical to this
  path (tested in ``tests/test_vector_env.py``) — vmap batches the same
  elementwise primitives this module writes out directly.

The batched refs take the *reset candidates* as arguments: reset
sampling needs ``jax.random`` (host-side key semantics the kernels do
not reproduce), so ``envs.base.auto_reset_batch`` draws one batched
reset outside and the fused step+select consumes it — on ``done`` the
reset state/obs replace the stepped ones leafwise, the reward stays the
terminal transition's (the ``auto_reset`` contract, DESIGN.md §6).

This module imports only ``jax.numpy`` — the env modules import *it*,
never the reverse, so the kernel plane stays import-cycle-free.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

# ------------------------------------------------------------- constants
# (moved verbatim from the env modules; DT collides across envs, so it is
# env-prefixed here and re-exported under its historical name there)
PENDULUM_MAX_SPEED = 8.0
PENDULUM_MAX_TORQUE = 2.0
PENDULUM_DT = 0.05
PENDULUM_G = 10.0
PENDULUM_M = 1.0
PENDULUM_L = 1.0

CARTPOLE_GRAVITY = 9.8
CARTPOLE_M_CART = 1.0
CARTPOLE_M_POLE = 0.1
CARTPOLE_L_POLE = 0.5          # half-length
CARTPOLE_FORCE_MAX = 10.0
CARTPOLE_DT = 0.02
CARTPOLE_X_LIMIT = 2.4
CARTPOLE_TH_LIMIT = 12 * jnp.pi / 180

CHEETAH_N_JOINTS = 6
CHEETAH_DT = 0.05
CHEETAH_DAMPING = 1.5
CHEETAH_STIFFNESS = 4.0
CHEETAH_GEAR = 6.0
CHEETAH_COUPLING = 0.8


def _angle_norm(x):
    return ((x + jnp.pi) % (2 * jnp.pi)) - jnp.pi


def select_reset_batch(done, reset_state, reset_obs, state, obs):
    """The batched auto-reset select: one leafwise ``where`` over the
    whole batch (``done`` broadcast up each leaf's trailing dims) instead
    of a vmapped per-instance tree select. Exact vmap parity."""
    import jax

    def pick(r, n):
        mask = done.reshape(done.shape + (1,) * (n.ndim - done.ndim))
        return jnp.where(mask, r, n)

    state = jax.tree.map(pick, reset_state, state)
    obs = pick(reset_obs, obs)
    return state, obs


# ================================================================ pendulum
def pendulum_obs(state, dtype):
    th, thdot, _ = state
    return jnp.stack([jnp.cos(th), jnp.sin(th),
                      thdot / PENDULUM_MAX_SPEED]).astype(dtype)


def pendulum_step(state, action, *, max_episode_steps, reward_scale,
                  max_torque, dtype):
    """One pendulum physics step (single instance, moved verbatim)."""
    th, thdot, t = state
    u = jnp.clip(action[0], -max_torque, max_torque)
    cost = _angle_norm(th) ** 2 + 0.1 * thdot ** 2 + 0.001 * u ** 2
    thdot = thdot + (3 * PENDULUM_G / (2 * PENDULUM_L) * jnp.sin(th)
                     + 3.0 / (PENDULUM_M * PENDULUM_L ** 2) * u) * PENDULUM_DT
    thdot = jnp.clip(thdot, -PENDULUM_MAX_SPEED, PENDULUM_MAX_SPEED)
    th = th + thdot * PENDULUM_DT
    t = t + 1
    state = (th, thdot, t)
    done = t >= max_episode_steps
    reward = -cost
    if reward_scale != 1.0:
        reward = reward * reward_scale
    return state, pendulum_obs(state, dtype), reward.astype(dtype), done


def pendulum_step_batch_ref(state, actions, reset_state, reset_obs, *,
                            max_episode_steps, reward_scale, max_torque,
                            dtype):
    """Batched pendulum step + fused auto-reset. state leaves (B,)/(B,),
    int32 (B,); actions (B, 1)."""
    th, thdot, t = state
    u = jnp.clip(actions[:, 0], -max_torque, max_torque)
    cost = _angle_norm(th) ** 2 + 0.1 * thdot ** 2 + 0.001 * u ** 2
    thdot = thdot + (3 * PENDULUM_G / (2 * PENDULUM_L) * jnp.sin(th)
                     + 3.0 / (PENDULUM_M * PENDULUM_L ** 2) * u) * PENDULUM_DT
    thdot = jnp.clip(thdot, -PENDULUM_MAX_SPEED, PENDULUM_MAX_SPEED)
    th = th + thdot * PENDULUM_DT
    t = t + 1
    done = t >= max_episode_steps
    reward = -cost
    if reward_scale != 1.0:
        reward = reward * reward_scale
    obs = jnp.stack([jnp.cos(th), jnp.sin(th),
                     thdot / PENDULUM_MAX_SPEED], axis=-1).astype(dtype)
    state, obs = select_reset_batch(done, reset_state, reset_obs,
                                    (th, thdot, t), obs)
    return state, obs, reward.astype(dtype), done


# ================================================================ cartpole
def cartpole_obs(state, dtype):
    x, xdot, th, thdot, _ = state
    return jnp.stack([x, xdot, th, thdot]).astype(dtype)


def cartpole_step(state, action, *, max_episode_steps, reward_scale,
                  force_max, dtype):
    """One cart-pole physics step (single instance, moved verbatim)."""
    x, xdot, th, thdot, t = state
    force = jnp.clip(action[0], -1.0, 1.0) * force_max
    total_m = CARTPOLE_M_CART + CARTPOLE_M_POLE
    pm_l = CARTPOLE_M_POLE * CARTPOLE_L_POLE
    costh, sinth = jnp.cos(th), jnp.sin(th)
    temp = (force + pm_l * thdot ** 2 * sinth) / total_m
    th_acc = ((CARTPOLE_GRAVITY * sinth - costh * temp)
              / (CARTPOLE_L_POLE
                 * (4.0 / 3.0 - CARTPOLE_M_POLE * costh ** 2 / total_m)))
    x_acc = temp - pm_l * th_acc * costh / total_m
    x = x + CARTPOLE_DT * xdot
    xdot = xdot + CARTPOLE_DT * x_acc
    th = th + CARTPOLE_DT * thdot
    thdot = thdot + CARTPOLE_DT * th_acc
    t = t + 1
    state = (x, xdot, th, thdot, t)
    fell = (jnp.abs(x) > CARTPOLE_X_LIMIT) | (jnp.abs(th) > CARTPOLE_TH_LIMIT)
    done = fell | (t >= max_episode_steps)
    reward = 1.0 - 0.01 * action[0] ** 2 - 1.0 * fell
    if reward_scale != 1.0:
        reward = reward * reward_scale
    return state, cartpole_obs(state, dtype), reward.astype(dtype), done


def cartpole_step_batch_ref(state, actions, reset_state, reset_obs, *,
                            max_episode_steps, reward_scale, force_max,
                            dtype):
    """Batched cart-pole step + fused auto-reset. state leaves (B,)."""
    x, xdot, th, thdot, t = state
    a0 = actions[:, 0]
    force = jnp.clip(a0, -1.0, 1.0) * force_max
    total_m = CARTPOLE_M_CART + CARTPOLE_M_POLE
    pm_l = CARTPOLE_M_POLE * CARTPOLE_L_POLE
    costh, sinth = jnp.cos(th), jnp.sin(th)
    temp = (force + pm_l * thdot ** 2 * sinth) / total_m
    th_acc = ((CARTPOLE_GRAVITY * sinth - costh * temp)
              / (CARTPOLE_L_POLE
                 * (4.0 / 3.0 - CARTPOLE_M_POLE * costh ** 2 / total_m)))
    x_acc = temp - pm_l * th_acc * costh / total_m
    x = x + CARTPOLE_DT * xdot
    xdot = xdot + CARTPOLE_DT * x_acc
    th = th + CARTPOLE_DT * thdot
    thdot = thdot + CARTPOLE_DT * th_acc
    t = t + 1
    fell = (jnp.abs(x) > CARTPOLE_X_LIMIT) | (jnp.abs(th) > CARTPOLE_TH_LIMIT)
    done = fell | (t >= max_episode_steps)
    reward = 1.0 - 0.01 * a0 ** 2 - 1.0 * fell
    if reward_scale != 1.0:
        reward = reward * reward_scale
    obs = jnp.stack([x, xdot, th, thdot], axis=-1).astype(dtype)
    state, obs = select_reset_batch(done, reset_state, reset_obs,
                                    (x, xdot, th, thdot, t), obs)
    return state, obs, reward.astype(dtype), done


# ================================================================= cheetah
def cheetah_obs(state, dtype):
    th, om, vx, pitch, _ = state
    return jnp.concatenate(
        [th, om, jnp.stack([vx, pitch])]).astype(dtype)


def cheetah_step(state, action, *, max_episode_steps, reward_scale,
                 ctrl_cost, dtype):
    """One cheetah physics step (single instance, moved verbatim)."""
    th, om, vx, pitch, t = state
    a = jnp.clip(action, -1.0, 1.0)
    # joint dynamics: torque-driven damped oscillators, neighbour-coupled
    neighbour = CHEETAH_COUPLING * (jnp.roll(th, 1) - th)
    om = om + CHEETAH_DT * (CHEETAH_GEAR * a - CHEETAH_DAMPING * om
                            - CHEETAH_STIFFNESS * th + neighbour)
    th = th + CHEETAH_DT * om
    # gait thrust: adjacent joints moving out of phase push the body
    thrust = jnp.mean(jnp.sin(th[:-1] - th[1:]) * (om[:-1] - om[1:]))
    vx = 0.9 * vx + CHEETAH_DT * (8.0 * thrust)
    pitch = 0.95 * pitch + 0.05 * jnp.mean(th)
    t = t + 1
    reward = vx - ctrl_cost * jnp.sum(a ** 2)
    if reward_scale != 1.0:
        reward = reward * reward_scale
    done = t >= max_episode_steps
    state = (th, om, vx, pitch, t)
    return state, cheetah_obs(state, dtype), reward.astype(dtype), done


def cheetah_step_batch_ref(state, actions, reset_state, reset_obs, *,
                           max_episode_steps, reward_scale, ctrl_cost,
                           dtype):
    """Batched cheetah step + fused auto-reset. th/om (B, 6), rest (B,)."""
    th, om, vx, pitch, t = state
    a = jnp.clip(actions, -1.0, 1.0)
    neighbour = CHEETAH_COUPLING * (jnp.roll(th, 1, axis=-1) - th)
    om = om + CHEETAH_DT * (CHEETAH_GEAR * a - CHEETAH_DAMPING * om
                            - CHEETAH_STIFFNESS * th + neighbour)
    th = th + CHEETAH_DT * om
    thrust = jnp.mean(jnp.sin(th[:, :-1] - th[:, 1:])
                      * (om[:, :-1] - om[:, 1:]), axis=-1)
    vx = 0.9 * vx + CHEETAH_DT * (8.0 * thrust)
    pitch = 0.95 * pitch + 0.05 * jnp.mean(th, axis=-1)
    t = t + 1
    reward = vx - ctrl_cost * jnp.sum(a ** 2, axis=-1)
    if reward_scale != 1.0:
        reward = reward * reward_scale
    done = t >= max_episode_steps
    obs = jnp.concatenate(
        [th, om, jnp.stack([vx, pitch], axis=-1)], axis=-1).astype(dtype)
    state, obs = select_reset_batch(done, reset_state, reset_obs,
                                    (th, om, vx, pitch, t), obs)
    return state, obs, reward.astype(dtype), done


STEP_BATCH_REF = {
    "pendulum": pendulum_step_batch_ref,
    "cartpole": cartpole_step_batch_ref,
    "cheetah": cheetah_step_batch_ref,
}
