from repro.kernels.flash_attention.flash_attention import (  # noqa: F401
    flash_attention,
)
from repro.kernels.flash_attention.ops import flash_attention_op  # noqa: F401
from repro.kernels.flash_attention.ref import attention_ref  # noqa: F401
