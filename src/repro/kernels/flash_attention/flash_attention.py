"""FlashAttention-2-style prefill kernel for TPU (Pallas).

The sampler's prefill hot-spot (``prefill_32k``). Streaming-softmax over KV
blocks with running (m, l, acc) carried in VMEM scratch across the
sequential last grid axis; GQA is handled in the BlockSpec index maps (the
KV block for head ``h`` is head ``h // G`` — no repeated KV in HBM).

Tiling: one (q_block x head_dim) Q tile and one (kv_block x head_dim) KV
tile live in VMEM per grid step; defaults 128/512 keep the MXU matmul dims
multiples of 128 (hardware-aligned) and the working set (~q*hd + 2*kv*hd +
q*kv floats ~ 1.3 MB) comfortably inside ~16 MB VMEM with double buffering.

Causal/SWA blocks that are fully masked are predicated off with ``pl.when``
(no MXU work issued), so the kernel's FLOP count matches the exact
lower-triangular / banded count.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: int,
            q_block: int, kv_block: int, num_kv_blocks: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * q_block
    k_start = ik * kv_block
    # block-level predication: fully-masked blocks issue no MXU work
    needed = jnp.bool_(True)
    if causal:
        needed = k_start <= q_start + q_block - 1
    if window:
        needed = needed & (k_start + kv_block - 1 >= q_start - window + 1)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (q_block, kv_block), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (q_block, kv_block), 1)
        mask = jnp.ones((q_block, kv_block), jnp.bool_)
        if causal:
            mask &= cols <= rows
        if window:
            mask &= rows - cols < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                             # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.where(mask, jnp.exp(s - m_safe), 0.0)
        alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe),
                          0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = (acc_ref[...] * alpha
                        + jax.lax.dot(p, v,
                                      preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    q_block: int = 128, kv_block: int = 512,
                    interpret: bool = True) -> jnp.ndarray:
    """q (B,H,Sq,hd); k/v (B,K,Skv,hd) with H = K*G. Returns (B,H,Sq,hd).

    ``interpret=True`` executes the kernel body on CPU for validation; on a
    real TPU pass ``interpret=False`` (identical body).
    """
    B, H, Sq, hd = q.shape
    _, K, Skv, _ = k.shape
    assert H % K == 0, (H, K)
    G = H // K
    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Skv)
    assert Sq % q_block == 0 and Skv % kv_block == 0, (Sq, q_block, Skv,
                                                       kv_block)
    nq, nk = Sq // q_block, Skv // kv_block

    kernel = functools.partial(
        _kernel, scale=1.0 / math.sqrt(hd), causal=causal, window=window,
        q_block=q_block, kv_block=kv_block, num_kv_blocks=nk)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, q_block, hd),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, kv_block, hd),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, kv_block, hd),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_block, hd),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block, hd), jnp.float32),   # acc
            pltpu.VMEM((q_block, 1), jnp.float32),    # running max
            pltpu.VMEM((q_block, 1), jnp.float32),    # running denom
        ],
        interpret=interpret,
    )(q, k, v)
