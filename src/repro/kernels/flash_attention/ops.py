"""jit'd public wrapper for the flash-attention kernel.

Accepts the model's native layout (q (B,S,K,G,hd), kv (B,S,K,hd)) and
handles layout transposition to the kernel's (B,H,S,hd).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "q_block",
                                    "kv_block", "interpret"))
def flash_attention_op(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                       causal: bool = True, window: int = 0,
                       q_block: int = 128, kv_block: int = 512,
                       interpret: bool = True) -> jnp.ndarray:
    """Model layout in/out: q (B,S,K,G,hd), k/v (B,S,K,hd) -> (B,S,K,G,hd)."""
    B, S, K, G, hd = q.shape
    qh = jnp.transpose(q.reshape(B, S, K * G, hd), (0, 2, 1, 3))
    kh = jnp.transpose(k, (0, 2, 1, 3))
    vh = jnp.transpose(v, (0, 2, 1, 3))
    o = flash_attention(qh, kh, vh, causal=causal, window=window,
                        q_block=q_block, kv_block=kv_block,
                        interpret=interpret)
    return jnp.transpose(o, (0, 2, 1, 3)).reshape(B, S, K, G, hd)
