"""Pure-jnp oracle for the flash-attention kernel (naive masked softmax)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: int = 0) -> jnp.ndarray:
    """q (B,H,Sq,hd); k/v (B,K,Skv,hd). Naive O(S^2) reference."""
    B, H, Sq, hd = q.shape
    K = k.shape[1]
    G = H // K
    kr = jnp.repeat(k, G, axis=1)
    vr = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) / jnp.sqrt(hd).astype(jnp.float32)
    Skv = k.shape[2]
    rows = jnp.arange(Sq)[:, None] + (Skv - Sq)   # align ends (prefill: Sq=Skv)
    cols = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= cols <= rows
    if window:
        mask &= rows - cols < window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask[None, None], p, 0.0)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      vr.astype(jnp.float32)).astype(q.dtype)
