from repro.kernels.gae.gae_pallas import (  # noqa: F401
    discounted_returns_pallas,
    gae_pallas,
)
from repro.kernels.gae.ops import discounted_returns, gae  # noqa: F401
from repro.kernels.gae.ref import (  # noqa: F401
    discounted_returns_ref,
    gae_ref,
)
