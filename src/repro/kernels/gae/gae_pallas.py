"""GAE / discounted-returns Pallas kernels for TPU.

The RL learner's per-iteration recurrence, moved on-device in chunks:
time-major ``(T, B)`` reward/value/done blocks are tiled ``b_block`` wide
over batch and cut into ``t_chunk`` chunks along the sequential last grid
axis, walked in *reverse* (chunk ``ci`` processes time block
``nc - 1 - ci``). The scan carry — ``(adv_{t+1}, v_{t+1})`` for GAE,
``R_{t+1}`` for returns — persists in VMEM scratch across chunks, the
same HBM->VMEM->VREG shape as ``selective_scan``: one kernel launch
replaces T host-scheduled scan steps.

Each in-VMEM step evaluates *exactly* the reference expressions
(``delta = r + gamma * v_next * nt - v`` etc.), so on every backend the
kernel is bitwise-identical to ``ref.gae_ref`` — the parity tests assert
equality, not closeness.

Ragged shapes are handled by padding: T is padded up to a whole number
of chunks (padded rows are skipped via ``pl.when`` so they never touch
the carry) and B up to a whole number of lanes (padded columns computed
then sliced away).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gae_kernel(r_ref, v_ref, nt_ref, lv_ref, adv_ref, ret_ref, carry_ref,
                *, t_chunk: int, num_chunks: int, t_true: int,
                gamma: float, lam: float):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        carry_ref[0] = jnp.zeros_like(lv_ref[0])     # adv_{t+1}
        carry_ref[1] = lv_ref[0]                     # v_{t+1}

    base = (num_chunks - 1 - ci) * t_chunk

    def step(i, _):
        t = t_chunk - 1 - i                          # reverse inside chunk

        @pl.when(base + t < t_true)                  # skip T-padding rows
        def _():
            r, v, nt = r_ref[t], v_ref[t], nt_ref[t]
            adv_next, v_next = carry_ref[0], carry_ref[1]
            delta = r + gamma * v_next * nt - v
            adv = delta + gamma * lam * nt * adv_next
            adv_ref[t] = adv
            ret_ref[t] = adv + v
            carry_ref[0] = adv
            carry_ref[1] = v
        return 0

    jax.lax.fori_loop(0, t_chunk, step, 0)


def _returns_kernel(r_ref, nt_ref, lv_ref, ret_ref, carry_ref,
                    *, t_chunk: int, num_chunks: int, t_true: int,
                    gamma: float):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        carry_ref[0] = lv_ref[0]                     # R_{t+1}

    base = (num_chunks - 1 - ci) * t_chunk

    def step(i, _):
        t = t_chunk - 1 - i

        @pl.when(base + t < t_true)
        def _():
            ret = r_ref[t] + gamma * nt_ref[t] * carry_ref[0]
            ret_ref[t] = ret
            carry_ref[0] = ret
        return 0

    jax.lax.fori_loop(0, t_chunk, step, 0)


def _pad_tb(x: jnp.ndarray, tp: int, bp: int) -> jnp.ndarray:
    T, B = x.shape
    return jnp.pad(x, ((0, tp - T), (0, bp - B)))


def gae_pallas(rewards: jnp.ndarray, values: jnp.ndarray,
               nonterm: jnp.ndarray, last_value: jnp.ndarray, *,
               gamma: float, lam: float, b_block: int = 128,
               t_chunk: int = 128, interpret: bool = True
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """rewards/values/nonterm (T, B) f32, last_value (B,) f32.

    Returns (advantages, returns), both (T, B) f32.
    """
    T, B = rewards.shape
    t_chunk = min(t_chunk, T)
    b_block = min(b_block, B)
    nc = pl.cdiv(T, t_chunk)
    nb = pl.cdiv(B, b_block)
    tp, bp = nc * t_chunk, nb * b_block

    args = [_pad_tb(x.astype(jnp.float32), tp, bp)
            for x in (rewards, values, nonterm)]
    lv = jnp.pad(last_value.astype(jnp.float32), (0, bp - B))[None, :]

    kernel = functools.partial(_gae_kernel, t_chunk=t_chunk, num_chunks=nc,
                               t_true=T, gamma=gamma, lam=lam)
    tb_spec = pl.BlockSpec((t_chunk, b_block),
                           lambda bi, ci: (nc - 1 - ci, bi))
    lv_spec = pl.BlockSpec((1, b_block), lambda bi, ci: (0, bi))
    adv, ret = pl.pallas_call(
        kernel,
        grid=(nb, nc),
        in_specs=[tb_spec, tb_spec, tb_spec, lv_spec],
        out_specs=[tb_spec, tb_spec],
        out_shape=[jax.ShapeDtypeStruct((tp, bp), jnp.float32)] * 2,
        scratch_shapes=[pltpu.VMEM((2, b_block), jnp.float32)],
        interpret=interpret,
    )(*args, lv)
    return adv[:T, :B], ret[:T, :B]


def discounted_returns_pallas(rewards: jnp.ndarray, nonterm: jnp.ndarray,
                              last_value: jnp.ndarray, *, gamma: float,
                              b_block: int = 128, t_chunk: int = 128,
                              interpret: bool = True) -> jnp.ndarray:
    """rewards/nonterm (T, B) f32, last_value (B,) f32 -> returns (T, B)."""
    T, B = rewards.shape
    t_chunk = min(t_chunk, T)
    b_block = min(b_block, B)
    nc = pl.cdiv(T, t_chunk)
    nb = pl.cdiv(B, b_block)
    tp, bp = nc * t_chunk, nb * b_block

    args = [_pad_tb(x.astype(jnp.float32), tp, bp)
            for x in (rewards, nonterm)]
    lv = jnp.pad(last_value.astype(jnp.float32), (0, bp - B))[None, :]

    kernel = functools.partial(_returns_kernel, t_chunk=t_chunk,
                               num_chunks=nc, t_true=T, gamma=gamma)
    tb_spec = pl.BlockSpec((t_chunk, b_block),
                           lambda bi, ci: (nc - 1 - ci, bi))
    lv_spec = pl.BlockSpec((1, b_block), lambda bi, ci: (0, bi))
    ret = pl.pallas_call(
        kernel,
        grid=(nb, nc),
        in_specs=[tb_spec, tb_spec, lv_spec],
        out_specs=tb_spec,
        out_shape=jax.ShapeDtypeStruct((tp, bp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, b_block), jnp.float32)],
        interpret=interpret,
    )(*args, lv)
    return ret[:T, :B]
