"""Dispatching public ops for the GAE kernel family.

``gae`` / ``discounted_returns`` accept the reference layout — time-major
``(T, ...)`` with an arbitrary batch shape — and select the
implementation through ``kernels.select`` (``impl=`` overrides per call).
The ref path forwards the original arrays untouched, so the CPU-default
resolution is the historical ``algos/gae.py`` recurrence bit for bit;
the pallas path flattens the batch dims to one lane axis for the kernel
and restores the caller's shape on the way out.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.kernels import select
from repro.kernels.gae.gae_pallas import (
    discounted_returns_pallas,
    gae_pallas,
)
from repro.kernels.gae.ref import discounted_returns_ref, gae_ref


def _flatten_batch(x: jnp.ndarray) -> jnp.ndarray:
    """(T, ...) -> (T, prod(...)); a scalar batch becomes one column."""
    return x.reshape(x.shape[0], -1)


def gae(rewards: jnp.ndarray, values: jnp.ndarray, dones: jnp.ndarray,
        last_value: jnp.ndarray, gamma: float = 0.99, lam: float = 0.95,
        *, impl: Optional[str] = None
        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Advantages + returns; see ``ref.gae_ref`` for semantics."""
    name, interpret = select.resolve(impl)
    if name == "ref":
        return gae_ref(rewards, values, dones, last_value, gamma, lam)
    nonterm = 1.0 - dones.astype(jnp.float32)
    adv, ret = gae_pallas(
        _flatten_batch(rewards), _flatten_batch(values),
        _flatten_batch(nonterm), last_value.reshape(-1),
        gamma=gamma, lam=lam, interpret=interpret)
    return adv.reshape(rewards.shape), ret.reshape(rewards.shape)


def discounted_returns(rewards: jnp.ndarray, dones: jnp.ndarray,
                       last_value: jnp.ndarray, gamma: float = 0.99,
                       *, impl: Optional[str] = None) -> jnp.ndarray:
    """Discounted returns-to-go; see ``ref.discounted_returns_ref``."""
    name, interpret = select.resolve(impl)
    if name == "ref":
        return discounted_returns_ref(rewards, dones, last_value, gamma)
    nonterm = 1.0 - dones.astype(jnp.float32)
    ret = discounted_returns_pallas(
        _flatten_batch(rewards), _flatten_batch(nonterm),
        last_value.reshape(-1), gamma=gamma, interpret=interpret)
    return ret.reshape(rewards.shape)
