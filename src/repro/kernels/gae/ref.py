"""Pure-jnp oracles for the GAE family: sequential reverse scans.

``gae_ref`` is the historical ``algos/gae.py`` recurrence moved here
verbatim — same expressions in the same order — so selecting ``ref``
(the CPU default) keeps every bitwise guarantee in the suite intact.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def gae_ref(rewards: jnp.ndarray, values: jnp.ndarray, dones: jnp.ndarray,
            last_value: jnp.ndarray, gamma: float = 0.99, lam: float = 0.95
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Compute advantages + returns.

    rewards/values/dones: (T, ...) time-major; last_value: (...) bootstrap.
    ``dones[t]`` marks that the episode ended *at* step t (no bootstrap
    across the boundary). Returns (advantages, returns), both (T, ...).
    """
    nonterm = 1.0 - dones.astype(jnp.float32)

    def step(carry, xs):
        adv_next, v_next = carry
        r, v, nt = xs
        delta = r + gamma * v_next * nt - v
        adv = delta + gamma * lam * nt * adv_next
        return (adv, v), adv

    init = (jnp.zeros_like(last_value), last_value)
    _, advs = jax.lax.scan(step, init, (rewards, values, nonterm),
                           reverse=True)
    return advs, advs + values


def discounted_returns_ref(rewards: jnp.ndarray, dones: jnp.ndarray,
                           last_value: jnp.ndarray, gamma: float = 0.99
                           ) -> jnp.ndarray:
    """Discounted returns-to-go: R_t = r_t + gamma * nt_t * R_{t+1},
    bootstrapped from ``last_value``. Shapes as ``gae_ref``."""
    nonterm = 1.0 - dones.astype(jnp.float32)

    def step(carry, xs):
        r, nt = xs
        ret = r + gamma * nt * carry
        return ret, ret

    _, rets = jax.lax.scan(step, last_value, (rewards, nonterm),
                           reverse=True)
    return rets
