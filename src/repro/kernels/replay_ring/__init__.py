from repro.kernels.replay_ring.ops import (  # noqa: F401
    ring_gather,
    ring_insert,
)
from repro.kernels.replay_ring.ref import (  # noqa: F401
    ring_gather_ref,
    ring_insert_ref,
)
from repro.kernels.replay_ring.replay_ring_pallas import (  # noqa: F401
    ring_gather_pallas,
    ring_insert_pallas,
)
