"""Dispatching public ops for the replay-ring kernel family.

Dict-of-leaves layout, exactly as ``data/replay.py`` stores it: each
leaf is ``(capacity, ...)``. The pallas path flattens trailing dims to
one feature axis per leaf and launches one fused kernel per leaf; the
ref path forwards to the oracle scatter/gather untouched, keeping the
CPU-default resolution bitwise-identical to the pre-plane behavior.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax.numpy as jnp

from repro.kernels import select
from repro.kernels.replay_ring.ref import ring_gather_ref, ring_insert_ref
from repro.kernels.replay_ring.replay_ring_pallas import (
    ring_gather_pallas,
    ring_insert_pallas,
)


def _as2d(x: jnp.ndarray) -> jnp.ndarray:
    return x.reshape(x.shape[0], -1)


def ring_insert(storage: Dict[str, jnp.ndarray],
                batch: Dict[str, jnp.ndarray], start: jnp.ndarray, *,
                impl: Optional[str] = None) -> Dict[str, jnp.ndarray]:
    """Scatter-insert (N, ...) transitions at the ring head (wraps)."""
    name, interpret = select.resolve(impl)
    if name == "ref":
        return ring_insert_ref(storage, batch, start)
    return {
        k: ring_insert_pallas(_as2d(storage[k]),
                              _as2d(batch[k]).astype(storage[k].dtype),
                              start, interpret=interpret)
        .reshape(storage[k].shape)
        for k in storage
    }


def ring_gather(storage: Dict[str, jnp.ndarray], idx: jnp.ndarray, *,
                impl: Optional[str] = None) -> Dict[str, jnp.ndarray]:
    """Draw the rows at ``idx`` (B,) from every leaf."""
    name, interpret = select.resolve(impl)
    if name == "ref":
        return ring_gather_ref(storage, idx)
    return {
        k: ring_gather_pallas(_as2d(v), idx, interpret=interpret)
        .reshape((idx.shape[0],) + v.shape[1:])
        for k, v in storage.items()
    }
