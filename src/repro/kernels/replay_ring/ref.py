"""Pure-jnp oracles for the replay-ring family.

Exactly the scatter/gather the historical ``data/replay.py`` /
``data/buffers.py`` paths performed — ``ring_insert_ref`` is the body of
``replay.add_batch``, ``ring_gather_ref`` the ``{k: v[idx]}`` minibatch
draw — so the ref selection (the CPU default) is bitwise-identical to
the pre-kernel-plane behavior.
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp


def ring_insert_ref(storage: Dict[str, jnp.ndarray],
                    batch: Dict[str, jnp.ndarray],
                    start: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Write (N, ...) rows at the ring head (wraps around; duplicates
    resolve last-write-wins, matching in-order scatter)."""
    cap = next(iter(storage.values())).shape[0]
    n = next(iter(batch.values())).shape[0]
    idx = (start + jnp.arange(n)) % cap
    return {k: storage[k].at[idx].set(batch[k]) for k in storage}


def ring_gather_ref(storage: Dict[str, jnp.ndarray],
                    idx: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Draw the rows at ``idx`` from every leaf."""
    return {k: v[idx] for k, v in storage.items()}
