"""Fused replay-ring Pallas kernels for TPU.

The uniform ring's two hot paths as single kernel launches per storage
leaf (leaves are 2D ``(capacity, features)`` tiles; the ops layer
flattens trailing dims):

* ``ring_insert_pallas`` — scatter-insert N transitions at the write
  head with wraparound, rows streamed through VMEM in one launch instead
  of an XLA scatter per leaf. Sequential row writes make duplicate
  positions (N > capacity) resolve last-write-wins, matching the
  reference's in-order scatter.
* ``ring_gather_pallas`` — the stratified/uniform minibatch draw: B
  dynamic row gathers in one launch.

Both kernels only move bytes — no arithmetic — so parity with the
reference is exact for every dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _insert_kernel(start_ref, storage_ref, batch_ref, out_ref, *,
                   cap: int, n: int):
    out_ref[...] = storage_ref[...]
    start = start_ref[0, 0]

    def write(j, _):
        pos = (start + j) % cap
        out_ref[pl.ds(pos, 1), :] = batch_ref[pl.ds(j, 1), :]
        return 0

    jax.lax.fori_loop(0, n, write, 0)


def _gather_kernel(idx_ref, storage_ref, out_ref, *, batch: int):
    def read(j, _):
        out_ref[pl.ds(j, 1), :] = storage_ref[pl.ds(idx_ref[0, j], 1), :]
        return 0

    jax.lax.fori_loop(0, batch, read, 0)


def ring_insert_pallas(storage: jnp.ndarray, batch: jnp.ndarray,
                       start: jnp.ndarray, *, interpret: bool = True
                       ) -> jnp.ndarray:
    """storage (cap, D), batch (n, D) same dtype, start scalar int ->
    updated storage."""
    cap, feat = storage.shape
    n = batch.shape[0]
    kernel = functools.partial(_insert_kernel, cap=cap, n=n)
    return pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((1, 1), lambda i: (0, 0)),
                  pl.BlockSpec((cap, feat), lambda i: (0, 0)),
                  pl.BlockSpec((n, feat), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((cap, feat), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((cap, feat), storage.dtype),
        # the ring is the canonical donate-in-place buffer: alias storage
        # (operand 1) to the output so the update never doubles HBM
        input_output_aliases={1: 0},
        interpret=interpret,
    )(jnp.asarray(start, jnp.int32).reshape(1, 1), storage, batch)


def ring_gather_pallas(storage: jnp.ndarray, idx: jnp.ndarray, *,
                       interpret: bool = True) -> jnp.ndarray:
    """storage (cap, D), idx (B,) int32 -> rows (B, D)."""
    cap, feat = storage.shape
    B = idx.shape[0]
    kernel = functools.partial(_gather_kernel, batch=B)
    return pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((1, B), lambda i: (0, 0)),
                  pl.BlockSpec((cap, feat), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((B, feat), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, feat), storage.dtype),
        interpret=interpret,
    )(idx[None, :].astype(jnp.int32), storage)
