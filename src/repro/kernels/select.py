"""Kernel-plane implementation selection: ``ref`` | ``pallas`` | ``auto``.

Every RL hot-loop kernel family (``gae``, ``sum_tree``, ``replay_ring``,
``env_step``) ships a pure-JAX reference and a Pallas kernel behind one
``ops.py`` dispatcher. Which implementation a dispatcher traces is
decided here:

* ``ref``    — always the pure-JAX oracle. The default resolution on
  CPU, and the implementation every bitwise guarantee in the test suite
  (``ppo`` × ``inline`` legacy identity, ``fused == stepped``) is stated
  against.
* ``pallas`` — always the Pallas kernel. On an accelerator (TPU via
  Mosaic, GPU via Triton) the kernel compiles; on CPU it runs in
  interpret mode (a correctness harness, not a timing one), so parity
  tests exercise the real kernel bodies on CPU CI.
* ``auto``   — ``pallas`` compiled on TPU *and* GPU, ``ref`` on CPU.
  The default: experiments pick up the kernels exactly where they pay
  off and stay on the oracle (and bitwise-stable) elsewhere.

The selection table (backend × mode -> implementation, interpret flag):

    mode     cpu               tpu / gpu
    ref      ref               ref
    pallas   pallas+interpret  pallas compiled
    auto     ref               pallas compiled

The mode is process-global and read at **trace time**: dispatchers
branch when a train step is traced, so already-jitted callables keep the
implementation they were traced with. Set it before building an
experiment (``ExperimentSpec.kernels`` does this in ``experiment.build``,
``launch/train.py`` exposes it as ``--kernels``), or override per call
with the dispatchers' ``impl=`` argument (how the parity tests and
benchmarks pin both sides).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

MODES = ("ref", "pallas", "auto")

# platforms where Pallas kernels compile to native code: TPU lowers via
# Mosaic, GPU via Triton (jax reports "gpu" for CUDA/ROCm builds, but
# accept the vendor spellings too)
COMPILED_PLATFORMS = ("tpu", "gpu", "cuda", "rocm")

_mode = "auto"


def set_kernel_mode(mode: str) -> str:
    """Set the process-global selection mode; returns the previous one."""
    global _mode
    if mode not in MODES:
        raise ValueError(f"unknown kernel mode {mode!r}; choose from {MODES}")
    prev, _mode = _mode, mode
    return prev


def kernel_mode() -> str:
    return _mode


def resolve(impl: Optional[str] = None) -> Tuple[str, bool]:
    """Resolve a per-call override (or the global mode) to a concrete
    implementation: ``("ref", False)`` or ``("pallas", interpret)``.

    ``interpret`` is True whenever the Pallas kernel would run on a
    platform with no native lowering (CPU) — the interpreter executes
    the kernel body with real JAX ops, so the result is exact but the
    timing is meaningless. On TPU and GPU the kernels compile.
    """
    mode = impl if impl is not None else _mode
    if mode not in MODES:
        raise ValueError(f"unknown kernel impl {mode!r}; choose from {MODES}")
    compiled = jax.default_backend() in COMPILED_PLATFORMS
    if mode == "auto":
        mode = "pallas" if compiled else "ref"
    if mode == "ref":
        return "ref", False
    return "pallas", not compiled
