from repro.kernels.selective_scan.ops import selective_scan  # noqa: F401
from repro.kernels.selective_scan.ref import (  # noqa: F401
    selective_scan_ref,
)
