"""jit'd public wrapper for the selective-scan kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.selective_scan.selective_scan import (
    selective_scan as _kernel_scan,
)


@functools.partial(jax.jit, static_argnames=("d_block", "t_chunk",
                                             "interpret"))
def selective_scan(dt, A, b, c, x, h0, *, d_block: int = 256,
                   t_chunk: int = 128, interpret: bool = True):
    return _kernel_scan(dt, A, b, c, x, h0, d_block=d_block,
                        t_chunk=t_chunk, interpret=interpret)
