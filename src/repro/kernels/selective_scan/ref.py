"""Pure-jnp oracle for the selective scan: naive sequential recurrence."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def selective_scan_ref(dt: jnp.ndarray, A: jnp.ndarray, b: jnp.ndarray,
                       c: jnp.ndarray, x: jnp.ndarray, h0: jnp.ndarray
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Step-by-step recurrence: h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t;
    y_t = C_t . h_t. All f32. Shapes as kernels.selective_scan."""

    def step(h, xs):
        dt_t, b_t, c_t, x_t = xs                      # (B,Di),(B,N),(B,N),(B,Di)
        abar = jnp.exp(dt_t[..., None] * A)           # (B,Di,N)
        bx = (dt_t * x_t)[..., None] * b_t[:, None, :]
        h = abar * h + bx
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    tm = lambda t: jnp.moveaxis(t, 1, 0)
    h, ys = jax.lax.scan(step, h0, (tm(dt), tm(b), tm(c), tm(x)))
    return jnp.moveaxis(ys, 0, 1), h
