"""Selective-scan (Mamba1) kernel for TPU (Pallas).

The SSM sampler hot-spot (falcon-mamba / hymba). Channels are tiled into
``d_block``-wide lanes; time is cut into ``t_chunk`` chunks along the
sequential last grid axis with the recurrent state ``h (d_block, N)``
persisted in VMEM scratch across chunks — the TPU-native analogue of the
CUDA kernel's register-resident state, re-thought for the HBM->VMEM->VREG
hierarchy: each grid step streams one (t_chunk x d_block) tile of
dt/x plus one (t_chunk x N) tile of B/C through VMEM and walks the chunk
with an in-VMEM ``fori_loop``.

Discretisation (Abar = exp(dt*A), Bx = dt*B*x) happens inside the kernel so
the (S, D, N) tensor never exists in HBM.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(dt_ref, a_ref, b_ref, c_ref, x_ref, h0_ref, y_ref, hout_ref,
            h_ref, *, t_chunk: int, num_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)      # (bd, N)

    a = a_ref[...].astype(jnp.float32)                  # (bd, N)

    def step(t, h):
        dt_t = dt_ref[0, t].astype(jnp.float32)         # (bd,)
        x_t = x_ref[0, t].astype(jnp.float32)           # (bd,)
        b_t = b_ref[0, t].astype(jnp.float32)           # (N,)
        c_t = c_ref[0, t].astype(jnp.float32)           # (N,)
        abar = jnp.exp(dt_t[:, None] * a)               # (bd, N)
        bx = (dt_t * x_t)[:, None] * b_t[None, :]
        h = abar * h + bx
        y_ref[0, t] = jnp.sum(h * c_t[None, :], axis=1).astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, t_chunk, step, h_ref[...])
    h_ref[...] = h

    @pl.when(ic == num_chunks - 1)
    def _finalize():
        hout_ref[0] = h.astype(hout_ref.dtype)


def selective_scan(dt: jnp.ndarray, A: jnp.ndarray, b: jnp.ndarray,
                   c: jnp.ndarray, x: jnp.ndarray, h0: jnp.ndarray, *,
                   d_block: int = 256, t_chunk: int = 128,
                   interpret: bool = True
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """dt/x (B,S,Di) f32, A (Di,N), b/c (B,S,N), h0 (B,Di,N).

    Returns (y (B,S,Di) f32, h_final (B,Di,N) f32).
    """
    B, S, Di = x.shape
    N = A.shape[-1]
    d_block = min(d_block, Di)
    t_chunk = min(t_chunk, S)
    assert Di % d_block == 0 and S % t_chunk == 0, (Di, d_block, S, t_chunk)
    nd, nc = Di // d_block, S // t_chunk

    kernel = functools.partial(_kernel, t_chunk=t_chunk, num_chunks=nc)

    y, h_final = pl.pallas_call(
        kernel,
        grid=(B, nd, nc),
        in_specs=[
            pl.BlockSpec((1, t_chunk, d_block),
                         lambda bi, di, ci: (bi, ci, di)),     # dt
            pl.BlockSpec((d_block, N), lambda bi, di, ci: (di, 0)),  # A
            pl.BlockSpec((1, t_chunk, N),
                         lambda bi, di, ci: (bi, ci, 0)),      # B
            pl.BlockSpec((1, t_chunk, N),
                         lambda bi, di, ci: (bi, ci, 0)),      # C
            pl.BlockSpec((1, t_chunk, d_block),
                         lambda bi, di, ci: (bi, ci, di)),     # x
            pl.BlockSpec((1, d_block, N),
                         lambda bi, di, ci: (bi, di, 0)),      # h0
        ],
        out_specs=[
            pl.BlockSpec((1, t_chunk, d_block),
                         lambda bi, di, ci: (bi, ci, di)),     # y
            pl.BlockSpec((1, d_block, N),
                         lambda bi, di, ci: (bi, di, 0)),      # h_final
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, Di), jnp.float32),
            jax.ShapeDtypeStruct((B, Di, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d_block, N), jnp.float32)],
        interpret=interpret,
    )(dt, A, b, c, x, h0)
    return y, h_final
