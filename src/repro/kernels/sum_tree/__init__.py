from repro.kernels.sum_tree.ops import (  # noqa: F401
    sumtree_find_batch,
    sumtree_update,
    tree_flatten,
    tree_unflatten,
)
from repro.kernels.sum_tree.ref import (  # noqa: F401
    SumTree,
    sumtree_build,
    sumtree_find,
    sumtree_find_batch_ref,
    sumtree_update_masked,
    sumtree_update_ref,
)
from repro.kernels.sum_tree.sum_tree_pallas import (  # noqa: F401
    sumtree_find_pallas,
    sumtree_update_pallas,
)
