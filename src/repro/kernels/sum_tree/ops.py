"""Dispatching public ops for the sum-tree kernel family.

The state type stays the registry-visible ``SumTree`` (a tuple of
per-level arrays — the pytree every buffer carry already flows through);
the pallas path flattens it to the kernels' concatenated layout at the
call boundary and splits the result back. Selection follows
``kernels.select`` (``impl=`` overrides per call); the ref path forwards
to the oracles untouched, keeping the CPU default bitwise-identical to
the historical ``data/buffers.py`` descent.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels import select
from repro.kernels.sum_tree.ref import (
    SumTree,
    sumtree_find_batch_ref,
    sumtree_update_ref,
)
from repro.kernels.sum_tree.sum_tree_pallas import (
    level_offsets,
    level_sizes,
    sumtree_find_pallas,
    sumtree_update_pallas,
)


def tree_flatten(tree: SumTree) -> jnp.ndarray:
    """Concatenate levels leaves-first into the kernels' flat layout."""
    return jnp.concatenate(list(tree.levels))


def tree_unflatten(flat: jnp.ndarray, capacity: int) -> SumTree:
    sizes = level_sizes(capacity)
    offsets = level_offsets(sizes)
    return SumTree(tuple(flat[off:off + size]
                         for off, size in zip(offsets, sizes)))


def sumtree_find_batch(tree: SumTree, masses: jnp.ndarray, *,
                       impl: Optional[str] = None) -> jnp.ndarray:
    """Stratified descent for a batch of masses -> leaf indices (B,)."""
    name, interpret = select.resolve(impl)
    if name == "ref":
        return sumtree_find_batch_ref(tree, masses)
    capacity = tree.levels[0].shape[0]
    return sumtree_find_pallas(tree_flatten(tree), masses,
                               capacity=capacity, interpret=interpret)


def sumtree_update(tree: SumTree, idx: jnp.ndarray,
                   leaf_values: jnp.ndarray, *,
                   impl: Optional[str] = None) -> SumTree:
    """Batched leaf write-back + parent recomputation."""
    name, interpret = select.resolve(impl)
    if name == "ref":
        return sumtree_update_ref(tree, idx, leaf_values)
    capacity = tree.levels[0].shape[0]
    flat = sumtree_update_pallas(
        tree_flatten(tree), jnp.atleast_1d(idx), jnp.atleast_1d(leaf_values),
        capacity=capacity, interpret=interpret)
    return tree_unflatten(flat, capacity)
