"""Pure-jnp sum-tree reference: the prioritized-replay substrate.

The tree is a tuple of per-level arrays (``levels[0]`` = leaf masses,
one per replay slot, capacity a power of two; ``levels[-1]`` = total) —
a plain pytree, so it lives in jit carries and donated scan state like
any other buffer array. These are the oracle implementations the Pallas
kernels are held bitwise-equal to; ``repro.data.buffers`` re-exports
them as its sum-tree API.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax.numpy as jnp


class SumTree(NamedTuple):
    """A binary sum-tree as a tuple of per-level arrays.

    ``levels[0]`` are the leaf masses (one per replay slot, capacity a
    power of two); ``levels[k]`` holds pairwise sums of ``levels[k-1]``;
    ``levels[-1]`` is the total mass ``(1,)``. A static tuple of arrays is
    a plain pytree, so the whole tree lives in jit carries and donated
    scan state like any other buffer array.
    """

    levels: Tuple[jnp.ndarray, ...]

    @property
    def total(self) -> jnp.ndarray:
        return self.levels[-1][0]


def sumtree_build(leaves: jnp.ndarray) -> SumTree:
    levels = [leaves]
    while levels[-1].shape[0] > 1:
        levels.append(levels[-1].reshape(-1, 2).sum(axis=-1))
    return SumTree(tuple(levels))


def sumtree_find(tree: SumTree, mass: jnp.ndarray) -> jnp.ndarray:
    """Descend from the root: the leaf whose prefix-sum interval holds
    ``mass``. The scalar form of ``sumtree_find_batch_ref`` (the descent
    is shape-polymorphic)."""
    return sumtree_find_batch_ref(tree, mass)


def sumtree_find_batch_ref(tree: SumTree, masses: jnp.ndarray
                           ) -> jnp.ndarray:
    """Batched stratified descent: one vectorized gather per level for
    the whole batch (elementwise identical to vmapping ``sumtree_find``,
    without materializing a per-sample descent)."""
    idx = jnp.zeros(masses.shape, jnp.int32)
    for level in tree.levels[-2::-1]:
        idx = idx * 2
        left = level[idx]
        go_right = masses >= left
        masses = jnp.where(go_right, masses - left, masses)
        idx = jnp.where(go_right, idx + 1, idx)
    return idx


def sumtree_update_ref(tree: SumTree, idx: jnp.ndarray,
                       leaf_values: jnp.ndarray) -> SumTree:
    """Set leaf masses at ``idx`` and recompute only the touched
    root-to-leaf paths — O(B log capacity) instead of an O(capacity)
    rebuild. Duplicate indices are safe: parents are recomputed from the
    post-scatter children, so every write of a parent stores the same
    (consistent) sum regardless of which duplicate leaf write won."""
    levels = list(tree.levels)
    levels[0] = levels[0].at[idx].set(leaf_values)
    child = idx
    for k in range(len(levels) - 1):
        parent = child // 2
        sums = levels[k][2 * parent] + levels[k][2 * parent + 1]
        levels[k + 1] = levels[k + 1].at[parent].set(sums)
        child = parent
    return SumTree(tuple(levels))


def sumtree_update_masked(tree: SumTree, idx: jnp.ndarray,
                          leaf_values: jnp.ndarray,
                          mask: jnp.ndarray) -> SumTree:
    """``sumtree_update_ref`` that only applies rows where ``mask`` is
    True — the sharded-replay form, where every shard sees the full
    (replicated) priority batch but owns only a slice of the leaves.

    Masked-out rows scatter to index ``capacity`` with ``mode="drop"``
    (silently discarded), then walk leaf 0's root path, whose parents are
    recomputed from the post-scatter children — i.e. rewritten with the
    values they already hold. With ``mask`` all-True this is elementwise
    identical to ``sumtree_update_ref``.
    """
    cap = tree.levels[0].shape[0]
    levels = list(tree.levels)
    drop_idx = jnp.where(mask, idx, cap)
    levels[0] = levels[0].at[drop_idx].set(leaf_values, mode="drop")
    child = jnp.where(mask, idx, 0)
    for k in range(len(levels) - 1):
        parent = child // 2
        sums = levels[k][2 * parent] + levels[k][2 * parent + 1]
        levels[k + 1] = levels[k + 1].at[parent].set(sums)
        child = parent
    return SumTree(tuple(levels))
