"""Fused sum-tree Pallas kernels for TPU.

Prioritized replay's two hot paths, each as one kernel launch over a
*flat* tree layout (all levels concatenated leaves-first — offsets are
static, derived from the capacity):

* ``sumtree_find_pallas``  — the full stratified root-to-leaf descent
  for a batch of B masses. The tree lives in VMEM for the whole walk
  (O(2·cap) floats — the only large buffer) and each sample walks
  root-to-leaf with ``log2(cap)`` scalar reads, so the launch does
  O(B·log cap) work instead of ``log2(cap)`` separately scheduled
  host-side gathers.
* ``sumtree_update_pallas`` — the batched priority write-back: a
  sequential last-write-wins leaf scatter (matching XLA's in-order
  ``.at[idx].set`` semantics under duplicates) followed by a pairwise
  rebuild of every parent level while the leaves are still in VMEM.
  Assumes the input tree is consistent (every parent the pairwise sum of
  its children — guaranteed by construction), in which case the rebuild
  is bitwise-identical to the reference's touched-path recomputation.

Both kernels evaluate the reference expressions exactly, so parity tests
assert equality, not closeness.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def level_sizes(capacity: int) -> Tuple[int, ...]:
    """Static per-level lengths of the flat layout, leaves first."""
    if capacity & (capacity - 1):
        raise ValueError(f"sum-tree capacity must be a power of two, "
                         f"got {capacity}")
    sizes = []
    n = capacity
    while n >= 1:
        sizes.append(n)
        if n == 1:
            break
        n //= 2
    return tuple(sizes)


def level_offsets(sizes: Sequence[int]) -> Tuple[int, ...]:
    offs, off = [], 0
    for s in sizes:
        offs.append(off)
        off += s
    return tuple(offs)


def _find_kernel(flat_ref, m_ref, idx_ref, *, sizes, offsets, batch: int):
    num_levels = len(sizes)

    def walk(j, _):
        idx = jnp.zeros((), jnp.int32)
        mass = m_ref[0, j]
        for k in range(num_levels - 2, -1, -1):
            idx = idx * 2
            left = flat_ref[0, offsets[k] + idx]
            go_right = mass >= left
            mass = jnp.where(go_right, mass - left, mass)
            idx = jnp.where(go_right, idx + 1, idx)
        idx_ref[0, j] = idx
        return 0

    jax.lax.fori_loop(0, batch, walk, 0)


def _update_kernel(flat_ref, idx_ref, vals_ref, out_ref, *, sizes, offsets,
                   batch: int):
    cap = sizes[0]
    pos = jax.lax.broadcasted_iota(jnp.int32, (1, cap), 1)
    leaves = flat_ref[:, 0:cap]

    def write(j, leaves):
        return jnp.where(pos == idx_ref[0, j], vals_ref[0, j], leaves)

    leaves = jax.lax.fori_loop(0, batch, write, leaves)
    out_ref[:, 0:cap] = leaves
    child = leaves
    for k in range(1, len(sizes)):
        child = child[:, 0::2] + child[:, 1::2]
        out_ref[:, offsets[k]:offsets[k] + sizes[k]] = child


def sumtree_find_pallas(flat: jnp.ndarray, masses: jnp.ndarray, *,
                        capacity: int, interpret: bool = True
                        ) -> jnp.ndarray:
    """flat (2*cap-1,) f32 (leaves-first levels), masses (B,) f32
    -> leaf indices (B,) int32."""
    sizes = level_sizes(capacity)
    offsets = level_offsets(sizes)
    (total,) = flat.shape
    B = masses.shape[0]
    kernel = functools.partial(_find_kernel, sizes=sizes, offsets=offsets,
                               batch=B)
    idx = pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((1, total), lambda i: (0, 0)),
                  pl.BlockSpec((1, B), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, B), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, B), jnp.int32),
        interpret=interpret,
    )(flat[None, :], masses[None, :])
    return idx[0]


def sumtree_update_pallas(flat: jnp.ndarray, idx: jnp.ndarray,
                          leaf_values: jnp.ndarray, *, capacity: int,
                          interpret: bool = True) -> jnp.ndarray:
    """flat (2*cap-1,) f32, idx (B,) int32, leaf_values (B,) f32
    -> updated flat tree."""
    sizes = level_sizes(capacity)
    offsets = level_offsets(sizes)
    (total,) = flat.shape
    B = idx.shape[0]
    kernel = functools.partial(_update_kernel, sizes=sizes,
                               offsets=offsets, batch=B)
    out = pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((1, total), lambda i: (0, 0)),
                  pl.BlockSpec((1, B), lambda i: (0, 0)),
                  pl.BlockSpec((1, B), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, total), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, total), jnp.float32),
        interpret=interpret,
    )(flat[None, :], idx[None, :].astype(jnp.int32),
      leaf_values[None, :].astype(jnp.float32))
    return out[0]
