# Launchers: mesh construction, multi-pod dry-run, training/serving drivers.
# NOTE: do not import dryrun here — it sets XLA_FLAGS at import time and is
# only meant to be run as a __main__ entry point.
from repro.launch import mesh, specs  # noqa: F401
