import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

The two lines above MUST precede any jax import (jax locks the device count
at first init): they materialise 512 placeholder host devices so the
production meshes (16x16 single-pod, 2x16x16 multi-pod) can be built.
Nothing is ever allocated — inputs are ShapeDtypeStructs and the artifact
is ``lowered.compile()``'s memory/cost analysis plus the collective
schedule parsed from the partitioned HLO.

Usage:
  python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.algos.ppo import PPOConfig, make_lm_train_step
from repro.configs import INPUT_SHAPES, ASSIGNED_ARCHS, get_config, \
    supports_shape
from repro.distributed import context as dist_ctx
from repro.distributed import sharding as sh
from repro.launch import specs as specs_mod
from repro.launch.hlo_analysis import collective_summary
from repro.launch.mesh import make_production_mesh
from repro.models import transformer
from repro.optim import adam


# ------------------------------------------------------------- lowering
def build_step(cfg, shape, mesh, spec):
    """Return (fn, args, in_shardings, out_shardings, donate, mode)."""
    mode = "serve" if spec["kind"] == "decode" else "train"
    pshapes = specs_mod.params_shapes(cfg)
    pspecs = sh.param_specs(cfg, pshapes, mesh, mode)

    if spec["kind"] == "train":
        opt = adam(3e-4, moment_dtype=cfg.dtype)
        step = make_lm_train_step(cfg, opt, PPOConfig())
        opt_shapes = jax.eval_shape(opt.init, pshapes)
        opt_specs = type(opt_shapes)(
            jax.sharding.PartitionSpec(), pspecs, pspecs)
        metrics_specs = {k: jax.sharding.PartitionSpec() for k in
                         ("loss", "pg_loss", "v_loss", "entropy", "aux",
                          "grad_norm")}
        return (step,
                (pshapes, opt_shapes) + spec["args"],
                (pspecs, opt_specs) + spec["arg_specs"],
                (pspecs, opt_specs, metrics_specs),
                (0, 1), mode)

    if spec["kind"] == "prefill":
        n_extra = len(spec["args"])

        def fn(params, *rest):
            tokens = rest[0]
            extra = rest[1] if cfg.frontend_embeds else None
            positions = rest[-1] if cfg.m_rope_sections else None
            return transformer.prefill(cfg, params, tokens, gen_budget=0,
                                       positions=positions,
                                       extra_embeds=extra)

        return (fn, (pshapes,) + spec["args"],
                (pspecs,) + spec["arg_specs"], spec["out_specs"], (), mode)

    def fn(params, state, token):
        return transformer.decode_step(cfg, params, state, token)

    return (fn, (pshapes,) + spec["args"],
            (pspecs,) + spec["arg_specs"], spec["out_specs"], (1,), mode)


def dryrun_one(arch: str, shape_name: str, multi_pod: bool = False,
               verbose: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    ok, why = supports_shape(cfg, shape)
    result: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    if not ok:
        result["status"] = "skipped"
        result["reason"] = why
        return result

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    spec = specs_mod.input_specs(cfg, shape, mesh)
    fn, args, in_specs, out_specs, donate, mode = build_step(cfg, shape,
                                                             mesh, spec)
    in_sh = sh.to_shardings(mesh, in_specs)
    out_sh = sh.to_shardings(mesh, out_specs)
    with mesh, dist_ctx.use_mesh(mesh, mode):
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_summary(hlo)
    result.update({
        "status": "ok",
        "lower_seconds": round(t_lower, 1),
        "compile_seconds": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            # NOTE: XLA-CPU temp_size sums allocations (reuse not deducted);
            # treat as an upper bound on live temps
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": mem.peak_memory_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "generated_code_bytes": mem.generated_code_size_in_bytes,
        },
        "flops_per_device_unweighted": cost.get("flops", -1.0),
        "bytes_accessed_per_device_unweighted": cost.get("bytes accessed",
                                                         -1.0),
        "dot_flops_per_device": coll.pop("dot_flops"),
        "collectives": coll,
        "hlo_bytes": len(hlo),
    })
    if verbose:
        mm = result["memory"]
        print(f"[{arch} x {shape_name} x {result['mesh']}] "
              f"compile={t_compile:.0f}s "
              f"args/dev={mm['argument_bytes']/2**30:.2f}GiB "
              f"temp/dev={mm['temp_bytes']/2**30:.2f}GiB "
              f"dotflops/dev={result['dot_flops_per_device']:.3e} "
              f"coll/dev={coll['total_bytes']/2**30:.3f}GiB")
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    combos = []
    archs = ASSIGNED_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                combos.append((arch, shape, mp))

    failures = 0
    for arch, shape, mp in combos:
        tag = f"{arch}_{shape}_{'mp' if mp else 'sp'}"
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path):
            print(f"[skip existing] {tag}")
            continue
        try:
            res = dryrun_one(arch, shape, multi_pod=mp)
        except Exception as e:
            traceback.print_exc()
            res = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if mp else "16x16",
                   "status": "error", "error": f"{type(e).__name__}: {e}"}
            failures += 1
        with open(path, "w") as f:
            json.dump(res, f, indent=2)
    print(f"done; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
