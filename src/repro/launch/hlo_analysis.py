"""Collective-schedule extraction from partitioned HLO text.

``cost_analysis`` gives FLOPs and HBM bytes but NOT collective traffic, so
the roofline's third term is derived here: walk the HLO call graph from the
entry computation, summing the moved bytes of every all-gather / all-reduce
/ reduce-scatter / all-to-all / collective-permute — **multiplying by while-
loop trip counts** (a collective inside the layer-scan body runs n_layers
times; counting the static instruction once would undercount by ~100x for
llama3-405b).

Moved-bytes model per participating device (ring algorithms):
  all-gather       (n-1)/n * result_bytes
  all-reduce       2 (n-1)/n * bytes
  reduce-scatter   (n-1) * result_bytes        (operand = n * result)
  all-to-all       (n-1)/n * bytes
  collective-permute  bytes
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

# computation headers start at column 0 and end with '{':
#   %region_0.66 (param: (s32[], ...)) -> (...) {     |  ENTRY %main.1 (...) {
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\-.]+)\s*\(.*\{\s*$")
_SHAPE_RE = re.compile(r"([a-z]\w*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"=\s*(?P<type>\([^=]*?\)|[a-z]\w*\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(?P<op>[\w\-]+)\(")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_WHILE_RE = re.compile(r"while\(.*condition=%?([\w\-.]+).*body=%?([\w\-.]+)",
                       re.S)
_CALL_RE = re.compile(r"(?:to_apply|calls)=%?([\w\-.]+)")
_CONST_RE = re.compile(r"[su]\d+\[\]\s+constant\((\d+)\)")


def _bytes_of(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _dot_flops(line: str, result_type: str, shapes: Dict) -> float:
    """2 * result_elements * contracted_size for one dot instruction."""
    m = _SHAPE_RE.findall(result_type)
    if not m:
        return 0.0
    relems = 1
    for d in m[0][1].split(","):
        if d:
            relems *= int(d)
    lhs = _DOT_LHS.search(line)
    cd = _DOT_CDIMS.search(line)
    if not lhs or not cd:
        return 0.0
    lshape = shapes.get(lhs.group(1))
    if lshape is None:
        return 0.0
    k = 1
    for i in cd.group(1).split(","):
        if i and int(i) < len(lshape):
            k *= lshape[int(i)]
    return 2.0 * relems * k


def _moved_bytes(op: str, size: int, n: int) -> float:
    n = max(n, 2)
    if op == "all-gather":
        return size * (n - 1) / n
    if op == "all-reduce":
        return 2 * size * (n - 1) / n
    if op == "reduce-scatter":
        return size * (n - 1)
    if op == "all-to-all":
        return size * (n - 1) / n
    return float(size)          # collective-permute


_DOT_LHS = re.compile(r"dot\(%?([\w\-.]+),")
_DOT_CDIMS = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_RESULT_NAME = re.compile(r"^(?:ROOT\s+)?%?([\w\-.]+)\s*=")


class _Comp:
    def __init__(self, name: str):
        self.name = name
        self.collectives: List[Tuple[str, float]] = []   # (op, moved bytes)
        self.coll_counts: Dict[str, int] = defaultdict(int)
        self.whiles: List[Tuple[str, str]] = []          # (cond, body)
        self.calls: List[str] = []
        self.max_const: int = 0
        self.flops: float = 0.0
        self.shapes: Dict[str, Tuple[int, ...]] = {}     # instr -> dims


def _parse_computations(text: str) -> Tuple[Dict[str, _Comp], Optional[str]]:
    comps: Dict[str, _Comp] = {}
    cur: Optional[_Comp] = None
    entry: Optional[str] = None
    for raw in text.splitlines():
        line = raw.strip()
        if raw and not raw[0].isspace():
            hdr = _COMP_HDR.match(raw)
            if hdr:
                cur = _Comp(hdr.group(1))
                comps[cur.name] = cur
                if raw.startswith("ENTRY"):
                    entry = cur.name
                continue
        if cur is None or not line:
            continue
        m = _CONST_RE.search(line)
        if m:
            cur.max_const = max(cur.max_const, int(m.group(1)))
        if " while(" in line or line.startswith("while("):
            w = _WHILE_RE.search(line)
            if w:
                cur.whiles.append((w.group(1), w.group(2)))
            continue
        mi = _INSTR_RE.search(line)
        if mi:
            op = mi.group("op")
            # record result shape for dot-FLOP lookups
            nm = _RESULT_NAME.match(line)
            if nm:
                dims = _SHAPE_RE.findall(mi.group("type"))
                if len(dims) == 1:
                    ds = tuple(int(d) for d in dims[0][1].split(",") if d)
                    cur.shapes[nm.group(1)] = ds
            if op == "dot":
                cur.flops += _dot_flops(line, mi.group("type"), cur.shapes)
            base = op[:-6] if op.endswith("-start") else op
            if base in _COLL_OPS:
                size = _bytes_of(mi.group("type"))
                gi = _GROUPS_IOTA.search(line)
                if gi:
                    n = int(gi.group(2))
                else:
                    gl = _GROUPS_LIST.search(line)
                    n = len(gl.group(1).split(",")) if gl else 2
                is_f32 = mi.group("type").lstrip("(").startswith("f32")
                cur.collectives.append((base, _moved_bytes(base, size, n),
                                        is_f32))
                cur.coll_counts[base] += 1
                continue
            if op in ("call", "conditional", "fusion"):
                for callee in _CALL_RE.findall(line):
                    cur.calls.append(callee)
    return comps, entry


def collective_summary(text: str) -> Dict:
    """Per-device collective bytes + dot FLOPs, trip-count weighted."""
    comps, entry = _parse_computations(text)
    bytes_by_type: Dict[str, float] = defaultdict(float)
    counts: Dict[str, int] = defaultdict(int)
    total = {"flops": 0.0, "f32_bytes": 0.0}
    visiting = set()

    def walk(name: str, mult: float) -> None:
        comp = comps.get(name)
        if comp is None or name in visiting:
            return
        visiting.add(name)
        for op, moved, is_f32 in comp.collectives:
            bytes_by_type[op] += moved * mult
            if is_f32:
                total["f32_bytes"] += moved * mult
        for op, c in comp.coll_counts.items():
            counts[op] += int(c * mult)
        total["flops"] += comp.flops * mult
        for cond, body in comp.whiles:
            trip = comps[cond].max_const if cond in comps else 1
            trip = max(trip, 1)
            walk(cond, mult)
            walk(body, mult * trip)
        for callee in comp.calls:
            walk(callee, mult)
        visiting.discard(name)

    if entry:
        walk(entry, 1.0)
    else:                       # fallback: flat sum, no trip weighting
        for comp in comps.values():
            for op, moved, is_f32 in comp.collectives:
                bytes_by_type[op] += moved
                if is_f32:
                    total["f32_bytes"] += moved
            total["flops"] += comp.flops
    grand = float(sum(bytes_by_type.values()))
    return {"bytes_by_type": dict(bytes_by_type),
            "counts": dict(counts),
            "total_bytes": grand,
            # XLA-CPU upcasts bf16 dot operands to f32 *before* SPMD
            # partitioning, inflating gathers 2x vs a TPU lowering (which
            # keeps bf16 through the collective). bf16-equivalent halves
            # the f32 share — use this for the roofline collective term.
            "total_bytes_bf16eq": grand - 0.5 * total["f32_bytes"],
            "f32_bytes": total["f32_bytes"],
            "dot_flops": total["flops"]}
