"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state. Single pod: 16x16 = 256 chips (v5e pod),
axes (data, model). Multi-pod: 2x16x16 = 512 chips, axes (pod, data, model);
the ``pod`` axis extends the data/sampler axis across the DCN/ICI boundary.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import math
    need = math.prod(shape)
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, found {len(devices)}; "
            "the dry-run launcher must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import (see launch/dryrun.py)")
    if len(devices) == need:
        return jax.make_mesh(shape, axes)
    import numpy as np
    from jax.sharding import Mesh
    arr = np.asarray(devices[:need]).reshape(shape)
    return Mesh(arr, axes)


def make_learner_mesh(num_devices: int, pods: int = 1, offset: int = 0):
    """The learner plane's mesh: ``(data, model)`` over ``num_devices``
    devices, or ``(pod, data, model)`` when ``pods > 1`` — the same axis
    names as ``make_production_mesh``, so a step built here lowers
    unchanged on the multi-pod production mesh (the ``pod`` axis extends
    the data axis across the DCN boundary; ``fsdp_axes`` spans both).

    ``offset`` starts the mesh at ``jax.devices()[offset:]`` — the overlap
    pipeline places the learner on devices disjoint from the rollout's
    device 0 so collect and learn genuinely execute concurrently.
    """
    import numpy as np
    from jax.sharding import Mesh
    devices = jax.devices()
    if offset + num_devices > len(devices):
        offset = max(0, len(devices) - num_devices)
    if num_devices > len(devices):
        raise ValueError(
            f"learner_devices={num_devices} but only {len(devices)} JAX "
            f"device(s) are visible; on CPU set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={num_devices} "
            f"before importing jax")
    if pods > 1 and num_devices % pods:
        raise ValueError(
            f"learner_pods={pods} must divide learner_devices="
            f"{num_devices}")
    arr = np.asarray(devices[offset:offset + num_devices])
    if pods > 1:
        return Mesh(arr.reshape(pods, num_devices // pods, 1),
                    ("pod", "data", "model"))
    return Mesh(arr.reshape(num_devices, 1), ("data", "model"))


def make_host_mesh():
    """1-device mesh for CPU smoke tests (no placeholder devices)."""
    import numpy as np
    from jax.sharding import Mesh
    return Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
