"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state. Single pod: 16x16 = 256 chips (v5e pod),
axes (data, model). Multi-pod: 2x16x16 = 512 chips, axes (pod, data, model);
the ``pod`` axis extends the data/sampler axis across the DCN/ICI boundary.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import math
    need = math.prod(shape)
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, found {len(devices)}; "
            "the dry-run launcher must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import (see launch/dryrun.py)")
    if len(devices) == need:
        return jax.make_mesh(shape, axes)
    import numpy as np
    from jax.sharding import Mesh
    arr = np.asarray(devices[:need]).reshape(shape)
    return Mesh(arr, axes)


def make_host_mesh():
    """1-device mesh for CPU smoke tests (no placeholder devices)."""
    import numpy as np
    from jax.sharding import Mesh
    return Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
