"""Serving driver: batched autoregressive generation (the sampler's decode
loop as a standalone service — WALL-E experience collection in isolation).

CPU-runnable with reduced archs:
  PYTHONPATH=src python -m repro.launch.serve --arch hymba-1.5b-reduced \
      --batch 4 --prompt-len 16 --gen-len 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.envs import lm_env
from repro.models import transformer


def generate(cfg, params, prompt, gen_len: int, key, temperature=1.0):
    state, logits = transformer.prefill(cfg, params, prompt,
                                        gen_budget=gen_len)

    def body(carry, key_t):
        state, logits = carry
        tok = jax.random.categorical(key_t, logits / temperature)
        state, logits2 = transformer.decode_step(cfg, params, state,
                                                 tok[:, None])
        return (state, logits2), tok

    keys = jax.random.split(key, gen_len)
    (_, _), toks = jax.lax.scan(body, (state, logits), keys)
    return toks.T                                        # (B, gen_len)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hymba-1.5b-reduced")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--requests", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = transformer.init_params(cfg, key)
    env = lm_env.make(cfg.vocab_size, episode_len=args.gen_len)
    gen = jax.jit(lambda p, t, k: generate(cfg, p, t, args.gen_len, k))

    for r in range(args.requests):
        key, kp, kg = jax.random.split(key, 3)
        prompt = jax.random.randint(kp, (args.batch, args.prompt_len), 0,
                                    cfg.vocab_size)
        t0 = time.perf_counter()
        toks = jax.block_until_ready(gen(params, prompt, kg))
        dt = time.perf_counter() - t0
        rew = env.token_rewards(toks).sum(axis=1)
        tps = args.batch * args.gen_len / dt
        print(f"request {r}: {toks.shape[1]} tokens x {toks.shape[0]} seqs "
              f"in {dt:.2f}s ({tps:.0f} tok/s), "
              f"mean reward {float(rew.mean()):.2f}")


if __name__ == "__main__":
    main()
