"""Policy serving driver: load a trained checkpoint, serve ``act()``
requests with dynamic batching, optionally follow a live params channel.

The train -> deploy story end-to-end (DESIGN.md §8):

  # 1. train and checkpoint
  PYTHONPATH=src python -m repro.launch.train --mode rl --env pendulum \
      --algo ppo --iterations 5 --ckpt-dir /tmp/ckpt
  # 2. serve it: 8 slots, 5 ms batching window, 64 demo requests from
  #    4 concurrent clients, with a live hot-swap mid-traffic
  PYTHONPATH=src python -m repro.launch.serve_policy --ckpt /tmp/ckpt \
      --slots 8 --deadline-ms 5 --requests 64 --clients 4 --swap-after 16

Built-in traffic driver: ``--requests N`` fires N synthetic observations
from ``--clients`` concurrent threads (each a blocking ``act()`` caller)
and prints the serving-stats snapshot as JSON. ``--swap-after K``
exercises the hot-swap protocol in-process: the CLI stands in for a
learner, publishing perturbed params on a ``ParamsChannel`` after K
completions, and exits nonzero unless the server picked up the new
version with every request completed. ``--channel-spec FILE`` instead
attaches to an external learner's channel (the JSON handoff written
with ``ChannelSpec.to_json``).
"""
from __future__ import annotations

import argparse
import concurrent.futures
import json
import os
import sys
import time
import uuid

import jax
import numpy as np

from repro.core.ipc import ChannelSpec, ParamsChannel
from repro.serve import PolicyServer, load_policy


def _drive_traffic(server: PolicyServer, handle, args, channel) -> int:
    """Fire ``--requests`` blocking acts from ``--clients`` threads;
    returns the number of completions observed."""
    rng = np.random.RandomState(args.seed)
    observations = rng.randn(args.requests,
                             handle.env.obs_dim).astype(np.float32)
    publish_at = (args.swap_after
                  if args.swap_after and not args.channel_spec else None)
    done_count = 0

    def one(i):
        return server.act(observations[i], timeout=args.timeout)

    with concurrent.futures.ThreadPoolExecutor(args.clients) as pool:
        futures = [pool.submit(one, i) for i in range(args.requests)]
        for fut in concurrent.futures.as_completed(futures):
            fut.result()                        # propagate request errors
            done_count += 1
            if publish_at is not None and done_count >= publish_at:
                # the CLI doubles as the learner: publish perturbed
                # params mid-traffic, exactly what a training run does
                leaves = [np.asarray(x) * 1.01 for x in
                          jax.tree_util.tree_leaves(handle.params)]
                version = channel.publish(leaves)
                print(f"# published params version {version} after "
                      f"{done_count} completions", file=sys.stderr)
                publish_at = None
    return done_count


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt", required=True,
                    help="checkpoint directory written by launch/train.py "
                         "--ckpt-dir (rl mode; metadata names env+algo)")
    ap.add_argument("--step", type=int, default=None,
                    help="checkpoint step (default: latest)")
    ap.add_argument("--slots", type=int, default=8,
                    help="fixed device batch width per dispatch")
    ap.add_argument("--deadline-ms", type=float, default=5.0,
                    help="max wait of the oldest queued request before a "
                         "partial batch dispatches")
    ap.add_argument("--queue-cap", type=int, default=None,
                    help="admission bound (default 16*slots); a full "
                         "queue rejects with ServerOverloaded")
    ap.add_argument("--requests", type=int, default=64,
                    help="synthetic traffic: total act() requests")
    ap.add_argument("--clients", type=int, default=4,
                    help="concurrent blocking act() client threads")
    ap.add_argument("--timeout", type=float, default=60.0,
                    help="per-request completion timeout (seconds)")
    ap.add_argument("--swap-after", type=int, default=0,
                    help="after this many completions, publish perturbed "
                         "params on a live ParamsChannel and require the "
                         "server to pick up the new version (0: off)")
    ap.add_argument("--channel-spec", default=None,
                    help="attach to an external learner's ParamsChannel: "
                         "path to its ChannelSpec JSON handoff file")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    handle = load_policy(args.ckpt, args.step)
    print(f"# serving {handle.name} from {args.ckpt} "
          f"(obs_dim={handle.env.obs_dim}, act_dim={handle.env.act_dim})",
          file=sys.stderr)

    channel = None
    own_channel = False
    if args.channel_spec:
        with open(args.channel_spec) as f:
            channel = ParamsChannel.attach(ChannelSpec.from_json(f.read()))
        own_channel = True
    elif args.swap_after:
        # in-process learner stand-in for the hot-swap demo/smoke
        leaves = [np.asarray(x)
                  for x in jax.tree_util.tree_leaves(handle.params)]
        channel = ParamsChannel.create(
            leaves, f"walle-serve-{os.getpid()}-{uuid.uuid4().hex[:6]}")
        channel.publish(leaves)                  # version 1 = the ckpt
        own_channel = True

    server = PolicyServer(handle.env, handle.algo, handle.params,
                          slots=args.slots, deadline_ms=args.deadline_ms,
                          queue_cap=args.queue_cap, seed=args.seed,
                          params_channel=channel)
    t0 = time.perf_counter()
    try:
        with server:
            start_version = server.params_version
            completed = _drive_traffic(server, handle, args, channel)
            if args.swap_after and not args.channel_spec:
                # traffic can drain before the publish lands; keep a
                # trickle flowing until the server observes the new
                # version (the pickup itself is what the smoke asserts)
                probe = np.zeros(handle.env.obs_dim, np.float32)
                deadline = time.monotonic() + 30.0
                while (server.params_version <= start_version
                       and time.monotonic() < deadline):
                    server.act(probe, timeout=args.timeout)
        snap = server.snapshot()
        snap["wall_seconds"] = round(time.perf_counter() - t0, 3)
        print(json.dumps(snap, indent=2))
        if completed != args.requests:
            sys.exit(f"FAIL: {completed}/{args.requests} requests "
                     f"completed")
        if args.swap_after and not args.channel_spec:
            if server.params_version <= start_version:
                sys.exit(f"FAIL: params version never advanced past "
                         f"{start_version} despite --swap-after "
                         f"{args.swap_after}")
            print(f"# hot-swap observed: version {start_version} -> "
                  f"{server.params_version}", file=sys.stderr)
    finally:
        if own_channel and channel is not None:
            channel.close(unlink=not args.channel_spec)


if __name__ == "__main__":
    main()
