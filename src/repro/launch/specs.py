"""ShapeDtypeStruct input stands-ins + shardings per (arch x input shape).

``input_specs`` returns weak-type-correct, shardable structs for every model
input of the lowered step — no device allocation ever happens (the full
configs are exercised ONLY through lower/compile).

Step kinds:
* train   -> the PPO learner update (``algos.ppo.make_lm_train_step``)
* prefill -> prompt processing + cache build (``transformer.prefill``)
* decode  -> ONE new token against a ``seq_len`` cache (``decode_step``)
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.distributed import sharding as sh
from repro.models import transformer


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def params_shapes(cfg: ModelConfig):
    return jax.eval_shape(
        lambda: transformer.init_params(cfg, jax.random.PRNGKey(0)))


# ------------------------------------------------------------------ train
def train_batch_shapes(cfg: ModelConfig, shape: InputShape
                       ) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    P_front = cfg.frontend_embeds
    S_tok = S - P_front
    batch = {
        "tokens": _sds((B, S_tok), jnp.int32),
        "targets": _sds((B, S_tok), jnp.int32),
        "behavior_logp": _sds((B, S_tok), jnp.float32),
        "advantages": _sds((B, S_tok), jnp.float32),
        "returns": _sds((B, S_tok), jnp.float32),
        "mask": _sds((B, S_tok), jnp.float32),
    }
    if P_front:
        batch["extra_embeds"] = _sds((B, P_front, cfg.d_model), cfg.dtype)
    if cfg.m_rope_sections:
        total = S_tok + P_front + cfg.n_meta_tokens
        batch["positions"] = _sds((3, B, total), jnp.int32)
    return batch


# ----------------------------------------------------------------- decode
def decode_state_shapes(cfg: ModelConfig, shape: InputShape):
    return jax.eval_shape(
        lambda: transformer.init_decode_state(cfg, shape.global_batch,
                                              shape.seq_len))


def input_specs(cfg: ModelConfig, shape: InputShape, mesh
                ) -> Dict[str, Any]:
    """Everything the dry-run needs for one (arch x shape):

    returns {kind, fn_args (structs), in_specs, out_specs} where fn_args
    excludes params (always first arg; params specs supplied separately).
    """
    B, S = shape.global_batch, shape.seq_len
    bspec = sh.batch_spec(B, mesh)

    if shape.kind == "train":
        batch = train_batch_shapes(cfg, shape)
        specs = sh.train_batch_specs(cfg, batch, mesh)
        return {"kind": "train", "args": (batch,), "arg_specs": (specs,)}

    if shape.kind == "prefill":
        P_front = cfg.frontend_embeds
        S_tok = S - P_front
        args = [_sds((B, S_tok), jnp.int32)]
        arg_specs = [P(bspec[0], None)]
        if P_front:
            args.append(_sds((B, P_front, cfg.d_model), cfg.dtype))
            arg_specs.append(P(bspec[0], None, None))
        if cfg.m_rope_sections:
            total = S_tok + P_front + cfg.n_meta_tokens
            args.append(_sds((3, B, total), jnp.int32))
            arg_specs.append(P(None, bspec[0], None))
        state_shapes = jax.eval_shape(
            lambda: transformer.init_decode_state(cfg, B, S))
        out_state_specs = sh.decode_state_specs(cfg, state_shapes, mesh)
        logits_spec = P(bspec[0],
                        sh.shard_axes(cfg.vocab_size, ("model",), mesh))
        return {"kind": "prefill", "args": tuple(args),
                "arg_specs": tuple(arg_specs),
                "out_specs": (out_state_specs, logits_spec)}

    # decode: serve_step(params, state, token)
    state = decode_state_shapes(cfg, shape)
    state_specs = sh.decode_state_specs(cfg, state, mesh)
    token = _sds((B, 1), jnp.int32)
    token_spec = P(bspec[0], None)
    logits_spec = P(bspec[0],
                    sh.shard_axes(cfg.vocab_size, ("model",), mesh))
    return {"kind": "decode", "args": (state, token),
            "arg_specs": (state_specs, token_spec),
            "out_specs": (state_specs, logits_spec)}
