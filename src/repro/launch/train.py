"""End-to-end training driver.

Two modes, selected by --mode:
* ``rl``  — the paper's experiment through the unified experiment API:
  any registered algo (ppo/trpo/ddpg/sac) + N parallel samplers on a
  pure-JAX env, on any backend/runtime, with any experience buffer
  (``--buffer {fifo,uniform,prioritized}``). The CLI only builds an
  ``ExperimentSpec`` and delegates to ``repro.experiment.run``;
  CPU-runnable.
* ``lm``  — sequence-model PPO (RLHF-style): synthetic rollout batches
  drive ``make_lm_train_step`` under a mesh, with checkpointing. On CPU use
  a reduced arch (``--arch <id>-reduced``); full configs belong to the
  dry-run.

Checkpoints record the fully-resolved spec in their metadata, so a run is
reproducible from the checkpoint directory alone.

Usage:
  PYTHONPATH=src python -m repro.launch.train --mode rl --env pendulum \
      --algo {ppo,trpo,ddpg,sac} --num-samplers 4 --iterations 20 \
      --backend {inline,threaded,sharded,process,fused} \
      [--num-workers 4]            # process backend: worker-process count \
      [--env-batch 1024]           # env plane: B-instance VectorEnv batch \
      [--buffer prioritized --replay-capacity 100000 --n-step 3] \
      [--kernels {ref,pallas,auto}]   # kernel plane (DESIGN.md §5) \
      [--inject-faults kill:0.2]   # chaos: process workers die on a \
      [--max-respawns 8]           # seeded schedule and are respawned \
      [--min-workers 2 --max-workers 8]  # async elastic fleet (§10) \
      [--staleness decay]          # async staleness-corrected learning
  PYTHONPATH=src python -m repro.launch.train --mode lm \
      --arch mixtral-8x7b-reduced --steps 5
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro import experiment
from repro.algos.ppo import PPOConfig, make_lm_train_step
from repro.checkpoint import save
from repro.configs import get_config
from repro.experiment import ExperimentSpec, Schedule
from repro.models import transformer
from repro.optim import adam


def spec_from_args(args) -> ExperimentSpec:
    """Resolve the CLI flags into a declarative ExperimentSpec.

    ``--backend fused`` and ``--async`` select runtimes rather than
    sampler backends; the spec keeps the distinction explicit.
    """
    runtime = ("async" if args.async_mode
               else "fused" if args.backend == "fused" else "sync")
    # normalize backend to what the runtime actually does, so checkpoint
    # metadata never records a collection schedule that didn't run: fused
    # has no host-visible backend; async samples with free-running threads
    # unless process workers were requested explicitly
    backend = ("inline" if args.backend == "fused"
               else "threaded" if args.async_mode
               and args.backend != "process" else args.backend)
    # only forward --lr when the user set it, so each algorithm's own
    # learning-rate defaults (ppo 3e-4, trpo vf 1e-3, ddpg 1e-3) apply
    algo_kwargs = {} if args.lr is None else {"lr": args.lr}
    # same for the buffer: only overrides the user set reach the spec, so
    # each buffer kind's own defaults apply and ckpt metadata stays honest
    buffer_kwargs = {k: v for k, v in [
        ("capacity", args.replay_capacity),
        ("batch_size", args.replay_batch),
        ("n_step", args.n_step),
    ] if v is not None}
    staleness = None
    if args.staleness and args.staleness != "off":
        staleness = {"mode": args.staleness}
        if args.staleness_decay is not None:
            staleness["decay"] = args.staleness_decay
    return ExperimentSpec(
        env=args.env,
        algo=args.algo,
        backend=backend,
        runtime=runtime,
        buffer=args.buffer,
        kernels=args.kernels,
        model={"hidden": args.hidden},
        algo_kwargs=algo_kwargs,
        buffer_kwargs=buffer_kwargs,
        staleness=staleness,
        faults=args.inject_faults,
        schedule=Schedule(
            num_samplers=args.num_samplers,
            global_batch=args.global_batch,
            horizon=args.horizon,
            iterations=args.iterations,
            seed=args.seed,
            chunk=args.chunk,
            num_workers=args.num_workers,
            env_batch=args.env_batch,
            learner_devices=args.learner_devices,
            learner_microbatches=args.learner_microbatches,
            fsdp=args.fsdp,
            overlap=args.overlap,
            learner_pods=args.learner_pods,
            max_respawns=args.max_respawns,
            min_workers=args.min_workers,
            max_workers=args.max_workers,
        ),
    )


def run_rl(args) -> None:
    spec = spec_from_args(args)
    result = experiment.run(spec)
    for log in result.logs:
        print(json.dumps(log.as_dict()))
    if args.ckpt_dir:
        save(args.ckpt_dir, args.iterations, result.params,
             metadata={"mode": "rl", "spec": spec.to_dict()})


def run_lm(args) -> None:
    cfg = get_config(args.arch)
    lr = args.lr if args.lr is not None else 3e-4
    key = jax.random.PRNGKey(args.seed)
    params = transformer.init_params(cfg, key)
    opt = adam(lr, moment_dtype=cfg.dtype)
    opt_state = opt.init(params)
    step = jax.jit(make_lm_train_step(cfg, opt, PPOConfig(lr=lr)))
    B, S = args.batch, args.seq_len
    kd = jax.random.PRNGKey(args.seed + 1)
    for i in range(args.steps):
        kd, kb = jax.random.split(kd)
        batch = {
            "tokens": jax.random.randint(kb, (B, S), 0, cfg.vocab_size),
            "targets": jax.random.randint(kb, (B, S), 0, cfg.vocab_size),
            "behavior_logp": -jnp.ones((B, S)) * 5.0,
            "advantages": jax.random.normal(kb, (B, S)),
            "returns": jax.random.normal(kb, (B, S)),
            "mask": jnp.ones((B, S)),
        }
        if cfg.frontend_embeds:
            batch["extra_embeds"] = jnp.zeros(
                (B, cfg.frontend_embeds, cfg.d_model),
                jnp.dtype(cfg.dtype))
        t0 = time.perf_counter()
        params, opt_state, metrics = step(params, opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        print(f"step {i}: loss={float(metrics['loss']):.4f} "
              f"({time.perf_counter() - t0:.2f}s)")
    if args.ckpt_dir:
        save(args.ckpt_dir, args.steps, params,
             metadata={"mode": "lm", "arch": args.arch, "seed": args.seed,
                       "lr": lr, "steps": args.steps,
                       "batch": args.batch, "seq_len": args.seq_len})


def main() -> None:
    ap = argparse.ArgumentParser()
    from repro import registry
    ap.add_argument("--mode", choices=("rl", "lm"), default="rl")
    ap.add_argument("--env", default="pendulum",
                    choices=registry.choices("env"))
    ap.add_argument("--algo", default="ppo",
                    choices=registry.choices("algo"))
    ap.add_argument("--arch", default="mixtral-8x7b-reduced")
    ap.add_argument("--num-samplers", type=int, default=4)
    ap.add_argument("--num-workers", type=int, default=None,
                    help="process backend: rollout worker-process count "
                         "(default: --num-samplers; worker i reuses "
                         "sampler i's seed, so process == inline exactly)")
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--env-batch", type=int, default=None,
                    help="env plane: collect with one device-resident "
                         "VectorEnv of B instances (one batched state "
                         "pytree, fused step+auto-reset) instead of the "
                         "num-samplers × global-batch split; combine "
                         "with --backend fused --kernels pallas for "
                         "single-dispatch iterations (DESIGN.md §7)")
    ap.add_argument("--horizon", type=int, default=128)
    ap.add_argument("--iterations", type=int, default=10)
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--lr", type=float, default=None,
                    help="learning rate (default: the algorithm's own; "
                         "lm mode: 3e-4)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="inline",
                    choices=registry.choices("backend") + ("fused",))
    from repro.kernels.select import MODES as KERNEL_MODES
    ap.add_argument("--kernels", default="auto",
                    choices=KERNEL_MODES,
                    help="kernel-plane implementation for the RL hot "
                         "loop (gae/sum_tree/replay_ring/env_step): "
                         "'ref' pure-JAX oracles (bitwise baseline), "
                         "'pallas' the fused kernels (interpret mode "
                         "off-accelerator), 'auto' compiled pallas on "
                         "TPU/GPU else ref")
    ap.add_argument("--buffer", default=None,
                    choices=registry.choices("buffer"),
                    help="experience buffer kind (default: the "
                         "algorithm's own — fifo on-policy, uniform "
                         "off-policy)")
    ap.add_argument("--replay-capacity", type=int, default=None,
                    help="off-policy buffers: ring capacity")
    ap.add_argument("--replay-batch", type=int, default=None,
                    help="off-policy buffers: learner minibatch size")
    ap.add_argument("--n-step", type=int, default=None,
                    help="off-policy buffers: n-step return horizon")
    ap.add_argument("--learner-devices", type=int, default=None,
                    help="shard the train step data-parallel over D "
                         "devices (shard_map; 1/unset = the historical "
                         "single-device path, bitwise unchanged)")
    ap.add_argument("--learner-microbatches", type=int, default=1,
                    help="gradient-accumulation slices per (per-shard) "
                         "learner batch")
    ap.add_argument("--fsdp", action="store_true",
                    help="shard params and Adam moments across the "
                         "learner mesh per the _param_spec layout rules "
                         "(per-layer all-gather + reduce-scattered grads; "
                         "requires --learner-devices > 1 — DESIGN.md §11)")
    ap.add_argument("--overlap", action="store_true",
                    help="double-buffered pipeline: dispatch iteration "
                         "k's learn and run iteration k+1's collect "
                         "while it executes (sync/fused runtimes; "
                         "IterationLog.overlap_saved_s reports the "
                         "hidden learn time)")
    ap.add_argument("--learner-pods", type=int, default=1,
                    help="split the learner shards over a (pod, data, "
                         "model) mesh — the multi-pod production axis "
                         "names, so the same step lowers across the DCN "
                         "boundary (must divide --learner-devices)")
    ap.add_argument("--chunk", type=int, default=None,
                    help="fused backend: iterations per device dispatch "
                         "(default: all of --iterations in one chunk)")
    ap.add_argument("--async", dest="async_mode", action="store_true")
    ap.add_argument("--inject-faults", default=None, metavar="SPEC",
                    help="fault-injection schedule for process workers, "
                         "e.g. 'kill:0.2,torn:0.05,delay:0.1:80' — "
                         "per-step probabilities of SIGKILL / die-mid-"
                         "write / hang / delay, deterministic per "
                         "(seed, worker, incarnation, step); requires "
                         "--backend process (DESIGN.md §10)")
    ap.add_argument("--max-respawns", type=int, default=3,
                    help="process backend: consecutive-failure budget "
                         "per worker before the run fails (0 disables "
                         "supervised respawn entirely)")
    ap.add_argument("--min-workers", type=int, default=None,
                    help="async process: elastic fleet floor — with "
                         "--max-workers, enables utilization-band "
                         "autoscaling between iterations")
    ap.add_argument("--max-workers", type=int, default=None,
                    help="async process: elastic fleet ceiling (the "
                         "pool pre-provisions ring slots and WorkerSpecs "
                         "up to this count; growth never reallocates)")
    from repro.algos.staleness import MODES as STALENESS_MODES
    ap.add_argument("--staleness", default="off",
                    choices=STALENESS_MODES,
                    help="async staleness correction: 'decay' weights "
                         "samples by decay**version_gap; 'vtrace' also "
                         "applies the truncated importance ratio "
                         "min(rho_clip, pi_now/pi_behavior); 'off' is "
                         "the historical bitwise path")
    ap.add_argument("--staleness-decay", type=float, default=None,
                    help="per-version-gap decay factor (default 0.9)")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    (run_rl if args.mode == "rl" else run_lm)(args)


if __name__ == "__main__":
    main()
