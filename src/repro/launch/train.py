"""End-to-end training driver.

Two modes, selected by --mode:
* ``rl``  — the paper's experiment: PPO + N parallel samplers on a pure-JAX
  env (sync or async runtime). CPU-runnable; this is what examples and
  benchmarks call.
* ``lm``  — sequence-model PPO (RLHF-style): synthetic rollout batches
  drive ``make_lm_train_step`` under a mesh, with checkpointing. On CPU use
  a reduced arch (``--arch <id>-reduced``); full configs belong to the
  dry-run.

Usage:
  PYTHONPATH=src python -m repro.launch.train --mode rl --env pendulum \
      --num-samplers 4 --iterations 20 --backend {inline,threaded,sharded,fused}
  PYTHONPATH=src python -m repro.launch.train --mode lm \
      --arch mixtral-8x7b-reduced --steps 5
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro import envs
from repro.algos.ppo import PPOConfig, make_lm_train_step, make_mlp_learner
from repro.checkpoint import save
from repro.configs import get_config
from repro.core import AsyncOrchestrator, FusedRunner, SyncRunner
from repro.core import make_backend
from repro.core import sampler as sampler_mod
from repro.models import mlp_policy, transformer
from repro.optim import adam


def build_rl_runner(args):
    """Construct the runner selected by --backend / --async.

    ``inline`` / ``threaded`` / ``sharded`` are SamplerBackends driven by
    SyncRunner; ``fused`` is the single-dispatch engine (whole
    collect->learn chunk under one jit); ``--async`` selects the paper's
    free-running sampler-thread architecture.
    """
    env = envs.make(args.env)
    key = jax.random.PRNGKey(args.seed)
    params = mlp_policy.init_policy(key, env.obs_dim, env.act_dim,
                                    hidden=args.hidden)
    opt = adam(args.lr)
    opt_state = opt.init(params)
    learn = make_mlp_learner(opt, PPOConfig(lr=args.lr))
    rollout = sampler_mod.make_env_rollout(env, args.horizon)
    per = sampler_mod.split_batch(args.global_batch, args.num_samplers)
    carries = [
        sampler_mod.init_env_carry(env, jax.random.PRNGKey(args.seed + i),
                                   per)
        for i in range(args.num_samplers)
    ]
    if args.async_mode:
        return AsyncOrchestrator(rollout, learn, params, opt_state, carries,
                                 args.num_samplers)
    if args.backend == "fused":
        carry = sampler_mod.init_env_carry(
            env, jax.random.PRNGKey(args.seed), args.global_batch)
        return FusedRunner(env, learn, params, opt_state, carry,
                           horizon=args.horizon, chunk=args.chunk)
    backend = make_backend(args.backend, rollout, carries,
                           env=env, horizon=args.horizon)
    return SyncRunner(None, learn, params, opt_state, backend=backend)


def run_rl(args) -> None:
    runner = build_rl_runner(args)
    logs = runner.run(args.iterations)
    for log in logs:
        print(json.dumps(log.as_dict()))
    if args.ckpt_dir:
        save(args.ckpt_dir, args.iterations, runner.params,
             metadata={"env": args.env, "backend": args.backend})


def run_lm(args) -> None:
    cfg = get_config(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = transformer.init_params(cfg, key)
    opt = adam(args.lr, moment_dtype=cfg.dtype)
    opt_state = opt.init(params)
    step = jax.jit(make_lm_train_step(cfg, opt, PPOConfig(lr=args.lr)))
    B, S = args.batch, args.seq_len
    kd = jax.random.PRNGKey(args.seed + 1)
    for i in range(args.steps):
        kd, kb = jax.random.split(kd)
        batch = {
            "tokens": jax.random.randint(kb, (B, S), 0, cfg.vocab_size),
            "targets": jax.random.randint(kb, (B, S), 0, cfg.vocab_size),
            "behavior_logp": -jnp.ones((B, S)) * 5.0,
            "advantages": jax.random.normal(kb, (B, S)),
            "returns": jax.random.normal(kb, (B, S)),
            "mask": jnp.ones((B, S)),
        }
        if cfg.frontend_embeds:
            batch["extra_embeds"] = jnp.zeros(
                (B, cfg.frontend_embeds, cfg.d_model),
                jnp.dtype(cfg.dtype))
        t0 = time.perf_counter()
        params, opt_state, metrics = step(params, opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        print(f"step {i}: loss={float(metrics['loss']):.4f} "
              f"({time.perf_counter() - t0:.2f}s)")
    if args.ckpt_dir:
        save(args.ckpt_dir, args.steps, params,
             metadata={"arch": args.arch})


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("rl", "lm"), default="rl")
    ap.add_argument("--env", default="pendulum")
    ap.add_argument("--arch", default="mixtral-8x7b-reduced")
    ap.add_argument("--num-samplers", type=int, default=4)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--horizon", type=int, default=128)
    ap.add_argument("--iterations", type=int, default=10)
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="inline",
                    choices=("inline", "threaded", "sharded", "fused"))
    ap.add_argument("--chunk", type=int, default=None,
                    help="fused backend: iterations per device dispatch "
                         "(default: all of --iterations in one chunk)")
    ap.add_argument("--async", dest="async_mode", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    (run_rl if args.mode == "rl" else run_lm)(args)


if __name__ == "__main__":
    main()
