from repro.models import (  # noqa: F401
    attention,
    layers,
    mlp_policy,
    moe,
    rope,
    ssm,
    transformer,
)
