"""Attention: GQA / sliding-window / QKV-bias / M-RoPE, cache-aware.

Reference (pure-jnp) implementation used by training, the dry-run, and as
the semantic spec for the Pallas kernels. Three paths:

* ``full_causal`` — work-efficient causal attention by **recursive halving**:
  the lower-left rectangle of each diagonal square is dense (computed
  chunked over KV with streaming-softmax stats, zero masked waste) and the
  two diagonal sub-squares recurse. Exact causal FLOPs, never materializes
  an (S x S) score tensor, and the recursion is resolved at trace time.
* ``swa`` — banded attention: each Q block attends to a statically-sized
  KV band ``[q0 - window_pad, q0 + q_block)`` sliced from a left-padded KV,
  so FLOPs are O(S * window) instead of O(S^2).
* ``decode`` — one query row against a (possibly ring-buffered) KV cache,
  masked by cache-slot positions.

Layout: q ``(B, S, K, G, hd)`` (K = kv heads, G = q-per-kv group), k/v
``(B, S, K, hd)``. Streaming-softmax stats are float32 throughout.
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import context as dist_ctx
from repro.models import layers, rope


# ===================================================================== init
def init_attention(cfg, key) -> dict:
    dtype = layers.param_dtype(cfg)
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, hd = cfg.d_model, cfg.head_dim
    p = {
        "wq": layers.dense_init(kq, (d, cfg.n_heads * hd), dtype),
        "wk": layers.dense_init(kk, (d, cfg.n_kv_heads * hd), dtype),
        "wv": layers.dense_init(kv, (d, cfg.n_kv_heads * hd), dtype),
        "wo": layers.dense_init(ko, (cfg.n_heads * hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
    return p


def project_qkv(cfg, p: dict, x: jnp.ndarray,
                cos: jnp.ndarray, sin: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x (B,S,D) -> q (B,S,K,G,hd), k/v (B,S,K,hd); RoPE applied."""
    B, S, _ = x.shape
    K, G, hd = cfg.n_kv_heads, cfg.q_per_kv, cfg.head_dim
    q = layers.matmul(x, dist_ctx.gather_weight(p["wq"], "col"))
    k = layers.matmul(x, dist_ctx.gather_weight(p["wk"], "col"))
    v = layers.matmul(x, dist_ctx.gather_weight(p["wv"], "col"))
    if "bq" in p:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    q = q.reshape(B, S, K * G, hd)
    k = k.reshape(B, S, K, hd)
    v = v.reshape(B, S, K, hd)
    q = rope.apply_rope(q, cos, sin).reshape(B, S, K, G, hd)
    k = rope.apply_rope(k, cos, sin)
    return _constrain_heads(q, k, v)


def _head_plan(K: int, G: int) -> str:
    """Which head dim to place on the model axis (uneven ok, GSPMD pads).

    Per-device attention work is ~ G*ceil(K/M) if K is sharded, else
    K*ceil(G/M); pick the smaller (M = model-axis size).
    """
    M = dist_ctx.model_axis_size()
    if M <= 1:
        return "none"
    work_k = G * -(-K // M)
    work_g = K * -(-G // M)
    return "kv" if work_k <= work_g else "group"


def _constrain_heads(q, k, v):
    """Pin attention-activation sharding: batch on data, one head dim on
    model, seq replicated (the residual stream is sequence-parallel; this
    forces the Megatron all-gather/reduce-scatter at the block boundary)."""
    from jax.sharding import PartitionSpec as P
    K, G = q.shape[2], q.shape[3]
    plan = _head_plan(K, G)
    if plan == "none":
        return q, k, v
    ctx = dist_ctx.get()
    from repro.distributed import sharding as shm
    bt = shm.shard_axes(q.shape[0], shm.batch_axes(ctx.mesh), ctx.mesh)
    if plan == "kv":
        q = dist_ctx.constrain_spec(q, P(bt, None, "model", None, None))
        k = dist_ctx.constrain_spec(k, P(bt, None, "model", None))
        v = dist_ctx.constrain_spec(v, P(bt, None, "model", None))
    else:
        q = dist_ctx.constrain_spec(q, P(bt, None, None, "model", None))
        k = dist_ctx.constrain_spec(k, P(bt, None, None, None))
        v = dist_ctx.constrain_spec(v, P(bt, None, None, None))
    return q, k, v


def attn_out(cfg, p: dict, o: jnp.ndarray) -> jnp.ndarray:
    """o (B,S,K,G,hd) -> (B,S,D)."""
    B, S = o.shape[:2]
    return layers.matmul(o.reshape(B, S, -1),
                         dist_ctx.gather_weight(p["wo"], "row"))


# ============================================================ softmax stats
class Stats(NamedTuple):
    acc: jnp.ndarray   # (B, Sq, K, G, hd) f32 — unnormalised weighted values
    m: jnp.ndarray     # (B, Sq, K, G)     f32 — running max
    l: jnp.ndarray     # (B, Sq, K, G)     f32 — running denominator


def _empty_stats(q: jnp.ndarray) -> Stats:
    B, Sq, K, G, hd = q.shape
    return Stats(
        jnp.zeros((B, Sq, K, G, hd), jnp.float32),
        jnp.full((B, Sq, K, G), -jnp.inf, jnp.float32),
        jnp.zeros((B, Sq, K, G), jnp.float32),
    )


def _block_stats(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                 mask: Optional[jnp.ndarray]) -> Stats:
    """One dense (q-block x kv-block) contribution. mask: (Sq, Skv) or None."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqkgh,bskh->bqkgs", q, k,
                   preferred_element_type=jnp.float32) * scale
    if mask is not None:
        s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
    m = jnp.max(s, axis=-1)
    # rows that are fully masked keep m=-inf; exp(-inf - -inf) is nan -> guard
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(s - m_safe[..., None])
    if mask is not None:
        p = jnp.where(mask[None, :, None, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bqkgs,bskh->bqkgh", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return Stats(acc, jnp.where(jnp.isfinite(m), m, -jnp.inf), l)


def _merge(a: Stats, b: Stats) -> Stats:
    """Combine two stats over the same Q rows, disjoint KV sets."""
    m = jnp.maximum(a.m, b.m)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    ca = jnp.where(jnp.isfinite(a.m), jnp.exp(a.m - m_safe), 0.0)
    cb = jnp.where(jnp.isfinite(b.m), jnp.exp(b.m - m_safe), 0.0)
    return Stats(
        a.acc * ca[..., None] + b.acc * cb[..., None],
        m,
        a.l * ca + b.l * cb,
    )


def _concat_q(a: Stats, b: Stats) -> Stats:
    return Stats(*(jnp.concatenate([x, y], axis=1) for x, y in zip(a, b)))


def _finalize(s: Stats, dtype) -> jnp.ndarray:
    l = jnp.where(s.l == 0.0, 1.0, s.l)
    return (s.acc / l[..., None]).astype(dtype)


# ================================================== dense rectangle, chunked
def _dense_stats(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                 kv_block: int) -> Stats:
    """Unmasked attention of q against all of k/v, scanned over KV blocks."""
    B, Sk, K, hd = k.shape
    if Sk <= kv_block:
        return _block_stats(q, k, v, None)
    nb = Sk // kv_block
    assert Sk % kv_block == 0, f"Skv={Sk} not divisible by {kv_block}"
    kb = jnp.moveaxis(k.reshape(B, nb, kv_block, K, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nb, kv_block, K, hd), 1, 0)

    def step(carry: Stats, xs):
        kc, vc = xs
        return _merge(carry, _block_stats(q, kc, vc, None)), None

    out, _ = jax.lax.scan(step, _empty_stats(q), (kb, vb))
    return out


# ======================================================= causal (recursive)
def _causal_stats(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  leaf: int, kv_block: int) -> Stats:
    """Exact-FLOPs causal attention over a diagonal square (Sq == Skv)."""
    Sq = q.shape[1]
    if Sq <= leaf or Sq % 2:
        tri = jnp.tril(jnp.ones((Sq, Sq), bool))
        return _block_stats(q, k, v, tri)
    h = Sq // 2
    top = _causal_stats(q[:, :h], k[:, :h], v[:, :h], leaf, kv_block)
    diag = _causal_stats(q[:, h:], k[:, h:], v[:, h:], leaf, kv_block)
    rect = _dense_stats(q[:, h:], k[:, :h], v[:, :h], kv_block)
    return _concat_q(top, _merge(rect, diag))


def full_causal(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                *, leaf: int = 1024, kv_block: int = 1024) -> jnp.ndarray:
    """Causal attention. q (B,S,K,G,hd), k/v (B,S,K,hd) -> (B,S,K,G,hd)."""
    S = q.shape[1]
    if S & (S - 1) or S <= leaf:        # non-power-of-two: single masked leaf
        assert S <= 8192, f"non-power-of-two S={S} too large for dense leaf"
        tri = jnp.tril(jnp.ones((S, S), bool))
        return _finalize(_block_stats(q, k, v, tri), q.dtype)
    return _finalize(_causal_stats(q, k, v, leaf, kv_block), q.dtype)


# ============================================================ sliding window
def swa(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, window: int,
        *, q_block: int = 512) -> jnp.ndarray:
    """Banded causal attention, O(S * window) FLOPs.

    Each Q block of ``q_block`` rows attends to the statically-shaped band
    ``[q0 - wpad, q0 + q_block)`` taken from a left-padded KV.
    """
    B, S, K, G, hd = q.shape
    if S <= window:
        # window covers everything: plain causal is exact
        return full_causal(q, k, v, leaf=min(512, S))
    q_block = min(q_block, S)
    if S % q_block:
        # pad up to a q_block multiple; padded tail rows are sliced off and
        # real queries can never attend to padded keys (causality)
        Sp = math.ceil(S / q_block) * q_block
        pad = ((0, 0), (0, Sp - S), (0, 0), (0, 0))
        out = swa(jnp.pad(q, pad + ((0, 0),)), jnp.pad(k, pad),
                  jnp.pad(v, pad), window, q_block=q_block)
        return out[:, :S]
    wpad = math.ceil(window / 128) * 128
    band = wpad + q_block
    kp = jnp.pad(k, ((0, 0), (wpad, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (wpad, 0), (0, 0), (0, 0)))
    nq = S // q_block
    qb = jnp.moveaxis(q.reshape(B, nq, q_block, K, G, hd), 1, 0)
    starts = jnp.arange(nq) * q_block          # band start in padded coords

    rel_q = jnp.arange(q_block)[:, None]       # local row
    rel_k = jnp.arange(band)[None, :] - wpad   # key offset rel. to q0
    # key global idx = q0 + rel_k ; query global idx = q0 + rel_q
    base_mask = (rel_k <= rel_q) & (rel_q - rel_k < window)

    def per_block(xs):
        qc, q0 = xs
        kc = jax.lax.dynamic_slice_in_dim(kp, q0, band, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(vp, q0, band, axis=1)
        valid = (q0 + rel_k) >= 0              # mask out left padding
        st = _block_stats(qc, kc, vc, base_mask & valid)
        return _finalize(st, q.dtype)

    out = jax.lax.map(per_block, (qb, starts))         # (nq, B, qb, K, G, hd)
    return jnp.moveaxis(out, 0, 1).reshape(B, S, K, G, hd)


# ================================================================== decode
def decode(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
           valid: jnp.ndarray) -> jnp.ndarray:
    """Single-token attention against a KV cache.

    q ``(B,K,G,hd)``; k_cache/v_cache ``(B,Sc,K,hd)``; valid ``(Sc,)`` bool
    (slot holds a live key). Returns ``(B,K,G,hd)``.
    """
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bkgh,bskh->bkgs", q, k_cache,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(valid[None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgs,bskh->bkgh", p.astype(v_cache.dtype), v_cache,
                      preferred_element_type=jnp.float32).astype(q.dtype)


# ============================================================== full apply
def attention_block(cfg, p: dict, x: jnp.ndarray,
                    cos: jnp.ndarray, sin: jnp.ndarray,
                    *, return_kv: bool = False):
    """Training/prefill attention for one layer. x (B,S,D)."""
    q, k, v = project_qkv(cfg, p, x, cos, sin)
    if cfg.sliding_window:
        o = swa(q, k, v, cfg.sliding_window)
    else:
        o = full_causal(q, k, v)
    y = attn_out(cfg, p, o)
    return (y, k, v) if return_kv else y


def attention_decode_block(cfg, p: dict, x: jnp.ndarray,
                           cos: jnp.ndarray, sin: jnp.ndarray,
                           k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                           valid: jnp.ndarray, write_idx: jnp.ndarray):
    """Decode one token. x (B,1,D); cache (B,Sc,K,hd); returns (y, k', v')."""
    B = x.shape[0]
    K, G, hd = cfg.n_kv_heads, cfg.q_per_kv, cfg.head_dim
    q, k, v = project_qkv(cfg, p, x, cos, sin)  # q (B,1,K,G,hd), k (B,1,K,hd)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, write_idx,
                                                  axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, write_idx,
                                                  axis=1)
    o = decode(q[:, 0], k_cache, v_cache, valid)
    y = attn_out(cfg, p, o[:, None].reshape(B, 1, K, G, hd))
    return y, k_cache, v_cache
