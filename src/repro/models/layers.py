"""Shared neural building blocks (no flax/haiku — plain pytrees of arrays).

Conventions
-----------
* Every ``init_*`` returns a (nested) dict of ``jnp.ndarray`` in ``cfg.dtype``.
* Every ``apply`` is a pure function ``(cfg, params, x, ...) -> y``.
* Matmuls accumulate in float32 (``preferred_element_type``) and cast back —
  the TPU-correct recipe for bf16 weights.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def param_dtype(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def dense_init(key, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init (the llama/mixtral recipe)."""
    if scale is None:
        scale = shape[0] ** -0.5
    return (jax.random.truncated_normal(key, -3.0, 3.0, shape, jnp.float32)
            * scale).astype(dtype)


def matmul(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x @ w in the activation dtype.

    bf16 x bf16 dots accumulate in f32 on the MXU natively; requesting
    ``preferred_element_type=f32`` here makes XLA's SPMD partitioner promote
    the *operands* (and their FSDP all-gathers) to f32 — 2x collective and
    temp bytes for nothing. Measured in EXPERIMENTS.md §Perf (llama3-405b
    train_4k).
    """
    return jnp.matmul(x, w)


# ------------------------------------------------------------------ norms
def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: dict, x: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


# ------------------------------------------------------------------ SwiGLU
def init_mlp(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": dense_init(k1, (d_model, d_ff), dtype),        # gate
        "w3": dense_init(k2, (d_model, d_ff), dtype),        # up
        "w2": dense_init(k3, (d_ff, d_model), dtype),        # down
    }


def mlp(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    from repro.distributed import context as dist_ctx
    w1 = dist_ctx.gather_weight(params["w1"], "col")
    w3 = dist_ctx.gather_weight(params["w3"], "col")
    w2 = dist_ctx.gather_weight(params["w2"], "row")
    gate = jax.nn.silu(matmul(x, w1).astype(jnp.float32))
    up = matmul(x, w3).astype(jnp.float32)
    return matmul((gate * up).astype(x.dtype), w2)


# ------------------------------------------------------------------ embed
def init_embedding(key, vocab: int, d_model: int, dtype) -> dict:
    return {"table": dense_init(key, (vocab, d_model), dtype, scale=0.02)}


def embed(params: dict, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params: dict, h: jnp.ndarray) -> jnp.ndarray:
    """h @ table.T  -> logits (f32)."""
    return jnp.matmul(h, params["table"].T,
                      preferred_element_type=jnp.float32)


def init_linear(key, d_in: int, d_out: int, dtype, bias: bool = False) -> dict:
    p = {"w": dense_init(key, (d_in, d_out), dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    y = matmul(x, params["w"])
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y
