"""Gaussian-MLP policy + value network — the paper's own model class.

WALL-E's experiments run PPO with a small MLP policy on MuJoCo continuous
control; this is that model (tanh hidden layers, state-independent log-std),
used by benchmarks/fig3..fig7 and examples/quickstart.py.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers

LOG_STD_INIT = -0.5


def init_mlp_net(key, sizes, dtype=jnp.float32) -> list:
    ks = jax.random.split(key, len(sizes) - 1)
    return [
        {"w": layers.dense_init(k, (i, o), dtype), "b": jnp.zeros((o,), dtype)}
        for k, i, o in zip(ks, sizes[:-1], sizes[1:])
    ]


def mlp_apply(net: list, x: jnp.ndarray) -> jnp.ndarray:
    for i, lyr in enumerate(net):
        x = x @ lyr["w"] + lyr["b"]
        if i < len(net) - 1:
            x = jnp.tanh(x)
    return x


def init_policy(key, obs_dim: int, act_dim: int,
                hidden: int = 64, depth: int = 2) -> Dict:
    kp, kv = jax.random.split(key)
    sizes = [obs_dim] + [hidden] * depth
    return {
        "pi": init_mlp_net(kp, sizes + [act_dim]),
        "log_std": jnp.full((act_dim,), LOG_STD_INIT, jnp.float32),
        "vf": init_mlp_net(kv, sizes + [1]),
    }


def policy_dist(params: Dict, obs: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    mean = mlp_apply(params["pi"], obs)
    std = jnp.exp(params["log_std"])
    return mean, jnp.broadcast_to(std, mean.shape)


def gaussian_logp(mean, std, action) -> jnp.ndarray:
    z = (action - mean) / std
    return jnp.sum(-0.5 * z ** 2 - jnp.log(std)
                   - 0.5 * math.log(2 * math.pi), axis=-1)


def sample_action(params: Dict, obs: jnp.ndarray, key
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    mean, std = policy_dist(params, obs)
    action = mean + std * jax.random.normal(key, mean.shape)
    return action, gaussian_logp(mean, std, action)


def action_logp(params: Dict, obs: jnp.ndarray, action: jnp.ndarray
                ) -> jnp.ndarray:
    mean, std = policy_dist(params, obs)
    return gaussian_logp(mean, std, action)


def entropy(params: Dict) -> jnp.ndarray:
    return jnp.sum(params["log_std"] + 0.5 * math.log(2 * math.pi * math.e))


def value_apply(params: Dict, obs: jnp.ndarray) -> jnp.ndarray:
    return mlp_apply(params["vf"], obs)[..., 0]
