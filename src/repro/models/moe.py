"""Mixture-of-Experts MLP: top-k routing with GShard-style capacity dispatch.

Dispatch/combine are grouped einsums (group = batch row), the standard
TPU-friendly formulation (MaxText "dropping" implementation): one-hot
dispatch tensors stay ``(B, S*k, E, C)`` with per-group capacity
``C = ceil(S*k/E * capacity_factor)`` so memory scales with the group, not
the global token count. Router runs in float32; load-balance aux loss is the
Switch-Transformer form.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed import context as dist_ctx
from repro.models import layers


def init_moe(cfg, key) -> dict:
    dtype = layers.param_dtype(cfg)
    kr, k1, k2, k3 = jax.random.split(key, 4)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff

    def expert_init(k, shape):
        ks = jax.random.split(k, e)
        return jnp.stack([layers.dense_init(ki, shape, dtype) for ki in ks])

    return {
        "router": layers.dense_init(kr, (d, e), dtype, scale=0.02),
        "w1": expert_init(k1, (d, f)),
        "w3": expert_init(k2, (d, f)),
        "w2": expert_init(k3, (f, d)),
    }


GROUP_TOKENS = 1024     # GShard-style dispatch group (capacity is per-group;
                        # dispatch/combine einsum FLOPs and memory scale
                        # linearly with this — §Perf mixtral iteration 3)


def capacity(cfg, seq_len: int) -> int:
    slots = seq_len * cfg.top_k
    return max(1, math.ceil(slots / cfg.n_experts * cfg.capacity_factor))


def route(cfg, router_w: jnp.ndarray, x: jnp.ndarray
          ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x (B,S,D) -> (weights (B,S,k) f32, idx (B,S,k), probs (B,S,E), aux)."""
    logits = jnp.matmul(x, router_w,
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_v, top_i = jax.lax.top_k(logits, cfg.top_k)
    weights = jax.nn.softmax(top_v, axis=-1)            # Mixtral renorm
    # Switch load-balance loss: E * sum_e f_e * p_e
    sel = jax.nn.one_hot(top_i, cfg.n_experts, dtype=jnp.float32)
    frac = jnp.mean(jnp.sum(sel, axis=2), axis=(0, 1))  # fraction per expert
    mean_p = jnp.mean(probs, axis=(0, 1))
    aux = cfg.n_experts * jnp.sum(frac * mean_p)
    return weights, top_i, probs, aux


def moe_block(cfg, p: dict, x: jnp.ndarray
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k MoE MLP. x (B,S,D) -> (y (B,S,D), aux_loss scalar f32).

    Long sequences are cut into ``GROUP_TOKENS``-sized dispatch groups so
    the one-hot dispatch/combine tensors stay O(group) — dispatch memory
    and FLOPs scale linearly with group size (EXPERIMENTS.md §Perf,
    mixtral-8x7b x prefill_32k iteration 1).
    """
    B, S, D = x.shape
    if S > GROUP_TOKENS and S % GROUP_TOKENS == 0:
        g = S // GROUP_TOKENS
        # seq arrives model-sharded (sequence-parallel residual); merging a
        # data-sharded B with a model-sharded S defeats GSPMD's reshape
        # propagation and replicates the dispatch tensors — pin the layout:
        # gather seq, reshape, and shard the merged group dim on batch axes
        x = dist_ctx.constrain(x, "batch", None, None)
        xg = x.reshape(B * g, GROUP_TOKENS, D)
        xg = dist_ctx.constrain(xg, "batch", None, None)
        y, aux = _moe_grouped(cfg, p, xg)
        y = dist_ctx.constrain(y, "batch", None, None)
        return y.reshape(B, S, D), aux
    return _moe_grouped(cfg, p, x)


def _moe_grouped(cfg, p: dict, x: jnp.ndarray
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = capacity(cfg, S)
    weights, top_i, _, aux = route(cfg, p["router"], x)

    # ---- slot bookkeeping: flatten (S, k) -> T routed slots per group
    T = S * k
    e_slot = top_i.reshape(B, T)                        # expert per slot
    w_slot = weights.reshape(B, T)
    e_oh = jax.nn.one_hot(e_slot, E, dtype=jnp.float32)         # (B,T,E)
    rank = jnp.cumsum(e_oh, axis=1) - e_oh              # position in expert
    rank_sel = jnp.sum(rank * e_oh, axis=-1)            # (B,T)
    keep = rank_sel < C
    # dispatch[b,t,e,c] = 1 iff slot t -> (expert e, capacity slot c)
    c_oh = jax.nn.one_hot(rank_sel.astype(jnp.int32), C, dtype=jnp.float32)
    disp = (e_oh[..., None] * c_oh[:, :, None, :]
            * keep[..., None, None].astype(jnp.float32))        # (B,T,E,C)
    comb = disp * w_slot[..., None, None]

    disp = disp.astype(x.dtype)
    xs = jnp.repeat(x, k, axis=1) if k > 1 else x       # token per slot (B,T,D)
    buf = jnp.einsum("btec,btd->becd", disp, xs,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    buf = dist_ctx.constrain(buf, "batch", None, None, None)

    # ---- expert FFN (SwiGLU), batched over E
    # Train/prefill: explicitly re-gather the experts' fsdp (D) shards so
    # every expert einsum is local — gathered slab = E*D*F/model_axis bytes
    # per layer, orders of magnitude below letting GSPMD psum
    # (group,E,C,F) partials. Serve mode keeps weights resident (the
    # single-token buffers are the cheap side there).
    from jax.sharding import PartitionSpec as _P
    if dist_ctx.mode() == "serve":
        w1, w3, w2 = p["w1"], p["w3"], p["w2"]
    else:
        w1 = dist_ctx.constrain_spec(p["w1"], _P(None, None, "model"))
        w3 = dist_ctx.constrain_spec(p["w3"], _P(None, None, "model"))
        w2 = dist_ctx.constrain_spec(p["w2"], _P(None, "model", None))
    gate = jnp.einsum("becd,edf->becf", buf, w1,
                      preferred_element_type=jnp.float32)
    up = jnp.einsum("becd,edf->becf", buf, w3,
                    preferred_element_type=jnp.float32)
    act = (jax.nn.silu(gate) * up).astype(x.dtype)
    out = jnp.einsum("becf,efd->becd", act, w2,
                     preferred_element_type=jnp.float32)

    y = jnp.einsum("btec,becd->btd", comb.astype(jnp.float32), out)
    y = y.reshape(B, S, k, D).sum(axis=2) if k > 1 else y.reshape(B, S, D)
    # cast before leaving the block: the residual-restore psum/reduce-scatter
    # then moves bf16, not f32 (halves the combine collective)
    return y.astype(x.dtype), aux
