"""Rotary position embeddings: classic RoPE + Qwen2-VL M-RoPE.

M-RoPE splits the ``head_dim/2`` frequency channels into (t, h, w) sections;
each section reads its angle from the matching component of a 3-row position
id tensor [arXiv:2409.12191]. Plain RoPE is the one-section special case.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np


def rope_cos_sin(positions: jnp.ndarray,
                 head_dim: int,
                 theta: float,
                 sections: Tuple[int, ...] = ()
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Return (cos, sin) of shape ``(..., S, head_dim/2)``.

    positions: ``(..., S)`` int32 for RoPE, ``(3, ..., S)`` for M-RoPE.
    """
    half = head_dim // 2
    freqs = theta ** (-np.arange(half, dtype=np.float32) / half)
    freqs = jnp.asarray(freqs)
    if sections:
        assert positions.shape[0] == len(sections) == 3
        sec_id = np.repeat(np.arange(len(sections)), np.asarray(sections))
        # (half, ..., S): pick the t/h/w position row per frequency channel
        pos = positions[sec_id]                       # static fancy index
        pos = jnp.moveaxis(pos, 0, -1)                # (..., S, half)
        angles = pos.astype(jnp.float32) * freqs
    else:
        angles = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray
               ) -> jnp.ndarray:
    """Rotate ``x`` of shape ``(B, S, n_heads, head_dim)``.

    cos/sin are ``(B, S, head_dim/2)`` (broadcast over the head axis).
    """
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(jnp.float32)
    s = sin[..., None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * c - x2f * s, x2f * c + x1f * s], axis=-1)
    return out.astype(x.dtype)
