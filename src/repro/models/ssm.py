"""Mamba1 selective-SSM block (falcon-mamba / hymba's SSM branch).

Training path uses a **chunked associative scan**: the sequence is cut into
chunks of ``chunk`` steps; within a chunk the recurrence
``h_t = Abar_t * h_{t-1} + Bx_t`` is solved with ``lax.associative_scan``
(log-depth), and chunks are threaded sequentially with ``lax.scan`` so the
materialised state tensor is ``(B, chunk, d_inner, N)`` instead of
``(B, S, d_inner, N)`` — the same working-set shape the Pallas kernel tiles
into VMEM (see kernels/selective_scan).

Decode path is the O(1) single-step recurrence (conv ring + state update).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import context as dist_ctx
from repro.models import layers


# ===================================================================== init
def init_ssm(cfg, key) -> dict:
    dtype = layers.param_dtype(cfg)
    di, n, r = cfg.d_inner, cfg.ssm_state, cfg.ssm_dt_rank
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    # S4D-real A init: A[:, j] = -(j+1)
    a = np.tile(np.arange(1, n + 1, dtype=np.float32), (di, 1))
    # dt bias: softplus^-1 of dt ~ U[1e-3, 1e-1]
    dt = np.exp(np.random.RandomState(0).uniform(
        np.log(1e-3), np.log(1e-1), size=(di,))).astype(np.float32)
    dt_bias = dt + np.log1p(-np.exp(-dt))
    return {
        "in_proj": layers.dense_init(k1, (cfg.d_model, 2 * di), dtype),
        "conv_w": layers.dense_init(k2, (cfg.ssm_conv, di), dtype, scale=0.5),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": layers.dense_init(k3, (di, r + 2 * n), dtype),
        "dt_proj": layers.dense_init(k4, (r, di), dtype),
        "dt_bias": jnp.asarray(dt_bias, dtype),
        "A_log": jnp.asarray(np.log(a), jnp.float32),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": layers.dense_init(k5, (di, cfg.d_model), dtype),
    }


# ============================================================== projections
def _ssm_inputs(cfg, p: dict, xc: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """xc (B,S,Di) (post-conv, post-silu) -> dt (f32), B_ssm, C_ssm."""
    r, n = cfg.ssm_dt_rank, cfg.ssm_state
    proj = layers.matmul(xc, p["x_proj"])
    dt_raw, b, c = jnp.split(proj, [r, r + n], axis=-1)
    dt = jax.nn.softplus(
        layers.matmul(dt_raw, p["dt_proj"]).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))
    return dt, b.astype(jnp.float32), c.astype(jnp.float32)


def causal_conv(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal 1-D conv. x (B,S,Di) -> (B,S,Di)."""
    conv, di = p["conv_w"].shape
    xp = jnp.pad(x, ((0, 0), (conv - 1, 0), (0, 0)))
    kernel = p["conv_w"][:, None, :]                    # (W, 1, Di)
    y = jax.lax.conv_general_dilated(
        xp, kernel.astype(x.dtype), window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"), feature_group_count=di)
    return y + p["conv_b"].astype(y.dtype)


# ============================================================ chunked scan
def _scan_combine(a, b):
    """Associative combine for (decay, increment) pairs."""
    a1, b1 = a
    a2, b2 = b
    return a1 * a2, a2 * b1 + b2


def selective_scan(dt: jnp.ndarray, A: jnp.ndarray, b: jnp.ndarray,
                   c: jnp.ndarray, xc: jnp.ndarray, h0: jnp.ndarray,
                   *, chunk: int = 256
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Selective-SSM scan (all-f32 inputs).

    dt (B,S,Di), A (Di,N), b/c (B,S,N), xc (B,S,Di), h0 (B,Di,N).
    Returns y (B,S,Di) and final state (B,Di,N).
    """
    B, S, Di = xc.shape
    N = A.shape[-1]
    if S % chunk:
        chunk = S                                       # single chunk
    nc = S // chunk

    def rs(t):                                          # (B,S,...) -> chunks
        return jnp.moveaxis(t.reshape(B, nc, chunk, *t.shape[2:]), 1, 0)

    def chunk_step(h, xs):
        dt_c, b_c, c_c, x_c = xs
        abar = jnp.exp(dt_c[..., None] * A)             # (B,Q,Di,N)
        bx = (dt_c * x_c)[..., None] * b_c[:, :, None, :]
        pa, pb = jax.lax.associative_scan(_scan_combine, (abar, bx), axis=1)
        h_t = pa * h[:, None] + pb                      # (B,Q,Di,N)
        y = jnp.einsum("bqdn,bqn->bqd", h_t, c_c)
        return h_t[:, -1], y

    h_last, ys = jax.lax.scan(chunk_step, h0,
                              (rs(dt), rs(b), rs(c), rs(xc)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, Di)
    return y, h_last


# ================================================================== blocks
def ssm_block(cfg, p: dict, x: jnp.ndarray, *, impl: str = "reference"
              ) -> jnp.ndarray:
    """Full Mamba1 mixer for training/prefill. x (B,S,D) -> (B,S,D)."""
    B, S, _ = x.shape
    di = cfg.d_inner
    xz = layers.matmul(x, p["in_proj"])
    xin, z = jnp.split(xz, [di], axis=-1)
    # SSM channels -> model axis: the scan is embarrassingly parallel over
    # d_inner, so each model shard owns a channel slice end-to-end
    xin = dist_ctx.constrain(xin, "batch", None, "dinner")
    xc = jax.nn.silu(causal_conv(p, xin).astype(jnp.float32)).astype(x.dtype)
    dt, b, c = _ssm_inputs(cfg, p, xc)
    dt = dist_ctx.constrain(dt, "batch", None, "dinner")
    A = -jnp.exp(p["A_log"])
    h0 = jnp.zeros((B, di, cfg.ssm_state), jnp.float32)
    if impl == "pallas":
        from repro.kernels.selective_scan import ops as ss_ops
        y, _ = ss_ops.selective_scan(dt, A, b, c, xc.astype(jnp.float32), h0)
    else:
        y, _ = selective_scan(dt, A, b, c, xc.astype(jnp.float32), h0)
    y = y + p["D"] * xc.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    return layers.matmul(y.astype(x.dtype), p["out_proj"])


def ssm_decode_block(cfg, p: dict, x: jnp.ndarray,
                     conv_state: jnp.ndarray, ssm_state: jnp.ndarray
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One decode step. x (B,1,D); conv_state (B,conv-1,Di);
    ssm_state (B,Di,N). Returns (y (B,1,D), conv_state', ssm_state')."""
    di = cfg.d_inner
    xz = layers.matmul(x[:, 0], p["in_proj"])           # (B, 2Di)
    xin, z = jnp.split(xz, [di], axis=-1)
    window = jnp.concatenate([conv_state, xin[:, None]], axis=1)  # (B,conv,Di)
    xconv = jnp.einsum("bwd,wd->bd", window.astype(jnp.float32),
                       p["conv_w"].astype(jnp.float32))
    xconv = xconv + p["conv_b"].astype(jnp.float32)
    xc = jax.nn.silu(xconv)                             # (B,Di) f32
    dt, b, c = _ssm_inputs(cfg, p, xc[:, None].astype(x.dtype))
    dt, b, c = dt[:, 0], b[:, 0], c[:, 0]               # (B,Di), (B,N)
    A = -jnp.exp(p["A_log"])
    abar = jnp.exp(dt[..., None] * A)                   # (B,Di,N)
    bx = (dt * xc)[..., None] * b[:, None, :]
    h = abar * ssm_state + bx
    y = jnp.einsum("bdn,bn->bd", h, c) + p["D"] * xc
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = layers.matmul(y[:, None].astype(x.dtype), p["out_proj"])
    return out, window[:, 1:].astype(conv_state.dtype), h
