"""Decoder-only sequence-model policy: dense / MoE / SSM / hybrid / audio / vlm.

One implementation covers all ten assigned architectures; the per-layer body
dispatches on ``cfg.family``. Layers are **stacked** (leading ``L`` axis) and
iterated with ``lax.scan`` so the 126-layer llama3-405b lowers to a single
compiled layer body, and activation rematerialisation is a scan-level policy.

Three entry points (these are what the launcher lowers):
* ``forward``       — full-sequence hidden states (training / prefill)
* ``prefill``       — forward + KV/SSM cache construction + last-token logits
* ``decode_step``   — one token against the cache (the sampler's inner step)
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import context as dist_ctx
from repro.models import attention, layers, moe, rope, ssm


# ===================================================================== init
def _init_layer(cfg, key) -> Dict[str, Any]:
    dtype = layers.param_dtype(cfg)
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {}
    if cfg.has_attention:
        p["attn_norm"] = layers.init_rmsnorm(cfg.d_model, dtype)
        p["attn"] = attention.init_attention(cfg, ks[0])
    if cfg.is_ssm:
        if cfg.family == "ssm":
            p["ssm_norm"] = layers.init_rmsnorm(cfg.d_model, dtype)
        p["ssm"] = ssm.init_ssm(cfg, ks[1])
    if cfg.family == "hybrid":
        p["fuse_norm_attn"] = layers.init_rmsnorm(cfg.d_model, dtype)
        p["fuse_norm_ssm"] = layers.init_rmsnorm(cfg.d_model, dtype)
    if cfg.d_ff:
        p["mlp_norm"] = layers.init_rmsnorm(cfg.d_model, dtype)
        if cfg.is_moe:
            p["moe"] = moe.init_moe(cfg, ks[2])
        else:
            p["mlp"] = layers.init_mlp(ks[2], cfg.d_model, cfg.d_ff, dtype)
    return p


def init_params(cfg, key) -> Dict[str, Any]:
    dtype = layers.param_dtype(cfg)
    k_emb, k_layers, k_head, k_val, k_meta = jax.random.split(key, 5)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    params = {
        "embed": layers.init_embedding(k_emb, cfg.vocab_size, cfg.d_model,
                                       dtype),
        "layers": jax.vmap(lambda k: _init_layer(cfg, k))(layer_keys),
        "final_norm": layers.init_rmsnorm(cfg.d_model, dtype),
        "lm_head": layers.init_linear(k_head, cfg.d_model, cfg.vocab_size,
                                      dtype),
        "value_head": layers.init_linear(k_val, cfg.d_model, 1, dtype,
                                         bias=True),
    }
    if cfg.n_meta_tokens:
        params["meta_tokens"] = layers.dense_init(
            k_meta, (cfg.n_meta_tokens, cfg.d_model), dtype, scale=0.02)
    return params


# ================================================================ positions
def _rope_tables(cfg, positions: jnp.ndarray):
    """positions (B,S) or (3,B,S) -> (cos, sin) of (B,S,half)."""
    return rope.rope_cos_sin(positions, cfg.head_dim, cfg.rope_theta,
                             cfg.m_rope_sections)


def default_positions(cfg, batch: int, seq: int) -> jnp.ndarray:
    pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32), (batch, seq))
    if cfg.m_rope_sections:
        pos = jnp.broadcast_to(pos, (3, batch, seq))
    return pos


# ============================================================== layer body
def _layer_fwd(cfg, p: Dict[str, Any], h: jnp.ndarray,
               cos, sin, impl: str) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One layer, full-sequence. Returns (h, moe_aux)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "ssm":
        h = h + ssm.ssm_block(cfg, p["ssm"],
                              layers.rmsnorm(p["ssm_norm"], h, cfg.norm_eps),
                              impl=impl)
        return h, aux
    xn = layers.rmsnorm(p["attn_norm"], h, cfg.norm_eps)
    if cfg.family == "hybrid":
        a = attention.attention_block(cfg, p["attn"], xn, cos, sin)
        s = ssm.ssm_block(cfg, p["ssm"], xn, impl=impl)
        mixed = 0.5 * (layers.rmsnorm(p["fuse_norm_attn"], a, cfg.norm_eps)
                       + layers.rmsnorm(p["fuse_norm_ssm"], s, cfg.norm_eps))
        h = h + mixed
    else:
        h = h + attention.attention_block(cfg, p["attn"], xn, cos, sin)
    if cfg.d_ff:
        xm = layers.rmsnorm(p["mlp_norm"], h, cfg.norm_eps)
        if cfg.is_moe:
            y, aux = moe.moe_block(cfg, p["moe"], xm)
            h = h + y
        else:
            h = h + layers.mlp(p["mlp"], xm)
    return h, aux


# ================================================================= forward
def embed_inputs(cfg, params, tokens: jnp.ndarray,
                 extra_embeds: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Token embeds with (meta tokens | frontend embeds) prepended."""
    h = layers.embed(params["embed"], tokens)
    prefix = []
    if "meta_tokens" in params:
        B = tokens.shape[0]
        prefix.append(jnp.broadcast_to(
            params["meta_tokens"][None], (B,) + params["meta_tokens"].shape))
    if extra_embeds is not None:
        prefix.append(extra_embeds.astype(h.dtype))
    if prefix:
        h = jnp.concatenate(prefix + [h], axis=1)
    return h


def _near_sqrt_factor(L: int) -> int:
    """Largest divisor of L that is <= sqrt(L) (1 if L is prime)."""
    for d in range(int(math.isqrt(L)), 0, -1):
        if L % d == 0:
            return d
    return 1


def forward(cfg, params, tokens: jnp.ndarray, *,
            positions: Optional[jnp.ndarray] = None,
            extra_embeds: Optional[jnp.ndarray] = None,
            impl: str = "reference",
            remat: str = "scan2") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens (B, S_tok) -> (hidden (B, S_total, D), moe_aux scalar).

    remat: "none" | "full" (checkpoint every layer) | "scan2" (sqrt-L
    two-level scan: peak saved residuals ~ (L1+L2) instead of L carries).
    """
    h = embed_inputs(cfg, params, tokens, extra_embeds)
    B, S, _ = h.shape
    if positions is None:
        positions = default_positions(cfg, B, S)
    cos, sin = (None, None)
    if cfg.has_attention:
        cos, sin = _rope_tables(cfg, positions)

    def body(carry, layer_p):
        # sequence-parallel residual stream: scan carries are saved sharded
        carry = dist_ctx.constrain(carry, "batch", "seq", None)
        y, aux = _layer_fwd(cfg, layer_p, carry, cos, sin, impl)
        y = dist_ctx.constrain(y, "batch", "seq", None)
        return y, aux

    L = cfg.n_layers
    two_level = remat in ("scan2", "scan2_dots")
    L1 = _near_sqrt_factor(L) if two_level else 1
    if two_level and L1 > 1:
        L2 = L // L1
        stacked2 = jax.tree.map(
            lambda x: x.reshape((L1, L2) + x.shape[1:]), params["layers"])

        # "scan2_dots": save projection outputs inside the inner scan so
        # the backward pass does not re-all-gather the sequence-parallel
        # residual stream (collective/memory trade, EXPERIMENTS.md §Perf
        # llama3-405b train iteration). Attention einsums carry batch dims
        # and are still rematerialised.
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if remat == "scan2_dots" else None)
        inner_body = jax.checkpoint(body, policy=policy)

        @jax.checkpoint
        def outer(carry, group_p):
            return jax.lax.scan(inner_body, carry, group_p)

        h, auxes = jax.lax.scan(outer, h, stacked2)
        aux_sum = jnp.sum(auxes)
    else:
        if remat in ("full", "scan2", "scan2_dots"):
            body = jax.checkpoint(body)
        elif remat == "dots":
            body = jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        h, auxes = jax.lax.scan(body, h, params["layers"])
        aux_sum = jnp.sum(auxes)
    h = layers.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return h, aux_sum


# ================================================================== heads
def lm_logits(cfg, params, h: jnp.ndarray) -> jnp.ndarray:
    """Full logits (f32). Only for small vocab / short suffixes."""
    return jnp.matmul(h, params["lm_head"]["w"],
                      preferred_element_type=jnp.float32)


def value(cfg, params, h: jnp.ndarray) -> jnp.ndarray:
    """Value head (B,S) f32."""
    return layers.linear(params["value_head"], h)[..., 0].astype(jnp.float32)


def token_logp_entropy(cfg, params, h: jnp.ndarray, targets: jnp.ndarray,
                       chunk: int = 256
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-token log-prob of ``targets`` and entropy, chunked over S so the
    (B,S,V) logits tensor never materialises. Returns two (B,S) f32 arrays."""
    B, S, D = h.shape
    w = params["lm_head"]["w"]
    if S % chunk:
        chunk = S
    nc = S // chunk

    def per_chunk(xs):
        hc, tc = xs
        z = jnp.matmul(hc, w, preferred_element_type=jnp.float32)
        lse = jax.nn.logsumexp(z, axis=-1)
        tgt = jnp.take_along_axis(z, tc[..., None], axis=-1)[..., 0]
        p = jax.nn.softmax(z, axis=-1)
        ent = lse - jnp.sum(p * z, axis=-1)
        return tgt - lse, ent

    hs = jnp.moveaxis(h.reshape(B, nc, chunk, D), 1, 0)
    ts = jnp.moveaxis(targets.reshape(B, nc, chunk), 1, 0)
    logp, ent = jax.lax.map(per_chunk, (hs, ts))
    return (jnp.moveaxis(logp, 0, 1).reshape(B, S),
            jnp.moveaxis(ent, 0, 1).reshape(B, S))


# ============================================================ decode cache
def cache_len(cfg, seq_len: int) -> int:
    if cfg.sliding_window:
        return min(cfg.sliding_window, seq_len)
    return seq_len


def init_decode_state(cfg, batch: int, seq_len: int) -> Dict[str, Any]:
    """Zero-initialised decode state sized for ``seq_len`` total positions."""
    dtype = layers.param_dtype(cfg)
    L = cfg.n_layers
    state: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.has_attention:
        C = cache_len(cfg, seq_len)
        K, hd = cfg.n_kv_heads, cfg.head_dim
        state["k"] = jnp.zeros((L, batch, C, K, hd), dtype)
        state["v"] = jnp.zeros((L, batch, C, K, hd), dtype)
        state["cache_pos"] = jnp.full((C,), -1, jnp.int32)
    if cfg.is_ssm:
        state["conv"] = jnp.zeros((L, batch, cfg.ssm_conv - 1, cfg.d_inner),
                                  dtype)
        state["ssm"] = jnp.zeros((L, batch, cfg.d_inner, cfg.ssm_state),
                                 jnp.float32)
    return state


def _layer_decode(cfg, p, h, cos, sin, caches, valid, write_idx):
    """One layer, one token. caches: per-layer slices. Returns (h, updates)."""
    upd = {}
    if cfg.family == "ssm":
        xn = layers.rmsnorm(p["ssm_norm"], h, cfg.norm_eps)
        y, upd["conv"], upd["ssm"] = ssm.ssm_decode_block(
            cfg, p["ssm"], xn, caches["conv"], caches["ssm"])
        return h + y, upd
    xn = layers.rmsnorm(p["attn_norm"], h, cfg.norm_eps)
    a, upd["k"], upd["v"] = attention.attention_decode_block(
        cfg, p["attn"], xn, cos, sin, caches["k"], caches["v"], valid,
        write_idx)
    if cfg.family == "hybrid":
        s, upd["conv"], upd["ssm"] = ssm.ssm_decode_block(
            cfg, p["ssm"], xn, caches["conv"], caches["ssm"])
        mixed = 0.5 * (layers.rmsnorm(p["fuse_norm_attn"], a, cfg.norm_eps)
                       + layers.rmsnorm(p["fuse_norm_ssm"], s, cfg.norm_eps))
        h = h + mixed
    else:
        h = h + a
    if cfg.d_ff:
        xm = layers.rmsnorm(p["mlp_norm"], h, cfg.norm_eps)
        if cfg.is_moe:
            y, _ = moe.moe_block(cfg, p["moe"], xm)
            h = h + y
        else:
            h = h + layers.mlp(p["mlp"], xm)
    return h, upd


def decode_step(cfg, params, state: Dict[str, Any], token: jnp.ndarray
                ) -> Tuple[Dict[str, Any], jnp.ndarray]:
    """One sampler inner step: token (B,1) int32 -> (state', logits (B,V))."""
    pos = state["pos"]
    h = layers.embed(params["embed"], token)            # (B,1,D)
    cos = sin = None
    valid = write_idx = None
    new_state: Dict[str, Any] = {"pos": pos + 1}
    if cfg.has_attention:
        p_ids = jnp.full((h.shape[0], 1), pos, jnp.int32)
        if cfg.m_rope_sections:
            p_ids = jnp.broadcast_to(p_ids, (3,) + p_ids.shape)
        cos, sin = _rope_tables(cfg, p_ids)
        C = state["k"].shape[2]
        write_idx = pos % C
        cache_pos = state["cache_pos"].at[write_idx].set(pos)
        valid = cache_pos >= 0
        if cfg.sliding_window:
            valid &= cache_pos > pos - cfg.sliding_window
        new_state["cache_pos"] = cache_pos

    cache_keys = [k for k in ("k", "v", "conv", "ssm") if k in state]

    def body(carry, xs):
        layer_p = xs[0]
        caches = dict(zip(cache_keys, xs[1:]))
        if dist_ctx.mode() == "serve":
            # resident-weight decode: the residual stream lives d_model-
            # sharded on `model`; matmuls psum tiny (B,1,*) activations
            # instead of streaming FSDP weight shards (§Perf llama decode)
            carry = dist_ctx.constrain(carry, "batch", None, "dmodel")
        y, upd = _layer_decode(cfg, layer_p, carry, cos, sin, caches, valid,
                               write_idx)
        return y, tuple(upd[k] for k in cache_keys)

    xs = (params["layers"],) + tuple(state[k] for k in cache_keys)
    h, updated = jax.lax.scan(body, h, xs)
    for name, arr in zip(cache_keys, updated):
        new_state[name] = arr
    h = layers.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = lm_logits(cfg, params, h[:, 0])            # (B,V) f32
    return new_state, logits


# ================================================================= prefill
def prefill(cfg, params, tokens: jnp.ndarray, gen_budget: int = 0, *,
            positions: Optional[jnp.ndarray] = None,
            extra_embeds: Optional[jnp.ndarray] = None,
            impl: str = "reference"
            ) -> Tuple[Dict[str, Any], jnp.ndarray]:
    """Process the prompt, build the decode state, return last-token logits.

    The cache is sized for the *internal* prompt length (tokens + frontend
    embeds + meta tokens) plus ``gen_budget`` further decode steps, capped
    at the sliding window for SWA archs.
    """
    h = embed_inputs(cfg, params, tokens, extra_embeds)
    B, P, _ = h.shape
    if positions is None:
        positions = default_positions(cfg, B, P)
    cos = sin = None
    if cfg.has_attention:
        cos, sin = _rope_tables(cfg, positions)
    state = init_decode_state(cfg, B, P + gen_budget)
    C = state["k"].shape[2] if "k" in state else 0

    def body(carry, layer_p):
        hc = dist_ctx.constrain(carry, "batch", "seq", None)
        ys = {}
        if cfg.family == "ssm":
            xn = layers.rmsnorm(layer_p["ssm_norm"], hc, cfg.norm_eps)
            y, ys["conv"], ys["ssm"] = _ssm_prefill(cfg, layer_p["ssm"], xn)
            return hc + y, ys
        xn = layers.rmsnorm(layer_p["attn_norm"], hc, cfg.norm_eps)
        if cfg.family == "hybrid":
            a, k, v = attention.attention_block(cfg, layer_p["attn"], xn,
                                                cos, sin, return_kv=True)
            s, ys["conv"], ys["ssm"] = _ssm_prefill(cfg, layer_p["ssm"], xn)
            mixed = 0.5 * (
                layers.rmsnorm(layer_p["fuse_norm_attn"], a, cfg.norm_eps)
                + layers.rmsnorm(layer_p["fuse_norm_ssm"], s, cfg.norm_eps))
            hc = hc + mixed
        else:
            a, k, v = attention.attention_block(cfg, layer_p["attn"], xn,
                                                cos, sin, return_kv=True)
            hc = hc + a
        ys["k"], ys["v"] = _fill_cache(k, C, P), _fill_cache(v, C, P)
        if cfg.d_ff:
            xm = layers.rmsnorm(layer_p["mlp_norm"], hc, cfg.norm_eps)
            if cfg.is_moe:
                y, _ = moe.moe_block(cfg, layer_p["moe"], xm)
                hc = hc + y
            else:
                hc = hc + layers.mlp(layer_p["mlp"], xm)
        return hc, ys

    h, caches = jax.lax.scan(body, h, params["layers"])
    for name, arr in caches.items():
        state[name] = arr
    state["pos"] = jnp.asarray(P, jnp.int32)
    if cfg.has_attention:
        slot = jnp.arange(C)
        if P >= C:          # ring already wrapped: slot s holds token index
            base = (slot - P % C) % C + (P - C)
            tok_idx = jnp.where(base < P - C, base + C, base)
            state["cache_pos"] = tok_idx.astype(jnp.int32)
        else:
            state["cache_pos"] = jnp.where(slot < P, slot, -1).astype(
                jnp.int32)
    h = layers.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = lm_logits(cfg, params, h[:, -1])
    return state, logits


def _fill_cache(kv: jnp.ndarray, C: int, P: int) -> jnp.ndarray:
    """Place the last min(P, C) keys at their ring slots (slot = t % C)."""
    B, _, K, hd = kv.shape
    if P >= C:
        tail = kv[:, P - C:]
        return jnp.roll(tail, P % C, axis=1)
    pad = jnp.zeros((B, C - P, K, hd), kv.dtype)
    return jnp.concatenate([kv, pad], axis=1)


def _ssm_prefill(cfg, p, x):
    """Run the SSM over the prompt, return (y, conv_state, ssm_state)."""
    di = cfg.d_inner
    xz = layers.matmul(x, p["in_proj"])
    xin, z = jnp.split(xz, [di], axis=-1)
    xc = jax.nn.silu(ssm.causal_conv(p, xin).astype(jnp.float32)).astype(
        x.dtype)
    dt, b, c = ssm._ssm_inputs(cfg, p, xc)
    A = -jnp.exp(p["A_log"])
    h0 = jnp.zeros((x.shape[0], di, cfg.ssm_state), jnp.float32)
    y, h_last = ssm.selective_scan(dt, A, b, c, xc.astype(jnp.float32), h0)
    y = y + p["D"] * xc.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = layers.matmul(y.astype(x.dtype), p["out_proj"])
    # conv ring state = the last (conv-1) raw inputs, left-padded if short
    lpad = max(0, (cfg.ssm_conv - 1) - x.shape[1])
    xin_p = jnp.pad(xin, ((0, 0), (lpad, 0), (0, 0)))
    conv_state = xin_p[:, xin_p.shape[1] - (cfg.ssm_conv - 1):]
    return out, conv_state, h_last
