from repro.optim.adam import adam, apply_updates  # noqa: F401
from repro.optim.clip import clip_by_global_norm, global_norm  # noqa: F401
from repro.optim.schedules import (  # noqa: F401
    constant,
    cosine_decay,
    linear_warmup_cosine,
)
from repro.optim.sgd import sgd  # noqa: F401
