"""Adam / AdamW on pytrees (no optax; optimizer state is a plain pytree).

The optimizer moments inherit the *sharding* of the parameters: the
moment trees share the params' tree paths and leaf names, so the learner
plane's layout rules (``distributed/sharding.fsdp_leaf_dim``) give each
moment exactly its param's spec. Under the FSDP learner (DESIGN.md §11)
the moments *stay* in storage layout through the whole step — ``update``
consumes the reduce-scattered gradient slice next to the local moment
slice, and only the resulting update slice is all-gathered
(``apply_updates``), which is the FSDP memory win.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

Schedule = Union[float, Callable[[jnp.ndarray], jnp.ndarray]]


class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]  # (grads, state, params)


def _lr_at(lr: Schedule, step) -> jnp.ndarray:
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


def adam(lr: Schedule, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, weight_decay: float = 0.0,
         moment_dtype: Optional[str] = None) -> Optimizer:
    """AdamW. Moments stored in ``moment_dtype`` (default: param dtype)."""

    def init(params):
        def zeros_like(p):
            dt = jnp.dtype(moment_dtype) if moment_dtype else p.dtype
            return jnp.zeros(p.shape, dt)
        return AdamState(jnp.zeros((), jnp.int32),
                         jax.tree.map(zeros_like, params),
                         jax.tree.map(zeros_like, params))

    def update(grads, state, params):
        step = state.step + 1
        lr_t = _lr_at(lr, step)
        t = step.astype(jnp.float32)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m2 = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            v2 = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
            delta = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps)
            if weight_decay:
                # FSDP: the gradient/moments may be a storage-layout
                # slice while p is full — decay with the matching slice
                from repro.distributed import grad_sync
                pf = grad_sync.localize_like(p, g) \
                    if grad_sync.fsdp_active() else p
                delta = delta + weight_decay * pf.astype(jnp.float32)
            return (-lr_t * delta).astype(p.dtype), m2.astype(m.dtype), \
                v2.astype(v.dtype)

        out = jax.tree.map(upd, grads, state.mu, state.nu, params)
        updates = jax.tree.map(lambda o: o[0], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree.map(lambda o: o[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree.map(lambda o: o[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        return updates, AdamState(step, mu, nu)

    return Optimizer(init=init, update=update)


def apply_updates(params, updates):
    from repro.distributed import grad_sync
    if grad_sync.fsdp_active() is not None:
        # sharded-storage leaves carry update *slices*: all-gather each
        # back to full (per-layer, tiled) so in-body params stay full
        updates = jax.tree.map(
            lambda p, u: grad_sync.expand_like(u, p), params, updates)
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)
