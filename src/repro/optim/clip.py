"""Gradient clipping utilities."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def global_norm(tree) -> jnp.ndarray:
    from repro.distributed import grad_sync
    if grad_sync.fsdp_active() is not None:
        # mixed-layout tree (FSDP learner): scattered leaves hold disjoint
        # slices, so the true global norm needs one psum over their
        # square-sums; the replicated path below stays bitwise-untouched
        return jnp.sqrt(grad_sync.fsdp_sumsq(tree))
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale
                                   ).astype(x.dtype), tree), norm
