"""SGD with optional momentum (used by ablations / DDPG target baselines)."""
from __future__ import annotations

from typing import NamedTuple, Any

import jax
import jax.numpy as jnp

from repro.optim.adam import Optimizer, _lr_at, Schedule


class SgdState(NamedTuple):
    step: jnp.ndarray
    velocity: Any


def sgd(lr: Schedule, momentum: float = 0.0) -> Optimizer:
    def init(params):
        vel = jax.tree.map(jnp.zeros_like, params) if momentum else None
        return SgdState(jnp.zeros((), jnp.int32), vel)

    def update(grads, state, params):
        del params
        step = state.step + 1
        lr_t = _lr_at(lr, step)
        if momentum:
            vel = jax.tree.map(lambda v, g: momentum * v + g,
                               state.velocity, grads)
            updates = jax.tree.map(lambda v: -lr_t * v, vel)
            return updates, SgdState(step, vel)
        updates = jax.tree.map(lambda g: -lr_t * g, grads)
        return updates, SgdState(step, None)

    return Optimizer(init=init, update=update)
