"""The unified registry: one ``register``/``make`` seam for every
pluggable component — envs, algos, sampler backends, experience buffers,
and model archs.

Before this module the framework kept three inconsistent ad-hoc tables
(``envs.__init__._REGISTRY``, ``configs.__init__._ARCH_MODULES`` and the
``if kind == ...`` chain in ``core.backends.make_backend``), each with its
own lookup, error message and extension story. Everything user-nameable
now goes through here:

    from repro import registry
    registry.register("env", "pendulum", pendulum.make)
    env = registry.make("env", "pendulum", max_episode_steps=100)
    registry.choices("algo")        # ("ddpg", "ppo", "trpo")

Kinds are created on first registration. The built-in entries for each
kind live with their implementations (``repro.envs``, ``repro.algos.api``,
``repro.core.backends``, ``repro.data.buffers``, ``repro.configs``);
``make``/``choices`` lazily
import those modules so lookup works regardless of import order.

Errors are uniform: registering a duplicate name raises ``ValueError``;
asking for an unknown name raises ``KeyError`` whose message lists the
registered choices.
"""
from __future__ import annotations

import importlib
from typing import Any, Callable, Dict, Optional, Tuple

# module that registers the built-in entries for each kind (imported
# lazily on first lookup so `registry.make("env", ...)` works without the
# caller having imported repro.envs first)
_BUILTIN_MODULES = {
    "env": "repro.envs",
    "algo": "repro.algos.api",
    "backend": "repro.core.backends",
    "buffer": "repro.data.buffers",
    "arch": "repro.configs",
    "kernel": "repro.kernels",
}

_REGISTRIES: Dict[str, Dict[str, Callable[..., Any]]] = {}


def _table(kind: str, autoload: bool = False) -> Dict[str, Callable]:
    if autoload and kind in _BUILTIN_MODULES:
        importlib.import_module(_BUILTIN_MODULES[kind])
    try:
        return _REGISTRIES[kind]
    except KeyError:
        raise KeyError(
            f"unknown registry kind {kind!r}; known kinds: "
            f"{sorted(set(_REGISTRIES) | set(_BUILTIN_MODULES))}")


def register(kind: str, name: str,
             factory: Optional[Callable[..., Any]] = None):
    """Register ``factory`` under ``(kind, name)``.

    Usable directly (``register("env", "pendulum", make)``) or as a
    decorator (``@register("algo", "ppo")``). Duplicate names are an
    error — shadowing a component silently is how experiments stop being
    reproducible.
    """
    def _do(fn: Callable) -> Callable:
        table = _REGISTRIES.setdefault(kind, {})
        if name in table:
            raise ValueError(
                f"{kind} {name!r} is already registered "
                f"(to {table[name]!r}); duplicate registration is not "
                f"allowed — pick a distinct name")
        table[name] = fn
        return fn

    return _do(factory) if factory is not None else _do


def make(kind: str, name: str, **kwargs) -> Any:
    """Instantiate the component registered under ``(kind, name)``.

    ``kwargs`` are passed to the registered factory verbatim.
    """
    table = _table(kind, autoload=True)
    try:
        factory = table[name]
    except KeyError:
        raise KeyError(
            f"unknown {kind} {name!r}; choose from {sorted(table)}")
    return factory(**kwargs)


def choices(kind: str) -> Tuple[str, ...]:
    """Sorted names registered under ``kind`` (built-ins autoloaded)."""
    return tuple(sorted(_table(kind, autoload=True)))


def contains(kind: str, name: str) -> bool:
    return name in _table(kind, autoload=True)
