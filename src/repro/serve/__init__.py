"""The policy serving plane (DESIGN.md §8): train -> checkpoint -> serve.

``PolicyServer`` micro-batches concurrent ``act(obs)`` requests into
fixed-width single device dispatches under a latency deadline, loads any
registered env x algo policy from a ``checkpoint/`` directory, and
hot-swaps params live through the versioned ``core.ipc.ParamsChannel``
a training run publishes to.
"""
from repro.serve.loader import PolicyHandle, load_policy  # noqa: F401
from repro.serve.server import (  # noqa: F401
    PendingAct,
    PolicyServer,
    ServerClosed,
    ServerOverloaded,
)
from repro.serve.stats import ServingStats, percentile  # noqa: F401
