"""Checkpoint -> servable policy resolution (DESIGN.md §8).

RL checkpoints record their fully-resolved ``ExperimentSpec`` in
``meta.json`` (``launch/train.py``), so a checkpoint directory alone
names everything a serving replica needs: the env (for ``obs_dim`` and
the action contract), the algorithm (whose ``act()`` is the policy
head), and the params structure (``algo.init`` builds the template the
arrays restore into). ``load_policy`` performs that resolution through
the same unified registry the trainer used — any env x algo that can
train can serve, MLP control policies today, sequence policies when
their ``act()`` lands on the Algorithm protocol.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax

from repro import checkpoint, registry
from repro.experiment import ExperimentSpec


@dataclasses.dataclass
class PolicyHandle:
    """A restored, servable policy: env + algo + params + provenance."""
    env: Any
    algo: Any
    params: Any
    spec: ExperimentSpec
    step: int
    directory: str

    @property
    def name(self) -> str:
        return f"{self.spec.algo}x{self.spec.env}@{self.step}"


def load_policy(ckpt_dir: str, step: Optional[int] = None) -> PolicyHandle:
    """Resolve ``ckpt_dir`` into a ``PolicyHandle``.

    Raises ``FileNotFoundError`` (from ``checkpoint.restore``) when the
    directory holds no checkpoints, and ``ValueError`` when the
    checkpoint predates spec-recording metadata (lm-mode checkpoints
    carry no env/algo identity and cannot resolve to a policy head).
    """
    meta = checkpoint.load_metadata(ckpt_dir, step)
    spec_dict = meta.get("spec")
    if spec_dict is None:
        raise ValueError(
            f"checkpoint {ckpt_dir!r} (step {meta.get('step')}) records no "
            f"ExperimentSpec in its metadata (mode="
            f"{meta.get('mode', 'unknown')!r}) — only rl-mode checkpoints "
            f"written by launch/train.py are servable")
    spec = ExperimentSpec.from_dict(spec_dict)
    env = registry.make("env", spec.env, **dict(spec.env_kwargs))
    algo = registry.make("algo", spec.algo,
                         **{**dict(spec.model), **dict(spec.algo_kwargs)})
    # template params: structure/dtypes are authoritative, values are
    # overwritten by the restore — any seed builds the same structure
    template, _ = algo.init(jax.random.PRNGKey(0), env)
    params = checkpoint.restore(ckpt_dir, template, step)
    return PolicyHandle(env=env, algo=algo, params=params, spec=spec,
                        step=int(meta["step"]), directory=ckpt_dir)


def policy_metadata(handle: PolicyHandle) -> Dict[str, Any]:
    """JSON-safe provenance block servers attach to their stats."""
    return {
        "env": handle.spec.env,
        "algo": handle.spec.algo,
        "step": handle.step,
        "directory": handle.directory,
    }
