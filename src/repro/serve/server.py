"""The policy serving plane: a dynamic-batching inference server with
live params hot-swap (DESIGN.md §8).

WALL-E decouples experience collection from learning with parallel
queues; ``PolicyServer`` applies the same decoupling to *inference*.
Concurrent ``act(obs)`` requests are admitted onto one bounded queue and
micro-batched into single device dispatches by a dispatcher thread under
a **latency deadline**: a batch launches when it fills (``slots``
requests) OR when the oldest queued request has waited ``deadline_ms``.
Batches are fixed-width and zero-padded, so request churn never
recompiles — the one jitted executable is
``vmap(algo.act)(params, obs[slots, obs_dim], keys[slots, 2])``, traced
once at ``start()``.

Determinism: a request's action depends only on its own row of the
padded batch (row-parallel ops, per-row counter-based PRNG), so the
serve path is bitwise-identical whether a request rides a full batch, a
deadline-expired partial batch, or the single-request reference path —
``tests/test_serve_plane.py`` pins this. Each request's PRNG key is
derived from ``(seed, request_id)``, so a replay of the same request ids
reproduces the same actions.

Hot-swap: a server attached to a ``core.ipc.ParamsChannel`` polls the
channel's version word between dispatches (one shared-memory read) and
copies the new leaves only when the version moved — the exact mechanism
that feeds rollout workers now feeds serving replicas, so a training
run's ``publish`` reaches a live server mid-traffic with no dropped
requests and no torn reads (the params pytree is swapped atomically
between dispatches; every completion records the version that served
it).

Backpressure: the admission queue is bounded (``queue_cap``); a full
queue rejects new work with ``ServerOverloaded`` at submit time instead
of letting latency grow without bound. In-flight requests are never
dropped — ``close()`` drains the queue before the dispatcher exits.
"""
from __future__ import annotations

import itertools
import queue as _queue
import threading
import time
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ipc import ChannelSpec, ParamsChannel
from repro.serve.stats import ServingStats


class ServerClosed(RuntimeError):
    """Submit after ``close()`` (or before ``start()``)."""


class ServerOverloaded(RuntimeError):
    """Admission queue full — backpressure; retry or raise capacity."""


class PendingAct:
    """A submitted request's completion handle (thread-safe future)."""

    __slots__ = ("request_id", "obs", "key", "enqueue_s", "_event",
                 "action", "params_version", "latency_s", "queue_wait_s")

    def __init__(self, request_id: int, obs: np.ndarray, key: np.ndarray):
        self.request_id = request_id
        self.obs = obs
        self.key = key
        self.enqueue_s = time.perf_counter()
        self._event = threading.Event()
        self.action: Optional[np.ndarray] = None
        self.params_version: Optional[int] = None
        self.latency_s: Optional[float] = None
        self.queue_wait_s: Optional[float] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"request {self.request_id} not served within {timeout}s")
        return self.action

    def _complete(self, action: np.ndarray, version: int,
                  dispatch_s: float, done_s: float) -> None:
        self.action = action
        self.params_version = version
        self.queue_wait_s = dispatch_s - self.enqueue_s
        self.latency_s = done_s - self.enqueue_s
        self._event.set()


class PolicyServer:
    """Dynamic-batching ``act()`` server over any registered env x algo.

    Parameters
    ----------
    env, algo, params : the policy — ``algo.act(params, obs, key)`` is
        the head being served; ``params`` is both the initial weights and
        the structure template hot-swapped leaves unflatten into.
    slots : fixed device batch width (requests per dispatch).
    deadline_ms : max time the *oldest* queued request waits before a
        partial batch dispatches anyway — the latency/throughput knob.
    queue_cap : admission bound (default ``16 * slots``); a full queue
        raises ``ServerOverloaded``.
    seed : per-request PRNG derivation base (key = ``(seed, request_id)``).
    params_channel : a ``ParamsChannel`` (or its picklable
        ``ChannelSpec`` to attach to) published by a live learner; the
        server follows its version mid-traffic. A spec-attached channel
        is closed with the server.
    """

    def __init__(self, env: Any, algo: Any, params: Any, *,
                 slots: int = 8, deadline_ms: float = 5.0,
                 queue_cap: Optional[int] = None, seed: int = 0,
                 params_channel: Optional[Any] = None):
        if slots < 1:
            raise ValueError(f"slots={slots} must be >= 1")
        if deadline_ms <= 0:
            raise ValueError(f"deadline_ms={deadline_ms} must be > 0")
        self.env = env
        self.algo = algo
        self.slots = int(slots)
        self.deadline_s = float(deadline_ms) / 1e3
        self.queue_cap = int(queue_cap) if queue_cap else 16 * self.slots
        self.seed = int(seed)
        self.stats = ServingStats(slots=self.slots)

        leaves, self._treedef = jax.tree_util.tree_flatten(params)
        self._params = params
        self._channel: Optional[ParamsChannel] = None
        self._own_channel = False
        self.params_version = 0
        if params_channel is not None:
            if isinstance(params_channel, ChannelSpec):
                self._channel = ParamsChannel.attach(params_channel)
                self._own_channel = True
            else:
                self._channel = params_channel
            if len(self._channel.spec.leaves) != len(leaves):
                raise ValueError(
                    f"params channel carries "
                    f"{len(self._channel.spec.leaves)} leaves, the policy "
                    f"has {len(leaves)} — channel and checkpoint disagree")

        self._batched_act = jax.jit(
            jax.vmap(self.algo.act, in_axes=(None, 0, 0)))
        self._queue: "_queue.Queue[PendingAct]" = _queue.Queue(
            maxsize=self.queue_cap)
        self._ids = itertools.count()
        self._stop = threading.Event()
        self._started = False
        self._closed = False
        self._thread: Optional[threading.Thread] = None

    @classmethod
    def from_checkpoint(cls, ckpt_dir: str, step: Optional[int] = None,
                        **kwargs) -> "PolicyServer":
        """Build a server from a training checkpoint directory (the
        ``launch/train.py --ckpt-dir`` output); see ``serve.loader``."""
        from repro.serve.loader import load_policy
        handle = load_policy(ckpt_dir, step)
        return cls(handle.env, handle.algo, handle.params, **kwargs)

    # ------------------------------------------------------------ lifecycle
    def start(self, warmup: bool = True) -> "PolicyServer":
        """Spawn the dispatcher thread; ``warmup`` traces/compiles the
        batched executable first so the first live request never pays
        compile time against its deadline."""
        if self._closed:
            raise ServerClosed("server was closed; build a new one")
        if self._started:
            return self
        if self._channel is not None:
            self._poll_channel()          # serve the freshest published v
        if warmup:
            obs, keys = self._alloc_batch()
            jax.block_until_ready(
                self._batched_act(self._params, obs, keys))
        self._started = True
        self._thread = threading.Thread(
            target=self._serve_loop, name="policy-server", daemon=True)
        self._thread.start()
        return self

    def close(self) -> None:
        """Stop admission, drain every queued request, join, release."""
        if self._closed:
            return
        self._closed = True
        self._stop.set()
        if self._thread is not None:
            self._thread.join()
        elif not self._queue.empty():
            self._serve_loop()      # never started: drain inline — the
            #                         no-dropped-requests rule still holds
        if self._own_channel and self._channel is not None:
            self._channel.close()
        self._channel = None

    def __enter__(self) -> "PolicyServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------ admission
    def submit(self, obs: Any, *, key: Optional[Any] = None) -> PendingAct:
        """Enqueue one observation; returns its completion handle.

        Admission is open from construction — requests submitted before
        ``start()`` queue up and are served once the dispatcher runs.
        Raises ``ServerOverloaded`` when the admission queue is full and
        ``ServerClosed`` after ``close()``.
        """
        if self._closed:
            raise ServerClosed("server is closed")
        obs = np.asarray(obs, dtype=np.float32)
        if obs.shape != (self.env.obs_dim,):
            raise ValueError(
                f"obs shape {obs.shape} != ({self.env.obs_dim},) for env "
                f"{self.env.name!r}")
        rid = next(self._ids)
        if key is None:
            # a threefry key is two uint32 words; (seed, request_id) gives
            # every request its own deterministic, replayable stream
            # without a host->device round-trip per submit
            key = np.array([self.seed, rid], dtype=np.uint32)
        else:
            key = np.asarray(key, dtype=np.uint32).reshape(2)
        pending = PendingAct(rid, obs, key)
        try:
            self._queue.put_nowait(pending)
        except _queue.Full:
            raise ServerOverloaded(
                f"admission queue full ({self.queue_cap} requests "
                f"in-flight at slots={self.slots}) — backpressure; retry "
                f"later or raise queue_cap/slots") from None
        return pending

    def act(self, obs: Any, *, key: Optional[Any] = None,
            timeout: Optional[float] = 30.0) -> np.ndarray:
        """Blocking convenience: ``submit`` + ``result``."""
        return self.submit(obs, key=key).result(timeout)

    def reference_act(self, obs: Any, key: Any) -> np.ndarray:
        """The single-request oracle: one observation through the same
        compiled padded-batch executable, occupancy 1. The serve path is
        bitwise-identical to this for every batching pattern (tested)."""
        obs_b, keys_b = self._alloc_batch()
        obs_b[0] = np.asarray(obs, dtype=np.float32)
        keys_b[0] = np.asarray(key, dtype=np.uint32).reshape(2)
        actions, _ = self._batched_act(self._params, obs_b, keys_b)
        return np.asarray(actions)[0].copy()

    # ------------------------------------------------------------ the loop
    def _alloc_batch(self) -> Tuple[np.ndarray, np.ndarray]:
        return (np.zeros((self.slots, self.env.obs_dim), np.float32),
                np.zeros((self.slots, 2), np.uint32))

    def _poll_channel(self) -> None:
        """Pick up a newly published params version, if any (one shared
        version-word read when nothing changed)."""
        leaves, version = self._channel.read(
            min_version=0, last_version=self.params_version)
        if leaves is not None:
            self._params = self._treedef.unflatten(
                [jnp.asarray(x) for x in leaves])
            self.params_version = version

    def _serve_loop(self) -> None:
        while True:
            batch = []
            while not batch:                      # wait for the first rider
                if self._stop.is_set() and self._queue.empty():
                    return                        # drained — nothing dropped
                if self._channel is not None:     # track publishes while idle
                    self._poll_channel()
                try:
                    batch.append(self._queue.get(timeout=0.005))
                except _queue.Empty:
                    continue
            deadline = batch[0].enqueue_s + self.deadline_s
            while len(batch) < self.slots:        # fill until full/expired
                remaining = deadline - time.perf_counter()
                if remaining <= 0:
                    break
                try:
                    batch.append(self._queue.get(timeout=remaining))
                except _queue.Empty:
                    break
            if self._channel is not None:         # hot-swap between batches
                self._poll_channel()
            self._dispatch(batch)

    def _dispatch(self, batch) -> None:
        obs_b, keys_b = self._alloc_batch()
        for i, req in enumerate(batch):
            obs_b[i] = req.obs
            keys_b[i] = req.key
        t_dispatch = time.perf_counter()
        actions, _extras = self._batched_act(self._params, obs_b, keys_b)
        actions = np.asarray(actions)             # blocks until ready
        t_done = time.perf_counter()
        version = self.params_version
        for i, req in enumerate(batch):
            req._complete(actions[i].copy(), version, t_dispatch, t_done)
            self.stats.observe(latency_s=t_done - req.enqueue_s,
                               queue_wait_s=t_dispatch - req.enqueue_s)
        self.stats.observe_batch(len(batch))

    # ------------------------------------------------------------- reading
    def snapshot(self) -> dict:
        """The shared serving-stats schema (``serve.stats``), plus the
        live params version."""
        snap = self.stats.snapshot()
        snap["params_version"] = self.params_version
        return snap
