"""Serving statistics — one schema for every batch server (DESIGN.md §8).

Both serving surfaces in the repo — the RL policy server
(``serve.server.PolicyServer``) and the LM token server
(``core.serving.SlotServer``) — admit requests into fixed-width slot
batches, so they share one accounting vocabulary: per-request latency and
queue wait, per-dispatch batch occupancy, and the slot-steps a fixed
batch width wastes on padding / finished slots. ``ServingStats`` is that
vocabulary as a class; ``snapshot()`` is the schema benchmarks and CI
read, identical for both servers:

    {"requests", "dispatches", "slots",
     "latency_ms":    {"p50", "p99", "mean", "max"},
     "queue_wait_ms": {"p50", "p99", "mean", "max"},
     "batch_occupancy": mean fraction of slots doing real work,
     "wasted_slot_steps": padded/finished slot-dispatches,
     "requests_per_sec": completion throughput over the observed span}

Percentiles use the nearest-rank method over every recorded sample —
serving benches record hundreds to thousands of requests, so the exact
empirical distribution is affordable and reproducible (no histogram
binning error in the recorded p99).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional


def percentile(samples: List[float], q: float) -> float:
    """Nearest-rank percentile (``q`` in [0, 100]) of ``samples``."""
    if not samples:
        return 0.0
    xs = sorted(samples)
    rank = max(1, int(-(-q * len(xs) // 100)))       # ceil, clamped to >= 1
    return xs[min(rank, len(xs)) - 1]


def _dist_ms(samples_s: List[float]) -> Dict[str, float]:
    if not samples_s:
        return {"p50": 0.0, "p99": 0.0, "mean": 0.0, "max": 0.0}
    return {
        "p50": percentile(samples_s, 50) * 1e3,
        "p99": percentile(samples_s, 99) * 1e3,
        "mean": sum(samples_s) / len(samples_s) * 1e3,
        "max": max(samples_s) * 1e3,
    }


class ServingStats:
    """Accumulates per-request and per-dispatch serving metrics.

    ``observe(latency_s, queue_wait_s)`` once per completed request;
    ``observe_batch(occupied)`` once per device dispatch (``occupied`` =
    slots carrying real work — the remaining ``slots - occupied`` are
    wasted on padding or already-finished requests and accumulate into
    ``wasted_slot_steps``). Not thread-safe by itself; servers call it
    from their single dispatcher thread and take a snapshot after (or
    guard externally).
    """

    def __init__(self, slots: int):
        self.slots = int(slots)
        self.latencies_s: List[float] = []
        self.queue_waits_s: List[float] = []
        self.dispatches = 0
        self.occupied_slot_steps = 0
        self.wasted_slot_steps = 0
        self._first_s: Optional[float] = None
        self._last_s: Optional[float] = None

    # ------------------------------------------------------------ recording
    def observe(self, latency_s: float, queue_wait_s: float) -> None:
        now = time.perf_counter()
        if self._first_s is None:
            self._first_s = now - latency_s      # back-date to the enqueue
        self._last_s = now
        self.latencies_s.append(float(latency_s))
        self.queue_waits_s.append(float(queue_wait_s))

    def observe_batch(self, occupied: int) -> None:
        occupied = int(occupied)
        if not 0 <= occupied <= self.slots:
            raise ValueError(
                f"occupied={occupied} out of range for slots={self.slots}")
        self.dispatches += 1
        self.occupied_slot_steps += occupied
        self.wasted_slot_steps += self.slots - occupied

    # ------------------------------------------------------------- reading
    @property
    def requests(self) -> int:
        return len(self.latencies_s)

    def snapshot(self) -> Dict:
        span = ((self._last_s - self._first_s)
                if self._first_s is not None and self._last_s is not None
                else 0.0)
        total_slot_steps = self.occupied_slot_steps + self.wasted_slot_steps
        return {
            "requests": self.requests,
            "dispatches": self.dispatches,
            "slots": self.slots,
            "latency_ms": _dist_ms(self.latencies_s),
            "queue_wait_ms": _dist_ms(self.queue_waits_s),
            "batch_occupancy": (self.occupied_slot_steps / total_slot_steps
                                if total_slot_steps else 0.0),
            "wasted_slot_steps": self.wasted_slot_steps,
            "requests_per_sec": (self.requests / span if span > 0 else 0.0),
        }
