"""Shared pytest fixtures. NOTE: no XLA_FLAGS here on purpose — smoke tests
and benches must see the real single CPU device; only launch/dryrun.py (run
as its own process) materialises the 512 placeholder devices."""
import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


@pytest.fixture(autouse=True, scope="session")
def _x64_off():
    jax.config.update("jax_enable_x64", False)
    yield


def assert_trees_close(a, b, atol=1e-5, rtol=1e-5):
    for xa, xb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(xa, np.float32),
                                   np.asarray(xb, np.float32),
                                   atol=atol, rtol=rtol)
