"""Shared pytest fixtures. NOTE: no XLA_FLAGS here on purpose — smoke tests
and benches must see the real single CPU device; only launch/dryrun.py (run
as its own process) materialises the 512 placeholder devices."""
import importlib.util

import jax
import numpy as np
import pytest

# Property-based modules need hypothesis; when it is absent (minimal
# environments), skip them at collection instead of erroring at import.
_HYPOTHESIS_MODULES = [
    "test_algos.py",
    "test_attention.py",
    "test_core_queues.py",
    "test_envs_data.py",
    "test_kernel_plane_prop.py",
    "test_optim_ckpt.py",
    "test_wrappers.py",
]
collect_ignore = (
    [] if importlib.util.find_spec("hypothesis") else _HYPOTHESIS_MODULES)


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running end-to-end tests (excluded in CI)")


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


@pytest.fixture(autouse=True, scope="session")
def _x64_off():
    jax.config.update("jax_enable_x64", False)
    yield


def assert_trees_close(a, b, atol=1e-5, rtol=1e-5):
    for xa, xb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(xa, np.float32),
                                   np.asarray(xb, np.float32),
                                   atol=atol, rtol=rtol)
