"""Algorithm invariants: GAE limits, PPO surrogate, DDPG update."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.algos import ddpg as ddpg_mod
from repro.algos.gae import gae, normalize
from repro.algos.ppo import PPOConfig, clipped_surrogate
from repro.optim import adam

finite_f = st.floats(-5, 5, allow_nan=False, allow_infinity=False,
                     width=32)


@settings(max_examples=25, deadline=None)
@given(st.lists(finite_f, min_size=2, max_size=20),
       st.floats(0.1, 0.99))
def test_gae_lambda1_is_discounted_mc(rs, gamma):
    """lam=1: advantage + value == discounted Monte-Carlo return."""
    T = len(rs)
    rewards = jnp.asarray(rs)[:, None]
    values = jnp.zeros((T, 1))
    dones = jnp.zeros((T, 1))
    adv, ret = gae(rewards, values, dones, jnp.zeros((1,)), gamma, 1.0)
    mc = np.zeros(T)
    acc = 0.0
    for t in reversed(range(T)):
        acc = rs[t] + gamma * acc
        mc[t] = acc
    np.testing.assert_allclose(np.asarray(ret[:, 0]), mc, rtol=2e-5,
                               atol=2e-5)


@settings(max_examples=25, deadline=None)
@given(st.lists(finite_f, min_size=3, max_size=15), st.floats(0.5, 0.99))
def test_gae_lambda0_is_td_residual(rs, gamma):
    T = len(rs)
    rewards = jnp.asarray(rs)[:, None]
    values = jnp.linspace(-1, 1, T)[:, None]
    dones = jnp.zeros((T, 1))
    last_v = jnp.ones((1,)) * 0.3
    adv, _ = gae(rewards, values, dones, last_v, gamma, 0.0)
    v_next = np.append(np.asarray(values[1:, 0]), 0.3)
    td = np.asarray(rewards[:, 0]) + gamma * v_next - np.asarray(
        values[:, 0])
    np.testing.assert_allclose(np.asarray(adv[:, 0]), td, rtol=2e-4,
                               atol=2e-4)


def test_gae_no_bootstrap_across_done():
    rewards = jnp.asarray([1.0, 1.0, 1.0, 1.0])[:, None]
    values = jnp.zeros((4, 1))
    dones = jnp.asarray([0.0, 1.0, 0.0, 0.0])[:, None]
    adv, ret = gae(rewards, values, dones, jnp.ones((1,)) * 100.0,
                   0.9, 1.0)
    # return at t=0,1 must not see the big bootstrap after the done at t=1
    assert float(ret[0, 0]) == pytest.approx(1.0 + 0.9, rel=1e-5)
    assert float(ret[1, 0]) == pytest.approx(1.0, rel=1e-5)


@settings(max_examples=40, deadline=None)
@given(finite_f, finite_f, st.floats(-3, 3), st.floats(0.05, 0.4))
def test_clipped_surrogate_pessimism(logp, blogp, adv, eps):
    """Clipped objective is always <= unclipped (surrogate is pessimistic)."""
    loss = float(clipped_surrogate(jnp.asarray(logp), jnp.asarray(blogp),
                                   jnp.asarray(adv), eps))
    ratio = np.exp(logp - blogp)
    unclipped = -ratio * adv
    assert loss >= unclipped - 1e-5


def test_normalize_stats():
    x = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    n = normalize(x)
    assert abs(float(jnp.mean(n))) < 1e-6
    assert abs(float(jnp.std(n)) - 1.0) < 1e-3


def test_ddpg_update_improves_critic():
    key = jax.random.PRNGKey(0)
    params = ddpg_mod.init_ddpg(key, obs_dim=3, act_dim=2, hidden=16)
    cfg = ddpg_mod.DDPGConfig()
    a_opt, c_opt = adam(1e-3), adam(1e-3)
    states = (a_opt.init(params["actor"]), c_opt.init(params["critic"]))
    batch = {
        "obs": jax.random.normal(key, (32, 3)),
        "actions": jax.random.uniform(key, (32, 2), minval=-1, maxval=1),
        "rewards": jax.random.normal(key, (32,)),
        "next_obs": jax.random.normal(key, (32, 3)),
        "dones": jnp.zeros((32,)),
    }
    step = jax.jit(lambda p, s: ddpg_mod.ddpg_update(p, s, batch, cfg,
                                                     a_opt, c_opt))
    losses = []
    for _ in range(20):
        params, states, metrics = step(params, states)
        losses.append(float(metrics["critic_loss"]))
    assert losses[-1] < losses[0]
    # polyak targets moved toward the online nets but are not equal
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     params["target_critic"], params["critic"])
    assert max(jax.tree.leaves(d)) > 0.0
