"""Per-architecture smoke tests (reduced variants of the assigned configs).

Each of the 10 archs: instantiate the reduced family member (2 layers,
d_model <= 512, <= 4 experts), run one forward + one PPO train step + a
prefill/decode roundtrip on CPU; assert output shapes and no NaNs, and that
decode agrees with teacher-forced forward (the sampler's inner step computes
the same function the learner differentiates).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.algos.ppo import PPOConfig, make_lm_train_step
from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import transformer as T
from repro.optim import adam

B, S = 2, 32


def _inputs(cfg, key):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    extra = None
    if cfg.frontend != "none":
        extra = 0.02 * jax.random.normal(
            key, (B, cfg.frontend_embeds, cfg.d_model))
    return toks, extra


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_shapes_no_nan(arch, rng_key):
    cfg = get_config(arch).reduced()
    params = T.init_params(cfg, rng_key)
    toks, extra = _inputs(cfg, rng_key)
    h, aux = T.forward(cfg, params, toks, extra_embeds=extra)
    total = S + (cfg.frontend_embeds if extra is not None else 0) \
        + cfg.n_meta_tokens
    assert h.shape == (B, total, cfg.d_model)
    assert not bool(jnp.isnan(h).any())
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step(arch, rng_key):
    cfg = get_config(arch).reduced()
    params = T.init_params(cfg, rng_key)
    opt = adam(1e-3)
    opt_state = opt.init(params)
    step = jax.jit(make_lm_train_step(cfg, opt, PPOConfig()))
    toks, extra = _inputs(cfg, rng_key)
    batch = {
        "tokens": toks,
        "targets": jnp.roll(toks, -1, axis=1),
        "behavior_logp": -jnp.full((B, S), 3.0),
        "advantages": jax.random.normal(rng_key, (B, S)),
        "returns": jax.random.normal(rng_key, (B, S)),
        "mask": jnp.ones((B, S)),
    }
    if extra is not None:
        batch["extra_embeds"] = extra
    params2, opt_state, metrics = step(params, opt_state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert float(metrics["grad_norm"]) > 0.0
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_matches_forward(arch, rng_key):
    """Teacher-forcing equivalence: logits from step-by-step decode must
    match the full forward pass (cache/ring/state correctness).

    MoE archs run with an ample capacity factor: capacity-based top-k MoE
    has inherent train/serve skew (a token that loses the within-sequence
    capacity race at train time cannot lose it when decoded alone). With no
    drops on either path the outputs must agree exactly — that isolates
    cache correctness, which is what this test is for.
    """
    import dataclasses
    cfg = get_config(arch).reduced()
    if cfg.is_moe:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = T.init_params(cfg, rng_key)
    toks, extra = _inputs(cfg, rng_key)

    h, _ = T.forward(cfg, params, toks, extra_embeds=extra, remat="none")
    full_logits = T.lm_logits(cfg, params, h[:, -4:])     # last 4 positions

    state, logits_p = T.prefill(cfg, params, toks[:, :-3], gen_budget=4,
                                extra_embeds=extra)
    # decode tokens S-3 .. S-1 (teacher forcing with the true tokens)
    got = [logits_p]
    for i in range(S - 3, S):
        state, lg = T.decode_step(cfg, params, state, toks[:, i:i + 1])
        got.append(lg)
    got = jnp.stack(got, axis=1)                          # (B, 4, V)
    err = float(jnp.max(jnp.abs(got - full_logits)))
    assert err < 2e-2, f"decode/forward mismatch: {err}"


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_count_matches(arch, rng_key):
    cfg = get_config(arch).reduced()
    params = T.init_params(cfg, rng_key)
    actual = sum(x.size for x in jax.tree.leaves(params))
    # value head (d_model + 1) is framework-side, not in the analytic count
    assert cfg.param_count() == actual - (cfg.d_model + 1)
