"""Model attention paths (recursive-halving causal, banded SWA, decode)
vs a naive oracle, including hypothesis property sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import attention as A

KEY = jax.random.PRNGKey(3)


def naive(q, k, v, window=0):
    B, S, K, G, hd = q.shape
    qr = q.reshape(B, S, K * G, hd)
    kr = jnp.repeat(k, G, axis=2)
    vr = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", qr, kr) / hd ** 0.5
    i, j = jnp.arange(S)[:, None], jnp.arange(S)[None, :]
    m = j <= i
    if window:
        m &= (i - j) < window
    s = jnp.where(m[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vr).reshape(B, S, K, G, hd)


def _rand(S, K, G, hd=16, B=1):
    ks = jax.random.split(KEY, 3)
    return (jax.random.normal(ks[0], (B, S, K, G, hd)),
            jax.random.normal(ks[1], (B, S, K, hd)),
            jax.random.normal(ks[2], (B, S, K, hd)))


@settings(max_examples=12, deadline=None)
@given(
    s_exp=st.integers(5, 10),
    K=st.integers(1, 3),
    G=st.integers(1, 3),
    leaf=st.sampled_from([64, 128, 256]),
)
def test_full_causal_property(s_exp, K, G, leaf):
    S = 2 ** s_exp
    q, k, v = _rand(S, K, G)
    got = A.full_causal(q, k, v, leaf=leaf, kv_block=leaf)
    ref = naive(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=3e-5)


@settings(max_examples=10, deadline=None)
@given(
    S=st.sampled_from([96, 256, 513, 640, 1100]),
    window=st.sampled_from([16, 100, 256]),
)
def test_swa_property(S, window):
    q, k, v = _rand(S, 2, 2)
    got = A.swa(q, k, v, window, q_block=128)
    ref = naive(q, k, v, window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=3e-5)


def test_decode_vs_naive_ring():
    """Ring-buffer decode with partially valid slots == masked softmax."""
    B, Sc, K, G, hd = 2, 64, 2, 3, 16
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, K, G, hd))
    kc = jax.random.normal(ks[1], (B, Sc, K, hd))
    vc = jax.random.normal(ks[2], (B, Sc, K, hd))
    valid = jax.random.bernoulli(ks[3], 0.5, (Sc,)).at[3].set(True)
    got = A.decode(q, kc, vc, valid)
    kr = jnp.repeat(kc, G, axis=2)
    vr = jnp.repeat(vc, G, axis=2)
    s = jnp.einsum("bkgh,bskh->bkgs", q.reshape(B, K, G, hd),
                   kc) / hd ** 0.5
    s = jnp.where(valid[None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, -1)
    ref = jnp.einsum("bkgs,bskh->bkgh", p, vc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


def test_softmax_stats_merge_associative():
    """_merge is associative and order-insensitive over KV partitions —
    the invariant flash-decoding's cross-shard combine relies on."""
    q, k, v = _rand(128, 1, 2)
    full = A._block_stats(q, k, v, None)
    s1 = A._block_stats(q, k[:, :32], v[:, :32], None)
    s2 = A._block_stats(q, k[:, 32:80], v[:, 32:80], None)
    s3 = A._block_stats(q, k[:, 80:], v[:, 80:], None)
    m_lr = A._merge(A._merge(s1, s2), s3)
    m_rl = A._merge(s1, A._merge(s2, s3))
    for a, b in zip(m_lr, m_rl):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    out_full = A._finalize(full, jnp.float32)
    out_merge = A._finalize(m_lr, jnp.float32)
    np.testing.assert_allclose(np.asarray(out_merge), np.asarray(out_full),
                               atol=1e-5)
