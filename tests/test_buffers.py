"""Experience-plane tests: buffer semantics (wraparound, n-step,
prioritized sampling distribution + importance weights, sum-tree
invariants), the empty-ring guard, and fused-vs-stepped parity for an
off-policy algorithm (buffer state riding the donated scan carry)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import experiment
from repro.data.buffers import (
    FifoBuffer,
    PrioritizedBuffer,
    UniformBuffer,
    nstep_transitions,
    sumtree_build,
    sumtree_find,
    sumtree_update,
)
from repro.data.replay import init_replay, sample
from repro.experiment import ExperimentSpec, Schedule


def make_traj(T, B, obs_dim=3, act_dim=2, reward=1.0, dones=None):
    """A recognizable off-policy trajectory batch: obs[t] = t."""
    t_grid = jnp.broadcast_to(
        jnp.arange(T, dtype=jnp.float32)[:, None, None], (T, B, obs_dim))
    return {
        "obs": t_grid,
        "actions": jnp.zeros((T, B, act_dim)),
        "rewards": jnp.full((T, B), reward),
        "dones": (jnp.zeros((T, B), bool) if dones is None else dones),
        "next_obs": t_grid + 1.0,
    }


def _example(obs_dim=3, act_dim=2):
    return {
        "obs": jnp.zeros((1, obs_dim)),
        "actions": jnp.zeros((1, act_dim)),
        "rewards": jnp.zeros((1,)),
        "next_obs": jnp.zeros((1, obs_dim)),
        "dones": jnp.zeros((1,), bool),
    }


# =============================================================== fifo
def test_fifo_is_identity_passthrough():
    buf = FifoBuffer()
    traj = make_traj(4, 2)
    state = buf.init(traj)
    assert all(float(jnp.sum(jnp.abs(v))) == 0.0
               for v in jax.tree.leaves(state))
    state = buf.add(state, traj)
    out = buf.sample(state, jax.random.PRNGKey(0))
    for k in traj:
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(traj[k]))


# ======================================================== ring wraparound
@pytest.mark.parametrize("cap,iters", [(64, 1), (64, 3), (32, 5), (17, 4)])
def test_uniform_ring_wraparound(cap, iters):
    """Property: after adding k trajectories of T*B transitions each, the
    ring holds min(cap, k*T*B) and the write head stays in range; once
    wrapped, only the newest `capacity` transitions survive."""
    T, B = 4, 2
    buf = UniformBuffer(capacity=cap, batch_size=8)
    state = buf.init(_example())
    for k in range(iters):
        state = buf.add(state, make_traj(T, B, reward=float(k)))
    n = iters * T * B
    assert int(state.size) == min(cap, n)
    assert 0 <= int(state.index) < cap
    if n > cap:
        # oldest rewards were overwritten: the ring only holds the newest
        survivors = np.asarray(state.storage["rewards"])
        dropped = (n - cap) // (T * B)  # fully-overwritten trajectories
        assert survivors.min() >= 0.0
        assert set(np.unique(survivors)) <= set(
            float(k) for k in range(dropped, iters))


def test_uniform_sample_contract():
    buf = UniformBuffer(capacity=64, batch_size=16)
    state = buf.add(buf.init(_example()), make_traj(4, 2, reward=7.0))
    batch = buf.sample(state, jax.random.PRNGKey(0))
    assert set(batch) == {"obs", "actions", "rewards", "next_obs",
                          "discounts", "indices", "weights"}
    assert batch["rewards"].shape == (16,)
    # only filled slots are drawn
    assert np.all(np.asarray(batch["indices"]) < 8)
    np.testing.assert_array_equal(np.asarray(batch["rewards"]),
                                  np.full((16,), 7.0))
    np.testing.assert_array_equal(np.asarray(batch["weights"]),
                                  np.ones((16,)))


# ================================================================= n-step
def test_nstep_matches_hand_computation():
    """n=2, gamma=0.5, a done inside one window: rewards truncate at the
    terminal and its discount zeroes the bootstrap."""
    T, B = 4, 1
    dones = jnp.asarray([[False], [True], [False], [False]])
    traj = make_traj(T, B, dones=dones)
    traj["rewards"] = jnp.asarray([[1.0], [2.0], [3.0], [4.0]])
    flat = nstep_transitions(traj, n_step=2, gamma=0.5)
    assert flat["rewards"].shape == (3,)          # T - n + 1 windows
    np.testing.assert_allclose(np.asarray(flat["rewards"]),
                               [1.0 + 0.5 * 2.0,  # full window
                                2.0,              # truncated at the done
                                3.0 + 0.5 * 4.0])
    np.testing.assert_allclose(np.asarray(flat["discounts"]),
                               [0.0, 0.0, 0.25])  # gamma^2 when alive
    # next_obs is the observation n steps ahead
    np.testing.assert_allclose(np.asarray(flat["next_obs"][:, 0]),
                               [2.0, 3.0, 4.0])


def test_nstep_1_is_plain_transitions():
    traj = make_traj(5, 2)
    flat = nstep_transitions(traj, n_step=1, gamma=0.9)
    assert flat["rewards"].shape == (10,)
    np.testing.assert_allclose(np.asarray(flat["discounts"]),
                               np.full((10,), 0.9))


def test_nstep_rejects_bad_horizon():
    with pytest.raises(ValueError, match="n_step"):
        nstep_transitions(make_traj(4, 1), n_step=5, gamma=0.9)


# =============================================================== sum-tree
def test_sumtree_build_and_find():
    leaves = jnp.asarray([1.0, 0.0, 2.0, 1.0])
    tree = sumtree_build(leaves)
    assert float(tree.total) == 4.0
    for mass, leaf in [(0.5, 0), (1.5, 2), (2.9, 2), (3.5, 3)]:
        assert int(sumtree_find(tree, jnp.float32(mass))) == leaf


def test_sumtree_path_update_matches_full_rebuild():
    """O(log cap) path recomputation leaves every tree level identical to
    a from-scratch rebuild, including with duplicate indices."""
    tree = sumtree_build(jnp.arange(16.0))
    idx = jnp.asarray([3, 7, 7, 12, 0])
    vals = jnp.asarray([1.0, 2.0, 2.0, 5.0, 0.5])
    updated = sumtree_update(tree, idx, vals)
    rebuilt = sumtree_build(updated.levels[0])
    for a, b in zip(updated.levels, rebuilt.levels):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_prioritized_sampling_follows_priorities():
    """Empirical draw frequencies track priority mass (alpha=1)."""
    buf = PrioritizedBuffer(capacity=4, batch_size=4096, alpha=1.0,
                            beta=0.4, eps=0.0)
    state = buf.add(buf.init(_example()), make_traj(2, 2))  # fills 4 slots
    priorities = jnp.asarray([1.0, 1.0, 2.0, 4.0])
    state = buf.update_priorities(state, jnp.arange(4), priorities)
    batch = buf.sample(state, jax.random.PRNGKey(0))
    counts = np.bincount(np.asarray(batch["indices"]), minlength=4)
    freqs = counts / counts.sum()
    np.testing.assert_allclose(freqs, np.asarray(priorities) / 8.0,
                               atol=0.02)


def test_prioritized_importance_weights():
    buf = PrioritizedBuffer(capacity=4, batch_size=512, alpha=1.0,
                            beta=1.0, eps=0.0)
    state = buf.add(buf.init(_example()), make_traj(2, 2))
    state = buf.update_priorities(state, jnp.arange(4),
                                  jnp.asarray([1.0, 1.0, 2.0, 4.0]))
    batch = buf.sample(state, jax.random.PRNGKey(1))
    idx = np.asarray(batch["indices"])
    w = np.asarray(batch["weights"])
    assert w.max() == pytest.approx(1.0)
    # beta=1: weights are exactly inverse-proportional to priority, and
    # the rarest transition carries the max weight
    w_hi = w[idx == 3].mean()
    w_lo = w[idx == 0].mean()
    assert w_lo == pytest.approx(4.0 * w_hi, rel=1e-5)


def test_prioritized_new_adds_get_max_priority():
    buf = PrioritizedBuffer(capacity=8, batch_size=8, alpha=1.0)
    state = buf.add(buf.init(_example()), make_traj(2, 2))
    state = buf.update_priorities(state, jnp.arange(4),
                                  jnp.asarray([0.1, 0.1, 0.1, 5.0]))
    assert float(state.max_priority) == pytest.approx(5.0, rel=1e-5)
    state = buf.add(state, make_traj(2, 2))        # slots 4..7
    leaves = np.asarray(state.tree.levels[0])
    np.testing.assert_allclose(leaves[4:], np.full((4,), 5.0), rtol=1e-5)


def test_prioritized_capacity_rounds_to_power_of_two():
    assert PrioritizedBuffer(capacity=100).capacity == 128
    assert PrioritizedBuffer(capacity=64).capacity == 64


# ==================================================== empty-ring guard
def test_replay_sample_empty_raises():
    """Regression: an empty ring used to silently yield zero-filled
    slot-0 transitions; eagerly it now raises."""
    state = init_replay(8, {"x": jnp.zeros((1, 2))})
    with pytest.raises(ValueError, match="empty replay"):
        sample(state, jax.random.PRNGKey(0), 4)


@pytest.mark.parametrize("cls", [UniformBuffer, PrioritizedBuffer])
def test_buffer_sample_empty_raises(cls):
    """The plane-level samplers go through the same guard."""
    buf = cls(capacity=8, batch_size=4)
    with pytest.raises(ValueError, match="empty replay"):
        buf.sample(buf.init(_example()), jax.random.PRNGKey(0))


def test_buffer_gamma_comes_from_the_algo():
    """One source of truth for the discount: buffer_kwargs['gamma'] is
    rejected, and the algo's gamma reaches the n-step transform."""
    spec = ExperimentSpec(env="pendulum", algo="ddpg",
                          model={"hidden": 16},
                          buffer_kwargs={"gamma": 0.5},
                          schedule=Schedule(num_samplers=1, global_batch=2,
                                            horizon=4, seed=0))
    with pytest.raises(ValueError, match="algo_kwargs"):
        experiment.build(spec)
    runner = experiment.build(ExperimentSpec(
        env="pendulum", algo="ddpg", model={"hidden": 16},
        algo_kwargs={"gamma": 0.9, "updates_per_collect": 1},
        buffer_kwargs={"capacity": 64, "batch_size": 4},
        schedule=Schedule(num_samplers=1, global_batch=2, horizon=4,
                          seed=0)))
    runner.run(1)
    # every stored transition's discount is gamma^1 = 0.9 (no terminals
    # in a 4-step pendulum rollout)
    discounts = np.asarray(runner.buffer_state.storage["discounts"][:8])
    np.testing.assert_allclose(discounts, np.full((8,), 0.9), rtol=1e-6)


# =============================================== fused-vs-stepped parity
@pytest.mark.parametrize("buffer", ["uniform", "prioritized"])
def test_fused_matches_stepped_offpolicy(buffer):
    """The buffer-in-scan-carry path: a fused DDPG run (ring + sum-tree
    inside the donated lax.scan carry) reproduces the stepped SyncRunner
    run exactly — fusing the plane is a scheduling change, not a
    numerical one."""
    common = dict(
        env="pendulum", algo="ddpg", model={"hidden": 16},
        buffer=buffer,
        buffer_kwargs={"capacity": 256, "batch_size": 16},
        algo_kwargs={"updates_per_collect": 2},
    )
    sched = dict(num_samplers=1, global_batch=4, horizon=8, iterations=3,
                 seed=0)
    stepped = experiment.run(ExperimentSpec(
        **common, backend="inline", runtime="sync",
        schedule=Schedule(**sched)))
    fused = experiment.run(ExperimentSpec(
        **common, backend="inline", runtime="fused",
        schedule=Schedule(**sched, chunk=3)))
    for xa, xb in zip(jax.tree.leaves(stepped.params),
                      jax.tree.leaves(fused.params)):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))
    # and the planes agree too: same ring contents, same write head
    for xa, xb in zip(jax.tree.leaves(stepped.runner.buffer_state),
                      jax.tree.leaves(fused.runner.buffer_state)):
        np.testing.assert_allclose(np.asarray(xa), np.asarray(xb),
                                   rtol=1e-6, atol=1e-6)
