"""Explicit flash-decoding combine == single-device masked softmax.

Runs shard_map on a small multi-device CPU mesh (own process would need
XLA_FLAGS before jax init; here we reuse however many devices exist and
fall back to a 1-slice mesh, which still exercises the shard_map path).
"""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.distributed.collectives import flash_decode_shardmap
from repro.kernels.decode_attention.ref import decode_ref


def test_flash_decode_shardmap_matches_ref():
    devs = np.asarray(jax.devices())
    n = len(devs)
    mesh = Mesh(devs.reshape(n), ("model",))
    B, K, G, Sc, hd = 2, 2, 3, 8 * max(n, 1), 16
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, K * G, hd))
    kc = jax.random.normal(ks[1], (B, Sc, K, hd))
    vc = jax.random.normal(ks[2], (B, Sc, K, hd))
    valid = jax.random.bernoulli(ks[3], 0.7, (Sc,)).at[0].set(True)
    fn = flash_decode_shardmap(mesh)
    with mesh:
        out = fn(q, kc, vc, valid)
    ref = decode_ref(q, jnp.transpose(kc, (0, 2, 1, 3)),
                     jnp.transpose(vc, (0, 2, 1, 3)), valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
