"""Queue semantics (the paper's policy/experience queues) + replay buffer."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.queues import Experience, ExperienceQueue, PolicyStore
from repro.data.replay import add_batch, init_replay, sample


def test_policy_store_latest_wins():
    store = PolicyStore({"w": 0})
    assert store.read() == ({"w": 0}, 0)
    for i in range(1, 5):
        store.publish({"w": i})
    params, version = store.read()
    assert params == {"w": 4} and version == 4


def test_policy_store_thread_safety():
    store = PolicyStore(0)

    def writer():
        for _ in range(200):
            store.publish(store.read()[0])

    threads = [threading.Thread(target=writer) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert store.version == 800


def test_experience_queue_staleness_accounting():
    q = ExperienceQueue()
    q.put(Experience(traj={}, policy_version=3, sampler_id=0,
                     collect_seconds=0.1))
    q.put(Experience(traj={}, policy_version=5, sampler_id=1,
                     collect_seconds=0.1))
    q.get(learner_version=5)
    q.get(learner_version=6)
    assert q.staleness == [2, 1]
    assert q.mean_staleness() == pytest.approx(1.5)


def test_experience_queue_drain_bounded():
    q = ExperienceQueue()
    for i in range(5):
        q.put(Experience({}, i, 0, 0.0))
    items = q.drain(learner_version=10, max_items=3)
    assert len(items) == 3 and q.qsize() == 2


# ---------------------------------------------------------------- replay
@settings(max_examples=15, deadline=None)
@given(cap=st.integers(4, 32), n1=st.integers(1, 40), n2=st.integers(1, 40))
def test_replay_ring_size_and_wrap(cap, n1, n2):
    ex = {"x": jnp.zeros((1, 2))}
    state = init_replay(cap, ex)
    state = add_batch(state, {"x": jnp.ones((n1, 2))})
    state = add_batch(state, {"x": 2 * jnp.ones((n2, 2))})
    assert int(state.size) == min(cap, n1 + n2)
    assert 0 <= int(state.index) < cap


def test_replay_overwrites_oldest():
    state = init_replay(4, {"x": jnp.zeros((1,))})
    state = add_batch(state, {"x": jnp.arange(4.0)})
    state = add_batch(state, {"x": jnp.asarray([9.0, 10.0])})
    vals = set(np.asarray(state.storage["x"]).tolist())
    assert vals == {9.0, 10.0, 2.0, 3.0}


def test_replay_sample_within_filled():
    state = init_replay(16, {"x": jnp.zeros((1,))})
    state = add_batch(state, {"x": jnp.arange(1.0, 7.0)})
    out = sample(state, jax.random.PRNGKey(0), 64)
    assert out["x"].shape == (64,)
    assert set(np.asarray(out["x"]).tolist()) <= set(range(1, 7))
