"""Queue semantics (the paper's policy/experience queues) + replay buffer."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.queues import Experience, ExperienceQueue, PolicyStore
from repro.data.replay import add_batch, init_replay, sample


def test_policy_store_latest_wins():
    store = PolicyStore({"w": 0})
    assert store.read() == ({"w": 0}, 0)
    for i in range(1, 5):
        store.publish({"w": i})
    params, version = store.read()
    assert params == {"w": 4} and version == 4


def test_policy_store_thread_safety():
    store = PolicyStore(0)

    def writer():
        for _ in range(200):
            store.publish(store.read()[0])

    threads = [threading.Thread(target=writer) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert store.version == 800


def test_experience_queue_staleness_accounting():
    q = ExperienceQueue()
    q.put(Experience(traj={}, policy_version=3, sampler_id=0,
                     collect_seconds=0.1))
    q.put(Experience(traj={}, policy_version=5, sampler_id=1,
                     collect_seconds=0.1))
    q.get(learner_version=5)
    q.get(learner_version=6)
    assert q.staleness == [2, 1]
    assert q.mean_staleness() == pytest.approx(1.5)


def test_experience_queue_drain_bounded():
    q = ExperienceQueue()
    for i in range(5):
        q.put(Experience({}, i, 0, 0.0))
    items = q.drain(learner_version=10, max_items=3)
    assert len(items) == 3 and q.qsize() == 2


def test_experience_queue_counts_overflow_drops():
    """Backpressure is measurable: a put that times out on a full queue
    drops the experience and bumps drop_count instead of failing
    silently."""
    q = ExperienceQueue(maxsize=1)
    assert q.put(Experience({}, 0, 0, 0.0), timeout=0.01)
    assert not q.put(Experience({}, 1, 0, 0.0), timeout=0.01)
    assert not q.put(Experience({}, 2, 0, 0.0), timeout=0.01)
    assert q.drop_count == 2 and q.put_count == 1
    # draining frees capacity; puts succeed again and drops stop growing
    q.get(learner_version=0)
    assert q.put(Experience({}, 3, 0, 0.0), timeout=0.01)
    assert q.drop_count == 2 and q.put_count == 2


# ---------------------------------------------------------------- replay
@settings(max_examples=15, deadline=None)
@given(cap=st.integers(4, 32), n1=st.integers(1, 40), n2=st.integers(1, 40))
def test_replay_ring_size_and_wrap(cap, n1, n2):
    ex = {"x": jnp.zeros((1, 2))}
    state = init_replay(cap, ex)
    state = add_batch(state, {"x": jnp.ones((n1, 2))})
    state = add_batch(state, {"x": 2 * jnp.ones((n2, 2))})
    assert int(state.size) == min(cap, n1 + n2)
    assert 0 <= int(state.index) < cap


def test_replay_overwrites_oldest():
    state = init_replay(4, {"x": jnp.zeros((1,))})
    state = add_batch(state, {"x": jnp.arange(4.0)})
    state = add_batch(state, {"x": jnp.asarray([9.0, 10.0])})
    vals = set(np.asarray(state.storage["x"]).tolist())
    assert vals == {9.0, 10.0, 2.0, 3.0}


def test_replay_sample_within_filled():
    state = init_replay(16, {"x": jnp.zeros((1,))})
    state = add_batch(state, {"x": jnp.arange(1.0, 7.0)})
    out = sample(state, jax.random.PRNGKey(0), 64)
    assert out["x"].shape == (64,)
    assert set(np.asarray(out["x"]).tolist()) <= set(range(1, 7))


@settings(max_examples=15, deadline=None)
@given(cap=st.integers(4, 48), T=st.integers(1, 6), B=st.integers(1, 4),
       iters=st.integers(1, 5))
def test_uniform_buffer_ring_wraparound_property(cap, T, B, iters):
    """Plane-level form of the ring property: UniformBuffer absorbing
    whole trajectories keeps size == min(cap, total) and head in range."""
    from repro.data.buffers import UniformBuffer
    buf = UniformBuffer(capacity=cap, batch_size=4)
    example = {"obs": jnp.zeros((1, 2)), "actions": jnp.zeros((1, 1)),
               "rewards": jnp.zeros((1,)), "next_obs": jnp.zeros((1, 2)),
               "dones": jnp.zeros((1,), bool)}
    state = buf.init(example)
    traj = {"obs": jnp.ones((T, B, 2)), "actions": jnp.ones((T, B, 1)),
            "rewards": jnp.ones((T, B)), "dones": jnp.zeros((T, B), bool),
            "next_obs": jnp.ones((T, B, 2))}
    for _ in range(iters):
        state = buf.add(state, traj)
    assert int(state.size) == min(cap, iters * T * B)
    assert 0 <= int(state.index) < cap
