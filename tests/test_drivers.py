"""End-to-end driver + multi-device integration tests (subprocesses, so
each can set its own XLA device count before jax initialises)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))


def _run(args, env=ENV, timeout=420):
    return subprocess.run([sys.executable] + args, cwd=REPO, env=env,
                          capture_output=True, text=True, timeout=timeout)


@pytest.mark.slow
def test_sharded_rollout_multidevice():
    """One WALL-E sampler per data-axis slice via shard_map on 8 host
    devices: trajectories born sharded, identical API to the local path."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro import envs
from repro.core import sampler as S
from repro.models import mlp_policy

env = envs.make("pendulum")
mesh = Mesh(np.asarray(jax.devices()).reshape(8, 1), ("data", "model"))
params = mlp_policy.init_policy(jax.random.PRNGKey(0), env.obs_dim,
                                env.act_dim, 16)
rollout = S.make_sharded_rollout(env, horizon=8, mesh=mesh)
carry = S.init_env_carry(env, jax.random.PRNGKey(1), 16)   # 2 envs/shard
with mesh:
    carry2, traj = rollout(params, carry)
assert traj["obs"].shape == (8, 16, env.obs_dim)
assert traj["last_value"].shape == (16,)
assert bool(jnp.all(jnp.isfinite(traj["rewards"])))
# shards actually differ (independent env keys per slice)
flat = np.asarray(traj["obs"][:, :, 0])
assert np.std(flat[:, 0]) > 0 or np.std(flat[:, 1]) > 0
print("SHARDED_OK")
"""
    r = _run(["-c", script])
    assert "SHARDED_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_train_cli_rl():
    r = _run(["-m", "repro.launch.train", "--mode", "rl", "--env",
              "cartpole", "--num-samplers", "2", "--global-batch", "4",
              "--horizon", "16", "--iterations", "2"])
    assert r.returncode == 0, r.stderr
    lines = [json.loads(l) for l in r.stdout.strip().splitlines()
             if l.startswith("{")]
    assert len(lines) == 2 and lines[0]["samples"] == 4 * 16


@pytest.mark.slow
def test_train_cli_lm_with_checkpoint(tmp_path):
    r = _run(["-m", "repro.launch.train", "--mode", "lm", "--arch",
              "h2o-danube-3-4b-reduced", "--steps", "2", "--batch", "2",
              "--seq-len", "16", "--ckpt-dir", str(tmp_path)])
    assert r.returncode == 0, r.stderr
    assert "step 1" in r.stdout
    assert any(n.startswith("ckpt_") for n in os.listdir(tmp_path))


@pytest.mark.slow
def test_serve_cli():
    r = _run(["-m", "repro.launch.serve", "--arch", "hymba-1.5b-reduced",
              "--batch", "2", "--prompt-len", "8", "--gen-len", "8",
              "--requests", "2"])
    assert r.returncode == 0, r.stderr
    assert "request 1" in r.stdout
