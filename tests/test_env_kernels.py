"""Env-step kernel family: Pallas (interpret on CPU) vs ref parity for
all three physics envs, the batched auto-reset fast-path regression
against single-env semantics, and the full kernel-selection table
(mode × platform, GPU included).

Parity contract (see also ``env_step_pallas``'s module docstring):

* int/bool leaves (step counters, ``done``) — EXACT, all envs.
* the auto-reset select — EXACT (reset candidates pass through the
  ``where`` untouched; pinned by the all-done terminal test).
* pendulum and cheetah f32 leaves — EXACT at every tested B.
* cartpole f32 arithmetic leaves — within 4 ulps (measured worst: 3).
  The kernel bodies evaluate the *verbatim* ref expressions, but XLA
  CPU applies FMA contraction per fusion context, so two
  differently-shaped compilations of the same ops (the ``(B,)`` ref vs
  the ``(1, b)``-tiled interpreted kernel) are not bitwise-stable
  against each other: cartpole's ``xdot``/``thdot`` chains hit one
  contraction difference (strict-rounding recomputation sides with the
  kernel) which propagates through the few remaining ops of the step.
  The bound is asserted in ulps, not an allclose hand-wave.

Comparisons run under ``jax.jit`` on both sides — that is how the
kernels are always reached in practice (rollouts trace them inside a
scan), and eager op-by-op execution is itself a third fusion context.

The guarantee training correctness rests on — ``auto_reset_batch`` (the
VectorEnv step, ref batch fast-path) bitwise-identical to
``vmap(auto_reset(env))`` — is EXACT and tested below; those two
compile through the same-shaped graphs.
"""
import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import envs
from repro.envs.base import auto_reset, auto_reset_batch
from repro.kernels import select
from repro.kernels.env_step import ops as env_ops
from repro.kernels.env_step import ref as env_ref

KEY = jax.random.PRNGKey(23)

ENV_PARAMS = {
    "pendulum": dict(max_torque=2.0),
    "cartpole": dict(force_max=10.0),
    "cheetah": dict(ctrl_cost=0.1),
}


@pytest.fixture(autouse=True)
def _restore_kernel_mode():
    prev = select.kernel_mode()
    yield
    select.set_kernel_mode(prev)


def assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for xa, xb in zip(la, lb):
        assert jnp.asarray(xa).dtype == jnp.asarray(xb).dtype
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))


def _ulp_distance(a, b):
    """Lexicographic-bit distance between f32 arrays (0 == bitwise equal,
    1 == adjacent representable floats)."""
    ia = a.view(np.int32).astype(np.int64)
    ib = b.view(np.int32).astype(np.int64)
    ia = np.where(ia < 0, np.int64(-(2 ** 31)) - ia, ia)
    ib = np.where(ib < 0, np.int64(-(2 ** 31)) - ib, ib)
    return np.abs(ia - ib)


def assert_trees_equal_ulp(a, b, max_ulps):
    """Exact on int/bool leaves; f32 leaves within ``max_ulps`` (the XLA
    CPU FMA-contraction bound — see the module docstring)."""
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for xa, xb in zip(la, lb):
        xa, xb = np.asarray(xa), np.asarray(xb)
        assert xa.dtype == xb.dtype and xa.shape == xb.shape
        if xa.dtype.kind in "iub" or max_ulps == 0:
            np.testing.assert_array_equal(xa, xb)
        else:
            dist = _ulp_distance(xa, xb)
            assert dist.max(initial=0) <= max_ulps, (
                f"float leaves differ by {dist.max()} ulps "
                f"({(dist > max_ulps).sum()} elements past {max_ulps})")


# pendulum/cheetah parity is bitwise; cartpole admits the contraction
# bound (measured worst across B in {1..4096}: 3 ulps)
PARITY_ULPS = {"pendulum": 0, "cartpole": 4, "cheetah": 0}


def _batch_inputs(name, B, *, max_episode_steps=3, key=KEY):
    """(state, actions, reset_state, reset_obs, params) for one batched
    step; ``max_episode_steps=3`` keeps terminal auto-resets in play."""
    env = envs.make(name, max_episode_steps=max_episode_steps)
    ks = jax.random.split(jax.random.fold_in(key, B), 3)
    states, _ = jax.vmap(env.reset)(jax.random.split(ks[0], B))
    actions = jax.random.uniform(ks[1], (B, env.act_dim),
                                 minval=-1.0, maxval=1.0)
    reset_state, reset_obs = jax.vmap(env.reset)(jax.random.split(ks[2], B))
    params = dict(max_episode_steps=max_episode_steps, reward_scale=1.0,
                  **ENV_PARAMS[name])
    return env, states, actions, reset_state, reset_obs, params


# B sweep crosses the default b_block=512: 513/700 exercise grid padding
# (nb=2 with a ragged final tile); 1 is the degenerate single instance.
@pytest.mark.parametrize("name", sorted(env_ref.STEP_BATCH_REF))
@pytest.mark.parametrize("B", [1, 7, 37, 512, 513, 700])
def test_env_step_pallas_matches_ref(name, B):
    env, states, actions, rs, ro, params = _batch_inputs(name, B)

    @partial(jax.jit, static_argnums=0)
    def run(impl, s, a, rs, ro):
        return env_ops.env_step(name, s, a, rs, ro, impl=impl, **params)

    out_ref = run("ref", states, actions, rs, ro)
    out_pl = run("pallas", states, actions, rs, ro)
    assert_trees_equal_ulp(out_ref, out_pl, PARITY_ULPS[name])
    # shapes/dtypes of the bundle: state pytree, obs (B, obs_dim),
    # rewards (B,) float, dones (B,) bool
    _, obs, rew, done = out_pl
    assert obs.shape == (B, env.obs_dim)
    assert rew.shape == (B,) and rew.dtype == jnp.float32
    assert done.shape == (B,) and done.dtype == jnp.bool_


@pytest.mark.parametrize("name", sorted(env_ref.STEP_BATCH_REF))
def test_env_step_terminal_auto_reset_parity(name):
    """Drive past the horizon so every instance hits done: the fused
    select must hand back the reset candidates exactly, with the reward
    staying the terminal transition's (the auto_reset contract). The
    reset re-synchronizes both impls to the identical candidates, so
    any in-flight ulp drift dies at each episode boundary."""
    B = 33
    env, states, actions, rs, ro, params = _batch_inputs(
        name, B, max_episode_steps=2)

    @partial(jax.jit, static_argnums=0)
    def run(impl, s):
        outs = []
        for _ in range(3):  # step 3x a horizon of 2 -> all instances reset
            s, obs, rew, done = env_ops.env_step(name, s, actions, rs, ro,
                                                 impl=impl, **params)
            outs.append((obs, rew, done))
        return s, outs

    out_ref = run("ref", states)
    out_pl = run("pallas", states)
    assert_trees_equal_ulp(out_ref, out_pl, PARITY_ULPS[name])
    # the reset step itself (step 2 of 3) handed back the candidates
    # through the select verbatim on both sides
    _, ref_steps = out_ref
    _, pl_steps = out_pl
    assert bool(np.all(np.asarray(ref_steps[1][2])))  # all done
    assert_trees_equal(ref_steps[1][0], pl_steps[1][0])  # reset obs exact


@pytest.mark.parametrize("name", sorted(env_ref.STEP_BATCH_REF))
def test_batched_fast_path_matches_vmap_exactly(name):
    """``auto_reset_batch`` (both with the env's fused ``batch_step`` and
    with the plain vmap+single-where fallback) is bitwise
    ``vmap(auto_reset(env))`` across steps that include terminal resets —
    the regression pin that single-env auto-reset semantics are
    unchanged by the batch fast-path."""
    B = 17
    env = envs.make(name, max_episode_steps=3)
    plain = dataclasses.replace(env, batch_step=None)
    states, obs = jax.vmap(env.reset)(
        jax.random.split(jax.random.fold_in(KEY, 1), B))
    keys = jax.random.split(jax.random.fold_in(KEY, 2), B)
    actions = jax.random.uniform(jax.random.fold_in(KEY, 3),
                                 (B, env.act_dim), minval=-1.0, maxval=1.0)

    def sweep(step):
        @jax.jit
        def run(s, k):
            outs = []
            for _ in range(5):
                s, obs, rew, done = step(s, actions, k)
                outs.append((obs, rew, done))
            return s, outs
        return run(states, keys)

    vm = jax.vmap(auto_reset(env))
    ref_out = sweep(lambda s, a, k: vm(s, a, k))
    fused_out = sweep(auto_reset_batch(env))
    fallback_out = sweep(auto_reset_batch(plain))
    assert_trees_equal(ref_out, fused_out)
    assert_trees_equal(ref_out, fallback_out)


def test_env_step_unknown_env_rejected():
    with pytest.raises(KeyError, match="pendulum"):
        env_ops.env_step("walker", None, None, None, None)


def test_env_step_non_f32_falls_back_to_ref():
    """The kernels are f32-only; other dtypes must dispatch the ref path
    (same values as an explicit ref call), not fail to lower."""
    name = "pendulum"
    env = envs.make(name, max_episode_steps=3, dtype=jnp.float16)
    B = 9
    states, _ = jax.vmap(env.reset)(
        jax.random.split(jax.random.fold_in(KEY, 4), B))
    actions = jnp.zeros((B, 1))
    rs, ro = jax.vmap(env.reset)(
        jax.random.split(jax.random.fold_in(KEY, 5), B))
    params = dict(max_episode_steps=3, reward_scale=1.0, max_torque=2.0,
                  dtype=jnp.float16)
    out_pl = env_ops.env_step(name, states, actions, rs, ro,
                              impl="pallas", **params)
    out_ref = env_ops.env_step(name, states, actions, rs, ro,
                               impl="ref", **params)
    assert_trees_equal(out_ref, out_pl)
    assert out_pl[1].dtype == jnp.float16


# ========================================================= selection table
# mode × platform -> (implementation, interpret): auto compiles Pallas on
# both TPU (Mosaic) and GPU (Triton); interpret only off-accelerator.
@pytest.mark.parametrize("platform,mode,expect", [
    ("cpu", "ref", ("ref", False)),
    ("cpu", "pallas", ("pallas", True)),
    ("cpu", "auto", ("ref", False)),
    ("tpu", "ref", ("ref", False)),
    ("tpu", "pallas", ("pallas", False)),
    ("tpu", "auto", ("pallas", False)),
    ("gpu", "ref", ("ref", False)),
    ("gpu", "pallas", ("pallas", False)),
    ("gpu", "auto", ("pallas", False)),
    ("cuda", "auto", ("pallas", False)),
    ("rocm", "auto", ("pallas", False)),
])
def test_selection_table(monkeypatch, platform, mode, expect):
    monkeypatch.setattr(select.jax, "default_backend", lambda: platform)
    assert select.resolve(mode) == expect
    # the global mode resolves through the same table
    select.set_kernel_mode(mode)
    assert select.resolve() == expect
