"""Environment invariants + trajectory container checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import envs
from repro.core import sampler as sampler_mod
from repro.data import trajectory
from repro.envs.base import auto_reset

ENVS = ["pendulum", "cartpole", "cheetah"]


@pytest.mark.parametrize("name", ENVS)
def test_env_shapes_and_determinism(name):
    env = envs.make(name)
    key = jax.random.PRNGKey(0)
    s1, o1 = env.reset(key)
    s2, o2 = env.reset(key)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2))
    assert o1.shape == (env.obs_dim,)
    a = jnp.zeros((env.act_dim,))
    s_next, obs, rew, done = env.step(s1, a, key)
    assert obs.shape == (env.obs_dim,)
    assert jnp.isfinite(rew)
    assert done.dtype == jnp.bool_ or done.dtype == bool


@pytest.mark.parametrize("name", ENVS)
@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_env_rollout_finite(name, seed):
    env = envs.make(name)
    key = jax.random.PRNGKey(seed)
    step = auto_reset(env)
    state, obs = env.reset(key)
    for i in range(20):
        key, ka, ke = jax.random.split(key, 3)
        a = jax.random.uniform(ka, (env.act_dim,), minval=-1, maxval=1)
        state, obs, rew, done = step(state, a, ke)
        assert bool(jnp.all(jnp.isfinite(obs))), name
        assert jnp.isfinite(rew)


def test_auto_reset_restarts_episode():
    env = envs.make("pendulum")     # 200-step episodes
    key = jax.random.PRNGKey(0)
    step = auto_reset(env)
    state, obs = env.reset(key)
    saw_done = False
    for i in range(205):
        key, ke = jax.random.split(key)
        state, obs, rew, done = step(state, jnp.zeros((1,)), ke)
        if bool(done):
            saw_done = True
    assert saw_done
    # after auto-reset the step counter went back below the limit
    assert int(state[2]) < 200


def test_auto_reset_terminal_step_semantics():
    """Pin the terminal-step contract of ``auto_reset`` directly (it was
    previously only exercised through algo tests): on ``done`` the
    *reset* observation replaces the terminal observation, the state
    pytree swaps to the reset state leafwise, and the reward is still
    the terminal transition's (never the reset's)."""
    env = envs.make("pendulum", max_episode_steps=3)
    step = auto_reset(env)
    key = jax.random.PRNGKey(42)
    state, obs = env.reset(key)
    action = jnp.ones((env.act_dim,)) * 0.3
    for i in range(3):
        key, k = jax.random.split(key)
        # replicate auto_reset's internal key split to predict the reset
        k_step, k_reset = jax.random.split(k)
        raw_state, raw_obs, raw_rew, raw_done = env.step(state, action,
                                                         k_step)
        reset_state, reset_obs = env.reset(k_reset)
        state, obs, rew, done = step(state, action, k)
        assert bool(done) == (i == 2)          # 3-step episodes
        np.testing.assert_array_equal(np.asarray(rew), np.asarray(raw_rew))
        if bool(done):
            # reset obs replaces the terminal obs...
            np.testing.assert_array_equal(np.asarray(obs),
                                          np.asarray(reset_obs))
            assert float(jnp.max(jnp.abs(obs - raw_obs))) > 0
            # ...and every state leaf swaps to the reset state's
            for got, want in zip(jax.tree.leaves(state),
                                 jax.tree.leaves(reset_state)):
                np.testing.assert_array_equal(np.asarray(got),
                                              np.asarray(want))
        else:
            np.testing.assert_array_equal(np.asarray(obs),
                                          np.asarray(raw_obs))
            for got, want in zip(jax.tree.leaves(state),
                                 jax.tree.leaves(raw_state)):
                np.testing.assert_array_equal(np.asarray(got),
                                              np.asarray(want))


def test_auto_reset_step_counter_leaf_swaps():
    """The step-counter leaf (state[2] on pendulum) is part of the state
    pytree swap: it returns to the reset value (0) after a terminal step
    instead of keeping counting."""
    env = envs.make("pendulum", max_episode_steps=2)
    step = auto_reset(env)
    state, _ = env.reset(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    counters = []
    for _ in range(5):
        key, k = jax.random.split(key)
        state, _, _, done = step(state, jnp.zeros((1,)), k)
        counters.append(int(state[2]))
    # counter pattern for 2-step episodes under auto-reset: 1, 0, 1, 0, 1
    assert counters == [1, 0, 1, 0, 1]


def test_rollout_traj_layout_and_merge(rng_key):
    env = envs.make("pendulum")
    from repro.models import mlp_policy
    params = mlp_policy.init_policy(rng_key, env.obs_dim, env.act_dim, 16)
    rollout = jax.jit(sampler_mod.make_env_rollout(env, horizon=16))
    c1 = sampler_mod.init_env_carry(env, jax.random.PRNGKey(1), 4)
    c2 = sampler_mod.init_env_carry(env, jax.random.PRNGKey(2), 4)
    _, t1 = rollout(params, c1)
    _, t2 = rollout(params, c2)
    trajectory.validate(t1)
    assert t1["obs"].shape == (16, 4, env.obs_dim)
    assert t1["last_value"].shape == (4,)
    merged = trajectory.merge([t1, t2])
    assert merged["obs"].shape == (16, 8, env.obs_dim)
    assert merged["last_value"].shape == (8,)
    assert trajectory.num_samples(merged) == 16 * 8
    # different seeds -> different experience
    assert float(jnp.max(jnp.abs(t1["obs"] - t2["obs"]))) > 0
