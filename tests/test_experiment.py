"""Unified experiment API: spec round-trip, the algo x backend parity
smoke grid, bitwise compatibility with the pre-refactor runner wiring,
and checkpoint-metadata reproducibility."""
import json

import jax
import numpy as np
import pytest

from repro import envs, experiment
from repro.algos.ppo import PPOConfig, make_mlp_learner
from repro.core import SyncRunner
from repro.core import sampler as sampler_mod
from repro.experiment import ExperimentSpec, Schedule
from repro.models import mlp_policy
from repro.optim import adam

TINY = dict(num_samplers=2, global_batch=4, horizon=8, iterations=2, seed=0)


def _tiny_spec(algo, backend="inline", runtime="sync", **sched):
    return ExperimentSpec(env="pendulum", algo=algo, backend=backend,
                          runtime=runtime, model={"hidden": 16},
                          schedule=Schedule(**{**TINY, **sched}))


def _assert_trees_equal(a, b):
    for xa, xb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))


# ============================================================== spec data
def test_spec_roundtrip():
    spec = ExperimentSpec(env="cheetah", algo="trpo", backend="threaded",
                          runtime="async", model={"hidden": 32},
                          env_kwargs={"reward_scale": 0.5},
                          algo_kwargs={"max_kl": 0.02},
                          schedule=Schedule(num_samplers=3, seed=7))
    assert ExperimentSpec.from_dict(spec.to_dict()) == spec
    # survives a JSON round-trip too — checkpoint metadata is JSON
    assert ExperimentSpec.from_dict(json.loads(json.dumps(spec.to_dict()))) \
        == spec


def test_spec_defaults_roundtrip():
    spec = ExperimentSpec()
    assert ExperimentSpec.from_dict(spec.to_dict()) == spec


def test_unknown_runtime_rejected():
    with pytest.raises(ValueError, match="unknown runtime"):
        experiment.build(_tiny_spec("ppo", runtime="warp"))


def test_unknown_algo_rejected_with_choices():
    with pytest.raises(KeyError, match="ppo"):
        experiment.build(_tiny_spec("sac"))


def test_unknown_backend_rejected_even_for_fused_runtime():
    with pytest.raises(KeyError, match="unknown backend"):
        experiment.build(_tiny_spec("ppo", backend="bogus",
                                    runtime="fused"))


def test_runtime_backend_conflicts_rejected():
    with pytest.raises(ValueError, match="fused"):
        experiment.build(_tiny_spec("ppo", backend="sharded",
                                    runtime="fused"))
    with pytest.raises(ValueError, match="async"):
        experiment.build(_tiny_spec("ppo", backend="sharded",
                                    runtime="async"))
    # async always collects with free-running sampler threads; the spec
    # must say so or ckpt metadata would misdescribe the run
    with pytest.raises(ValueError, match="threaded"):
        experiment.build(_tiny_spec("ppo", backend="inline",
                                    runtime="async"))


# ================================================= algo x backend parity
@pytest.mark.parametrize("algo", ["ppo", "trpo", "ddpg"])
def test_algo_backend_parity_grid(algo):
    """Every algorithm runs on every backend, and because the backends are
    just schedules of the same sampler work, final params agree across
    inline/threaded/sharded from identical specs."""
    results = {}
    for backend in ("inline", "threaded", "sharded"):
        res = experiment.run(_tiny_spec(algo, backend=backend))
        assert len(res.logs) == 2, (algo, backend)
        for log in res.logs:
            assert np.isfinite(log.mean_return)
            assert log.samples == TINY["global_batch"] * TINY["horizon"]
        assert all(np.isfinite(np.asarray(x)).all()
                   for x in jax.tree.leaves(res.params))
        results[backend] = res.params
    _assert_trees_equal(results["inline"], results["threaded"])
    _assert_trees_equal(results["inline"], results["sharded"])


@pytest.mark.parametrize("algo", ["ppo", "trpo", "ddpg"])
def test_fused_runtime_runs_every_algo(algo):
    res = experiment.run(_tiny_spec(algo, runtime="fused", chunk=2))
    assert len(res.logs) == 2
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree.leaves(res.params))


def test_ddpg_replay_fills():
    res = experiment.run(_tiny_spec("ddpg"))
    replay = res.runner.opt_state[2]
    # 2 iterations x global_batch x horizon transitions inserted
    assert int(replay.size) == 2 * TINY["global_batch"] * TINY["horizon"]


# ====================================== bitwise vs pre-refactor wiring
def test_ppo_inline_bitwise_matches_legacy_runner():
    """experiment.run(ppo x inline) reproduces the pre-refactor SyncRunner
    construction (launch/train.py's historical build_rl_runner) bitwise."""
    seed, hidden, lr, horizon, gb, ns, iters = 0, 32, 3e-4, 8, 4, 2, 2
    env = envs.make("pendulum")
    params = mlp_policy.init_policy(jax.random.PRNGKey(seed), env.obs_dim,
                                    env.act_dim, hidden=hidden)
    opt = adam(lr)
    learn = make_mlp_learner(opt, PPOConfig(lr=lr))
    rollout = sampler_mod.make_env_rollout(env, horizon)
    per = sampler_mod.split_batch(gb, ns)
    carries = [sampler_mod.init_env_carry(env, jax.random.PRNGKey(seed + i),
                                          per)
               for i in range(ns)]
    legacy = SyncRunner(rollout, learn, params, opt.init(params), carries,
                        ns)
    legacy.run(iters)

    spec = ExperimentSpec(
        env="pendulum", algo="ppo", backend="inline",
        model={"hidden": hidden}, algo_kwargs={"lr": lr},
        schedule=Schedule(num_samplers=ns, global_batch=gb, horizon=horizon,
                          iterations=iters, seed=seed))
    res = experiment.run(spec)
    _assert_trees_equal(legacy.params, res.params)
    _assert_trees_equal(legacy.opt_state, res.runner.opt_state)


# ==================================================== ckpt reproducibility
def test_checkpoint_metadata_reproduces_spec(tmp_path):
    from repro.checkpoint import load_metadata, save
    spec = _tiny_spec("trpo", backend="threaded")
    res = experiment.run(spec)
    save(str(tmp_path), spec.schedule.iterations, res.params,
         metadata={"mode": "rl", "spec": spec.to_dict()})
    meta = load_metadata(str(tmp_path))
    restored = ExperimentSpec.from_dict(meta["spec"])
    assert restored == spec
    assert restored.schedule.num_samplers == TINY["num_samplers"]
    assert restored.schedule.seed == TINY["seed"]
