"""Unified experiment API: spec round-trip, the algo x backend parity
smoke grid, bitwise compatibility with the pre-refactor runner wiring,
and checkpoint-metadata reproducibility."""
import json

import jax
import numpy as np
import pytest

from repro import envs, experiment
from repro.algos.ppo import PPOConfig, make_mlp_learner
from repro.core import SyncRunner
from repro.core import sampler as sampler_mod
from repro.experiment import ExperimentSpec, Schedule
from repro.models import mlp_policy
from repro.optim import adam

TINY = dict(num_samplers=2, global_batch=4, horizon=8, iterations=2, seed=0)


def _tiny_spec(algo, backend="inline", runtime="sync", buffer=None,
               buffer_kwargs=None, algo_kwargs=None, **sched):
    return ExperimentSpec(env="pendulum", algo=algo, backend=backend,
                          runtime=runtime, model={"hidden": 16},
                          buffer=buffer, buffer_kwargs=buffer_kwargs or {},
                          algo_kwargs=algo_kwargs or {},
                          schedule=Schedule(**{**TINY, **sched}))


def _assert_trees_equal(a, b):
    for xa, xb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))


# ============================================================== spec data
def test_spec_roundtrip():
    spec = ExperimentSpec(env="cheetah", algo="trpo", backend="threaded",
                          runtime="async", model={"hidden": 32},
                          buffer="prioritized",
                          buffer_kwargs={"capacity": 1024, "n_step": 3},
                          env_kwargs={"reward_scale": 0.5},
                          algo_kwargs={"max_kl": 0.02},
                          schedule=Schedule(num_samplers=3, seed=7))
    assert ExperimentSpec.from_dict(spec.to_dict()) == spec
    # survives a JSON round-trip too — checkpoint metadata is JSON
    assert ExperimentSpec.from_dict(json.loads(json.dumps(spec.to_dict()))) \
        == spec


def test_spec_defaults_roundtrip():
    spec = ExperimentSpec()
    assert ExperimentSpec.from_dict(spec.to_dict()) == spec


def test_unknown_runtime_rejected():
    with pytest.raises(ValueError, match="unknown runtime"):
        experiment.build(_tiny_spec("ppo", runtime="warp"))


def test_unknown_algo_rejected_with_choices():
    with pytest.raises(KeyError, match="ppo"):
        experiment.build(_tiny_spec("dreamer"))


def test_unknown_buffer_rejected_with_choices():
    with pytest.raises(KeyError, match="fifo"):
        experiment.build(_tiny_spec("ppo", buffer="bogus"))


def test_algo_buffer_mismatch_rejected():
    # on-policy learners eat whole trajectories, not replay minibatches
    with pytest.raises(ValueError, match="on-policy"):
        experiment.build(_tiny_spec("ppo", buffer="uniform"))
    # and off-policy learners need transition minibatches
    with pytest.raises(ValueError, match="off-policy"):
        experiment.build(_tiny_spec("ddpg", buffer="fifo"))


def test_unknown_backend_rejected_even_for_fused_runtime():
    with pytest.raises(KeyError, match="unknown backend"):
        experiment.build(_tiny_spec("ppo", backend="bogus",
                                    runtime="fused"))


def test_runtime_backend_conflicts_rejected():
    with pytest.raises(ValueError, match="fused"):
        experiment.build(_tiny_spec("ppo", backend="sharded",
                                    runtime="fused"))
    with pytest.raises(ValueError, match="async"):
        experiment.build(_tiny_spec("ppo", backend="sharded",
                                    runtime="async"))
    # async always collects with free-running sampler threads; the spec
    # must say so or ckpt metadata would misdescribe the run
    with pytest.raises(ValueError, match="threaded"):
        experiment.build(_tiny_spec("ppo", backend="inline",
                                    runtime="async"))


# ================================================= algo x backend parity
@pytest.mark.parametrize("algo", ["ppo", "trpo", "ddpg", "sac"])
def test_algo_backend_parity_grid(algo):
    """Every algorithm runs on every backend, and because the backends are
    just schedules of the same sampler work, final params agree across
    inline/threaded/sharded/process from identical specs — for process
    that means four worker OS processes reproduced the inline rollouts
    exactly through the shared-memory transport (matched per-worker
    seeds, worker-index merge order)."""
    results = {}
    for backend in ("inline", "threaded", "sharded", "process"):
        res = experiment.run(_tiny_spec(algo, backend=backend))
        assert len(res.logs) == 2, (algo, backend)
        for log in res.logs:
            assert np.isfinite(log.mean_return)
            assert log.samples == TINY["global_batch"] * TINY["horizon"]
        assert all(np.isfinite(np.asarray(x)).all()
                   for x in jax.tree.leaves(res.params))
        results[backend] = res.params
    _assert_trees_equal(results["inline"], results["threaded"])
    _assert_trees_equal(results["inline"], results["sharded"])
    _assert_trees_equal(results["inline"], results["process"])


@pytest.mark.parametrize("algo", ["ppo", "trpo", "ddpg", "sac"])
def test_fused_runtime_runs_every_algo(algo):
    res = experiment.run(_tiny_spec(algo, runtime="fused", chunk=2))
    assert len(res.logs) == 2
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree.leaves(res.params))


# ======================================== the experience-plane grid
OFFPOLICY_TINY = dict(buffer_kwargs={"capacity": 512, "batch_size": 16},
                      algo_kwargs={"updates_per_collect": 2})


@pytest.mark.parametrize("mode", ["inline", "threaded", "sharded", "fused",
                                  "async"])
@pytest.mark.parametrize("buffer", ["uniform", "prioritized"])
@pytest.mark.parametrize("algo", ["ddpg", "sac"])
def test_offpolicy_buffer_grid(algo, buffer, mode):
    """{ddpg,sac} x {uniform,prioritized} x every runtime runs green —
    the experience plane rides every scheduling of the same sampler
    work, including the free-running async learner."""
    runtime = ("fused" if mode == "fused"
               else "async" if mode == "async" else "sync")
    backend = ("inline" if mode == "fused"
               else "threaded" if mode == "async" else mode)
    spec = _tiny_spec(algo, backend=backend, runtime=runtime,
                      buffer=buffer, chunk=2 if mode == "fused" else None,
                      **OFFPOLICY_TINY)
    res = experiment.run(spec)
    assert len(res.logs) == 2
    for log in res.logs:
        assert np.isfinite(log.mean_return)
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree.leaves(res.params))
    # the plane is runner-owned and filled; sync/fused insert exactly
    # 2 iterations of T x B transitions (n_step=1), async at least that
    ring = (res.runner.buffer_state.ring if buffer == "prioritized"
            else res.runner.buffer_state)
    expected = 2 * TINY["global_batch"] * TINY["horizon"]
    if mode == "async":
        # free-running samplers: the learner consumed >= 2 drains of
        # min_batches trajectories (per-sampler batch x horizon each)
        assert int(ring.size) >= 2 * (TINY["global_batch"] // 2) \
            * TINY["horizon"]
    else:
        assert int(ring.size) == expected


def test_offpolicy_async_process_orchestrator():
    """An off-policy algorithm through ``AsyncOrchestrator`` driving true
    worker processes: continuous collection into the shared-memory ring
    while the learner drains it. Params-staleness and worker-utilization
    are measured, the buffer fills, nothing is dropped (ring
    backpressure), and the pool is reaped by ``experiment.run``."""
    spec = _tiny_spec("ddpg", backend="process", runtime="async",
                      buffer="uniform", **OFFPOLICY_TINY)
    res = experiment.run(spec)
    assert len(res.logs) == 2
    for log in res.logs:
        assert np.isfinite(log.mean_return)
        assert log.staleness >= 0.0
        assert 0.0 < log.worker_utilization <= 1.0
        assert log.queue_drops == 0          # ring backpressure never drops
    # free-running workers: the learner consumed >= 2 drains of
    # min_batches trajectories (per-worker batch x horizon each)
    ring = res.runner.buffer_state
    assert int(ring.size) >= 2 * (TINY["global_batch"] // 2) \
        * TINY["horizon"]
    assert all(not p.is_alive()
               for p in res.runner.pool._procs)      # reaped by run()


@pytest.mark.parametrize("algo", ["ddpg", "sac"])
def test_offpolicy_opt_state_is_only_optimizer_state(algo):
    """The acceptance criterion of the plane refactor: replay storage no
    longer hides inside ``opt_state`` — every opt_state leaf is
    parameter-shaped (Adam moments/counters), and the ring lives in the
    runner-owned buffer state."""
    from repro.data.replay import ReplayState
    res = experiment.run(_tiny_spec(algo, **OFFPOLICY_TINY))

    def contains_replay(tree):
        found = []
        jax.tree.map(lambda x: found.append(isinstance(x, ReplayState)),
                     tree, is_leaf=lambda x: isinstance(x, ReplayState))
        return any(found)

    assert not contains_replay(res.runner.opt_state)
    assert isinstance(res.runner.buffer_state, ReplayState)
    assert int(res.runner.buffer_state.size) > 0


def test_ddpg_replay_fills():
    res = experiment.run(_tiny_spec("ddpg", **OFFPOLICY_TINY))
    ring = res.runner.buffer_state
    # 2 iterations x global_batch x horizon transitions inserted
    assert int(ring.size) == 2 * TINY["global_batch"] * TINY["horizon"]


# ====================================== bitwise vs pre-refactor wiring
def test_ppo_inline_bitwise_matches_legacy_runner():
    """experiment.run(ppo x inline) reproduces the pre-refactor SyncRunner
    construction (launch/train.py's historical build_rl_runner) bitwise."""
    seed, hidden, lr, horizon, gb, ns, iters = 0, 32, 3e-4, 8, 4, 2, 2
    env = envs.make("pendulum")
    params = mlp_policy.init_policy(jax.random.PRNGKey(seed), env.obs_dim,
                                    env.act_dim, hidden=hidden)
    opt = adam(lr)
    learn = make_mlp_learner(opt, PPOConfig(lr=lr))
    rollout = sampler_mod.make_env_rollout(env, horizon)
    per = sampler_mod.split_batch(gb, ns)
    carries = [sampler_mod.init_env_carry(env, jax.random.PRNGKey(seed + i),
                                          per)
               for i in range(ns)]
    legacy = SyncRunner(rollout, learn, params, opt.init(params), carries,
                        ns)
    legacy.run(iters)

    spec = ExperimentSpec(
        env="pendulum", algo="ppo", backend="inline",
        model={"hidden": hidden}, algo_kwargs={"lr": lr},
        schedule=Schedule(num_samplers=ns, global_batch=gb, horizon=horizon,
                          iterations=iters, seed=seed))
    res = experiment.run(spec)
    _assert_trees_equal(legacy.params, res.params)
    _assert_trees_equal(legacy.opt_state, res.runner.opt_state)


# ==================================================== ckpt reproducibility
def test_checkpoint_metadata_reproduces_spec(tmp_path):
    from repro.checkpoint import load_metadata, save
    spec = _tiny_spec("trpo", backend="threaded")
    res = experiment.run(spec)
    save(str(tmp_path), spec.schedule.iterations, res.params,
         metadata={"mode": "rl", "spec": spec.to_dict()})
    meta = load_metadata(str(tmp_path))
    restored = ExperimentSpec.from_dict(meta["spec"])
    assert restored == spec
    assert restored.schedule.num_samplers == TINY["num_samplers"]
    assert restored.schedule.seed == TINY["seed"]
