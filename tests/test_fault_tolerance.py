"""Fault tolerance (DESIGN.md §10): the fault-injection harness, the
seqlock stuck-slot repair path, supervised respawn + crash-loop budget,
per-iteration accounting under churn, shutdown-crash exception chaining,
elastic autoscaling, and the staleness-correction exact-off guarantee."""
import multiprocessing as mp
import os
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import experiment
from repro.algos.staleness import StalenessConfig, decay_weights, vtrace_rho
from repro.core.faults import KINDS, FaultPlan, decide
from repro.core.ipc import RingSlotStuck, ShmRing, WorkerCrashed
from repro.core.ipc import Heartbeat
from repro.core.supervisor import SupervisorConfig, WorkerSupervisor
from repro.experiment import ExperimentSpec, Schedule

TINY = dict(num_samplers=2, global_batch=4, horizon=8, iterations=2, seed=0)


def _spec(backend, algo="ppo", runtime="sync", staleness=None, faults=None,
          buffer_kwargs=None, **sched):
    return ExperimentSpec(env="pendulum", algo=algo, backend=backend,
                          runtime=runtime, model={"hidden": 16},
                          staleness=staleness, faults=faults,
                          buffer_kwargs=buffer_kwargs or {},
                          schedule=Schedule(**{**TINY, **sched}))


# ============================================================== fault plan
def test_fault_plan_parse_and_roundtrip():
    plan = FaultPlan.parse("kill:0.2,torn:0.05,delay:0.1:80,seed:7")
    assert (plan.kill, plan.torn, plan.delay, plan.delay_ms, plan.seed) == \
        (0.2, 0.05, 0.1, 80.0, 7)
    assert plan.any
    assert FaultPlan.from_dict(plan.to_dict()) == plan
    assert not FaultPlan().any
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPlan.parse("explode:0.5")
    with pytest.raises(ValueError, match="probabilit"):
        FaultPlan(kill=1.5)


def test_fault_decide_deterministic_and_incarnation_keyed():
    plan = FaultPlan.parse("kill:0.3", seed=0)
    draws = [decide(plan, 0, 1, s) for s in range(64)]
    assert draws == [decide(plan, 0, 1, s) for s in range(64)]  # pure
    assert "kill" in draws                # fires at this rate over 64 steps
    assert all(d in (None,) + KINDS for d in draws)
    # a respawned worker draws a fresh (still deterministic) schedule
    assert draws != [decide(plan, 0, 2, s) for s in range(64)]
    # a zero-rate plan never fires
    off = FaultPlan()
    assert all(decide(off, 0, 1, s) is None for s in range(64))


# ================================================= stuck-slot repair (ring)
def _ring_example():
    return {"obs": np.zeros((4, 3), np.float32),
            "rewards": np.zeros((4,), np.float32)}


def test_ring_read_timeout_names_slot_writer_and_state():
    ring = ShmRing.create(_ring_example(), slots=2, prefix=f"ft-{os.getpid()}-a")
    try:
        ring.begin_torn_write(1, worker_id=3)        # seq odd, never finishes
        with pytest.raises(RingSlotStuck, match=r"slot 1.*write in progress"
                           ) as ei:
            ring.read(1, timeout=0.2)
        err = ei.value
        assert (err.slot, err.worker_id) == (1, 3)
        assert err.writer_pid == os.getpid()
        assert err.seq % 2 == 1
        assert str(err.writer_pid) in str(err)       # message names the pid
        assert isinstance(err, WorkerCrashed)
    finally:
        ring.close(unlink=True)


def test_ring_reclaim_torn_unread_and_free():
    ring = ShmRing.create(_ring_example(), slots=3, prefix=f"ft-{os.getpid()}-b")
    try:
        ring.begin_torn_write(0, worker_id=1)
        assert ring.reclaim(0) == "torn"
        assert ring.is_free(0)                       # writable again
        traj = {k: np.ones_like(v) for k, v in _ring_example().items()}
        ring.write(1, traj, worker_id=1, policy_version=1,
                   collect_seconds=0.0, loop_seconds=0.0)
        assert ring.reclaim(1) == "unread"           # orphaned stable write
        assert ring.is_free(1)
        assert ring.reclaim(2) is None               # untouched slot
        # a reclaimed-torn slot accepts a fresh write and reads clean
        seq = ring.write(0, traj, worker_id=2, policy_version=5,
                         collect_seconds=0.0, loop_seconds=0.0)
        out, meta = ring.read(0)
        np.testing.assert_array_equal(out["obs"], traj["obs"])
        assert meta["worker_id"] == 2 and ring.seq(0) == seq
    finally:
        ring.close(unlink=True)


def _torn_writer_child(ring_spec, slot, wid):
    """Attach, start a write, and die mid-write — the real failure mode."""
    from repro.core.ipc import ShmRing
    ring = ShmRing.attach(ring_spec)
    ring.begin_torn_write(slot, wid)
    os.kill(os.getpid(), signal.SIGKILL)


def test_sigkilled_writer_mid_write_regression():
    """Regression (satellite a): a producer SIGKILLed mid-write used to
    hang the consumer forever; now read() raises a pointed RingSlotStuck
    naming the dead writer, and reclaim() repairs the slot."""
    ring = ShmRing.create(_ring_example(), slots=1, prefix=f"ft-{os.getpid()}-c")
    try:
        ctx = mp.get_context("spawn")
        p = ctx.Process(target=_torn_writer_child, args=(ring.spec, 0, 9))
        p.start()
        p.join(timeout=60)
        assert p.exitcode == -signal.SIGKILL
        with pytest.raises(RingSlotStuck) as ei:
            ring.read(0, timeout=0.3)
        assert ei.value.writer_pid == p.pid          # the dead writer, named
        assert ei.value.worker_id == 9
        assert ring.reclaim(0) == "torn"
        assert ring.is_free(0)
    finally:
        ring.close(unlink=True)


def test_heartbeat_ages_cross_attach():
    hb = Heartbeat(f"ft-hb-{os.getpid()}", slots=3, create=True)
    try:
        assert hb.age(0) == float("inf")             # never beaten
        hb.beat(0)
        assert hb.age(0) < 5.0
        other = Heartbeat(hb.name)                   # attach side
        assert other.age(0) < 5.0 and other.age(1) == float("inf")
        other.close()
    finally:
        hb.close(unlink=True)


# ==================================================== supervised lock-step
def test_supervised_collect_respawns_after_kill():
    """Chaos acceptance, lock-step: SIGKILL a worker mid-run; collection
    completes, the worker is respawned under a fresh incarnation, and no
    trajectory is lost or double-consumed."""
    runner = experiment.build(_spec("process", max_respawns=3))
    try:
        sup = runner.backend.supervisor
        assert sup is not None                       # supervision default ON
        pool = runner.backend.pool
        _, s0 = runner.backend.collect(runner.params)    # healthy sweep
        pool._procs[0].kill()                            # SIGKILL mid-idle
        pool._procs[0].join(timeout=30)
        merged, s1 = runner.backend.collect(runner.params)
        assert sup.respawns == 1
        assert pool._incarnation[0] == 2
        assert s1.respawns == 1 and s1.active_workers == 2
        assert s1.samples == s0.samples              # nothing lost
        assert len(sup.recovery_s) == 1 and sup.recovery_s[0] > 0
        # next sweep runs clean on the respawned fleet, budget reset
        runner.backend.collect(runner.params)
        assert sup._consec[0] == 0
    finally:
        runner.close()


def test_crash_loop_budget_exhausts_with_pointed_error():
    runner = experiment.build(_spec("process", max_respawns=0))
    assert runner.backend.supervisor is None         # 0 disables supervision
    runner.close()
    # budget=1: first death respawns, a stubborn second one raises
    runner = experiment.build(_spec("process", max_respawns=1))
    sup = runner.backend.supervisor
    try:
        with pytest.raises(WorkerCrashed, match="crash-looping"):
            for _ in range(3):
                sup._respawn(1, "test-injected failure")
        assert sup.respawns == 1                     # one respawn, then budget
        assert 1 in runner.backend.pool._crash_surfaced
    finally:
        runner.close()                               # must not re-raise


def test_lockstep_chaos_run_completes_with_respawns():
    """Deterministic chaos: kill:0.3 at seed 0 SIGKILLs both workers
    within their first three rollouts (verified against the plan here),
    and the supervised run still completes every iteration."""
    plan = FaultPlan.parse("kill:0.3", seed=0)
    first_kill = [min(s for s in range(8)
                      if decide(plan, w, 1, s) == "kill") for w in (0, 1)]
    assert max(first_kill) < 4                       # fires inside the run
    res = experiment.run(_spec("process", faults="kill:0.3", iterations=4,
                               max_respawns=8))
    logs = res.logs
    assert len(logs) == 4
    assert logs[-1].respawns >= 2                    # both workers died
    assert all(log.samples == TINY["global_batch"] * TINY["horizon"]
               for log in logs)                      # exactly-once, no loss
    assert all(log.active_workers == 2 for log in logs)


def test_torn_fault_reclaimed_in_lockstep():
    """A worker that dies *mid-ring-write* (torn seqlock) is detected,
    its slot repaired, and the sweep re-issued — the consumer never
    hangs and never sees torn payload."""
    plan = FaultPlan.parse("torn:0.3", seed=0)
    firsts = [min(s for s in range(8)
                  if decide(plan, w, 1, s) == "torn") for w in (0, 1)]
    assert min(firsts) < 4
    res = experiment.run(_spec("process", faults="torn:0.3", iterations=4,
                               max_respawns=8))
    assert len(res.logs) == 4
    assert res.logs[-1].respawns >= 1
    assert all(log.samples == TINY["global_batch"] * TINY["horizon"]
               for log in res.logs)


# =========================================================== async free-run
def test_async_chaos_completes_with_respawns():
    """Chaos acceptance, pool mode: free-running workers SIGKILLed on a
    seeded schedule; the learner keeps draining, the supervisor respawns,
    training completes all iterations."""
    plan = FaultPlan.parse("kill:0.3", seed=0)
    firsts = [min(s for s in range(8)
                  if decide(plan, w, 1, s) == "kill") for w in (0, 1)]
    assert min(firsts) <= 2                          # dies almost immediately
    res = experiment.run(_spec("process", runtime="async", faults="kill:0.3",
                               iterations=5, max_respawns=12))
    logs = res.logs
    assert len(logs) == 5
    assert logs[-1].respawns >= 1
    assert all(log.samples > 0 for log in logs)
    assert all(log.staleness >= 0.0 for log in logs)
    procs = res.runner.pool._procs
    assert all(p is None or not p.is_alive() for p in procs)


# ========================================= accounting under churn (stubbed)
class _StubPool:
    """Scripted stand-in for ProcessWorkerPool: hands the orchestrator a
    fixed sequence of (policy_version, collect_s, loop_s) experiences so
    the per-iteration accounting is checked against exact numbers."""

    def __init__(self, script, version=10):
        from repro.core.queues import Experience
        self.version = version
        self.num_workers = 2
        self._exps = [
            (Experience(traj={"obs": np.zeros((4, 2, 3), np.float32),
                              "rewards": np.zeros((4, 2), np.float32),
                              "dones": np.zeros((4, 2), np.float32)},
                        policy_version=v, sampler_id=0, collect_seconds=c),
             loop)
            for v, c, loop in script]
        self._i = 0

    def start_freerun(self):
        pass

    def publish(self, params):
        self.version += 1

    def next_experience(self, timeout=1.0):
        if self._i >= len(self._exps):
            return None
        exp = self._exps[self._i]
        self._i += 1
        return exp

    def close(self, raise_on_crash=True):
        pass


def test_pool_accounting_is_windowed_per_iteration():
    """Satellite: staleness / worker_utilization are *this* iteration's
    window, not a cumulative average — a gap-5 batch after a gap-0 batch
    logs staleness 5.0 (not 2.5), and utilization tracks each window."""
    from repro.core.orchestrator import AsyncOrchestrator

    # iteration 1: version gap 10-10=0, util 0.5/1.0; publish -> version 11
    # iteration 2: gap 11-6=5, util 0.25/1.0
    pool = _StubPool([(10, 0.5, 1.0), (6, 0.25, 1.0)], version=10)
    params = {"w": jnp.zeros((2,))}

    def train_step(p, o, s, batch):
        return p, o, s, {"loss": jnp.mean(batch["rewards"])}

    orch = AsyncOrchestrator(None, None, params, None, None, 2,
                             min_batches_per_update=1,
                             train_step=train_step, plane_state=(),
                             pool=pool)
    logs = orch.run(2, timeout=30.0)
    assert len(logs) == 2
    assert logs[0].staleness == 0.0
    assert logs[1].staleness == 5.0                  # windowed, not averaged
    assert logs[0].worker_utilization == pytest.approx(0.5)
    assert logs[1].worker_utilization == pytest.approx(0.25)
    assert all(log.active_workers == 2 for log in logs)
    assert all(log.respawns == 0 for log in logs)    # no supervisor attached


# ======================================================= shutdown ordering
def test_close_does_not_mask_crash_raised_first():
    """Ordering A (satellite b): the crash surfaces from collect; close()
    running afterwards (the ``finally``) must re-raise nothing — the
    original exception, not a shutdown error, reaches the caller."""
    runner = experiment.build(_spec("process", max_respawns=0))
    pool = runner.backend.pool
    with pytest.raises(WorkerCrashed, match="died") as ei:
        try:
            runner.backend.collect(runner.params)            # healthy
            pool._procs[0].kill()
            pool._procs[0].join(timeout=30)
            runner.backend.collect(runner.params)            # raises "died"
        finally:
            runner.close()                      # must not mask or re-raise
    assert "shutdown" not in str(ei.value)


def test_close_surfaces_crash_during_shutdown():
    """Ordering B: no exception in flight, a worker found dead at close()
    time raises WorkerCrashed naming the shutdown phase."""
    runner = experiment.build(_spec("process", max_respawns=0))
    pool = runner.backend.pool
    runner.backend.collect(runner.params)
    pool._procs[1].kill()
    pool._procs[1].join(timeout=30)
    with pytest.raises(WorkerCrashed, match="crashed during shutdown"):
        pool.close()
    pool.close()                                     # idempotent afterwards


# ================================================================ elastic
class _ElasticStubPool:
    def __init__(self, active=2, max_workers=4):
        self.active = list(range(active))
        self.max_workers = max_workers

    def grow(self):
        wid = len(self.active)
        self.active.append(wid)
        return wid

    def shrink(self):
        return self.active.pop() if len(self.active) > 1 else None


def test_autoscale_band_cooldown_and_clamps():
    pool = _ElasticStubPool(active=2, max_workers=4)
    sup = WorkerSupervisor(pool, SupervisorConfig(
        min_workers=2, max_workers=3, resize_cooldown=1))
    assert sup.autoscale(0.95) == ("grow", 2)        # above band -> grow
    assert sup.autoscale(0.95) is None               # cooldown gates
    assert sup.autoscale(0.95) is None               # ceiling (3) clamps
    assert len(pool.active) == 3
    assert sup.autoscale(0.7) is None                # inside the band
    assert sup.autoscale(0.1) == ("shrink", 2)
    assert sup.autoscale(0.1) is None                # cooldown again
    assert sup.autoscale(0.1) is None                # floor (2) clamps
    assert len(pool.active) == 2
    assert [e.kind for e in sup.events] == ["grow", "shrink"]
    # elastic off: never resizes
    off = WorkerSupervisor(_ElasticStubPool(), SupervisorConfig())
    assert off.autoscale(0.99) is None and off.autoscale(0.0) is None


def test_async_elastic_pool_grows_within_bounds():
    """End-to-end: an async run provisioned to max_workers=3 starts at 2
    and stays within [1, 3] while autoscaling between iterations."""
    res = experiment.run(_spec("process", runtime="async", iterations=4,
                               min_workers=1, max_workers=3))
    actives = [log.active_workers for log in res.logs]
    assert actives[0] == 2                           # starts at num_samplers
    assert all(1 <= a <= 3 for a in actives)
    assert res.runner.pool.max_workers == 3          # provisioned upfront


# ============================================== staleness: math + exact-off
def test_staleness_config_parse_and_validation():
    assert not StalenessConfig.parse(None).enabled
    assert not StalenessConfig.parse("off").enabled
    cfg = StalenessConfig.parse("decay")
    assert cfg.mode == "decay" and cfg.enabled
    cfg = StalenessConfig.parse({"mode": "vtrace", "decay": 0.8})
    assert (cfg.mode, cfg.decay) == ("vtrace", 0.8)
    assert StalenessConfig.parse(cfg) is cfg
    with pytest.raises(ValueError, match="mode"):
        StalenessConfig(mode="banana")
    with pytest.raises(ValueError, match="decay"):
        StalenessConfig(mode="decay", decay=1.5)


def test_staleness_weight_math():
    cfg = StalenessConfig(mode="decay", decay=0.5)
    gap = jnp.asarray([0.0, 1.0, 3.0])
    np.testing.assert_allclose(np.asarray(decay_weights(cfg, gap)),
                               [1.0, 0.5, 0.125])
    rho = vtrace_rho(StalenessConfig(mode="vtrace", rho_clip=1.0),
                     jnp.asarray([0.0, 0.0]), jnp.asarray([-1.0, 1.0]))
    # exp(0-(-1))=e clipped to 1; exp(0-1)=1/e kept
    np.testing.assert_allclose(np.asarray(rho), [1.0, np.exp(-1.0)],
                               rtol=1e-6)


def test_ppo_loss_exact_off_is_bitwise():
    """The exact-off guarantee: with correction disabled no ``weights``
    key exists and the loss path is the historical computation bitwise;
    a learner built with staleness but fed gap-free trajectories is
    bitwise identical too."""
    from repro.algos.ppo import PPOConfig, make_mlp_learner, mlp_ppo_loss
    from repro.models import mlp_policy
    from repro.optim import adam

    key = jax.random.PRNGKey(0)
    params = mlp_policy.init_policy(key, 3, 1, hidden=16)
    B = 8
    batch = {
        "obs": jax.random.normal(key, (B, 3)),
        "actions": jax.random.normal(key, (B, 1)),
        "behavior_logp": jax.random.normal(key, (B,)),
        "advantages": jax.random.normal(key, (B,)),
        "returns": jax.random.normal(key, (B,)),
    }
    cfg = PPOConfig()
    loss_off, _ = mlp_ppo_loss(params, batch, cfg)
    loss_w1, _ = mlp_ppo_loss(params, {**batch,
                                       "weights": jnp.ones((B,))}, cfg)
    assert np.asarray(loss_off) == np.asarray(loss_w1)   # w=1 is exact

    traj = {
        "obs": jax.random.normal(key, (4, 2, 3)),
        "actions": jax.random.normal(key, (4, 2, 1)),
        "logp": jax.random.normal(key, (4, 2)),
        "rewards": jax.random.normal(key, (4, 2)),
        "dones": jnp.zeros((4, 2)),
        "values": jax.random.normal(key, (4, 2)),
        "last_value": jax.random.normal(key, (2,)),
    }
    opt = adam(3e-4)
    opt_state = opt.init(params)
    plain = make_mlp_learner(opt, cfg)
    stale = make_mlp_learner(opt, cfg,
                             staleness=StalenessConfig(mode="decay"))
    p1, _, m1 = jax.jit(plain)(params, opt_state, traj)
    p2, _, m2 = jax.jit(stale)(params, opt_state, traj)  # no gap key
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert np.asarray(m1["loss"]) == np.asarray(m2["loss"])


def test_offpolicy_staleness_weights_ride_the_buffer():
    """Enabled off-policy staleness stores an ingest-time weight per
    transition; disabled, the storage schema is unchanged (the exact-off
    guarantee is the key's absence)."""
    from repro import registry
    env = registry.make("env", "pendulum")
    algo = registry.make("algo", "ddpg", hidden=16)
    ex_off = algo.transition_example(env)
    assert "staleness_w" not in ex_off
    algo.enable_staleness("decay")
    ex_on = algo.transition_example(env)
    assert "staleness_w" in ex_on


def test_enable_staleness_rejects_unsupported_algo():
    from repro import registry
    algo = registry.make("algo", "trpo", hidden=16)
    with pytest.raises(ValueError, match="trpo"):
        algo.enable_staleness("decay")
    algo.enable_staleness("off")                     # off is always fine


def test_spec_validation_errors():
    with pytest.raises(ValueError, match="async"):
        experiment.build(_spec("inline", staleness="decay"))
    with pytest.raises(ValueError, match="process"):
        experiment.build(_spec("inline", faults="kill:0.2"))
    with pytest.raises(ValueError, match="elastic"):
        experiment.build(_spec("inline", max_workers=4))
    with pytest.raises(ValueError, match="min_workers"):
        experiment.build(_spec("process", runtime="async",
                               min_workers=3, max_workers=4))
