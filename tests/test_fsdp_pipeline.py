"""Pipelined FSDP learner (DESIGN.md §11): the ``_param_spec`` storage
layout on 2-D and pod meshes, Adam moments inheriting their param's spec,
D>1 FSDP parity against the single-device path, the overlapped runner,
and the bench-hygiene guards. Mesh-shaped checks run in subprocesses —
device fan-out must be fixed before jax initialises."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import experiment
from repro.core.orchestrator import OverlapClock, SyncRunner, tree_ready
from repro.experiment import ExperimentSpec, Schedule

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
sys.path.insert(0, REPO)                      # for the benchmarks package


def _run(args, env=ENV, timeout=420):
    return subprocess.run([sys.executable] + args, cwd=REPO, env=env,
                          capture_output=True, text=True, timeout=timeout)


def _child_json(script, timeout=420):
    proc = _run(["-c", script], timeout=timeout)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    return json.loads(line.split(" ", 1)[1])


# ================================================ storage layout (specs)
_LAYOUT_SCRIPT = r"""
import json, os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
from repro.distributed.sharding import fsdp_leaf_dim, fsdp_axes
from repro.launch.mesh import make_learner_mesh

mesh2 = make_learner_mesh(4)              # (data, model) = (4, 1)
mesh3 = make_learner_mesh(4, pods=2)      # (pod, data, model) = (2, 2, 1)
out = {"axes2": list(fsdp_axes(mesh2)), "axes3": list(fsdp_axes(mesh3))}


def dims(tree, mesh):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): fsdp_leaf_dim(path, leaf, mesh)
            for path, leaf in flat}

# an RL policy-shaped tree: divisible 2-D weights, 1-D bias / log_std,
# and a non-divisible contracting dim (obs_dim=6 over 4 shards)
tree = {"l0": {"w": jnp.zeros((64, 64)), "b": jnp.zeros((64,))},
        "head": {"w": jnp.zeros((6, 64))},
        "log_std": jnp.zeros((1,))}
out["d2"] = dims(tree, mesh2)
out["d3"] = dims(tree, mesh3)
# pod mesh fsdp product is also 4, but a dim divisible only by 2 must
# fall back to replicated (strict full-product sharding, no partial axis)
out["partial"] = dims({"l0": {"w": jnp.zeros((6, 8))}}, mesh3)

# mesh construction contracts
err = None
try:
    make_learner_mesh(4, pods=3)
except ValueError as e:
    err = str(e)
out["pods_err"] = err
clamped = make_learner_mesh(8, offset=1)   # 8 devices: offset clamps to 0
out["clamp_ok"] = clamped.devices.size == 8
print("RESULT " + json.dumps(out))
"""


def test_param_spec_layouts_on_2d_and_pod_meshes():
    out = _child_json(_LAYOUT_SCRIPT)
    assert out["axes2"] == ["data"] and out["axes3"] == ["pod", "data"]
    for d in (out["d2"], out["d3"]):
        assert d["['l0']['w']"] == 0        # contracting dim sharded
        assert d["['head']['w']"] is None   # 6 % 4 != 0: replicated
        assert d["['l0']['b']"] is None     # 1-D bias: replicated
        assert d["['log_std']"] is None
    # divisible by 2 (a prefix of the pod fsdp product) but not by 4:
    # strict full-product sharding replicates rather than half-sharding
    assert out["partial"]["['l0']['w']"] is None
    assert "must divide" in out["pods_err"]
    assert out["clamp_ok"]


# ===================================== sharded storage through a real run
_SHARDING_SCRIPT = r"""
import json, os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro import experiment
from repro.experiment import ExperimentSpec, Schedule

spec = ExperimentSpec(
    env="pendulum", algo="ppo", backend="inline", runtime="sync",
    model={"hidden": 512},                 # 512x512 fp32 = 1 MiB leaves
    schedule=Schedule(num_samplers=1, global_batch=8, horizon=8,
                      iterations=1, seed=0, learner_devices=4, fsdp=True))
runner = experiment.build(spec)
try:
    runner.run(1)
finally:
    runner.close()
learner = runner._train_step.__self__      # the self-jitted ShardedLearner


def leaf_specs(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(p): [
                list(e) if isinstance(e, tuple) else e
                for e in tuple(l.sharding.spec)]
            for p, l in flat}


def leaf_bytes(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(p): int(l.size * l.dtype.itemsize)
            for p, l in flat}

mu = runner.opt_state.mu
print("RESULT " + json.dumps({
    "params": leaf_specs(runner.params),
    "bytes": leaf_bytes(runner.params),
    "mu": leaf_specs(mu),
    "nu": leaf_specs(runner.opt_state.nu),
    "step": list(runner.opt_state.step.sharding.spec),
    "table": {f"{n}|{s}": d
              for (n, s), d in learner._fsdp_info.full_table.items()},
}))
"""


def test_fsdp_shards_big_leaves_and_moments_match_param_specs():
    out = _child_json(_SHARDING_SCRIPT)
    # every >= 1-MiB param leaf is stored sharded (acceptance criterion)
    big = [k for k, b in out["bytes"].items() if b >= 1 << 20]
    assert big, "expected >= 1-MiB leaves at hidden=512"
    for k in big:
        assert "data" in str(out["params"][k]), (k, out["params"][k])
    # Adam moments carry exactly their param's sharding spec; the step
    # counter (scalar) is replicated
    assert out["mu"] == out["params"]
    assert out["nu"] == out["params"]
    assert out["step"] == []
    # and the layout table agrees: dim 0 for sharded 2-D weights
    assert any(d == 0 for d in out["table"].values())


# ======================================================= numeric parity
_PARITY_SCRIPT = r"""
import json, os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
from repro.experiment import ExperimentSpec, Schedule, run


def final(algo, **sched):
    base = dict(global_batch=16, horizon=16, iterations=3, seed=0,
                num_samplers=1)
    spec = ExperimentSpec(env="pendulum", algo=algo, backend="inline",
                          runtime="sync", model={"hidden": 32},
                          schedule=Schedule(**{**base, **sched}))
    return run(spec).params


def diff(a, b):
    return max(float(jnp.max(jnp.abs(x - y)))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))

p1 = final("ppo")
out = {
    "fsdp4": diff(p1, final("ppo", learner_devices=4, fsdp=True)),
    "pod22": diff(p1, final("ppo", learner_devices=4, learner_pods=2,
                            fsdp=True)),
    # fsdp=False must stay bitwise vs the PR-8 replicated schedule
    "repl_bitwise": diff(final("ppo", learner_devices=4),
                         final("ppo", learner_devices=4)),
}
print("RESULT " + json.dumps(out))
"""


def test_fsdp_parity_on_2d_and_pod_meshes():
    out = _child_json(_PARITY_SCRIPT, timeout=600)
    # reduce-scatter reorders the reduction; ppo tolerance matches the
    # replicated learner-plane tests
    assert out["fsdp4"] < 1e-4, out
    assert out["pod22"] < 1e-4, out
    assert out["repl_bitwise"] == 0.0, out


_OVERLAP_SCRIPT = r"""
import json, os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
from repro.experiment import ExperimentSpec, Schedule, run


def result(**sched):
    base = dict(global_batch=16, horizon=16, iterations=6, seed=0,
                num_samplers=1)
    spec = ExperimentSpec(env="pendulum", algo="ppo", backend="inline",
                          runtime="sync", model={"hidden": 32},
                          schedule=Schedule(**{**base, **sched}))
    return run(spec)


def diff(a, b):
    return max(float(jnp.max(jnp.abs(x - y)))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))

serial = result()
over = result(learner_devices=4, fsdp=True, overlap=True)
logs = [l.as_dict() for l in over.logs]
out = {"diff": diff(serial.params, over.params), "logs": logs}
print("RESULT " + json.dumps(out))
"""


def test_overlap_pipeline_staleness_and_tolerance():
    out = _child_json(_OVERLAP_SCRIPT, timeout=600)
    logs = out["logs"]
    # two serial warmup iterations: fresh data, nothing saved
    for l in logs[:2]:
        assert l["staleness"] == 0.0 and l["overlap_saved_s"] == 0.0
    # pipelined iterations consume data collected with one-version-stale
    # params; the final iteration has no next collect to overlap with
    for l in logs[3:]:
        assert l["staleness"] == 1.0
    assert all(l["overlap_saved_s"] >= 0.0 for l in logs)
    assert any(l["overlap_saved_s"] > 0.0 for l in logs[2:-1])
    # overlapped training follows the serial trajectory within the
    # documented tolerance (stale collection perturbs the data schedule;
    # measured max drift ~0.01 over 8 iterations — DESIGN.md §11)
    assert out["diff"] < 0.05, out["diff"]


# ============================================ in-process overlap pieces
def test_overlap_matches_serial_within_warmup():
    # iterations <= warmup never pipeline: identical to overlap=False,
    # bitwise, on the plain single-device path
    sched = dict(num_samplers=2, global_batch=4, horizon=8, seed=0)

    def final(overlap):
        spec = ExperimentSpec(env="pendulum", algo="ppo", backend="inline",
                              runtime="sync", model={"hidden": 16},
                              schedule=Schedule(**sched, overlap=overlap))
        runner = experiment.build(spec)
        try:
            runner.run(2)
        finally:
            runner.close()
        return runner.params

    for a, b in zip(jax.tree.leaves(final(False)),
                    jax.tree.leaves(final(True))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_overlap_clock_accounting():
    clock = OverlapClock()
    # learn still running when the collect finished: whole collect hidden
    assert clock.saved(0.5, learn_ready=False) == 0.5
    # no serial reference yet: cap at the collect duration
    assert clock.saved(0.5, learn_ready=True) == 0.5
    clock.note_serial(0.3)
    clock.note_serial(0.2)      # keeps the fastest clean reference
    assert clock.learn_ref == 0.2
    assert clock.saved(0.5, learn_ready=True) == 0.2
    assert clock.saved(0.1, learn_ready=True) == 0.1


def test_tree_ready_on_concrete_and_host_values():
    x = jax.block_until_ready(jnp.ones((2,)))
    assert tree_ready({"a": x, "b": 1.0})
    assert tree_ready(None)


def test_overlap_requires_train_step():
    with pytest.raises(ValueError, match="train_step"):
        SyncRunner(lambda p, c: (c, {}), lambda p, o, t: (p, o, {}),
                   {}, {}, carries=[None], overlap=True)


def test_schedule_validation_is_eager_and_pointed():
    def build(**kw):
        return experiment.build(ExperimentSpec(
            env="pendulum", algo="ppo", backend="inline", runtime="sync",
            model={"hidden": 16},
            schedule=Schedule(num_samplers=1, global_batch=4, horizon=8,
                              **kw)))

    with pytest.raises(ValueError, match="fsdp.*learner_devices"):
        build(fsdp=True)
    with pytest.raises(ValueError, match="learner_pods"):
        build(learner_pods=2)
    with pytest.raises(ValueError, match="async"):
        experiment.build(ExperimentSpec(
            env="pendulum", algo="ppo", backend="threaded",
            runtime="async", model={"hidden": 16},
            schedule=Schedule(num_samplers=1, global_batch=4, horizon=8,
                              overlap=True)))


def test_schedule_roundtrips_new_fields():
    spec = ExperimentSpec(schedule=Schedule(
        learner_devices=4, fsdp=True, overlap=True, learner_pods=2))
    again = ExperimentSpec.from_dict(spec.to_dict())
    assert again.schedule.fsdp and again.schedule.overlap
    assert again.schedule.learner_pods == 2


# ========================================================= bench hygiene
def _bench_payload(rev):
    return {"rev": rev, "benchmarks": [
        {"name": "r", "us_per_call": 1.0, "derived": "",
         "metrics": {"samples_per_sec": 10.0}}]}


def test_bench_refuses_dirty_overwrite_next_to_clean(tmp_path):
    from benchmarks import run as bench_run
    (tmp_path / "BENCH_abc123.json").write_text("{}")
    with pytest.raises(SystemExit, match="dirty"):
        bench_run.check_dirty_overwrite(str(tmp_path), "abc123-dirty",
                                        force=False)
    # --force, a clean rev, or no clean sibling are all allowed
    bench_run.check_dirty_overwrite(str(tmp_path), "abc123-dirty",
                                    force=True)
    bench_run.check_dirty_overwrite(str(tmp_path), "abc123", force=False)
    bench_run.check_dirty_overwrite(str(tmp_path), "fff999-dirty",
                                    force=False)


def test_bench_compare_warns_on_dirty_revs(tmp_path, capsys):
    import json as _json

    from benchmarks import run as bench_run
    old = tmp_path / "old.json"
    new = tmp_path / "new.json"
    old.write_text(_json.dumps(_bench_payload("abc123")))
    new.write_text(_json.dumps(_bench_payload("abc123-dirty")))
    assert bench_run.compare(str(old), str(new), threshold=10.0) == 0
    assert "dirty tree" in capsys.readouterr().err
    old.write_text(_json.dumps(_bench_payload("abc123")))
    new.write_text(_json.dumps(_bench_payload("def456")))
    bench_run.compare(str(old), str(new), threshold=10.0)
    assert "dirty tree" not in capsys.readouterr().err
