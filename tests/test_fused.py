"""Fused-engine and backend-layer tests.

* The fused collect->GAE->PPO scan must reproduce a stepped SyncRunner run
  bitwise (same seed, same params out) — fusing is a scheduling change,
  not a numerical one.
* Inline/Threaded/Sharded backends are different schedules of the same
  sampler work and must produce identically-shaped (and, from identical
  carries, identical-valued) merged trajectories.
"""
import jax
import numpy as np
import pytest

from repro import envs
from repro.algos.ppo import PPOConfig, make_mlp_learner
from repro.core import (
    FusedRunner,
    InlineBackend,
    SyncRunner,
    ThreadedBackend,
    make_backend,
)
from repro.core import sampler as sampler_mod
from repro.core.fused import TrainState, make_fused_train_loop
from repro.data import trajectory
from repro.optim import adam

HORIZON = 16
BATCH = 8


def _pieces(seed=0, hidden=32):
    env = envs.make("pendulum")
    from repro.models import mlp_policy
    params = mlp_policy.init_policy(jax.random.PRNGKey(seed), env.obs_dim,
                                    env.act_dim, hidden)
    opt = adam(1e-3)
    learn = make_mlp_learner(opt, PPOConfig(epochs=2, minibatches=2))
    return env, params, opt, learn


def _carry(env, seed=1, batch=BATCH):
    return sampler_mod.init_env_carry(env, jax.random.PRNGKey(seed), batch)


def _assert_trees_equal(a, b):
    for xa, xb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))


# ============================================================ fused parity
def test_fused_matches_stepped_bitwise():
    """3 iterations on pendulum: fused scan == stepped SyncRunner, exact."""
    env, params, opt, learn = _pieces()
    stepped = SyncRunner(sampler_mod.make_env_rollout(env, HORIZON), learn,
                         params, opt.init(params), [_carry(env)], 1)
    stepped.run(3)

    fused = FusedRunner(env, learn, params, opt.init(params), _carry(env),
                        horizon=HORIZON)
    fused.run(3)

    _assert_trees_equal(stepped.params, fused.params)
    _assert_trees_equal(stepped.opt_state, fused.opt_state)


def test_fused_chunking_invariant():
    """Running 4 iterations as 1 chunk or 2+2 gives identical params."""
    env, params, opt, learn = _pieces()
    one = FusedRunner(env, learn, params, opt.init(params), _carry(env),
                      horizon=HORIZON, chunk=4)
    one.run(4)
    two = FusedRunner(env, learn, params, opt.init(params), _carry(env),
                      horizon=HORIZON, chunk=2)
    two.run(4)
    _assert_trees_equal(one.params, two.params)
    assert len(one.logs) == len(two.logs) == 4


def test_fused_loop_metrics_stacked():
    env, params, opt, learn = _pieces()
    loop = make_fused_train_loop(env, learn, HORIZON, chunk=3)
    # the loop donates its input; copy so ``params`` survives for comparison
    state = jax.tree.map(jax.numpy.copy,
                         TrainState(params, opt.init(params), _carry(env)))
    state2, metrics = loop(state)
    assert metrics["loss"].shape == (3,)
    assert metrics["mean_return"].shape == (3,)
    assert np.all(np.isfinite(np.asarray(metrics["loss"])))
    # params actually changed
    moved = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(state2.params)))
    assert moved


def test_fused_runner_logs():
    env, params, opt, learn = _pieces()
    runner = FusedRunner(env, learn, params, opt.init(params), _carry(env),
                         horizon=HORIZON)
    logs = runner.run(3)
    assert [l.iteration for l in logs] == [0, 1, 2]
    for log in logs:
        assert log.samples == BATCH * HORIZON
        assert log.learn_time > 0
        assert log.collect_time == 0.0      # no host-visible split, by design


# ========================================================== backend parity
def _backend_pair(kind):
    env, params, opt, learn = _pieces()
    rollout = sampler_mod.make_env_rollout(env, HORIZON)
    carries = lambda: [_carry(env, seed=1 + i, batch=4) for i in range(2)]
    ref = InlineBackend(rollout, carries())
    other = make_backend(kind, rollout, carries(), env=env, horizon=HORIZON)
    return params, ref, other


@pytest.mark.parametrize("kind", ["threaded", "sharded"])
def test_backend_parity_with_inline(kind):
    params, ref, other = _backend_pair(kind)
    merged_ref, stats_ref = ref.collect(params)
    merged, stats = other.collect(params)
    assert set(merged) == set(merged_ref)
    for k in merged_ref:
        assert merged[k].shape == merged_ref[k].shape, k
        np.testing.assert_array_equal(np.asarray(merged[k]),
                                      np.asarray(merged_ref[k]))
    assert stats.samples == stats_ref.samples
    assert stats.critical_path > 0
    assert stats.serial_equivalent >= stats.critical_path - 1e-9


def test_threaded_backend_advances_carries():
    env, params, opt, learn = _pieces()
    rollout = sampler_mod.make_env_rollout(env, HORIZON)
    bk = ThreadedBackend(rollout, [_carry(env, seed=i) for i in range(3)])
    m1, _ = bk.collect(params)
    m2, _ = bk.collect(params)
    assert not np.array_equal(np.asarray(m1["obs"]), np.asarray(m2["obs"]))
    bk.close()


def test_sync_runner_over_threaded_backend():
    env, params, opt, learn = _pieces()
    rollout = sampler_mod.make_env_rollout(env, HORIZON)
    bk = ThreadedBackend(rollout, [_carry(env, seed=i) for i in range(2)])
    runner = SyncRunner(None, learn, params, opt.init(params), backend=bk)
    logs = runner.run(2)
    assert len(logs) == 2
    assert logs[0].samples == 2 * BATCH * HORIZON
    assert runner.timer.total("collect") > 0
    bk.close()
