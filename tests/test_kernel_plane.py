"""Kernel-plane parity + selection tests.

Every RL hot-loop family (gae / sum_tree / replay_ring) must be
*exactly* equal between its Pallas kernel (interpret mode on CPU — the
real kernel bodies, executed by the interpreter) and its pure-JAX
reference — these assert equality, not closeness, across the T/B/
capacity edge cases (T=1, B=1, capacity not a power of two, all-done
trajectories, duplicate scatter indices). Plus the selection seam:
``kernels.select`` modes, ``ExperimentSpec.kernels``, and the
``"kernel"`` registry kind.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import registry
from repro.algos import gae as algo_gae
from repro.data.buffers import PrioritizedBuffer
from repro.experiment import ExperimentSpec
from repro.kernels import gae as gae_k
from repro.kernels import replay_ring as ring_k
from repro.kernels import select
from repro.kernels import sum_tree as tree_k

KEY = jax.random.PRNGKey(11)


@pytest.fixture(autouse=True)
def _restore_kernel_mode():
    prev = select.kernel_mode()
    yield
    select.set_kernel_mode(prev)


def assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for xa, xb in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))


def _dones(T, B, mode, key):
    if mode == "none":
        return jnp.zeros((T, B), bool)
    if mode == "all":
        return jnp.ones((T, B), bool)
    return jax.random.bernoulli(key, 0.3, (T, B))


# ===================================================================== gae
GAE_SHAPES = [(1, 1), (2, 1), (1, 7), (5, 3), (64, 8), (130, 4)]


@pytest.mark.parametrize("T,B", GAE_SHAPES)
@pytest.mark.parametrize("done_mode", ["none", "random", "all"])
def test_gae_pallas_matches_ref_exactly(T, B, done_mode):
    ks = jax.random.split(jax.random.fold_in(KEY, T * 1000 + B), 4)
    r = jax.random.normal(ks[0], (T, B))
    v = jax.random.normal(ks[1], (T, B))
    d = _dones(T, B, done_mode, ks[2])
    lv = jax.random.normal(ks[3], (B,))
    adv_r, ret_r = gae_k.gae(r, v, d, lv, impl="ref")
    adv_p, ret_p = gae_k.gae(r, v, d, lv, impl="pallas")
    np.testing.assert_array_equal(np.asarray(adv_r), np.asarray(adv_p))
    np.testing.assert_array_equal(np.asarray(ret_r), np.asarray(ret_p))


@pytest.mark.parametrize("T,B", GAE_SHAPES)
@pytest.mark.parametrize("done_mode", ["none", "random", "all"])
def test_returns_pallas_matches_ref_exactly(T, B, done_mode):
    ks = jax.random.split(jax.random.fold_in(KEY, T * 991 + B), 3)
    r = jax.random.normal(ks[0], (T, B))
    d = _dones(T, B, done_mode, ks[1])
    lv = jax.random.normal(ks[2], (B,))
    ret_r = gae_k.discounted_returns(r, d, lv, impl="ref")
    ret_p = gae_k.discounted_returns(r, d, lv, impl="pallas")
    np.testing.assert_array_equal(np.asarray(ret_r), np.asarray(ret_p))


def test_gae_entry_point_default_is_bitwise_ref():
    """``algos.gae.gae`` with the default selection (auto, off-TPU)
    is the historical sequential recurrence bit for bit."""
    ks = jax.random.split(KEY, 4)
    r = jax.random.normal(ks[0], (16, 2))
    v = jax.random.normal(ks[1], (16, 2))
    d = jax.random.bernoulli(ks[2], 0.2, (16, 2))
    lv = jax.random.normal(ks[3], (2,))
    adv, ret = algo_gae.gae(r, v, d, lv)
    adv_ref, ret_ref = gae_k.gae_ref(r, v, d, lv)
    np.testing.assert_array_equal(np.asarray(adv), np.asarray(adv_ref))
    np.testing.assert_array_equal(np.asarray(ret), np.asarray(ret_ref))


def test_gae_trailing_batch_dims_roundtrip():
    """The pallas path flattens (T, B1, B2) batches and restores them."""
    ks = jax.random.split(KEY, 4)
    r = jax.random.normal(ks[0], (9, 2, 3))
    v = jax.random.normal(ks[1], (9, 2, 3))
    d = jax.random.bernoulli(ks[2], 0.2, (9, 2, 3))
    lv = jax.random.normal(ks[3], (2, 3))
    adv_r, _ = gae_k.gae(r, v, d, lv, impl="ref")
    adv_p, _ = gae_k.gae(r, v, d, lv, impl="pallas")
    assert adv_p.shape == (9, 2, 3)
    np.testing.assert_array_equal(np.asarray(adv_r), np.asarray(adv_p))


# ================================================================ sum_tree
CAPS = [1, 2, 8, 64, 1024]


@pytest.mark.parametrize("cap", CAPS)
def test_sumtree_find_pallas_matches_ref_exactly(cap):
    leaves = jnp.abs(jax.random.normal(jax.random.fold_in(KEY, cap),
                                       (cap,)))
    # zero-mass slots exercise the unfilled-capacity case
    leaves = leaves.at[:: max(cap // 4, 1)].set(0.0)
    tree = tree_k.sumtree_build(leaves)
    B = 32
    u = (jnp.arange(B, dtype=jnp.float32) + 0.5) / B
    masses = u * tree.total
    idx_r = tree_k.sumtree_find_batch(tree, masses, impl="ref")
    idx_p = tree_k.sumtree_find_batch(tree, masses, impl="pallas")
    np.testing.assert_array_equal(np.asarray(idx_r), np.asarray(idx_p))
    assert np.asarray(idx_p).max() < cap
    # the batched descent is elementwise the scalar descent
    scalar = jnp.stack([tree_k.sumtree_find(tree, m) for m in masses[:4]])
    np.testing.assert_array_equal(np.asarray(scalar),
                                  np.asarray(idx_r[:4]))


@pytest.mark.parametrize("cap", CAPS)
def test_sumtree_update_pallas_matches_ref_exactly(cap):
    leaves = jnp.abs(jax.random.normal(jax.random.fold_in(KEY, cap + 1),
                                       (cap,)))
    tree = tree_k.sumtree_build(leaves)
    # duplicates on purpose: both impls must resolve last-write-wins
    idx = jnp.asarray([0, cap - 1, 0, cap // 2, 0])[: max(3, min(5, cap))]
    idx = idx % cap
    vals = jnp.asarray([1.5, 2.0, 0.25, 3.0, 0.125])[: idx.shape[0]]
    t_r = tree_k.sumtree_update(tree, idx, vals, impl="ref")
    t_p = tree_k.sumtree_update(tree, idx, vals, impl="pallas")
    assert_trees_equal(t_r, t_p)
    # and the updated tree descends identically
    masses = (jnp.arange(8, dtype=jnp.float32) + 0.5) / 8 * t_r.total
    np.testing.assert_array_equal(
        np.asarray(tree_k.sumtree_find_batch(t_r, masses, impl="ref")),
        np.asarray(tree_k.sumtree_find_batch(t_p, masses, impl="pallas")))


def test_sumtree_flatten_roundtrip():
    tree = tree_k.sumtree_build(jnp.arange(16.0))
    flat = tree_k.tree_flatten(tree)
    assert flat.shape == (31,)
    assert_trees_equal(tree, tree_k.tree_unflatten(flat, 16))


# ============================================================= replay_ring
@pytest.mark.parametrize("cap,n,start", [
    (17, 5, 0),        # capacity not a power of two
    (17, 5, 15),       # wraparound
    (12, 12, 7),       # exactly one full ring, offset start
    (8, 11, 3),        # n > capacity: self-overwrite, last write wins
    (1, 1, 0),         # degenerate ring
])
def test_ring_insert_pallas_matches_ref_exactly(cap, n, start):
    ks = jax.random.split(jax.random.fold_in(KEY, cap * 100 + n), 2)
    storage = {"obs": jax.random.normal(ks[0], (cap, 3)),
               "rewards": jnp.zeros((cap,))}
    batch = {"obs": jax.random.normal(ks[1], (n, 3)),
             "rewards": jnp.arange(float(n))}
    s_r = ring_k.ring_insert(storage, batch, jnp.int32(start), impl="ref")
    s_p = ring_k.ring_insert(storage, batch, jnp.int32(start),
                             impl="pallas")
    assert_trees_equal(s_r, s_p)


@pytest.mark.parametrize("cap,B", [(17, 6), (1, 1), (64, 64)])
def test_ring_gather_pallas_matches_ref_exactly(cap, B):
    ks = jax.random.split(jax.random.fold_in(KEY, cap * 7 + B), 2)
    storage = {"obs": jax.random.normal(ks[0], (cap, 2, 2)),
               "rewards": jax.random.normal(ks[1], (cap,))}
    idx = jax.random.randint(jax.random.fold_in(KEY, B), (B,), 0, cap)
    g_r = ring_k.ring_gather(storage, idx, impl="ref")
    g_p = ring_k.ring_gather(storage, idx, impl="pallas")
    assert g_p["obs"].shape == (B, 2, 2)
    assert_trees_equal(g_r, g_p)


# ============================================== buffer-level end-to-end
def _traj(T, B):
    t = jnp.broadcast_to(jnp.arange(T, dtype=jnp.float32)[:, None, None],
                         (T, B, 3))
    return {"obs": t, "actions": jnp.zeros((T, B, 2)),
            "rewards": jnp.ones((T, B)),
            "dones": jnp.zeros((T, B), bool), "next_obs": t + 1.0}


def _example():
    return {"obs": jnp.zeros((1, 3)), "actions": jnp.zeros((1, 2)),
            "rewards": jnp.zeros((1,)), "next_obs": jnp.zeros((1, 3)),
            "dones": jnp.zeros((1,), bool)}


def test_prioritized_buffer_pallas_matches_ref_end_to_end():
    """add -> update_priorities -> sample through the whole buffer, once
    per kernel mode: same tree, same drawn indices, same weights."""
    outs = {}
    for mode in ("ref", "pallas"):
        select.set_kernel_mode(mode)
        buf = PrioritizedBuffer(capacity=64, batch_size=32)
        state = buf.add(buf.init(_example()), _traj(8, 4))
        state = buf.update_priorities(state, jnp.arange(8),
                                      jnp.linspace(0.1, 3.0, 8))
        outs[mode] = (state, buf.sample(state, jax.random.PRNGKey(0)))
    assert_trees_equal(outs["ref"][0], outs["pallas"][0])
    for k in outs["ref"][1]:
        np.testing.assert_array_equal(np.asarray(outs["ref"][1][k]),
                                      np.asarray(outs["pallas"][1][k]))


# ========================================================= selection seam
def test_kernel_mode_validation_and_resolution():
    with pytest.raises(ValueError, match="kernel mode"):
        select.set_kernel_mode("cuda")
    with pytest.raises(ValueError, match="kernel impl"):
        select.resolve("cuda")
    assert select.resolve("ref") == ("ref", False)
    name, interpret = select.resolve("pallas")
    assert name == "pallas"
    compiled = jax.default_backend() in select.COMPILED_PLATFORMS
    assert interpret == (not compiled)  # off-accelerator pallas interprets
    assert select.resolve("auto") == (("pallas", False) if compiled
                                      else ("ref", False))


def test_set_kernel_mode_returns_previous():
    prev = select.set_kernel_mode("ref")
    assert select.kernel_mode() == "ref"
    assert select.set_kernel_mode(prev) == "ref"


def test_spec_kernels_field_roundtrip_and_validation():
    spec = ExperimentSpec(kernels="pallas")
    assert ExperimentSpec.from_dict(spec.to_dict()) == spec
    assert ExperimentSpec().kernels == "auto"
    from repro import experiment
    with pytest.raises(ValueError, match="kernel mode"):
        experiment.build(ExperimentSpec(kernels="nope"))


def test_registry_kernel_kind_lists_families():
    names = registry.choices("kernel")
    assert {"gae", "sum_tree", "replay_ring", "env_step"} <= set(names)
    ops = registry.make("kernel", "gae")
    assert hasattr(ops, "gae") and hasattr(ops, "gae_ref")
