"""Property-based kernel-plane parity (hypothesis; skipped by conftest
when hypothesis is absent).

Randomized shapes/values for the three RL kernel families, asserting the
Pallas kernels (interpret mode on CPU) equal the pure-JAX references
*exactly* — the generators bias toward the edges the parametrized tests
pin (T=1, B=1, non-power-of-two ring capacities, all-done trajectories,
duplicate scatter indices).
"""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import gae as gae_k
from repro.kernels import replay_ring as ring_k
from repro.kernels import sum_tree as tree_k

# interpret-mode pallas launches are slow; keep the example budget tight
# and the deadline off (first call per shape pays a trace)
SETTINGS = dict(max_examples=20, deadline=None)

finite = st.floats(-10.0, 10.0, allow_nan=False, width=32)


def _arr(draw, shape, elements=finite):
    vals = draw(st.lists(elements, min_size=int(np.prod(shape)),
                         max_size=int(np.prod(shape))))
    return jnp.asarray(np.asarray(vals, np.float32).reshape(shape))


@settings(**SETTINGS)
@given(st.data(), st.integers(1, 24), st.integers(1, 6),
       st.sampled_from(["none", "all", "random"]))
def test_gae_parity_property(data, T, B, done_mode):
    r = _arr(data.draw, (T, B))
    v = _arr(data.draw, (T, B))
    lv = _arr(data.draw, (B,))
    if done_mode == "none":
        d = jnp.zeros((T, B), bool)
    elif done_mode == "all":
        d = jnp.ones((T, B), bool)
    else:
        d = jnp.asarray(np.asarray(
            data.draw(st.lists(st.booleans(), min_size=T * B,
                               max_size=T * B))).reshape(T, B))
    adv_r, ret_r = gae_k.gae(r, v, d, lv, impl="ref")
    adv_p, ret_p = gae_k.gae(r, v, d, lv, impl="pallas")
    np.testing.assert_array_equal(np.asarray(adv_r), np.asarray(adv_p))
    np.testing.assert_array_equal(np.asarray(ret_r), np.asarray(ret_p))


@settings(**SETTINGS)
@given(st.data(), st.integers(0, 7), st.integers(1, 16))
def test_sumtree_find_and_update_parity_property(data, cap_exp, B):
    cap = 1 << cap_exp
    leaves = _arr(data.draw, (cap,),
                  st.floats(0.0, 10.0, allow_nan=False, width=32))
    tree = tree_k.sumtree_build(leaves)
    u = _arr(data.draw, (B,),
             st.floats(0.0, 1.0, exclude_max=True, allow_nan=False,
                       width=32))
    masses = u * tree.total
    np.testing.assert_array_equal(
        np.asarray(tree_k.sumtree_find_batch(tree, masses, impl="ref")),
        np.asarray(tree_k.sumtree_find_batch(tree, masses,
                                             impl="pallas")))
    idx = jnp.asarray(np.asarray(
        data.draw(st.lists(st.integers(0, cap - 1), min_size=B,
                           max_size=B)), np.int32))
    vals = _arr(data.draw, (B,),
                st.floats(0.0, 10.0, allow_nan=False, width=32))
    t_r = tree_k.sumtree_update(tree, idx, vals, impl="ref")
    t_p = tree_k.sumtree_update(tree, idx, vals, impl="pallas")
    for a, b in zip(t_r.levels, t_p.levels):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(**SETTINGS)
@given(st.data(), st.integers(1, 24), st.integers(1, 24),
       st.integers(0, 23), st.integers(1, 8))
def test_ring_parity_property(data, cap, n, start, B):
    start = start % cap
    storage = {"x": _arr(data.draw, (cap, 2)),
               "r": _arr(data.draw, (cap,))}
    batch = {"x": _arr(data.draw, (n, 2)), "r": _arr(data.draw, (n,))}
    s_r = ring_k.ring_insert(storage, batch, jnp.int32(start), impl="ref")
    s_p = ring_k.ring_insert(storage, batch, jnp.int32(start),
                             impl="pallas")
    for k in s_r:
        np.testing.assert_array_equal(np.asarray(s_r[k]),
                                      np.asarray(s_p[k]))
    idx = jnp.asarray(np.asarray(
        data.draw(st.lists(st.integers(0, cap - 1), min_size=B,
                           max_size=B)), np.int32))
    g_r = ring_k.ring_gather(s_r, idx, impl="ref")
    g_p = ring_k.ring_gather(s_r, idx, impl="pallas")
    for k in g_r:
        np.testing.assert_array_equal(np.asarray(g_r[k]),
                                      np.asarray(g_p[k]))
