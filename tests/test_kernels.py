"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import decode_attention_op, decode_ref
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.selective_scan import selective_scan, selective_scan_ref

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize(
    "B,H,K,S,hd,causal,window,qb,kb",
    [
        (1, 4, 2, 256, 64, True, 0, 64, 64),
        (2, 2, 2, 512, 32, True, 0, 128, 128),
        (1, 4, 1, 256, 64, True, 100, 64, 64),       # SWA
        (1, 2, 2, 128, 64, False, 0, 128, 64),       # non-causal
        (1, 8, 2, 256, 128, True, 0, 256, 128),      # GQA 4:1, MXU-width hd
    ])
def test_flash_attention_sweep(B, H, K, S, hd, causal, window, qb, kb,
                               dtype, tol):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, K, S, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, K, S, hd)).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          q_block=qb, kv_block=kb)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize("B,K,G,Sc,hd,kb", [
    (2, 2, 4, 1024, 64, 128),
    (1, 4, 1, 512, 32, 512),
    (3, 1, 5, 256, 64, 64),
    (2, 2, 2, 384, 128, 128),     # Sc not a power of two
])
def test_decode_attention_sweep(B, K, G, Sc, hd, kb, dtype, tol):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, K, G, hd)).astype(dtype)
    kc = jax.random.normal(ks[1], (B, Sc, K, hd)).astype(dtype)
    vc = jax.random.normal(ks[2], (B, Sc, K, hd)).astype(dtype)
    valid = jax.random.bernoulli(ks[3], 0.6, (Sc,)).at[0].set(True)
    out = decode_attention_op(q, kc, vc, valid, kv_block=kb)
    ref = decode_ref(q.reshape(B, K * G, hd),
                     jnp.transpose(kc, (0, 2, 1, 3)),
                     jnp.transpose(vc, (0, 2, 1, 3)),
                     valid).reshape(B, K, G, hd)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


@pytest.mark.parametrize("B,S,Di,N,db,tc", [
    (2, 256, 128, 16, 64, 64),
    (1, 128, 64, 8, 64, 128),
    (2, 64, 256, 16, 128, 32),
    (1, 192, 64, 4, 32, 64),      # S not a multiple of t_chunk -> S chunk
])
def test_selective_scan_sweep(B, S, Di, N, db, tc):
    ks = jax.random.split(KEY, 5)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, Di))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[1], (Di, N)) * 0.2)
    b = jax.random.normal(ks[2], (B, S, N))
    c = jax.random.normal(ks[3], (B, S, N))
    x = jax.random.normal(ks[4], (B, S, Di))
    h0 = jnp.zeros((B, Di, N))
    y, h = selective_scan(dt, A, b, c, x, h0, d_block=db, t_chunk=tc)
    yr, hr = selective_scan_ref(dt, A, b, c, x, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=2e-4)


def test_selective_scan_state_chaining():
    """Scanning two halves with carried state == scanning the whole."""
    ks = jax.random.split(KEY, 5)
    B, S, Di, N = 1, 128, 32, 8
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, Di))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[1], (Di, N)) * 0.2)
    b = jax.random.normal(ks[2], (B, S, N))
    c = jax.random.normal(ks[3], (B, S, N))
    x = jax.random.normal(ks[4], (B, S, Di))
    h0 = jnp.zeros((B, Di, N))
    y_full, h_full = selective_scan(dt, A, b, c, x, h0, d_block=32,
                                    t_chunk=32)
    h = h0
    ys = []
    for sl in (slice(0, 64), slice(64, 128)):
        y, h = selective_scan(dt[:, sl], A, b[:, sl], c[:, sl], x[:, sl], h,
                              d_block=32, t_chunk=32)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, axis=1)),
                               np.asarray(y_full), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_full), atol=1e-4)


# ------------------------------------------------- kernel <-> model cross
def test_flash_kernel_matches_model_blockwise():
    """The Pallas kernel and the model's recursive-halving reference are
    two implementations of the same spec — cross-validate them directly
    (not just each against the naive oracle)."""
    from repro.kernels.flash_attention.ops import flash_attention_op
    from repro.models import attention as A
    ks = jax.random.split(KEY, 3)
    B, S, K, G, hd = 2, 256, 2, 2, 64
    q = jax.random.normal(ks[0], (B, S, K, G, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    kern = flash_attention_op(q, k, v, causal=True, q_block=64, kv_block=64)
    model = A.full_causal(q, k, v, leaf=128, kv_block=128)
    np.testing.assert_allclose(np.asarray(kern), np.asarray(model),
                               atol=2e-5)
    # SWA variant too
    kern_w = flash_attention_op(q, k, v, causal=True, window=100,
                                q_block=64, kv_block=64)
    model_w = A.swa(q, k, v, 100, q_block=64)
    np.testing.assert_allclose(np.asarray(kern_w), np.asarray(model_w),
                               atol=2e-5)


def test_decode_kernel_matches_model_decode():
    from repro.kernels.decode_attention import decode_attention_op
    from repro.models import attention as A
    ks = jax.random.split(KEY, 4)
    B, K, G, Sc, hd = 2, 2, 3, 256, 32
    q = jax.random.normal(ks[0], (B, K, G, hd))
    kc = jax.random.normal(ks[1], (B, Sc, K, hd))
    vc = jax.random.normal(ks[2], (B, Sc, K, hd))
    valid = jax.random.bernoulli(ks[3], 0.5, (Sc,)).at[0].set(True)
    kern = decode_attention_op(q, kc, vc, valid, kv_block=64)
    model = A.decode(q, kc, vc, valid)
    np.testing.assert_allclose(np.asarray(kern), np.asarray(model),
                               atol=2e-5)
