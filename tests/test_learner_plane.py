"""Multi-device learner plane (distributed/learner + grad_sync): the
trace-time gradient-sync context, the experiment wiring, and D>1
equivalence against the single-device path (subprocess — device fan-out
must be fixed before jax initialises)."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import experiment
from repro.distributed import grad_sync
from repro.experiment import ExperimentSpec, Schedule

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))


def _run(args, env=ENV, timeout=420):
    return subprocess.run([sys.executable] + args, cwd=REPO, env=env,
                          capture_output=True, text=True, timeout=timeout)


TINY = dict(num_samplers=2, global_batch=4, horizon=8, iterations=2, seed=0)


def _tiny_spec(algo="ppo", **sched):
    return ExperimentSpec(env="pendulum", algo=algo, backend="inline",
                          runtime="sync", model={"hidden": 16},
                          schedule=Schedule(**{**TINY, **sched}))


def _final_params(spec, iterations=2):
    runner = experiment.build(spec)
    try:
        runner.run(iterations)
    finally:
        runner.close()
    return runner.params


def _assert_trees_equal(a, b):
    for xa, xb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))


def _loss(params, batch):
    pred = batch["x"] @ params["w"] + params["b"]
    return jnp.mean((pred - batch["y"]) ** 2)


def _toy():
    k = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(k, (3,)), "b": jnp.zeros(())}
    batch = {"x": jax.random.normal(jax.random.PRNGKey(1), (8, 3)),
             "y": jax.random.normal(jax.random.PRNGKey(2), (8,)),
             # no leading batch dim: must pass through microbatch slicing
             "rng": jax.random.PRNGKey(3)}
    return params, batch


# ================================================== grad_sync context unit
def test_value_and_grad_outside_context_is_plain():
    params, batch = _toy()
    want = jax.value_and_grad(_loss)(params, batch)
    got = grad_sync.value_and_grad(_loss, params, batch)
    _assert_trees_equal(got, want)
    assert grad_sync.active() is None
    assert grad_sync.reduce_axes() is None


def test_sync_is_noop_outside_context():
    tree = {"a": jnp.ones((3,))}
    assert grad_sync.sync(tree) is tree


def test_microbatch_accumulation_matches_full_batch():
    params, batch = _toy()
    _, g_ref = jax.value_and_grad(_loss)(params, batch)
    with grad_sync.activate(None, 4):
        loss, g = grad_sync.value_and_grad(_loss, params, batch)
    for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(g_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(loss), float(_loss(params, batch)),
                               rtol=1e-6)


def test_microbatch_aux_concatenates_per_sample_leaves():
    params, batch = _toy()

    def loss_aux(p, b):
        per = (b["x"] @ p["w"] + p["b"] - b["y"]) ** 2
        return jnp.mean(per), per

    (_, per_ref), _ = jax.value_and_grad(loss_aux, has_aux=True)(
        params, batch)
    with grad_sync.activate(None, 2):
        (_, per), _ = grad_sync.value_and_grad(loss_aux, params, batch,
                                               has_aux=True)
    assert per.shape == per_ref.shape                     # (8,), not (2, 4)
    np.testing.assert_allclose(np.asarray(per), np.asarray(per_ref),
                               rtol=1e-6)


def test_microbatch_divisibility_error():
    params, batch = _toy()
    with grad_sync.activate(None, 3):
        with pytest.raises(ValueError, match="divisible"):
            grad_sync.value_and_grad(_loss, params, batch)


# ================================================= experiment.build wiring
def test_learner_devices_1_is_legacy_bitwise():
    base = _final_params(_tiny_spec())
    gated = _final_params(_tiny_spec(learner_devices=1))
    _assert_trees_equal(gated, base)


def test_learner_microbatches_close_to_plain():
    base = _final_params(_tiny_spec())
    micro = _final_params(_tiny_spec(learner_microbatches=2))
    for a, b in zip(jax.tree.leaves(micro), jax.tree.leaves(base)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_unshardable_algo_rejected():
    with pytest.raises(ValueError, match="shard"):
        experiment.build(_tiny_spec("trpo", learner_devices=2))


def test_learner_devices_exceeding_host_raises_with_hint():
    if len(jax.devices()) >= 16:
        pytest.skip("host exposes enough devices")
    with pytest.raises(ValueError, match="XLA_FLAGS"):
        experiment.build(_tiny_spec(learner_devices=16, global_batch=16))


# ========================================== D=4 == D=1 equivalence (slow)
@pytest.mark.slow
def test_learner_d4_matches_d1():
    """4 learner shards (8 forced host devices) reach the same final
    params as the single-device path. ppo is tight (pmean'd gradients ==
    full-batch gradients up to float reduction order); sac/ddpg carry the
    DESIGN.md §9 documented tolerance — per-shard rings realize a
    different (equally distributed) physical replay layout, so the
    realized draws differ while following the same sampling law."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro import experiment
from repro.experiment import ExperimentSpec, Schedule

def final(algo, buffer, devices):
    spec = ExperimentSpec(
        env="pendulum", algo=algo, backend="inline", runtime="sync",
        model={"hidden": 16}, buffer=buffer,
        buffer_kwargs=({"capacity": 1024, "batch_size": 32}
                       if buffer else {}),
        schedule=Schedule(num_samplers=2, global_batch=8, horizon=8,
                          seed=0, learner_devices=devices))
    runner = experiment.build(spec)
    try:
        runner.run(3)
    finally:
        runner.close()
    return runner.params

for algo, buffer, tol in (("ppo", None, 1e-5),
                          ("sac", "prioritized", 0.05),
                          ("ddpg", "uniform", 0.05)):
    p1 = final(algo, buffer, None)
    p4 = final(algo, buffer, 4)
    diff = max(float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
               for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)))
    assert diff < tol, f"{algo}: D4 diverged from D1 by {diff} (tol {tol})"
    print(f"LEARNER_D4_OK {algo} {diff:.2e}")
"""
    r = _run(["-c", script], timeout=900)
    assert r.stdout.count("LEARNER_D4_OK") == 3, r.stdout + r.stderr


@pytest.mark.slow
def test_train_cli_learner_devices():
    env = dict(ENV, XLA_FLAGS="--xla_force_host_platform_device_count=8")
    r = _run(["-m", "repro.launch.train", "--mode", "rl", "--env",
              "cartpole", "--num-samplers", "2", "--global-batch", "8",
              "--horizon", "8", "--iterations", "2",
              "--learner-devices", "4"], env=env)
    assert r.returncode == 0, r.stderr
    lines = [json.loads(l) for l in r.stdout.strip().splitlines()
             if l.startswith("{")]
    assert len(lines) == 2 and lines[0]["samples"] == 8 * 8
