"""Token-level PPO (the train_4k computation) semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.algos.ppo import PPOConfig, lm_ppo_loss
from repro.configs import get_config
from repro.models import transformer as T

KEY = jax.random.PRNGKey(5)
B, S = 2, 16


def _batch(cfg, key, mask=None):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return {
        "tokens": toks,
        "targets": jnp.roll(toks, -1, axis=1),
        "behavior_logp": -jnp.full((B, S), 2.0),
        "advantages": jax.random.normal(key, (B, S)),
        "returns": jax.random.normal(key, (B, S)),
        "mask": jnp.ones((B, S)) if mask is None else mask,
    }


def test_masked_positions_do_not_affect_loss():
    cfg = get_config("h2o-danube-3-4b").reduced()
    params = T.init_params(cfg, KEY)
    mask = jnp.ones((B, S)).at[:, S // 2:].set(0.0)
    batch = _batch(cfg, KEY, mask)
    loss1, _ = lm_ppo_loss(cfg, params, batch, PPOConfig())
    # corrupt everything under the mask — loss must not move
    batch2 = dict(batch)
    batch2["advantages"] = batch["advantages"].at[:, S // 2:].set(1e3)
    batch2["returns"] = batch["returns"].at[:, S // 2:].set(-1e3)
    batch2["behavior_logp"] = batch["behavior_logp"].at[:, S // 2:].set(0.0)
    loss2, _ = lm_ppo_loss(cfg, params, batch2, PPOConfig())
    assert float(loss1) == pytest.approx(float(loss2), rel=1e-6)


def test_zero_advantage_reduces_to_value_entropy():
    cfg = get_config("h2o-danube-3-4b").reduced()
    params = T.init_params(cfg, KEY)
    batch = _batch(cfg, KEY)
    batch["advantages"] = jnp.zeros((B, S))
    loss, m = lm_ppo_loss(cfg, params, batch, PPOConfig())
    assert float(m["pg_loss"]) == pytest.approx(0.0, abs=1e-6)


def test_moe_aux_included():
    cfg = get_config("mixtral-8x7b").reduced()
    params = T.init_params(cfg, KEY)
    loss_with, m = lm_ppo_loss(cfg, params, _batch(cfg, KEY),
                               PPOConfig(aux_coef=1.0))
    loss_without, _ = lm_ppo_loss(cfg, params, _batch(cfg, KEY),
                                  PPOConfig(aux_coef=0.0))
    assert float(m["aux"]) > 0
    assert float(loss_with) == pytest.approx(
        float(loss_without) + float(m["aux"]), rel=1e-4)


def test_logp_entropy_chunked_matches_full():
    cfg = get_config("h2o-danube-3-4b").reduced()
    params = T.init_params(cfg, KEY)
    h = jax.random.normal(KEY, (B, S, cfg.d_model)).astype(jnp.float32)
    tgt = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    logp_c, ent_c = T.token_logp_entropy(cfg, params, h, tgt, chunk=4)
    z = T.lm_logits(cfg, params, h)
    lse = jax.nn.logsumexp(z, axis=-1)
    logp_f = jnp.take_along_axis(z, tgt[..., None], -1)[..., 0] - lse
    p = jax.nn.softmax(z, -1)
    ent_f = lse - jnp.sum(p * z, -1)
    np.testing.assert_allclose(np.asarray(logp_c), np.asarray(logp_f),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(ent_c), np.asarray(ent_f),
                               rtol=1e-4, atol=1e-4)


def test_lm_sampler_rollout_shapes():
    from repro.core.sampler import make_lm_rollout
    from repro.envs import lm_env
    cfg = get_config("musicgen-medium").reduced()
    params = T.init_params(cfg, KEY)
    env = lm_env.make(cfg.vocab_size, episode_len=8)
    rollout = jax.jit(make_lm_rollout(cfg, env, gen_len=8))
    prompt = jax.random.randint(KEY, (3, 5), 0, cfg.vocab_size)
    traj = rollout(params, prompt, KEY)
    assert traj["tokens"].shape == (3, 8)
    assert traj["logp"].shape == (3, 8)
    assert traj["rewards"].shape == (3, 8)
    assert bool(jnp.all(jnp.isfinite(traj["logp"])))
