"""MoE routing invariants + SSM block consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import layers, moe, ssm

KEY = jax.random.PRNGKey(11)


def _moe_cfg(**kw):
    cfg = get_config("mixtral-8x7b").reduced()
    return dataclasses.replace(cfg, **kw) if kw else cfg


def test_router_weights_sum_to_one():
    cfg = _moe_cfg()
    p = moe.init_moe(cfg, KEY)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model)).astype(p["w1"].dtype)
    w, idx, probs, aux = moe.route(cfg, p["router"], x)
    np.testing.assert_allclose(np.asarray(jnp.sum(w, -1)), 1.0, rtol=1e-5)
    assert int(jnp.max(idx)) < cfg.n_experts
    # aux loss is E * sum f_e p_e >= 1 (Cauchy-Schwarz, = 1 iff uniform)
    assert float(aux) >= 0.99


def test_moe_equals_dense_when_single_expert():
    """E=1, k=1, ample capacity: MoE output == plain SwiGLU of expert 0."""
    cfg = dataclasses.replace(_moe_cfg(), n_experts=1, top_k=1,
                              capacity_factor=2.0)
    p = moe.init_moe(cfg, KEY)
    x = jax.random.normal(KEY, (2, 8, cfg.d_model)).astype(p["w1"].dtype)
    y, aux = moe.moe_block(cfg, p, x)
    dense = layers.mlp({"w1": p["w1"][0], "w3": p["w3"][0],
                        "w2": p["w2"][0]}, x)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(dense, np.float32), atol=2e-5)


def test_moe_capacity_drops_tokens():
    """With capacity_factor -> 0, (almost) everything is dropped -> y ~ 0."""
    cfg = dataclasses.replace(_moe_cfg(), capacity_factor=1e-6)
    p = moe.init_moe(cfg, KEY)
    x = jax.random.normal(KEY, (1, 16, cfg.d_model)).astype(p["w1"].dtype)
    y, _ = moe.moe_block(cfg, p, x)
    # capacity is max(1, ...) = 1 slot per expert: most tokens dropped
    kept_norm = float(jnp.sum(jnp.abs(y) > 0) / y.size)
    assert kept_norm < 0.6


def test_moe_permutation_equivariance():
    """Permuting tokens within a group permutes outputs identically
    (capacity permitting) — routing must not depend on position."""
    cfg = dataclasses.replace(_moe_cfg(), capacity_factor=4.0)
    p = moe.init_moe(cfg, KEY)
    x = jax.random.normal(KEY, (1, 8, cfg.d_model)).astype(p["w1"].dtype)
    perm = jnp.asarray([3, 1, 7, 0, 5, 2, 6, 4])
    y1, _ = moe.moe_block(cfg, p, x)
    y2, _ = moe.moe_block(cfg, p, x[:, perm])
    np.testing.assert_allclose(np.asarray(y1[:, perm], np.float32),
                               np.asarray(y2, np.float32), atol=2e-5)


# ------------------------------------------------------------------- SSM
def test_ssm_block_decode_matches_prefill():
    """Step-by-step SSM decode reproduces the full-sequence block output."""
    cfg = get_config("falcon-mamba-7b").reduced()
    p = ssm.init_ssm(cfg, KEY)
    B, S = 2, 12
    x = (0.1 * jax.random.normal(KEY, (B, S, cfg.d_model))).astype(
        jnp.float32)
    full = ssm.ssm_block(cfg, p, x)

    conv_state = jnp.zeros((B, cfg.ssm_conv - 1, cfg.d_inner))
    ssm_state = jnp.zeros((B, cfg.d_inner, cfg.ssm_state))
    outs = []
    for t in range(S):
        y, conv_state, ssm_state = ssm.ssm_decode_block(
            cfg, p, x[:, t:t + 1], conv_state, ssm_state)
        outs.append(y)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step, np.float32),
                               np.asarray(full, np.float32), atol=2e-3)


def test_ssm_pallas_impl_matches_reference():
    cfg = get_config("falcon-mamba-7b").reduced()
    p = ssm.init_ssm(cfg, KEY)
    x = (0.1 * jax.random.normal(KEY, (1, 64, cfg.d_model))).astype(
        jnp.float32)
    ref = ssm.ssm_block(cfg, p, x, impl="reference")
    pal = ssm.ssm_block(cfg, p, x, impl="pallas")
    np.testing.assert_allclose(np.asarray(pal, np.float32),
                               np.asarray(ref, np.float32), atol=2e-3)
