"""Optimizer math + checkpoint roundtrip."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import checkpoint
from repro.optim import (adam, apply_updates, clip_by_global_norm,
                         cosine_decay, global_norm, linear_warmup_cosine,
                         sgd)


def test_adam_matches_reference_math():
    lr, b1, b2, eps = 0.1, 0.9, 0.999, 1e-8
    opt = adam(lr, b1, b2, eps)
    p = {"w": jnp.asarray([1.0, -2.0])}
    state = opt.init(p)
    g = {"w": jnp.asarray([0.5, 0.25])}
    m = v = np.zeros(2)
    ref = np.asarray([1.0, -2.0])
    for t in range(1, 4):
        upd, state = opt.update(g, state, p)
        p = apply_updates(p, upd)
        m = b1 * m + (1 - b1) * np.asarray(g["w"])
        v = b2 * v + (1 - b2) * np.asarray(g["w"]) ** 2
        ref = ref - lr * (m / (1 - b1 ** t)) / (
            np.sqrt(v / (1 - b2 ** t)) + eps)
    np.testing.assert_allclose(np.asarray(p["w"]), ref, rtol=1e-5)


def test_adam_converges_quadratic():
    opt = adam(0.1)
    p = {"w": jnp.asarray(5.0)}
    state = opt.init(p)
    for _ in range(300):
        g = jax.grad(lambda q: (q["w"] - 2.0) ** 2)(p)
        upd, state = opt.update(g, state, p)
        p = apply_updates(p, upd)
    assert abs(float(p["w"]) - 2.0) < 1e-2


def test_sgd_momentum_direction():
    opt = sgd(0.1, momentum=0.9)
    p = jnp.asarray(1.0)
    state = opt.init(p)
    upd1, state = opt.update(jnp.asarray(1.0), state, p)
    upd2, state = opt.update(jnp.asarray(1.0), state, p)
    assert float(upd2) < float(upd1) < 0       # momentum accumulates


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(-100, 100, allow_nan=False, width=32),
                min_size=1, max_size=8),
       st.floats(0.1, 10))
def test_clip_global_norm_bound(vals, max_norm):
    tree = {"a": jnp.asarray(vals)}
    clipped, norm = clip_by_global_norm(tree, max_norm)
    assert float(global_norm(clipped)) <= max_norm * (1 + 1e-4)
    if float(norm) <= max_norm:     # below threshold -> untouched
        np.testing.assert_allclose(np.asarray(clipped["a"]),
                                   np.asarray(tree["a"]), rtol=1e-5)


def test_schedules_monotone_shapes():
    cos = cosine_decay(1.0, 100)
    assert float(cos(jnp.asarray(0))) == pytest.approx(1.0)
    assert float(cos(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-6)
    wc = linear_warmup_cosine(1.0, warmup=10, total_steps=110)
    assert float(wc(jnp.asarray(5))) == pytest.approx(0.5)
    assert float(wc(jnp.asarray(10))) == pytest.approx(1.0)


def test_checkpoint_roundtrip(tmp_path, rng_key):
    from repro.configs import get_config
    from repro.models import transformer as T
    cfg = get_config("hymba-1.5b").reduced()
    params = T.init_params(cfg, rng_key)
    path = str(tmp_path / "ckpt")
    checkpoint.save(path, 7, params, metadata={"arch": cfg.name})
    template = jax.tree.map(jnp.zeros_like, params)
    restored = checkpoint.restore(path, template)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))
    assert checkpoint.load_metadata(path)["arch"] == cfg.name
    assert checkpoint.latest_step(path) == 7


def test_restore_empty_or_absent_dir_names_directory(tmp_path):
    """Regression: restore on an empty or absent directory must raise a
    clear FileNotFoundError naming the directory and latest_step()'s
    result — not an opaque downstream np.load failure."""
    template = {"w": jnp.zeros((2,))}
    absent = str(tmp_path / "nope")
    with pytest.raises(FileNotFoundError) as exc:
        checkpoint.restore(absent, template)
    assert absent in str(exc.value) and "latest_step" in str(exc.value)

    empty = str(tmp_path / "empty")
    os.makedirs(empty)
    with pytest.raises(FileNotFoundError) as exc:
        checkpoint.restore(empty, template)
    assert empty in str(exc.value) and "latest_step" in str(exc.value)

    # explicit missing step: error names the step asked for AND what the
    # directory actually holds
    checkpoint.save(empty, 3, template)
    with pytest.raises(FileNotFoundError) as exc:
        checkpoint.restore(empty, template, step=7)
    msg = str(exc.value)
    assert "7" in msg and "latest_step() -> 3" in msg

    # load_metadata goes through the same resolution
    with pytest.raises(FileNotFoundError):
        checkpoint.load_metadata(absent)


def test_checkpoint_keep_last_k(tmp_path):
    path = str(tmp_path / "ckpt")
    for step in range(5):
        checkpoint.save(path, step, {"w": jnp.asarray(float(step))}, keep=2)
    steps = [checkpoint.latest_step(path)]
    assert steps == [4]
    names = sorted(os.listdir(path))
    assert len(names) == 2
