"""The actor plane: WorkerSpec serialization, the shared-memory transport
primitives, ``process == inline`` determinism, worker-crash surfacing and
lifecycle (close/reap) semantics."""
import json

import jax
import numpy as np
import pytest

from repro import experiment
from repro.core import sampler as sampler_mod
from repro.core.ipc import ParamsChannel, ShmRing, WorkerCrashed
from repro.experiment import ExperimentSpec, Schedule

TINY = dict(num_samplers=4, global_batch=8, horizon=8, iterations=2, seed=0)


def _spec(backend, algo="ppo", runtime="sync", buffer=None,
          buffer_kwargs=None, **sched):
    return ExperimentSpec(env="pendulum", algo=algo, backend=backend,
                          runtime=runtime, model={"hidden": 16},
                          buffer=buffer, buffer_kwargs=buffer_kwargs or {},
                          schedule=Schedule(**{**TINY, **sched}))


def _assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for xa, xb in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))


# ============================================================== WorkerSpec
def test_worker_spec_roundtrips_through_json():
    spec = sampler_mod.WorkerSpec(
        env="pendulum", algo="ppo", horizon=8, batch=2, seed=7,
        kernels="ref", env_kwargs={"reward_scale": 0.5},
        algo_kwargs={"hidden": 16, "lr": 1e-3})
    restored = sampler_mod.WorkerSpec.from_dict(
        json.loads(json.dumps(spec.to_dict())))
    assert restored == spec


def test_worker_spec_build_is_registry_only():
    """A spec rebuilds rollout/carry/params without any parent state."""
    spec = sampler_mod.WorkerSpec(env="pendulum", algo="ppo", horizon=4,
                                  batch=3, seed=5,
                                  algo_kwargs={"hidden": 16})
    rollout, carry, params = spec.build()
    assert carry[1].shape == (3, 3)           # (batch, obs_dim)
    _, traj = jax.jit(rollout)(params, carry)
    assert traj["obs"].shape == (4, 3, 3)
    # the carry is the one the inline path builds for the same seed
    import repro.envs as envs
    env = envs.make("pendulum")
    expected = sampler_mod.init_env_carry(env, jax.random.PRNGKey(5), 3)
    _assert_trees_equal(carry, expected)


# ============================================================= split_batch
def test_split_batch_raises_naming_both_values():
    with pytest.raises(ValueError, match=r"global_batch=10.*num_samplers=4"):
        sampler_mod.split_batch(10, 4)
    with pytest.raises(ValueError, match="num_samplers=0"):
        sampler_mod.split_batch(8, 0)
    assert sampler_mod.split_batch(8, 4) == 2


def test_split_batch_error_reaches_experiment_build():
    with pytest.raises(ValueError, match="not divisible"):
        experiment.build(_spec("inline", global_batch=10))


# ==================================================== transport primitives
def test_shm_ring_write_read_ack(tmp_path):
    example = {"obs": np.zeros((4, 3), np.float32),
               "dones": np.zeros((4,), bool)}
    ring = ShmRing.create(example, slots=2, prefix=f"t-{id(tmp_path)}")
    try:
        traj = {"obs": np.arange(12, dtype=np.float32).reshape(4, 3),
                "dones": np.array([0, 1, 0, 1], bool)}
        assert ring.is_free(1)
        ring.write(1, traj, worker_id=3, policy_version=9,
                   collect_seconds=0.5, loop_seconds=1.0)
        assert not ring.is_free(1)
        out, meta = ring.read(1)
        np.testing.assert_array_equal(out["obs"], traj["obs"])
        np.testing.assert_array_equal(out["dones"], traj["dones"])
        assert (meta["worker_id"], meta["policy_version"]) == (3, 9)
        assert meta["collect_seconds"] == 0.5
        ring.ack(1)
        assert ring.is_free(1)
        # slot 0 untouched
        assert ring.is_free(0)
    finally:
        ring.close(unlink=True)


def test_params_channel_versioning(tmp_path):
    leaves = [np.zeros((2, 2), np.float32), np.zeros((3,), np.float32)]
    chan = ParamsChannel.create(leaves, prefix=f"c-{id(tmp_path)}")
    try:
        assert chan.version == 0
        v1 = chan.publish([np.ones((2, 2), np.float32),
                           np.full((3,), 2.0, np.float32)])
        assert v1 == 1 and chan.version == 1
        out, v = chan.read(min_version=1)
        assert v == 1
        np.testing.assert_array_equal(out[0], np.ones((2, 2)))
        # unchanged version -> no copy
        none, v = chan.read(last_version=1)
        assert none is None and v == 1
        with pytest.raises(ValueError, match="leaves"):
            chan.publish([np.ones((2, 2), np.float32)])
    finally:
        chan.close(unlink=True)


# =========================================== determinism: process == inline
def test_process_collect_exactly_matches_inline_n4():
    """The acceptance criterion: N=4 worker processes produce trajectories
    exactly equal to the inline backend's for matched per-worker seeds —
    including across iterations (carry state persists inside workers)."""
    ri = experiment.build(_spec("inline"))
    rp = experiment.build(_spec("process"))
    try:
        assert rp.backend.num_samplers == 4
        for _ in range(2):                       # carries persist exactly
            ti, si = ri.backend.collect(ri.params)
            tp, sp = rp.backend.collect(rp.params)
            assert sorted(ti) == sorted(tp)
            _assert_trees_equal(ti, tp)
            assert si.samples == sp.samples
            assert len(sp.per_sampler_seconds) == 4
    finally:
        ri.close()
        rp.close()


def test_num_workers_overrides_num_samplers():
    res = experiment.run(_spec("process", num_samplers=4, num_workers=2))
    assert res.runner.backend.num_samplers == 2
    assert res.logs[-1].samples == TINY["global_batch"] * TINY["horizon"]


# ====================================================== crash + lifecycle
def test_worker_crash_surfaces_with_worker_id():
    """With supervision disabled (max_respawns=0), worker death surfaces
    as WorkerCrashed from collect — the pre-supervisor contract."""
    runner = experiment.build(_spec("process", num_samplers=2,
                                    max_respawns=0))
    try:
        assert runner.backend.supervisor is None
        runner.backend.collect(runner.params)        # healthy first sweep
        runner.backend.pool._procs[0].terminate()
        runner.backend.pool._procs[0].join(timeout=10)
        with pytest.raises(WorkerCrashed, match="died"):
            runner.backend.collect(runner.params)
    finally:
        runner.close()


def test_run_reaps_workers_and_close_is_idempotent():
    spec = _spec("process", num_samplers=2)
    res = experiment.run(spec)                       # run() closes in finally
    procs = res.runner.backend.pool._procs
    assert procs and all(not p.is_alive() for p in procs)
    res.runner.close()                               # double-close is safe
    assert all(log.samples == TINY["global_batch"] * TINY["horizon"]
               for log in res.logs)
    assert np.isfinite(res.logs[-1].mean_return)
