"""Unified-registry invariants: registration errors, lookup errors,
per-env kwargs, and shared dtype conventions."""
import jax
import jax.numpy as jnp
import pytest

from repro import envs, registry


# ========================================================== registration
def test_duplicate_registration_raises():
    registry.register("scratch-kind", "thing", lambda: 1)
    with pytest.raises(ValueError, match="already registered"):
        registry.register("scratch-kind", "thing", lambda: 2)
    # the original entry survives the rejected overwrite
    assert registry.make("scratch-kind", "thing") == 1


def test_duplicate_registration_of_builtin_raises():
    with pytest.raises(ValueError, match="already registered"):
        registry.register("env", "pendulum", lambda: None)


def test_register_as_decorator():
    @registry.register("scratch-kind", "decorated")
    def factory(x=3):
        return x * 2

    assert registry.make("scratch-kind", "decorated", x=5) == 10


# ================================================================ lookup
def test_unknown_name_lists_choices():
    with pytest.raises(KeyError) as e:
        registry.make("env", "nope")
    msg = str(e.value)
    assert "unknown env 'nope'" in msg
    for name in ("pendulum", "cartpole", "cheetah"):
        assert name in msg


def test_unknown_algo_lists_choices():
    with pytest.raises(KeyError) as e:
        registry.make("algo", "dreamer")
    msg = str(e.value)
    assert "unknown algo 'dreamer'" in msg
    for name in ("ppo", "trpo", "ddpg", "sac"):
        assert name in msg


def test_unknown_kind_lists_kinds():
    with pytest.raises(KeyError) as e:
        registry.make("flavour", "vanilla")
    assert "unknown registry kind" in str(e.value)
    assert "env" in str(e.value)


def test_choices_cover_builtins():
    assert set(registry.choices("algo")) >= {"ppo", "trpo", "ddpg", "sac"}
    assert set(registry.choices("backend")) >= {"inline", "threaded",
                                                "sharded"}
    assert set(registry.choices("buffer")) == {"fifo", "uniform",
                                               "prioritized"}
    assert "walle-mlp" in registry.choices("arch")


# ======================================================= env make kwargs
def test_envs_make_accepts_kwargs():
    env = envs.make("pendulum", max_episode_steps=5)
    assert env.max_episode_steps == 5
    state, obs = env.reset(jax.random.PRNGKey(0))
    done = False
    for _ in range(5):
        state, obs, rew, done = env.step(state, jnp.zeros((env.act_dim,)),
                                         jax.random.PRNGKey(1))
    assert bool(done)


def test_envs_make_reward_scale():
    key = jax.random.PRNGKey(0)
    base = envs.make("cheetah")
    scaled = envs.make("cheetah", reward_scale=10.0)
    s1, _ = base.reset(key)
    s2, _ = scaled.reset(key)
    a = jnp.ones((base.act_dim,)) * 0.5
    _, _, r1, _ = base.step(s1, a, key)
    _, _, r2, _ = scaled.step(s2, a, key)
    assert float(r2) == pytest.approx(10.0 * float(r1), rel=1e-5)


def test_envs_make_unknown_kwarg_rejected():
    with pytest.raises(TypeError):
        envs.make("pendulum", gravity=3.7)


@pytest.mark.parametrize("name", ["pendulum", "cartpole", "cheetah"])
def test_env_dtype_conventions(name):
    """All envs follow pendulum's conventions: f32 obs/reward (with an
    explicit dtype override), int32 step counter, bool done."""
    key = jax.random.PRNGKey(0)
    env = envs.make(name)
    state, obs = env.reset(key)
    assert obs.dtype == jnp.float32
    state, obs, rew, done = env.step(state, jnp.zeros((env.act_dim,)), key)
    assert obs.dtype == jnp.float32
    assert rew.dtype == jnp.float32
    assert done.dtype == jnp.bool_
