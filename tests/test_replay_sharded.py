"""Sharding-aware replay (distributed/replay_sharded): per-shard rings and
sum-trees whose sampled distribution must match the single-buffer
reference — the DESIGN.md §9 protocol.

Fast tests drive the shard_map bodies on a 1-device mesh (bitwise vs the
reference buffers) and check the masked sum-tree update against the
unmasked reference. The D=4 exact-equality test runs in a subprocess so
it can force 8 host devices before jax initialises.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.data import replay
from repro.data.buffers import (
    FifoBuffer,
    PrioritizedBuffer,
    PrioritizedState,
    SumTree,
    UniformBuffer,
    sumtree_build,
)
from repro.distributed.replay_sharded import (
    ShardedPrioritizedBuffer,
    ShardedUniformBuffer,
    shard_buffer,
)
from repro.distributed.sharding import shard_map_compat
from repro.kernels.sum_tree import sumtree_update_masked
from repro.kernels.sum_tree.ref import sumtree_update_ref

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))


def _run(args, env=ENV, timeout=420):
    return subprocess.run([sys.executable] + args, cwd=REPO, env=env,
                          capture_output=True, text=True, timeout=timeout)


def _paired_states(capacity: int, d: int, seed: int = 0):
    """A full sharded PrioritizedState (D local trees/rings concatenated)
    plus the reference single-tree state over the *same* global leaves and
    storage — global leaf ``s*C_loc + i`` is shard ``s``'s local leaf
    ``i`` by construction."""
    rng = np.random.RandomState(seed)
    leaves = rng.uniform(0.1, 2.0, capacity).astype(np.float32)
    storage = {
        "obs": jnp.arange(capacity, dtype=jnp.float32)[:, None],
        "rewards": jnp.asarray(rng.randn(capacity).astype(np.float32)),
    }
    c_loc = capacity // d
    local_trees = [sumtree_build(jnp.asarray(leaves[s * c_loc:(s + 1) * c_loc]))
                   for s in range(d)]
    tree_sh = SumTree(tuple(
        jnp.concatenate([t.levels[k] for t in local_trees])
        for k in range(len(local_trees[0].levels))))
    # ring index/size are replicated leaves and hold the *local* values
    ring_sh = replay.ReplayState(storage, jnp.zeros((), jnp.int32),
                                 jnp.asarray(c_loc, jnp.int32))
    state_sh = PrioritizedState(ring_sh, tree_sh, jnp.ones((), jnp.float32))
    ring_ref = replay.ReplayState(storage, jnp.zeros((), jnp.int32),
                                  jnp.asarray(capacity, jnp.int32))
    state_ref = PrioritizedState(ring_ref, sumtree_build(jnp.asarray(leaves)),
                                 jnp.ones((), jnp.float32))
    return state_sh, state_ref, leaves


# ======================================================= masked tree update
def test_sumtree_update_masked_all_true_matches_unmasked():
    leaves = jnp.asarray(
        np.random.RandomState(1).uniform(0.1, 1.0, 16), jnp.float32)
    tree = sumtree_build(leaves)
    idx = jnp.asarray([3, 7, 0, 12])
    vals = jnp.asarray([0.5, 2.0, 0.1, 1.5], jnp.float32)
    want = sumtree_update_ref(tree, idx, vals)
    got = sumtree_update_masked(tree, idx, vals,
                                jnp.ones((4,), jnp.bool_))
    for a, b in zip(want.levels, got.levels):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sumtree_update_masked_partial_rows_untouched():
    leaves = jnp.asarray(
        np.random.RandomState(2).uniform(0.1, 1.0, 16), jnp.float32)
    tree = sumtree_build(leaves)
    idx = jnp.asarray([3, 7, 0, 12])
    vals = jnp.asarray([0.5, 2.0, 0.1, 1.5], jnp.float32)
    mask = jnp.asarray([True, False, True, False])
    got = sumtree_update_masked(tree, idx, vals, mask)
    want = sumtree_update_ref(tree, jnp.asarray([3, 0]),
                              jnp.asarray([0.5, 0.1], jnp.float32))
    for a, b in zip(want.levels, got.levels):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# =================================================== dispatch + validation
def test_shard_buffer_dispatch_and_validation():
    assert isinstance(shard_buffer(UniformBuffer(64, 16), 4, ("data",)),
                      ShardedUniformBuffer)
    assert isinstance(shard_buffer(PrioritizedBuffer(64, 16), 4, ("data",)),
                      ShardedPrioritizedBuffer)
    fifo = FifoBuffer()
    assert shard_buffer(fifo, 4, ("data",)) is fifo       # trajectory kind
    with pytest.raises(ValueError, match="power-of-two"):
        ShardedPrioritizedBuffer(PrioritizedBuffer(64, 16), 3, ("data",))
    with pytest.raises(ValueError, match="batch_size"):
        ShardedUniformBuffer(UniformBuffer(64, 15), 4, ("data",))
    with pytest.raises(ValueError, match="capacity"):
        ShardedUniformBuffer(UniformBuffer(66, 16), 4, ("data",))


# ==================================================== 1-device mesh bitwise
def _mesh1():
    return Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))


def test_sharded_prioritized_d1_mesh_bitwise():
    cap, batch = 32, 16
    buf = ShardedPrioritizedBuffer(PrioritizedBuffer(cap, batch), 1,
                                   ("data",))
    state_sh, state_ref, _ = _paired_states(cap, 1)
    spec = buf.state_spec(state_sh)
    out_spec = {k: P(("data",))
                for k in ("obs", "rewards", "indices", "weights")}
    sample = shard_map_compat(buf.sample, _mesh1(), (spec, P()), out_spec)
    key = jax.random.PRNGKey(7)
    got = sample(state_sh, key)
    want = PrioritizedBuffer(cap, batch).sample(state_ref, key)
    for k in want:
        np.testing.assert_array_equal(np.asarray(got[k]),
                                      np.asarray(want[k]), err_msg=k)


def test_sharded_uniform_d1_mesh_bitwise():
    cap, batch = 32, 16
    buf = ShardedUniformBuffer(UniformBuffer(cap, batch), 1, ("data",))
    rng = np.random.RandomState(3)
    storage = {
        "obs": jnp.arange(cap, dtype=jnp.float32)[:, None],
        "rewards": jnp.asarray(rng.randn(cap).astype(np.float32)),
    }
    state = replay.ReplayState(storage, jnp.zeros((), jnp.int32),
                               jnp.asarray(cap, jnp.int32))
    spec = buf.state_spec(state)
    out_spec = {k: P(("data",))
                for k in ("obs", "rewards", "indices", "weights")}
    sample = shard_map_compat(buf.sample, _mesh1(), (spec, P()), out_spec)
    key = jax.random.PRNGKey(11)
    got = sample(state, key)
    want = UniformBuffer(cap, batch).sample(state, key)
    for k in want:
        np.testing.assert_array_equal(np.asarray(got[k]),
                                      np.asarray(want[k]), err_msg=k)


def test_sharded_prioritized_d1_mesh_update_priorities():
    cap, batch = 32, 16
    buf = ShardedPrioritizedBuffer(PrioritizedBuffer(cap, batch), 1,
                                   ("data",))
    state_sh, state_ref, _ = _paired_states(cap, 1)
    spec = buf.state_spec(state_sh)
    idx = jnp.asarray(np.random.RandomState(4).permutation(cap)[:batch])
    pri = (idx.astype(jnp.float32) % 7 + 1.0) * 0.3
    upd = shard_map_compat(buf.update_priorities, _mesh1(),
                           (spec, P(("data",)), P(("data",))), spec)
    got = upd(state_sh, idx, pri)
    want = PrioritizedBuffer(cap, batch).update_priorities(
        state_ref, idx, pri)
    np.testing.assert_array_equal(np.asarray(got.tree.levels[0]),
                                  np.asarray(want.tree.levels[0]))
    np.testing.assert_array_equal(np.asarray(got.max_priority),
                                  np.asarray(want.max_priority))


# ============================================== D=4 exact-equality (slow)
@pytest.mark.slow
def test_sharded_prioritized_d4_matches_reference():
    """On 8 forced host devices: 4-shard stratified sampling draws the
    *exact same* global leaf indices as the single-tree reference over the
    same leaf masses, the per-shard roots psum to the reference total, the
    realized per-shard draw counts equal the exact interval allocation of
    the stratified masses, and the priority write-back lands on the same
    leaves with the same values."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.data import replay
from repro.data.buffers import PrioritizedBuffer, PrioritizedState, \
    SumTree, sumtree_build
from repro.distributed.replay_sharded import ShardedPrioritizedBuffer
from repro.distributed.sharding import shard_map_compat

cap, batch, d = 32, 16, 4
c_loc = cap // d
rng = np.random.RandomState(0)
leaves = rng.uniform(0.1, 2.0, cap).astype(np.float32)
storage = {"obs": jnp.arange(cap, dtype=jnp.float32)[:, None],
           "rewards": jnp.asarray(rng.randn(cap).astype(np.float32))}
local_trees = [sumtree_build(jnp.asarray(leaves[s*c_loc:(s+1)*c_loc]))
               for s in range(d)]
tree_sh = SumTree(tuple(jnp.concatenate([t.levels[k] for t in local_trees])
                        for k in range(len(local_trees[0].levels))))
state_sh = PrioritizedState(
    replay.ReplayState(storage, jnp.zeros((), jnp.int32),
                       jnp.asarray(c_loc, jnp.int32)),
    tree_sh, jnp.ones((), jnp.float32))
state_ref = PrioritizedState(
    replay.ReplayState(storage, jnp.zeros((), jnp.int32),
                       jnp.asarray(cap, jnp.int32)),
    sumtree_build(jnp.asarray(leaves)), jnp.ones((), jnp.float32))

buf = ShardedPrioritizedBuffer(PrioritizedBuffer(cap, batch), d, ("data",))
ref = PrioritizedBuffer(cap, batch)
mesh = Mesh(np.asarray(jax.devices()[:d]).reshape(d, 1), ("data", "model"))
spec = buf.state_spec(state_sh)
out_spec = {k: P(("data",)) for k in ("obs", "rewards", "indices",
                                      "weights")}
sample = shard_map_compat(buf.sample, mesh, (spec, P()), out_spec)

key = jax.random.PRNGKey(7)
got = sample(state_sh, key)
want = ref.sample(state_ref, key)

# exact leaf-index equality: the per-shard descent is the exact tail of
# the reference root descent (depth-log2(D) subtree factoring)
np.testing.assert_array_equal(np.asarray(got["indices"]),
                              np.asarray(want["indices"]))
np.testing.assert_array_equal(np.asarray(got["obs"]),
                              np.asarray(want["obs"]))
np.testing.assert_allclose(np.asarray(got["weights"]),
                           np.asarray(want["weights"]), rtol=1e-5)

# root invariant: per-shard roots sum (the psum'd global root) == ref total
roots = np.asarray([float(t.total) for t in local_trees])
np.testing.assert_allclose(roots.sum(), float(state_ref.tree.total),
                           rtol=1e-6)

# exact-count allocation: realized draws per shard == the interval counts
# of the replicated stratified masses over the shard prefix offsets
b = batch
u = np.asarray((jnp.arange(b, dtype=jnp.float32)
                + jax.random.uniform(key, (b,))) / b)
m = u * roots.sum()
prefix = np.concatenate([[0.0], np.cumsum(roots)])
owner = np.clip(np.searchsorted(prefix, m, side="right") - 1, 0, d - 1)
realized = np.bincount(np.asarray(got["indices"]) // c_loc, minlength=d)
np.testing.assert_array_equal(realized, np.bincount(owner, minlength=d))

# priority write-back: same leaves, same values, same max_priority
idx = jnp.asarray(want["indices"])
pri = (idx.astype(jnp.float32) % 7 + 1.0) * 0.3
upd = shard_map_compat(buf.update_priorities, mesh,
                       (spec, P(("data",)), P(("data",))), spec)
got_st = upd(state_sh, idx, pri)
want_st = ref.update_priorities(state_ref, idx, pri)
np.testing.assert_array_equal(np.asarray(got_st.tree.levels[0]),
                              np.asarray(want_st.tree.levels[0]))
np.testing.assert_array_equal(np.asarray(got_st.max_priority),
                              np.asarray(want_st.max_priority))
print("SHARDED_REPLAY_OK")
"""
    r = _run(["-c", script])
    assert "SHARDED_REPLAY_OK" in r.stdout, r.stdout + r.stderr
