"""SAC invariants: squashed-Gaussian log-prob correctness, update
improves the critic, temperature stays positive and entropy-driven."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.algos import sac as sac_mod
from repro.optim import adam


def _batch(key, n=64, obs_dim=3, act_dim=2):
    ks = jax.random.split(key, 4)
    return {
        "obs": jax.random.normal(ks[0], (n, obs_dim)),
        "actions": jax.random.uniform(ks[1], (n, act_dim),
                                      minval=-0.99, maxval=0.99),
        "rewards": jax.random.normal(ks[2], (n,)),
        "next_obs": jax.random.normal(ks[3], (n, obs_dim)),
        "discounts": jnp.full((n,), 0.99),
    }


def test_sample_action_squashed_logp():
    """The stable softplus form of the tanh correction matches the naive
    log(1 - a^2) form, and actions stay inside (-1, 1)."""
    key = jax.random.PRNGKey(0)
    params = sac_mod.init_sac(key, obs_dim=3, act_dim=2, hidden=16)
    obs = jax.random.normal(key, (128, 3))
    actions, logp = sac_mod.sample_action(params["actor"], obs,
                                          jax.random.PRNGKey(1))
    assert np.all(np.abs(np.asarray(actions)) < 1.0)
    mean, std = sac_mod.actor_dist(params["actor"], obs)
    from repro.models.mlp_policy import gaussian_logp
    u = jnp.arctanh(jnp.clip(actions, -0.999999, 0.999999))
    naive = gaussian_logp(mean, std, u) - jnp.sum(
        jnp.log(1.0 - actions ** 2 + 1e-6), axis=-1)
    np.testing.assert_allclose(np.asarray(logp), np.asarray(naive),
                               rtol=1e-3, atol=1e-3)


def test_sac_update_improves_critic():
    key = jax.random.PRNGKey(0)
    params = sac_mod.init_sac(key, obs_dim=3, act_dim=2, hidden=16)
    cfg = sac_mod.SACConfig()
    a_opt, c_opt, al_opt = adam(3e-4), adam(3e-4), adam(3e-4)
    states = (a_opt.init(params["actor"]), c_opt.init(params["critic"]),
              al_opt.init(params["log_alpha"]))
    batch = _batch(jax.random.PRNGKey(1))
    step = jax.jit(lambda p, s, k: sac_mod.sac_update(
        p, s, batch, k, cfg, a_opt, c_opt, al_opt))
    losses = []
    for i in range(30):
        params, states, metrics = step(params, states,
                                       jax.random.PRNGKey(i))
        losses.append(float(metrics["critic_loss"]))
    assert losses[-1] < losses[0]
    assert float(metrics["alpha"]) > 0.0
    assert np.isfinite(float(metrics["entropy"]))
    assert metrics["priorities"].shape == (64,)
    assert np.all(np.asarray(metrics["priorities"]) >= 0.0)
    # polyak targets trail the online critic
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     params["target_critic"], params["critic"])
    assert max(jax.tree.leaves(d)) > 0.0


def test_sac_update_respects_importance_weights():
    """Zero-weighting every sample kills the critic gradient."""
    key = jax.random.PRNGKey(0)
    params = sac_mod.init_sac(key, obs_dim=3, act_dim=2, hidden=16)
    cfg = sac_mod.SACConfig()
    a_opt, c_opt, al_opt = adam(3e-4), adam(3e-4), adam(3e-4)
    states = (a_opt.init(params["actor"]), c_opt.init(params["critic"]),
              al_opt.init(params["log_alpha"]))
    batch = _batch(jax.random.PRNGKey(1))
    batch["weights"] = jnp.zeros_like(batch["rewards"])
    new_params, _, metrics = sac_mod.sac_update(
        params, states, batch, jax.random.PRNGKey(2), cfg,
        a_opt, c_opt, al_opt)
    assert float(metrics["critic_loss"]) == pytest.approx(0.0)
    for xa, xb in zip(jax.tree.leaves(params["critic"]),
                      jax.tree.leaves(new_params["critic"])):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))
