"""The policy serving plane (DESIGN.md §8): dynamic batching bitwise
parity, deadline dispatch, live hot-swap, backpressure, and the
checkpoint -> serve path."""
import concurrent.futures
import multiprocessing
import os
import time
import uuid

import jax
import numpy as np
import pytest

from repro import registry
from repro.core.ipc import ChannelSpec, ParamsChannel
from repro.serve import (
    PolicyServer,
    ServerClosed,
    ServerOverloaded,
    ServingStats,
    load_policy,
)


def _policy(env_name="pendulum", algo_name="ppo", seed=0):
    env = registry.make("env", env_name)
    algo = registry.make("algo", algo_name)
    params, _ = algo.init(jax.random.PRNGKey(seed), env)
    return env, algo, params


def _obs(env, n, seed=0):
    return np.random.RandomState(seed).randn(
        n, env.obs_dim).astype(np.float32)


# ===================================================== batching bitwise
def test_batched_act_bitwise_equals_single_request():
    """The acceptance bar: a request's action is identical whether it
    rides a full batch, a deadline-expired partial batch, or the
    single-request reference path — same compiled executable, row-
    independent rows."""
    env, algo, params = _policy()
    observations = _obs(env, 4)

    # full batch: submit everything at once, one dispatch serves all
    # (a full batch dispatches immediately; the generous deadline only
    # bounds how long a straggler submission could lag)
    with PolicyServer(env, algo, params, slots=4,
                      deadline_ms=500.0) as server:
        pending = [server.submit(o) for o in observations]
        batched = [p.result(30.0) for p in pending]
        refs = [server.reference_act(observations[i],
                                     np.array([0, i], np.uint32))
                for i in range(4)]
        assert server.stats.dispatches == 1   # they really shared a batch

    # per-request: a fresh server (request ids restart at 0 -> same
    # derived keys), one at a time, each its own partial-batch dispatch
    with PolicyServer(env, algo, params, slots=4,
                      deadline_ms=1.0) as server:
        singles = [server.act(o, timeout=30.0) for o in observations]
        assert server.stats.dispatches == 4

    for i in range(4):
        assert np.array_equal(batched[i], singles[i])      # bitwise
        assert np.array_equal(batched[i], refs[i])


def test_explicit_keys_and_extras_algos():
    """Any registered algo's act() serves; explicit per-request keys
    reproduce jax.random semantics exactly."""
    for algo_name in ("ppo", "ddpg", "sac"):
        env, algo, params = _policy(algo_name=algo_name)
        obs = _obs(env, 1)[0]
        key = np.asarray(jax.random.PRNGKey(123))
        with PolicyServer(env, algo, params, slots=2,
                          deadline_ms=1.0) as server:
            action = server.act(obs, key=key, timeout=30.0)
            again = server.act(obs, key=key, timeout=30.0)
        assert np.array_equal(action, again), algo_name
        assert action.shape == (env.act_dim,), algo_name


# ==================================================== deadline dispatch
def test_deadline_triggers_partial_batch():
    """Fewer requests than slots still dispatch once the oldest request's
    deadline expires — nothing waits for a batch that never fills."""
    env, algo, params = _policy()
    with PolicyServer(env, algo, params, slots=8,
                      deadline_ms=150.0) as server:
        t0 = time.perf_counter()
        pending = [server.submit(o) for o in _obs(env, 3)]
        actions = [p.result(30.0) for p in pending]
        elapsed = time.perf_counter() - t0
        snap = server.snapshot()
    assert len(actions) == 3
    assert snap["dispatches"] == 1            # one partial batch
    assert snap["batch_occupancy"] == pytest.approx(3 / 8)
    assert snap["wasted_slot_steps"] == 5
    # dispatched because of the deadline, not because the batch filled:
    # the oldest request waited >= the window (compile happened at start)
    assert elapsed >= 0.15


def test_full_batch_dispatches_before_deadline():
    env, algo, params = _policy()
    with PolicyServer(env, algo, params, slots=4,
                      deadline_ms=10_000.0) as server:
        pending = [server.submit(o) for o in _obs(env, 4)]
        for p in pending:
            p.result(30.0)                     # would hang if we waited
        assert server.stats.dispatches == 1


# ========================================================== backpressure
def test_overload_raises_and_inflight_requests_survive():
    """A full admission queue rejects new work with ServerOverloaded;
    everything already admitted still completes. (Admission is open
    before start(), so the queue can be filled deterministically.)"""
    env, algo, params = _policy()
    server = PolicyServer(env, algo, params, slots=2, deadline_ms=5.0,
                          queue_cap=4)
    pending = []
    with pytest.raises(ServerOverloaded, match="backpressure"):
        for o in _obs(env, 16):
            pending.append(server.submit(o))
    assert len(pending) == 4                   # exactly queue_cap admitted
    server.start()                             # now drain: overload
    for p in pending:                          # rejected new work, it
        assert p.result(30.0).shape == (env.act_dim,)  # dropped nothing
    server.close()


def test_submit_after_close_raises():
    env, algo, params = _policy()
    server = PolicyServer(env, algo, params, slots=2, deadline_ms=1.0)
    server.start()
    server.close()
    with pytest.raises(ServerClosed):
        server.submit(_obs(env, 1)[0])


def test_close_drains_queued_requests():
    """close() completes every admitted request — nothing is dropped."""
    env, algo, params = _policy()
    server = PolicyServer(env, algo, params, slots=4, deadline_ms=50.0,
                          queue_cap=64)
    server.start()
    pending = [server.submit(o) for o in _obs(env, 11)]
    server.close()
    for p in pending:
        assert p.done()
        assert p.action.shape == (env.act_dim,)


# ============================================================== hot-swap
def _publish_from_child(spec_json: str, scale: float) -> None:
    """Child-process learner stand-in: attach to the channel and publish
    every leaf scaled by ``scale``. (Module-level for spawn pickling.)"""
    import numpy as np

    from repro.core.ipc import ChannelSpec, ParamsChannel
    chan = ParamsChannel.attach(ChannelSpec.from_json(spec_json))
    leaves, _version = chan.read()
    chan.publish([np.asarray(x) * scale for x in leaves])
    chan.close()


def test_hot_swap_mid_traffic_from_concurrent_process():
    """A ParamsChannel.publish from a *separate process* is picked up
    mid-traffic: no request is dropped, no action is torn (every action
    bitwise-matches either the old or the new params, by version), and
    the server ends on the published version."""
    env, algo, params = _policy()
    leaves = [np.asarray(x) for x in jax.tree_util.tree_leaves(params)]
    channel = ParamsChannel.create(
        leaves, f"walle-test-{os.getpid()}-{uuid.uuid4().hex[:6]}")
    channel.publish(leaves)                        # version 1: the ckpt
    scale = 1.5
    params_v2 = jax.tree.map(lambda x: x * scale, params)
    observations = _obs(env, 64)
    try:
        with PolicyServer(env, algo, params, slots=4, deadline_ms=2.0,
                          queue_cap=256, params_channel=channel) as server:
            assert server.params_version == 1
            ctx = multiprocessing.get_context("spawn")
            proc = ctx.Process(
                target=_publish_from_child,
                args=(channel.spec.to_json(), scale))
            with concurrent.futures.ThreadPoolExecutor(8) as pool:
                futures = [pool.submit(server.submit, o)
                           for o in observations[:16]]
                pending = [f.result() for f in futures]
                proc.start()                       # publish concurrently
                futures = [pool.submit(server.submit, o)
                           for o in observations[16:]]
                pending += [f.result() for f in futures]
            results = [p.result(30.0) for p in pending]
            proc.join(30.0)
            assert proc.exitcode == 0
            # drain any last requests, then the version must have landed
            deadline = time.monotonic() + 10.0
            while (server.params_version < 2
                   and time.monotonic() < deadline):
                server.act(observations[0], timeout=30.0)
            assert server.params_version == 2
            # not torn: each action bitwise-matches the params version
            # its completion reports — never a mix
            with PolicyServer(env, algo, params, slots=4,
                              deadline_ms=2.0) as ref_v1, \
                 PolicyServer(env, algo, params_v2, slots=4,
                              deadline_ms=2.0) as ref_v2:
                for p, action in zip(pending, results):
                    ref = ref_v1 if p.params_version == 1 else ref_v2
                    expect = ref.reference_act(p.obs, p.key)
                    assert np.array_equal(action, expect)
            assert len(results) == 64              # nothing dropped
    finally:
        channel.close(unlink=True)


def test_channel_spec_json_roundtrip():
    leaves = [np.zeros((2, 3), np.float32), np.zeros((4,), np.float64)]
    chan = ParamsChannel.create(
        leaves, f"walle-json-{os.getpid()}-{uuid.uuid4().hex[:6]}")
    try:
        spec = ChannelSpec.from_json(chan.spec.to_json())
        assert spec == chan.spec
    finally:
        chan.close(unlink=True)


def test_leaf_count_mismatch_rejected():
    env, algo, params = _policy()
    chan = ParamsChannel.create(
        [np.zeros((1,), np.float32)],
        f"walle-mism-{os.getpid()}-{uuid.uuid4().hex[:6]}")
    try:
        with pytest.raises(ValueError, match="leaves"):
            PolicyServer(env, algo, params, params_channel=chan)
    finally:
        chan.close(unlink=True)


# ============================================== checkpoint -> serve path
def test_serve_from_checkpoint_end_to_end(tmp_path):
    """train (tiny) -> checkpoint -> load_policy -> serve: the restored
    policy's served actions bitwise-match acting with the trained params
    directly."""
    from repro import experiment
    from repro.checkpoint import save
    from repro.experiment import ExperimentSpec, Schedule
    spec = ExperimentSpec(
        env="pendulum", algo="ppo",
        schedule=Schedule(num_samplers=1, global_batch=2, horizon=8,
                          iterations=2, seed=0))
    result = experiment.run(spec)
    ckpt = str(tmp_path / "ckpt")
    save(ckpt, 2, result.params,
         metadata={"mode": "rl", "spec": spec.to_dict()})

    handle = load_policy(ckpt)
    assert handle.spec.env == "pendulum" and handle.step == 2
    for a, b in zip(jax.tree.leaves(result.params),
                    jax.tree.leaves(handle.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))

    obs = _obs(handle.env, 1)[0]
    key = np.asarray(jax.random.PRNGKey(7))
    with PolicyServer.from_checkpoint(ckpt, slots=2,
                                      deadline_ms=1.0) as server:
        served = server.act(obs, key=key, timeout=30.0)
        expect = server.reference_act(obs, key)
    assert np.array_equal(served, expect)


def test_load_policy_absent_dir_clear_error(tmp_path):
    """The serve loader surfaces checkpoint.restore's clear error for an
    empty/absent checkpoint directory (regression: was an opaque np.load
    failure; full coverage in test_optim_ckpt.py)."""
    absent = str(tmp_path / "no-such-ckpt")
    with pytest.raises(FileNotFoundError) as exc:
        load_policy(absent)
    assert absent in str(exc.value) and "latest_step" in str(exc.value)


def test_load_policy_rejects_specless_checkpoint(tmp_path):
    from repro.checkpoint import save
    ckpt = str(tmp_path / "lm")
    save(ckpt, 1, {"w": np.zeros((2,))}, metadata={"mode": "lm"})
    with pytest.raises(ValueError, match="ExperimentSpec"):
        load_policy(ckpt)


# ================================================== the stats helper
def test_serving_stats_schema_and_percentiles():
    stats = ServingStats(slots=4)
    for ms in (1, 2, 3, 4, 5, 6, 7, 8, 9, 100):
        stats.observe(latency_s=ms / 1e3, queue_wait_s=ms / 2e3)
    stats.observe_batch(4)
    stats.observe_batch(2)
    snap = stats.snapshot()
    assert snap["requests"] == 10 and snap["dispatches"] == 2
    assert snap["latency_ms"]["p50"] == pytest.approx(5.0)
    assert snap["latency_ms"]["p99"] == pytest.approx(100.0)
    assert snap["latency_ms"]["max"] == pytest.approx(100.0)
    assert snap["batch_occupancy"] == pytest.approx(6 / 8)
    assert snap["wasted_slot_steps"] == 2
    assert set(snap) == {"requests", "dispatches", "slots", "latency_ms",
                         "queue_wait_ms", "batch_occupancy",
                         "wasted_slot_steps", "requests_per_sec"}
    with pytest.raises(ValueError, match="occupied"):
        stats.observe_batch(5)


def test_slot_server_reports_shared_schema():
    """core.serving.SlotServer reports through the same stats schema —
    wasted_slot_steps surfaced, occupancy/latency populated."""
    from repro.configs import get_config
    from repro.core.serving import Request, SlotServer
    from repro.models import transformer as T
    cfg = get_config("h2o-danube-3-4b").reduced()
    server = SlotServer(cfg, T.init_params(cfg, jax.random.PRNGKey(0)),
                        slots=2, prompt_len=6, max_new_tokens=4)
    import jax.numpy as jnp
    server.submit(Request(request_id=0, prompt=jnp.zeros((6,), jnp.int32),
                          max_new_tokens=2))
    server.submit(Request(request_id=1, prompt=jnp.zeros((6,), jnp.int32),
                          max_new_tokens=4))
    server.run()
    snap = server.snapshot()
    assert set(snap) >= {"requests", "dispatches", "latency_ms",
                         "batch_occupancy", "wasted_slot_steps",
                         "requests_per_sec"}
    assert snap["requests"] == 2
    # request 0 finished at 2 tokens and rode out steps 3..4 wasted
    assert snap["wasted_slot_steps"] == server.wasted_slot_steps == 2
    assert 0 < snap["batch_occupancy"] < 1
    assert snap["latency_ms"]["p99"] >= snap["latency_ms"]["p50"] > 0
