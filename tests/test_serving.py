"""Wave-scheduled batch serving: queue semantics + completion accounting."""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.serving import Request, SlotServer
from repro.models import transformer as T

KEY = jax.random.PRNGKey(0)


def _server(slots=3, prompt_len=6, max_new=5, eos=None):
    cfg = get_config("h2o-danube-3-4b").reduced()
    params = T.init_params(cfg, KEY)
    return cfg, SlotServer(cfg, params, slots=slots, prompt_len=prompt_len,
                           max_new_tokens=max_new, eos_id=eos)


def test_all_requests_complete():
    cfg, server = _server()
    for i in range(7):              # 7 requests on 3 slots -> 3 waves
        prompt = jax.random.randint(jax.random.PRNGKey(i), (6,), 0,
                                    cfg.vocab_size)
        server.submit(Request(request_id=i, prompt=prompt,
                              max_new_tokens=5))
    completions = server.run()
    assert sorted(c.request_id for c in completions) == list(range(7))
    for c in completions:
        assert 1 <= len(c.tokens) <= 5
        assert c.latency > 0 and c.queue_wait >= 0


def test_eos_stops_early_and_counts_waste():
    cfg, server = _server(slots=2, max_new=30, eos=0)
    for i in range(2):
        prompt = jax.random.randint(jax.random.PRNGKey(10 + i), (6,), 0,
                                    cfg.vocab_size)
        server.submit(Request(request_id=i, prompt=prompt,
                              max_new_tokens=30))
    completions = server.run()
    assert len(completions) == 2
    for c in completions:
        # reduced vocab 512, random logits: eos=0 should hit before 30 with
        # decent probability; either way tokens never exceed the budget
        assert len(c.tokens) <= 30
        if len(c.tokens) < 30:
            assert c.tokens[-1] == 0
    assert server.decode_steps >= 1


def test_per_request_budget_respected():
    cfg, server = _server(slots=2, max_new=8)
    p = jnp.zeros((6,), jnp.int32)
    server.submit(Request(request_id=0, prompt=p, max_new_tokens=2))
    server.submit(Request(request_id=1, prompt=p, max_new_tokens=8))
    completions = {c.request_id: c for c in server.run()}
    assert len(completions[0].tokens) == 2
    assert len(completions[1].tokens) == 8
    assert server.wasted_slot_steps > 0     # request 0 rode out the wave
