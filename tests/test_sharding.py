"""Sharding-rule unit tests on an AbstractMesh (no placeholder devices)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config, \
    supports_shape
from repro.distributed import sharding as sh
from repro.launch import specs as specs_mod
from repro.models import transformer

def _abstract_mesh(sizes, names):
    """AbstractMesh across JAX versions: >=0.5 takes (axis_sizes,
    axis_names); 0.4.x takes a single ((name, size), ...) tuple."""
    try:
        return AbstractMesh(sizes, names)
    except TypeError:
        return AbstractMesh(tuple(zip(names, sizes)))


MESH_SP = _abstract_mesh((16, 16), ("data", "model"))
MESH_MP = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def test_shard_axes_divisibility_fallback():
    assert sh.shard_axes(256, ("data",), MESH_SP) == "data"
    assert sh.shard_axes(7, ("data",), MESH_SP) is None          # replicate
    assert sh.shard_axes(32, ("pod", "data"), MESH_MP) == ("pod", "data")
    # 16 doesn't divide 32 -> falls back to the 16-wide suffix
    assert sh.shard_axes(16, ("pod", "data"), MESH_MP) == "data"
    assert sh.shard_axes(2, ("pod", "data"), MESH_MP) == "pod"
    assert sh.shard_axes(1, ("pod", "data"), MESH_MP) is None


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
@pytest.mark.parametrize("mesh", [MESH_SP, MESH_MP],
                         ids=["16x16", "2x16x16"])
def test_param_specs_cover_all_leaves(arch, mesh):
    cfg = get_config(arch)
    shapes = jax.eval_shape(
        lambda: transformer.init_params(cfg, jax.random.PRNGKey(0)))
    specs = sh.param_specs(cfg, shapes, mesh)
    n_leaves = len(jax.tree.leaves(shapes))
    spec_leaves = jax.tree.leaves(specs,
                                  is_leaf=lambda x: isinstance(x, P))
    assert len(spec_leaves) == n_leaves
    # every spec's sharded dims divide the mesh axes (fallback worked)
    for leaf, spec in zip(jax.tree.leaves(shapes), spec_leaves):
        for dim, entry in zip(leaf.shape, tuple(spec)):
            if entry is None:
                continue
            axes = (entry,) if isinstance(entry, str) else entry
            total = 1
            for a in axes:
                total *= mesh.shape[a]
            assert dim % total == 0, (arch, spec, leaf.shape)


@pytest.mark.parametrize("arch", ["llama3-405b", "mixtral-8x7b",
                                  "falcon-mamba-7b", "hymba-1.5b"])
def test_weights_sharded_enough_to_fit(arch):
    """2-D sharded params must fit v5e HBM (16 GiB) with Adam moments."""
    cfg = get_config(arch)
    shapes = jax.eval_shape(
        lambda: transformer.init_params(cfg, jax.random.PRNGKey(0)))
    specs = sh.param_specs(cfg, shapes, MESH_SP)
    per_device = 0
    for leaf, spec in zip(
            jax.tree.leaves(shapes),
            jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))):
        n = 1
        for entry in tuple(spec):
            if entry is None:
                continue
            axes = (entry,) if isinstance(entry, str) else entry
            for a in axes:
                n *= MESH_SP.shape[a]
        per_device += leaf.size * 2 // n          # bf16
    assert per_device * 3 < 16 * 2 ** 30, (      # params + 2 Adam moments
        f"{arch}: {per_device * 3 / 2**30:.1f} GiB/device")


@pytest.mark.parametrize("shape_name", list(INPUT_SHAPES))
def test_input_specs_exist_for_all_archs(shape_name):
    shape = INPUT_SHAPES[shape_name]
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        ok, why = supports_shape(cfg, shape)
        if not ok:
            assert shape_name == "long_500k" and why
            continue
        spec = specs_mod.input_specs(cfg, shape, MESH_SP)
        assert spec["kind"] in ("train", "prefill", "decode")
        for leaf in jax.tree.leaves(spec["args"]):
            assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_decode_state_specs_flash_decoding_layout():
    cfg = get_config("llama3-405b")
    shape = INPUT_SHAPES["decode_32k"]
    state = specs_mod.decode_state_shapes(cfg, shape)
    specs = sh.decode_state_specs(cfg, state, MESH_SP)
    assert tuple(specs["k"]) == (None, "data", "model", None, None)
    assert tuple(specs["ssm"]) if "ssm" in specs else True
