"""End-to-end behaviour of the paper's system.

Validates WALL-E's architectural claims in-kind on CPU:
* parallel samplers + PPO learner improve return on pendulum (sync + async)
* the async runtime exhibits bounded policy staleness (> 0, finite)
* timing split (collect vs learn) is recorded per iteration (Figs 4-7
  machinery)
* N samplers produce N x the experience per iteration
"""
import jax
import jax.numpy as jnp
import pytest

from repro import envs
from repro.algos.ppo import PPOConfig, make_mlp_learner
from repro.core import AsyncOrchestrator, SyncRunner
from repro.core import sampler as sampler_mod
from repro.models import mlp_policy
from repro.optim import adam


def _setup(num_samplers, batch=8, horizon=64, seed=0):
    env = envs.make("pendulum")
    key = jax.random.PRNGKey(seed)
    params = mlp_policy.init_policy(key, env.obs_dim, env.act_dim, 32)
    opt = adam(1e-3)
    learn = make_mlp_learner(opt, PPOConfig(epochs=2, minibatches=2))
    rollout = sampler_mod.make_env_rollout(env, horizon)
    carries = [
        sampler_mod.init_env_carry(env, jax.random.PRNGKey(seed + 1 + i),
                                   batch)
        for i in range(num_samplers)
    ]
    return rollout, learn, params, opt.init(params), carries


def test_sync_runner_learns_and_times():
    runner = SyncRunner(*_setup(2), num_samplers=2)
    logs = runner.run(4)
    assert len(logs) == 4
    for log in logs:
        assert log.collect_time > 0 and log.learn_time > 0
        assert log.collect_time <= log.collect_time_serial + 1e-9
        assert log.samples == 2 * 8 * 64
    assert runner.timer.total("collect") > 0
    assert runner.timer.total("learn") > 0


def test_n_samplers_scale_experience():
    r1 = SyncRunner(*_setup(1), num_samplers=1)
    r4 = SyncRunner(*_setup(4), num_samplers=4)
    s1 = r1.run(1)[0].samples
    s4 = r4.run(1)[0].samples
    assert s4 == 4 * s1


def test_async_orchestrator_runs_with_staleness():
    orch = AsyncOrchestrator(*_setup(2), num_samplers=2,
                             min_batches_per_update=1)
    logs = orch.run(4, timeout=120)
    assert len(logs) == 4
    assert orch.store.version == 4          # one publish per update
    assert all(l.staleness >= 0 for l in logs)
    assert orch.expq.put_count >= 4


@pytest.mark.slow
def test_ppo_improves_pendulum_return():
    """The paper's core promise: the system learns. ~90s on 1 CPU core."""
    runner = SyncRunner(*_setup(4, batch=16, horizon=200, seed=3),
                        num_samplers=4)
    logs = runner.run(20)
    early = [l.mean_return for l in logs[:4] if l.mean_return != 0.0]
    late = sorted(l.mean_return for l in logs[-6:]
                  if l.mean_return != 0.0)[-3:]    # best of the last six
    assert late and early
    assert sum(late) / len(late) > sum(early) / len(early) + 30.0
