"""TRPO invariants: CG solves, FVP is PSD, KL constraint holds, learning."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import envs
from repro.algos.trpo import (TRPOConfig, _dist, _flatten, conjugate_gradient,
                              fisher_vp, make_trpo_learner, mean_kl,
                              trpo_update)
from repro.core import sampler as sampler_mod
from repro.models import mlp_policy

KEY = jax.random.PRNGKey(0)


def _setup():
    env = envs.make("pendulum")
    params = mlp_policy.init_policy(KEY, env.obs_dim, env.act_dim, 16)
    rollout = jax.jit(sampler_mod.make_env_rollout(env, 64))
    carry = sampler_mod.init_env_carry(env, jax.random.PRNGKey(1), 8)
    _, traj = rollout(params, carry)
    return env, params, traj


def test_cg_solves_spd_system():
    a = jax.random.normal(KEY, (12, 12))
    spd = a @ a.T + 0.5 * jnp.eye(12)
    b = jax.random.normal(jax.random.PRNGKey(1), (12,))
    x = conjugate_gradient(lambda v: spd @ v, b, iters=24)
    np.testing.assert_allclose(np.asarray(spd @ x), np.asarray(b),
                               atol=1e-3)


def test_fisher_vp_psd_and_symmetric():
    env, params, traj = _setup()
    pi = {"pi": params["pi"], "log_std": params["log_std"]}
    obs = traj["obs"].reshape(-1, env.obs_dim)
    om, os_ = _dist(pi, obs)
    flat, meta = _flatten(pi)
    avp = lambda v: fisher_vp(pi, obs, om, os_, v, meta, damping=0.0)
    k1, k2 = jax.random.split(KEY)
    v = jax.random.normal(k1, flat.shape)
    w = jax.random.normal(k2, flat.shape)
    assert float(jnp.dot(v, avp(v))) >= -1e-5                  # PSD
    np.testing.assert_allclose(float(jnp.dot(w, avp(v))),      # symmetric
                               float(jnp.dot(v, avp(w))), rtol=1e-3,
                               atol=1e-5)


def test_kl_zero_at_same_params():
    env, params, traj = _setup()
    pi = {"pi": params["pi"], "log_std": params["log_std"]}
    obs = traj["obs"].reshape(-1, env.obs_dim)
    om, os_ = _dist(pi, obs)
    assert float(mean_kl(pi, om, os_, obs)) == pytest.approx(0.0, abs=1e-6)


def test_trpo_update_respects_trust_region_and_improves():
    env, params, traj = _setup()
    cfg = TRPOConfig(max_kl=0.01)
    learn = make_trpo_learner(cfg)
    new_params, _, metrics = learn(params, None, traj)
    assert float(metrics["kl"]) <= 1.5 * cfg.max_kl + 1e-6
    assert float(metrics["surrogate_gain"]) >= 0.0
    moved = any(float(jnp.max(jnp.abs(a - b))) > 0 for a, b in zip(
        jax.tree.leaves(params["pi"]), jax.tree.leaves(new_params["pi"])))
    assert moved or float(metrics["step_coef"]) == 0.0
