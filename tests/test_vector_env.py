"""The env plane's VectorEnv layer (DESIGN.md §7): batched auto-reset
parity against vmapped single-instance semantics, per-instance RNG
independence, spec wiring (``schedule.env_batch``), and the two bitwise
train-level guarantees — vector collection reproduces legacy inline
collection at matched B, and the fused runtime reproduces the stepped
one with a VectorEnv carry.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import envs, experiment
from repro.core import sampler as sampler_mod
from repro.envs.base import auto_reset
from repro.envs.vector import VectorEnv
from repro.experiment import ExperimentSpec, Schedule

KEY = jax.random.PRNGKey(7)


def _assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for xa, xb in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))


def _spec(algo="ppo", backend="inline", runtime="sync", **sched):
    base = dict(num_samplers=1, global_batch=4, horizon=8, iterations=2,
                seed=0)
    return ExperimentSpec(env="pendulum", algo=algo, backend=backend,
                          runtime=runtime, model={"hidden": 16},
                          schedule=Schedule(**{**base, **sched}))


# ============================================================ env layer
@pytest.mark.parametrize("name", ["pendulum", "cartpole", "cheetah"])
def test_vector_env_carry_shapes_and_step_parity(name):
    """One batched state pytree, and ``batched_step`` bitwise equal to
    ``vmap(auto_reset(env))`` across steps that include terminal resets."""
    B = 13
    env = envs.make(name, max_episode_steps=3)
    venv = VectorEnv(env, B)
    assert venv.batch == B and venv.name == env.name
    assert venv.obs_dim == env.obs_dim and venv.act_dim == env.act_dim

    states, obs, keys = venv.init_carry(KEY)
    assert obs.shape == (B, env.obs_dim)
    assert keys.shape[0] == B
    for leaf in jax.tree.leaves(states):
        assert leaf.shape[0] == B

    actions = jax.random.uniform(jax.random.fold_in(KEY, 1),
                                 (B, env.act_dim), minval=-1.0, maxval=1.0)
    vm = jax.vmap(auto_reset(env))

    def sweep(step):
        @jax.jit
        def run(s, k):
            outs = []
            for _ in range(5):  # crosses the max_episode_steps=3 horizon
                s, o, r, d = step(s, actions, k)
                outs.append((o, r, d))
            return s, outs
        return run(states, keys)

    _assert_trees_equal(sweep(vm), sweep(venv.batched_step))


def test_vector_env_rng_independence():
    """Every instance carries its own key chain: with a horizon of 1 each
    step resets every instance, and the B reset draws must all differ —
    one shared key would collapse them to identical rows."""
    B = 16
    env = envs.make("pendulum", max_episode_steps=1)
    venv = VectorEnv(env, B)
    states, obs, keys = venv.init_carry(KEY)
    # the initial reset already draws per-instance
    assert len({tuple(r) for r in np.asarray(obs).tolist()}) == B
    actions = jnp.zeros((B, env.act_dim))
    _, obs2, _, done = jax.jit(venv.batched_step)(states, actions, keys)
    assert bool(np.all(np.asarray(done)))
    assert len({tuple(r) for r in np.asarray(obs2).tolist()}) == B


def test_vector_env_rejects_bad_batch():
    env = envs.make("pendulum")
    with pytest.raises(ValueError, match="batch=0"):
        VectorEnv(env, 0)


# ============================================================ spec wiring
def test_schedule_env_batch_roundtrips():
    spec = _spec(env_batch=512)
    assert ExperimentSpec.from_dict(spec.to_dict()) == spec
    assert spec.schedule.env_batch == 512
    # default stays None (legacy split) and round-trips too
    spec2 = _spec()
    assert ExperimentSpec.from_dict(spec2.to_dict()).schedule.env_batch \
        is None


@pytest.mark.parametrize("backend", ["process", "sharded"])
def test_env_batch_rejects_split_backends(backend):
    with pytest.raises(ValueError, match="vector collection"):
        experiment.build(_spec(backend=backend, env_batch=8))


# ================================================= train-level guarantees
def test_vector_collection_matches_legacy_inline_bitwise():
    """ppo x inline at env_batch=B reproduces the legacy
    num_samplers=1 / global_batch=B run bitwise: VectorEnv's fused
    batched step is bitwise vmap-of-auto_reset, and the carry is seeded
    identically (PRNGKey(seed), one sampler)."""
    B = 6
    legacy = experiment.run(_spec(num_samplers=1, global_batch=B))
    vector = experiment.run(_spec(env_batch=B))
    _assert_trees_equal(legacy.params, vector.params)
    _assert_trees_equal(legacy.runner.opt_state, vector.runner.opt_state)
    assert [log.samples for log in legacy.logs] == \
        [log.samples for log in vector.logs]


def test_fused_vector_matches_stepped_vector_bitwise():
    """The one-dispatch iteration (runtime='fused') with a VectorEnv
    carry reproduces the stepped sync runner at the same env_batch."""
    B = 6
    stepped = experiment.run(_spec(env_batch=B))
    fused = experiment.run(_spec(env_batch=B, runtime="fused"))
    _assert_trees_equal(stepped.params, fused.params)
    _assert_trees_equal(stepped.runner.opt_state, fused.runner.opt_state)


def test_fused_vector_large_batch_smoke():
    """--env-batch 1024 --backend fused: one donated dispatch per chunk,
    1024 x horizon samples per iteration."""
    B, horizon, iters = 1024, 4, 2
    res = experiment.run(_spec(env_batch=B, horizon=horizon,
                               iterations=iters, runtime="fused"))
    assert len(res.logs) == iters
    assert all(log.samples == B * horizon for log in res.logs)
    assert all(np.isfinite(log.mean_return) for log in res.logs)


def test_vector_threaded_backend_allowed():
    """'threaded' drives the single VectorEnv carry from a worker thread
    (no batch split) — explicitly allowed by the spec check."""
    B = 6
    res = experiment.run(_spec(env_batch=B, backend="threaded"))
    inline = experiment.run(_spec(env_batch=B))
    _assert_trees_equal(inline.params, res.params)
