"""Running-normalization invariants (hypothesis)."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.envs.wrappers import (init_norm, merge_norm_states,
                                 normalize_obs, update_norm)

arrays = st.lists(
    st.lists(st.floats(-50, 50, allow_nan=False, width=32),
             min_size=3, max_size=3),
    min_size=2, max_size=30)


@settings(max_examples=25, deadline=None)
@given(arrays)
def test_update_matches_full_batch_stats(rows):
    data = jnp.asarray(rows)
    state = init_norm(3)
    state = update_norm(state, data)
    np.testing.assert_allclose(np.asarray(state.mean),
                               np.mean(rows, axis=0), atol=1e-3)
    np.testing.assert_allclose(np.asarray(state.var),
                               np.var(rows, axis=0), atol=1e-2)


@settings(max_examples=25, deadline=None)
@given(arrays, arrays)
def test_shard_merge_equals_concat(rows_a, rows_b):
    """merge(stats(A), stats(B)) == stats(A ++ B) — what lets each WALL-E
    sampler shard keep local statistics."""
    a = update_norm(init_norm(3), jnp.asarray(rows_a))
    b = update_norm(init_norm(3), jnp.asarray(rows_b))
    merged = merge_norm_states(a, b)
    both = update_norm(init_norm(3), jnp.asarray(rows_a + rows_b))
    np.testing.assert_allclose(np.asarray(merged.mean),
                               np.asarray(both.mean), atol=1e-3)
    np.testing.assert_allclose(np.asarray(merged.var),
                               np.asarray(both.var), rtol=1e-2, atol=1e-2)


def test_normalize_clips():
    state = init_norm(2)
    state = update_norm(state, jnp.asarray([[0.0, 0.0], [2.0, 2.0]]))
    out = normalize_obs(state, jnp.asarray([1e6, -1e6]), clip=5.0)
    assert float(jnp.max(jnp.abs(out))) <= 5.0
